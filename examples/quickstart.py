"""Quickstart: build an assigned architecture, run one training step and a
short greedy generation — all on CPU with a reduced config.

    PYTHONPATH=src python examples/quickstart.py [arch]
"""
import sys

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, list_archs
from repro.models import NULL_CTX, build_model

arch = sys.argv[1] if len(sys.argv) > 1 else "internlm2-1.8b"
print(f"available archs: {list_archs(assigned_only=True)}")
cfg = get_config(arch).reduced()
print(f"\n== {arch} (reduced: {cfg.n_layers}L d={cfg.d_model}) ==")

api = build_model(cfg)
params = api.init(jax.random.key(0))
n = sum(x.size for x in jax.tree.leaves(params))
print(f"params: {n/1e6:.2f}M")

# --- one loss/grad step -----------------------------------------------------
B, S = 2, 32
batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0,
                                      cfg.vocab_size),
         "labels": jax.random.randint(jax.random.key(2), (B, S), 0,
                                      cfg.vocab_size)}
if cfg.family == "audio":
    batch["frames"] = jax.random.normal(
        jax.random.key(3), (B, cfg.encoder.n_frames, cfg.d_model))
if cfg.family == "vlm":
    batch["vision_embeds"] = jax.random.normal(
        jax.random.key(4), (B, cfg.n_vision_tokens, cfg.d_model))
loss = jax.jit(lambda p: api.loss(p, batch, NULL_CTX))(params)
print(f"loss: {float(loss):.4f}")

# --- greedy generation -------------------------------------------------------
gen_batch = dict(batch)
gen_batch.pop("labels")
caches, logits = jax.jit(lambda p, b: api.prefill(p, b, NULL_CTX))(
    params, gen_batch)
cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
out = [cur]
step = jax.jit(lambda p, c, t: api.decode(p, c, t, NULL_CTX))
for _ in range(8):
    caches, logits = step(params, caches, cur)
    cur = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
    out.append(cur)
print("generated token ids:", jnp.stack(out, 1).tolist())
print("OK")
