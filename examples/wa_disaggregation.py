"""Weight–Attention disaggregation demo (paper §3.1) on simulated devices.

Three acts:
1. policy — the residency-planner verdicts that drive the separation,
2. eager equivalence — the SAME reduced dense model decoded colocated and
   WA-disaggregated across two submeshes with per-layer device_put routing,
3. serving — the WA path as a first-class engine backend
   (``ServingEngine(backend="wa")``): a staggered continuous-batching serve
   with macro-step blocks + chunked prefill where the W→A→W routing is
   compiled INTO every AOT step program (sharding-constrained, zero
   retracing), token streams byte-identical to the colocated backend.

NOTE: this example launches itself with 8 simulated host devices.
"""
import os
import subprocess
import sys

if os.environ.get("_WA_DEMO_CHILD") != "1":
    env = dict(os.environ, _WA_DEMO_CHILD="1",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    raise SystemExit(subprocess.call([sys.executable, __file__], env=env))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES
from repro.core.wa import WADisaggregated, WAPlan, routing_bytes, wa_plan
from repro.models import NULL_CTX, build_model

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
api = build_model(cfg)
params = api.init(jax.random.key(0))

# --- policy: who gets separated? -----------------------------------------
for arch in ("llama2-70b", "llama3.2-3b", "mamba2-1.3b"):
    plan = wa_plan(get_config(arch), SHAPES["decode_32k"], mesh)
    print(f"{arch:16s} separate={plan.separate!s:5s} ({plan.reason[:70]})")

# --- equivalence: colocated vs disaggregated ------------------------------
B, S = 4, 12
toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)
caches, _ = api.prefill(params, {"tokens": toks[:, :S]}, NULL_CTX)
_, want = api.decode(params, caches, toks[:, S], NULL_CTX)

wa = WADisaggregated(cfg, mesh, WAPlan(True, 2, 2, "demo"))
kv = caches._replace(k=caches.k.astype(jnp.float32),
                     v=caches.v.astype(jnp.float32))
kv2, got = wa.decode_step(params, kv, toks[:, S])
err = float(jnp.max(jnp.abs(got - want)))
print(f"\nWA-disaggregated decode max|Δ| vs colocated: {err:.2e} "
      f"({'OK' if err < 1e-3 else 'MISMATCH'})")
print(f"W↔A routing traffic: {routing_bytes(cfg, B)/1024:.1f} KiB/token "
      "('only embeddings move' — paper §4.1)")

# --- serving: the WA backend as a first-class engine path -----------------
from repro.models.sharding import ShardingCtx, sub_operator
from repro.runtime.serving import Request, ServingEngine

ctx = ShardingCtx(mesh, sub_operator())


def make_reqs():
    rng = np.random.default_rng(0)     # same prompts for both backends
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8, dtype=np.int32),
                    max_new_tokens=n, arrival_step=a)
            for i, (n, a) in enumerate([(8, 0), (12, 0), (6, 2), (6, 4)])]


r_co, r_wa = make_reqs(), make_reqs()
kw = dict(mode="continuous", max_new_cap=24, block_size=4,
          kv_bucket_chunk=16, prefill_chunk=4)
ServingEngine(api, ctx, 2, 8, **kw).run(params, r_co, max_steps=300)
st = ServingEngine(api, ctx, 2, 8, backend="wa", **kw).run(
    params, r_wa, max_steps=300)
match = all(a.generated == b.generated for a, b in zip(r_co, r_wa))
compiles = {k: v["compiles"] for k, v in st["runtime"].items()}
print(f"\nServingEngine(backend='wa'): {st['completed']} requests, "
      f"tokens {'byte-identical to colocated' if match else 'MISMATCH'}")
print(f"  programs (compiles must be 1): {compiles}")
print(f"  routed: {st['wa']['routing_bytes_per_token']/1024:.1f} KiB/token, "
      f"{st['wa']['routing_total_bytes']/1e6:.2f} MB total this serve")
