"""Weight–Attention disaggregation demo (paper §3.1) on simulated devices.

Runs the SAME reduced dense model colocated and WA-disaggregated across two
submeshes (weight domain / attention domain), checks numerical equivalence,
and prints the residency-planner verdicts that drive the separation policy.

NOTE: this example launches itself with 8 simulated host devices.
"""
import os
import subprocess
import sys

if os.environ.get("_WA_DEMO_CHILD") != "1":
    env = dict(os.environ, _WA_DEMO_CHILD="1",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    raise SystemExit(subprocess.call([sys.executable, __file__], env=env))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES
from repro.core.wa import WADisaggregated, WAPlan, routing_bytes, wa_plan
from repro.models import NULL_CTX, build_model

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
api = build_model(cfg)
params = api.init(jax.random.key(0))

# --- policy: who gets separated? -----------------------------------------
for arch in ("llama2-70b", "llama3.2-3b", "mamba2-1.3b"):
    plan = wa_plan(get_config(arch), SHAPES["decode_32k"], mesh)
    print(f"{arch:16s} separate={plan.separate!s:5s} ({plan.reason[:70]})")

# --- equivalence: colocated vs disaggregated ------------------------------
B, S = 4, 12
toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)
caches, _ = api.prefill(params, {"tokens": toks[:, :S]}, NULL_CTX)
_, want = api.decode(params, caches, toks[:, S], NULL_CTX)

wa = WADisaggregated(cfg, mesh, WAPlan(True, 2, 2, "demo"))
kv = caches._replace(k=caches.k.astype(jnp.float32),
                     v=caches.v.astype(jnp.float32))
kv2, got = wa.decode_step(params, kv, toks[:, S])
err = float(jnp.max(jnp.abs(got - want)))
print(f"\nWA-disaggregated decode max|Δ| vs colocated: {err:.2e} "
      f"({'OK' if err < 1e-3 else 'MISMATCH'})")
print(f"W↔A routing traffic: {routing_bytes(cfg, B)/1024:.1f} KiB/token "
      f"('only embeddings move' — paper §4.1)")
