"""End-to-end serving driver: continuous-batching requests through the
ServingEngine (static AOT dispatch, per-slot admission) with TPOT/TTFT/
queue-delay stats — the paper's measurement loop at laptop scale, extended
with the staggered-arrival workload the drain baseline cannot serve well.

    PYTHONPATH=src python examples/serve_decode.py [--arch internlm2-1.8b] \
        [--arrival-every 4] [--mode drain] [--block-size 8] [--backend wa]
"""
import argparse

from repro.launch.serve import serve

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="internlm2-1.8b")
ap.add_argument("--requests", type=int, default=12)
ap.add_argument("--batch-slots", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--max-new", type=int, default=16)
ap.add_argument("--mode", default="auto",
                choices=("auto", "continuous", "drain"))
ap.add_argument("--arrival-every", type=int, default=2,
                help="request i arrives at decode step i*N (0 = all at start)")
ap.add_argument("--block-size", type=int, default=8,
                help="decode micro-steps per host sync (macro-step decode)")
ap.add_argument("--kv-bucket-chunk", type=int, default=64,
                help="KV bucket granularity for length-aware decode "
                     "(block mode; 0 = full extent)")
ap.add_argument("--prefill-chunk", type=int, default=16,
                help="chunked-prefill lane: admit prompts as fixed (1,C) "
                     "chunks interleaved with decode blocks, length-true "
                     "cursors (0 = monolithic admission)")
ap.add_argument("--backend", default="colocated", choices=("colocated", "wa"),
                help="executor backend: colocated, or weight-attention "
                     "disaggregated (W→A→W routing compiled into every "
                     "step program; reports routed bytes)")
ap.add_argument("--a-shards", type=int, default=1,
                help="split-KV flash decode width: each slot's KV walk is "
                     "split into N equal sequence shards recombined by the "
                     "LSE merge — token-exact, and the long-context "
                     "attention walk scales with the A-domain width "
                     "(prompt_len + decode slack must divide by N)")
ap.add_argument("--overlap", type=int, default=1,
                help="sub-operator micro-batch pipelining depth across the "
                     "W/A boundary (backend wa only; 1, 2 or 4): while A "
                     "attends one micro-batch, W runs QKV/FFN for the "
                     "next — token-exact at every depth, same compiled "
                     "program names (DESIGN.md §3)")
ap.add_argument("--preemptible", action="store_true",
                help="compile the token-exact KV swap pair and allow "
                     "priority/pressure preemption at block boundaries "
                     "(DESIGN.md §7)")
ap.add_argument("--max-queue", type=int, default=0,
                help="bounded-queue backpressure: shed lowest-priority "
                     "queued work beyond N (0 = unbounded)")
ap.add_argument("--hot-window", type=int, default=0,
                help="tiered KV cache: most recent N tokens per slot stay "
                     "at the resident dtype, older tokens demote to the "
                     "quantized cold tier inside the compiled programs "
                     "(0 = flat cache)")
ap.add_argument("--kv-cold-dtype", default="int8",
                choices=("bfloat16", "int8", "int4"),
                help="cold-tier storage dtype (int4 packs two lanes per "
                     "byte with per-block scales)")
ap.add_argument("--kv-cold-block", type=int, default=16,
                help="demotion granularity in tokens (build-time static)")
ap.add_argument("--kv-budget-bytes", type=int, default=0,
                help="tiered-KV arbiter byte budget (0 = unbounded)")
args = ap.parse_args()

print(f"serving {args.requests} requests on {args.arch} "
      f"(batch={args.batch_slots}, prompt={args.prompt_len}, "
      f"max_new={args.max_new}, mode={args.mode}, "
      f"arrival_every={args.arrival_every}, block_size={args.block_size}, "
      f"prefill_chunk={args.prefill_chunk}, backend={args.backend}, "
      f"a_shards={args.a_shards})")
stats = serve(args.arch, args.requests, args.batch_slots, args.prompt_len,
              args.max_new, mode=args.mode, arrival_every=args.arrival_every,
              block_size=args.block_size,
              kv_bucket_chunk=args.kv_bucket_chunk,
              prefill_chunk=args.prefill_chunk, backend=args.backend,
              a_shards=args.a_shards, overlap=args.overlap,
              preemptible=args.preemptible, max_queue=args.max_queue,
              hot_window=args.hot_window, kv_cold_dtype=args.kv_cold_dtype,
              kv_cold_block=args.kv_cold_block,
              kv_budget_bytes=args.kv_budget_bytes)
print(f"\nmode:        {stats['mode']} (backend={stats['backend']})")
print(f"completed:   {stats['completed']} "
      f"({stats['admissions']} admissions, "
      f"{stats['overlapped_admissions']} into a live batch)")
print(f"TPOT mean:   {stats['tpot_mean_ms']:.2f} ms "
      f"(p50 {stats['tpot_p50_ms']:.2f}, p99 {stats['tpot_p99_ms']:.2f})")
print(f"TTFT mean:   {stats['ttft_mean_ms']:.1f} ms "
      f"(p99 {stats['ttft_p99_ms']:.1f}); "
      f"queue delay mean {stats['queue_delay_mean_ms']:.1f} ms")
print(f"throughput:  {stats['throughput_tok_s']:.1f} decode tok/s "
      f"({stats['decode_tokens']} decode tokens)")
print(f"host syncs:  {stats['host_syncs']} "
      f"({stats['syncs_per_token']:.3f}/token; "
      f"{stats['tokens_per_macro_step_mean']:.1f} tok/macro-step)")
compiles = {k: v["compiles"] for k, v in stats["runtime"].items()}
print(f"compiles:    {compiles} (must stay 1 per step — zero retracing)")
print(f"pressure:    {stats['preemptions']} preemptions / "
      f"{stats['restores']} restores, {stats['rejections']} rejections, "
      f"{stats['deadline_misses']} deadline misses, "
      f"{stats['retries']} retries, quarantined={stats['quarantined_slots']} "
      f"(swap lane {stats['swap_time_ms']:.2f} ms — DESIGN.md §7)")
for e in stats["rejected"]:
    print(f"  shed rid={e['rid']:3d} [{e['status']}] "
          f"priority={e['priority']} reason={e['reason']}")
if "tiered" in stats:
    t = stats["tiered"]
    print(f"tiered KV:   hot_window={t['hot_window']} "
          f"cold={t['cold_dtype']}/block{t['cold_block']}; "
          f"{t['demotions']} in-program demotions, "
          f"{t['kv_bytes_per_slot'] / 1024:.1f} KiB/slot allocated, "
          f"peak live {t['peak_kv_bytes'] / 1024:.1f} KiB, "
          f"cold tier saved {t['cold_bytes_saved'] / 1024:.1f} KiB")
    for s in t["per_slot"]:
        print(f"  slot {s['slot']}: {s['tokens']} tokens "
              f"({s['hot_tokens']} hot / {s['cold_tokens']} cold)")
    print(f"  arbiter: {t['recommendation']}")
if "wa" in stats:
    wa = stats["wa"]
    print(f"W<->A route: {wa['routing_bytes_per_token'] / 1024:.1f} KiB/token "
          f"({wa['routing_total_bytes'] / 1e6:.2f} MB total — "
          "'only embeddings move', DESIGN.md §3)")
    print(f"overlap:     depth={wa['overlap']} "
          f"efficiency={wa['overlap_efficiency']:.3f} "
          f"(W busy {wa['w_busy_ticks']}/{wa['schedule_ticks']} ticks, "
          f"A busy {wa['a_busy_ticks']}/{wa['schedule_ticks']}); "
          f"W-idle {wa['w_idle_ms_per_macro_step']:.2f} ms / "
          f"A-idle {wa['a_idle_ms_per_macro_step']:.2f} ms per macro-step; "
          f"micro-batch occupancy {wa['micro_batch_occupancy']:.2f}")
