"""End-to-end serving driver: batched requests through the ServingEngine
(static AOT dispatch, slot-swap batching) with TPOT/throughput stats — the
paper's measurement loop at laptop scale.

    PYTHONPATH=src python examples/serve_decode.py [--arch internlm2-1.8b]
"""
import argparse

import numpy as np

from repro.launch.serve import serve

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="internlm2-1.8b")
ap.add_argument("--requests", type=int, default=12)
ap.add_argument("--batch-slots", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--max-new", type=int, default=16)
args = ap.parse_args()

print(f"serving {args.requests} requests on {args.arch} "
      f"(batch={args.batch_slots}, prompt={args.prompt_len}, "
      f"max_new={args.max_new})")
stats = serve(args.arch, args.requests, args.batch_slots, args.prompt_len,
              args.max_new)
print(f"\ncompleted:   {stats['completed']}")
print(f"TPOT mean:   {stats['tpot_mean_ms']:.2f} ms "
      f"(p50 {stats['tpot_p50_ms']:.2f}, p99 {stats['tpot_p99_ms']:.2f})")
print(f"throughput:  {stats['throughput_tok_s']:.1f} tok/s")
