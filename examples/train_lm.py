"""End-to-end training driver: train a ~100M-param dense LM for a few hundred
steps on the synthetic pipeline, with checkpointing and a simulated failure +
resume halfway through (the fault-tolerance loop).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import shutil
import tempfile

from repro.configs.registry import REGISTRY
from repro.launch.train import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

# ~100M-param dense LM (qwen2-0.5b skeleton, slimmed)
CFG_100M = REGISTRY["qwen2-0.5b"].replace(
    name="dense-100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
    head_dim=64, d_ff=2048, vocab_size=32000, tie_embeddings=True)
REGISTRY["dense-100m"] = CFG_100M
print(f"dense-100m params ≈ {CFG_100M.param_count()/1e6:.0f}M")

ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
half = args.steps // 2
print(f"\n--- phase 1: train to step {half}, checkpoint every 50 ---")
train("dense-100m", steps=half, batch=args.batch, seq=args.seq,
      reduced=False, ckpt_dir=ckpt, ckpt_every=50, log_every=25)

print("\n--- simulated node failure: process restarts, resumes from ckpt ---")
_, opt, losses = train("dense-100m", steps=args.steps, batch=args.batch,
                       seq=args.seq, reduced=False, ckpt_dir=ckpt,
                       ckpt_every=100, log_every=25)
first, last = losses[0][1], losses[-1][1]
print(f"\nloss {first:.3f} → {last:.3f} "
      f"({'IMPROVED' if last < first else 'no improvement'}); "
      f"resumed training reached step {int(opt.step) + half}")
shutil.rmtree(ckpt, ignore_errors=True)
