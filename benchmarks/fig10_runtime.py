"""Paper Fig 10 — specialized static thread pool vs OpenMP.

TPU/JAX analogue (DESIGN.md §2): static AOT runtime (compile once, cached
dispatch) vs dynamic dispatch (re-trace per call = the generic-runtime tax).
This is MEASURED on this host — the fixed per-step overhead removed by the
static runtime is real wall-clock here, mirroring the paper's finding that a
fixed tens-of-µs saving matters at small batch and amortizes at large batch.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs.registry import get_config
from repro.models import NULL_CTX, build_model


def run():
    cfg = get_config("internlm2-1.8b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    for batch in (1, 4, 16):
        toks = jnp.ones((batch, 16), jnp.int32)
        caches, _ = jax.jit(lambda p, b: api.prefill(p, b, NULL_CTX))(
            params, {"tokens": toks})
        cur = jnp.zeros((batch,), jnp.int32)

        # static runtime: AOT-cached dispatch
        step = jax.jit(lambda p, c, t: api.decode(p, c, t, NULL_CTX))
        static_us = time_fn(lambda: step(params, caches, cur)[1])

        # dynamic dispatch: re-trace each call (the OpenMP-analogue tax)
        def dynamic():
            f = jax.jit(lambda p, c, t: api.decode(p, c, t, NULL_CTX))
            return f(params, caches, cur)[1]
        t0 = time.perf_counter()
        jax.block_until_ready(dynamic())
        dyn_us = (time.perf_counter() - t0) * 1e6

        emit(f"fig10/static/b{batch}", static_us, "")
        emit(f"fig10/dynamic/b{batch}", dyn_us,
             f"speedup_x={dyn_us/static_us:.2f};"
             f"fixed_overhead_us={dyn_us-static_us:.0f}")
