"""§Perf hillclimb report — before/after per iteration, from the dry-run
artifacts (baseline_single.jsonl + hillclimb.jsonl)."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

CELLS = {
    "qwen2-decode": ("qwen2-0.5b", "decode_32k"),
    "moe-train": ("qwen3-moe-235b-a22b", "train_4k"),
    "moe-prefill": ("qwen3-moe-235b-a22b", "prefill_32k"),
    "moe-decode": ("qwen3-moe-235b-a22b", "decode_32k"),
    "phi3-decode": ("phi3-medium-14b", "decode_32k"),
}


def _load(path):
    if not os.path.exists(path):
        return []
    return [json.loads(l) for l in open(path) if l.strip()]


def run():
    base = _load(os.path.join(ART, "baseline_single.jsonl"))
    hc = _load(os.path.join(ART, "hillclimb.jsonl"))
    if not hc:
        emit("hillclimb/missing", 0.0, "run scratch/hillclimb.py")
        return
    for cell, (arch, shape) in CELLS.items():
        b = [r for r in base if r["arch"] == arch and r["shape"] == shape
             and r["status"] == "ok" and r["executor"] == "sub_operator"]
        if b:
            t = b[0]["roofline"]
            emit(f"hillclimb/{cell}/baseline", t["step_s"] * 1e6,
                 f"dom={t['dominant']};mem_s={t['memory_s']:.2e};"
                 f"coll_s={t['collective_s']:.2e};"
                 f"gb={b[0]['memory']['peak_per_device_gb']}")
        for r in hc:
            if r.get("cell") != cell or r["status"] != "ok":
                continue
            t = r["roofline"]
            emit(f"hillclimb/{cell}/{r['variant']}", t["step_s"] * 1e6,
                 f"dom={t['dominant']};mem_s={t['memory_s']:.2e};"
                 f"coll_s={t['collective_s']:.2e};"
                 f"gb={r['memory']['peak_per_device_gb']}")
