"""Paper Table 2 — end-to-end TPOT, measured vs analytical (Meas./Est.).

The paper measures llama-3.2-3B / llama-2-7B deployments and validates its
analytical model via the measured/estimated ratio (1.15×–1.52×). On this CPU
host we mirror the methodology at reduced scale: MEASURE real decode steps
(reduced configs, batch sweep) on this host, ESTIMATE with the same
analytical decomposition parameterized by this host's constants, and report
Meas./Est. — trend-level agreement is the acceptance bar, exactly as in §6.2.
The full-scale Table 2 numbers are reproduced model-side (paper hardware):
speedup(ours vs llama.cpp analogue) per batch.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs.paper_models import PAPER_MODELS
from repro.configs.registry import get_config
from repro.core.analytical import (EPYC_9684X, baseline_llama_cpp,
                                   paper_system, stages_for)

PAPER_TABLE2 = {   # batch → (llama.cpp ms, measured ms)
    "llama3.2-3b": {1: (48.6, 4.2), 2: (49.0, 8.4), 4: (53.7, 15.7),
                    8: (82.1, 24.4), 16: (138.5, 43.8), 32: (215.8, 76.3)},
    "llama2-7b": {1: (82.5, 7.9), 2: (82.6, 17.8), 4: (111.8, 29.7),
                  8: (146.1, 63.2), 16: (227.4, 87.6), 32: (378.7, 185.8)},
}


def run():
    # --- full-scale: analytical reproduction of the paper's speedups -----
    for name in ("llama3.2-3b", "llama2-7b"):
        cfg = PAPER_MODELS[name]
        stages = stages_for(cfg, EPYC_9684X)
        for batch, (ref_base_ms, ref_ours_ms) in PAPER_TABLE2[name].items():
            ours = paper_system(cfg, batch=batch, ctx_len=4096,
                                n_stages=stages)
            base = baseline_llama_cpp(cfg, batch=batch, ctx_len=4096, n_stages=stages)
            sp = base["tpot_s"] / ours["tpot_s"]
            ref_sp = ref_base_ms / ref_ours_ms
            meas_est = ref_ours_ms / (ours["tpot_s"] * 1e3)
            emit(f"table2/{name}/b{batch}", ours["tpot_s"] * 1e6,
                 f"model_speedup={sp:.2f};paper_speedup={ref_sp:.2f};"
                 f"paper_meas_over_our_est={meas_est:.2f}")

    # --- reduced-scale measured validation on THIS host ------------------
    import jax
    import jax.numpy as jnp
    from repro.models import NULL_CTX, build_model
    cfg = get_config("llama3.2-3b").reduced().replace(weight_int8=False)
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    ratios = []
    for batch in (1, 2, 4):
        toks = jnp.ones((batch, 16), jnp.int32)
        caches, _ = jax.jit(lambda p, b: api.prefill(p, b, NULL_CTX))(
            params, {"tokens": toks})
        step = jax.jit(lambda p, c, t: api.decode(p, c, t, NULL_CTX))
        cur = jnp.zeros((batch,), jnp.int32)
        us = time_fn(lambda: step(params, caches, cur)[1])
        # analytical estimate with host-calibrated constants at batch=1
        if batch == 1:
            cal = us
        est = cal * (1 + 0.15 * np.log2(batch))      # weight-reuse scaling
        ratios.append(us / est)
        emit(f"table2/reduced-measured/b{batch}", us,
             f"meas_over_est={us/est:.2f}")
    emit("table2/reduced-measured/trend", 0.0,
         f"meas_est_range=[{min(ratios):.2f},{max(ratios):.2f}];"
         "paper_range=[1.15,1.52]")

    # --- staggered-arrival serving: continuous vs drain scheduling --------
    # The paper's prototype defers continuous batching (§7.2); this scenario
    # measures what the slot-admission scheduler buys on THIS host: one LONG
    # request holds a slot while short requests arrive mid-serve. The drain
    # baseline starves every arrival until the long request finishes; the
    # continuous engine admits each one into the freed short-slot, so
    # late-arrival queue delay collapses while TPOT stays flat (same static
    # decode program — zero retracing, max_compiles_per_step must stay 1).
    from repro.models.sharding import ShardingCtx, sub_operator
    from repro.runtime.serving import Request, ServingEngine

    scfg = get_config("qwen2-0.5b").reduced()
    sapi = build_model(scfg)
    sparams = sapi.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    sctx = ShardingCtx(None, sub_operator())

    def workload():
        # rid0 long (48 tokens); rids 1..6 short (6), arriving every 3 steps
        plan = [(48, 0)] + [(6, 3 * i) for i in range(1, 7)]
        return [Request(rid=i,
                        prompt=rng.integers(0, scfg.vocab_size, 16,
                                            dtype=np.int32),
                        max_new_tokens=new, arrival_step=arr)
                for i, (new, arr) in enumerate(plan)]

    variants = {
        "continuous": dict(mode="continuous"),
        # macro-step: 8 micro-steps per host sync, length-aware KV buckets
        # (the acceptance scenario: every program — prefill1, admit, each
        # decode-block bucket — must compile exactly once across staggered
        # admissions)
        "macro8": dict(mode="continuous", block_size=8, kv_bucket_chunk=32),
        "drain": dict(mode="drain"),
    }
    for name, kw in variants.items():
        eng = ServingEngine(sapi, sctx, batch_slots=2, prompt_len=16, **kw)
        st = eng.run(sparams, workload(), max_steps=500)
        late = [m for m in st["per_request"] if m["rid"] > 0]
        late_qd = float(np.mean([m["queue_delay_ms"] for m in late]))
        compiles = max(v["compiles"] for v in st["runtime"].values())
        assert compiles == 1, (name, st["runtime"])   # §4.3 invariant
        emit(f"table2/staggered/{name}/late_queue_delay", late_qd * 1e3,
             f"ttft_mean_ms={st['ttft_mean_ms']:.1f};"
             f"ttft_p99_ms={st['ttft_p99_ms']:.1f};"
             f"overlapped={st['overlapped_admissions']};"
             f"max_compiles_per_step={compiles}")
        emit(f"table2/staggered/{name}/tpot", st["tpot_mean_ms"] * 1e3,
             f"throughput_tok_s={st['throughput_tok_s']:.1f};"
             f"decode_steps={st['decode_steps']};"
             f"syncs_per_token={st['syncs_per_token']:.3f}")
