"""Paper Fig 9 — effect of weight–attention separation on per-block latency
across (model × ctx × batch): neutral at low cache pressure (3B: 1.00×),
positive under pressure (7B: 1.13×, 70B: 1.16×).

Model-side reproduction via core.analytical with/without wa_separated, plus
the residency planner's profitability verdict per cell.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs.paper_models import PAPER_MODELS
from repro.configs.shapes import ShapeConfig
from repro.core.analytical import EPYC_9684X, stage_latency, stages_for
from repro.core.residency import plan

PAPER_GEOMEAN = {"llama3.2-3b": 1.00, "llama2-7b": 1.13, "llama2-70b": 1.16}


def run():
    for name in ("llama3.2-3b", "llama2-7b", "llama2-70b"):
        cfg = PAPER_MODELS[name]
        stages = stages_for(cfg, EPYC_9684X)
        sps = []
        for ctx in (4096,):
            for b in (1, 4, 16, 32):
                colo = stage_latency(cfg, EPYC_9684X, batch=b, ctx_len=ctx,
                                     n_stages=stages, wa_separated=False)
                # separation doubles the domain budget for a stage (paper:
                # one extra socket) but adds routing hops
                sep = stage_latency(cfg, EPYC_9684X, batch=b, ctx_len=ctx,
                                    n_stages=stages, wa_separated=True,
                                    domains_per_stage=1)
                sps.append(colo / sep)
        g = float(np.exp(np.mean(np.log(sps))))
        shape = ShapeConfig("d", 4096, 32, "decode")
        rep = plan(cfg, shape, n_chips=stages)
        emit(f"fig9/{name}/geomean", 0.0,
             f"wa_speedup_x={g:.2f};paper={PAPER_GEOMEAN[name]};"
             f"profitable={rep.wa_profitable}")
