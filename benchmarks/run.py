"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [names...]

Prints ``name,us_per_call,derived`` CSV rows (harness contract).
"""
from __future__ import annotations

import sys
import traceback

from benchmarks.common import header

ALL = [
    "table1_partitioning",
    "table2_end_to_end",
    "fig2_arith_intensity",
    "fig8_sensitivity",
    "fig9_wa_separation",
    "fig10_runtime",
    "fig11_breakdown",
    "serve_tpot",
    "roofline_report",
    "hillclimb_report",
]


def main() -> None:
    names = sys.argv[1:] or ALL
    header()
    failed = []
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        try:
            mod.run()
        except Exception:  # noqa: BLE001 — keep the harness sweeping
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
