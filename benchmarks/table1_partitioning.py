"""Paper Table 1 — model partitioning parameters, reproduced for both the
paper's platform (EPYC LLC-resident stages) and the TPU v5e target.

Paper values (INT8 weights): llama3.2-3b 3.21 GB / 4+1 sockets / 7 layers;
llama2-7b 6.74 GB / 8+1 / 4; qwen3-8b 8.19 GB / 9+1 / 4; llama2-70b
68.98 GB / 80+1 / 1.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs.paper_models import PAPER_MODELS
from repro.core.analytical import EPYC_9684X, stages_for, weight_bytes

PAPER_TABLE1 = {          # (#sockets, layers/socket, INT8 weight GB)
    "llama3.2-3b": (4, 7, 3.21),
    "llama2-7b": (8, 4, 6.74),
    "qwen3-8b": (9, 4, 8.19),
    "llama2-70b": (80, 1, 68.98),
}


def run():
    for name, cfg in PAPER_MODELS.items():
        wb = weight_bytes(cfg, bytes_per_param=1.0)
        emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
        total_gb = (wb + emb) / 1e9
        stages = stages_for(cfg, EPYC_9684X, bytes_per_param=1.0)
        lps = cfg.n_layers // stages
        ref_sock, ref_lps, ref_gb = PAPER_TABLE1[name]
        emit(f"table1/{name}/int8_weights_gb", 0.0,
             f"ours={total_gb:.2f};paper={ref_gb};"
             f"ratio={total_gb/ref_gb:.2f}")
        emit(f"table1/{name}/stages", 0.0,
             f"ours={stages};paper={ref_sock};layers_per={lps};"
             f"paper_layers_per={ref_lps}")
