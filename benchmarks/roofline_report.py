"""§Roofline report generator — reads the dry-run artifacts (JSONL) and
prints the per-(arch × shape) roofline table used in EXPERIMENTS.md."""
from __future__ import annotations

import json
import os
from typing import Dict, List

from benchmarks.common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(path: str) -> List[Dict]:
    recs = []
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                recs.append(json.loads(line))
    return recs


def run():
    recs = load(os.path.join(ART, "baseline_single.jsonl"))
    if not recs:
        emit("roofline/missing", 0.0,
             "run: PYTHONPATH=src python scratch/sweep.py")
        return
    for r in recs:
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['executor']}"
        if r["status"] == "skip":
            emit(tag, 0.0, "skip=" + r["reason"][:60])
            continue
        if r["status"] != "ok":
            emit(tag, 0.0, "error=" + r.get("error", "?")[:60])
            continue
        t = r["roofline"]
        emit(tag, t["step_s"] * 1e6,
             f"dom={t['dominant']};frac={t['roofline_frac']:.3f};"
             f"compute_s={t['compute_s']:.2e};memory_s={t['memory_s']:.2e};"
             f"coll_s={t['collective_s']:.2e};"
             f"useful={t['useful_ratio']:.2f};"
             f"mem_gb={r['memory']['peak_per_device_gb']}")
