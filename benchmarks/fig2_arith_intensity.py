"""Paper Fig 2 — FLOPs/byte during decoding vs batch size (ctx 4096).

Shows the paper's motivating observation: arithmetic intensity grows only
modestly with batch because KV traffic scales with batch while weight
traffic is amortized. Derived analytically from the same accounting the
roofline uses; cross-checked against compiled cost_analysis by the dry-run.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs.paper_models import PAPER_MODELS
from repro.core.analytical import (flops_per_token, kv_bytes_per_token,
                                   weight_bytes)


def run():
    ctx = 4096
    for name in ("llama3.2-3b", "llama2-7b"):
        cfg = PAPER_MODELS[name]
        wb = weight_bytes(cfg, 1.0)
        for batch in (1, 2, 4, 8, 16, 32, 64):
            fl = flops_per_token(cfg, ctx) * batch
            byts = wb + kv_bytes_per_token(cfg, ctx, 1.0) * batch
            emit(f"fig2/{name}/b{batch}", 0.0,
                 f"flops_per_byte={fl/byts:.2f}")
