"""Paper Fig 11 — llama-2-70B @ ctx 4096: per-phase (weight-ops vs attention)
time breakdown, colocated vs WA-separated.

Both phases speed up under separation because KV stops evicting weights and
attention stops contending for cache. Model-side from the same residency +
bandwidth accounting used everywhere.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs.paper_models import PAPER_MODELS
from repro.core.analytical import (EPYC_9684X, kv_bytes_per_token,
                                   stages_for, weight_bytes)


def run():
    cfg = PAPER_MODELS["llama2-70b"]
    hw = EPYC_9684X
    stages = stages_for(cfg, hw)
    ctx = 4096
    for batch in (16, 32):
        wb = weight_bytes(cfg, 1.0) / stages
        kvb = kv_bytes_per_token(cfg, ctx, 1.0) * batch / stages
        kv_foot = kv_bytes_per_token(cfg, ctx, 1.0) * batch   # paradox: ×p/p
        cap = hw.fast_capacity
        # colocated: combined set spills → both phases at DRAM bw
        spill = (wb + kv_foot) > cap
        w_t_colo = wb / (hw.slow_bw if spill else hw.fast_bw)
        a_t_colo = kvb / (hw.slow_bw if spill else hw.fast_bw)
        # separated: each phase judged on its own domain
        w_t_sep = wb / (hw.fast_bw if wb <= cap else hw.slow_bw)
        a_t_sep = kvb / (hw.fast_bw if kv_foot <= cap else hw.slow_bw)
        emit(f"fig11/b{batch}/weight_ops", 0.0,
             f"colocated_us={w_t_colo*1e6:.0f};separated_us={w_t_sep*1e6:.0f};"
             f"speedup_x={w_t_colo/max(w_t_sep,1e-12):.2f}")
        emit(f"fig11/b{batch}/attention", 0.0,
             f"colocated_us={a_t_colo*1e6:.0f};separated_us={a_t_sep*1e6:.0f};"
             f"speedup_x={a_t_colo/max(a_t_sep,1e-12):.2f}")
