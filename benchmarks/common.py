"""Benchmark utilities: wall-clock timing + the harness CSV contract
(``name,us_per_call,derived``)."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time in µs of a blocking call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def header():
    print("name,us_per_call,derived")
