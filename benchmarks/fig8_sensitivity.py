"""Paper Fig 8 — throughput & TPOT speedup grid over (model × ctx × batch),
ours vs the llama.cpp analogue, under the validated analytical model.

Paper headline: up to 13.9× TPOT / 12.5× throughput; geomean 3.7–5.0×
(throughput) and 5.3–6.7× (TPOT).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs.paper_models import PAPER_MODELS
from repro.core.analytical import (EPYC_9684X, baseline_llama_cpp,
                                   paper_system, stages_for)

CTXS = (1024, 2048, 4096)
BATCHES = (1, 2, 4, 8, 16, 32)


def run():
    all_tp, all_th = [], []
    for name, cfg in PAPER_MODELS.items():
        stages = stages_for(cfg, EPYC_9684X)
        sp_tp, sp_th = [], []
        for ctx in CTXS:
            for b in BATCHES:
                ours = paper_system(cfg, batch=b, ctx_len=ctx, n_stages=stages)
                base = baseline_llama_cpp(cfg, batch=b, ctx_len=ctx, n_stages=stages)
                sp_tp.append(base["tpot_s"] / ours["tpot_s"])
                sp_th.append(ours["throughput_tok_s"] / base["throughput_tok_s"])
                if ctx == 4096 and b in (1, 32):
                    emit(f"fig8/{name}/ctx{ctx}/b{b}",
                         ours["tpot_s"] * 1e6,
                         f"tpot_x={sp_tp[-1]:.2f};thru_x={sp_th[-1]:.2f};"
                         f"tok_s={ours['throughput_tok_s']:.0f}")
        g_tp = float(np.exp(np.mean(np.log(sp_tp))))
        g_th = float(np.exp(np.mean(np.log(sp_th))))
        all_tp.append(max(sp_tp))
        all_th.append(max(sp_th))
        emit(f"fig8/{name}/geomean", 0.0,
             f"tpot_x={g_tp:.2f};thru_x={g_th:.2f}")
    emit("fig8/max", 0.0,
         f"tpot_x={max(all_tp):.1f};thru_x={max(all_th):.1f};"
         "paper=13.9/12.5")
