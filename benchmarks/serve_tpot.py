"""Serving TPOT/TTFT: per-step vs macro-step decode, chunked vs monolithic
prefill, and colocated vs WA-disaggregated backends (BENCH_serving.json).

Three claims are measured on the CPU dry-run config:

1. Macro-step decode (ISSUE 3 / DESIGN.md §7): moving the host sync from
   every token to every ``block_size`` tokens removes per-token dispatch +
   transfer stalls from the decode critical path — the step-axis analogue
   of the paper's sub-operator dependency relaxation (§5). Measured as the
   SAME staggered-arrival workload through the per-step engine
   (block_size=1) and the macro-step engine (block_size=8, chunk-bucketed
   length-aware KV).

2. Chunked prefill (ISSUE 4 / DESIGN.md §7): a LONG prompt admitted
   mid-serve stalls every in-flight decoder for its whole monolithic
   prefill; the chunked-prefill lane bounds that stall to one fixed-(1,C)
   chunk per block boundary. Measured as a long-prompt staggered arrival
   into a live decode batch: **max inter-token gap** (the decode-stall each
   in-flight request observes) and the long request's TTFT, chunked vs
   monolithic admission — the acceptance claim is max gap strictly lower
   with TPOT no worse.

3. WA backend (ISSUE 5 / DESIGN.md §3): the SAME staggered-arrival
   workload served by ``backend="colocated"`` and ``backend="wa"`` — the
   weight–attention disaggregated layer loop with the W→A→W routing
   compiled into every step program. Measured: TPOT, TTFT, host syncs,
   compile counts, and the ``routing_bytes``-derived W↔A traffic per token
   (the paper's "only embeddings move" as a number). On the single-host
   dry-run the routing constraints are no-ops, so the delta is the routed
   layer-loop program structure (python-unrolled layers vs the colocated
   ``lax.scan``), not transfer cost — the committed numbers are the
   regression baseline for the routed program path, exercised by
   ``make bench-smoke`` on every PR.

Per mode: TPOT (mean/p50/p99 per micro-step), TTFT, decode-token
throughput, host syncs per generated token, compile counts (every program
must compile exactly once). Results go to the CSV contract AND to
``BENCH_serving.json`` at the repo root — the committed perf-trajectory
artifact.

Each engine is run twice and the SECOND run is reported: AOT compiles all
land in ``prepare`` (first run), so run 2 is the steady-state the paper's
§4.3 regime cares about.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit

BLOCK_SIZE = 8
KV_BUCKET_CHUNK = 32
PROMPT_LEN = 16
SLOTS = 2
MAX_NEW_CAP = 64
# -- long-prompt (chunked-prefill) scenario --------------------------------
LP_PROMPT_LEN = 256          # static width = the long prompt's true length
LP_SHORT_LEN = 8             # in-flight decoders hold short prompts
LP_CHUNK = 32                # chunked lane: 256-token prompt = 8 chunks
LP_KV_BUCKET = 64            # coarser buckets (extent 320 → 5 programs)
JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serving.json")


def _workload(cfg, seed=0):
    # one LONG request holding a slot + shorts arriving mid-serve — the
    # continuous-scheduler scenario of benchmarks/table2_end_to_end.py
    rng = np.random.default_rng(seed)
    from repro.runtime.serving import Request
    plan = [(48, 0)] + [(8, 4 * i) for i in range(1, 6)]
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                        dtype=np.int32),
                    max_new_tokens=new, arrival_step=arr)
            for i, (new, arr) in enumerate(plan)]


def _long_prompt_workload(cfg, seed=0):
    # two short requests decoding when a LONG-prompt request lands mid-serve:
    # its admission prefill is the decode-stall the chunked lane bounds
    rng = np.random.default_rng(seed)
    from repro.runtime.serving import Request
    plan = [(48, 0, LP_SHORT_LEN), (8, 0, LP_SHORT_LEN),
            (24, 8, LP_PROMPT_LEN)]
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, plen,
                                        dtype=np.int32),
                    max_new_tokens=new, arrival_step=arr)
            for i, (new, arr, plen) in enumerate(plan)]


def _long_prompt_scenario(api, params, ctx):
    from repro.runtime.serving import ServingEngine
    cfg = api.config
    out = {"config": {"prompt_len": LP_PROMPT_LEN,
                      "short_prompt_len": LP_SHORT_LEN,
                      "prefill_chunk": LP_CHUNK,
                      "block_size": BLOCK_SIZE,
                      "kv_bucket_chunk": LP_KV_BUCKET,
                      "batch_slots": SLOTS}}
    for name, pc in (("monolithic", 0), ("chunked", LP_CHUNK)):
        eng = ServingEngine(api, ctx, SLOTS, LP_PROMPT_LEN,
                            mode="continuous", max_new_cap=MAX_NEW_CAP,
                            block_size=BLOCK_SIZE,
                            kv_bucket_chunk=LP_KV_BUCKET,
                            prefill_chunk=pc)
        eng.run(params, _long_prompt_workload(cfg), max_steps=2000)  # warm
        st = eng.run(params, _long_prompt_workload(cfg), max_steps=2000)
        compiles = {k: v["compiles"] for k, v in st["runtime"].items()}
        long_req = next(m for m in st["per_request"] if m["rid"] == 2)
        short_gaps = [m["max_gap_ms"] for m in st["per_request"]
                      if m["rid"] != 2]
        out[name] = {
            "completed": st["completed"],
            "tpot_mean_ms": st["tpot_mean_ms"],
            "tpot_p99_ms": st["tpot_p99_ms"],
            "max_inter_token_gap_ms": st["max_inter_token_gap_ms"],
            "inflight_max_gap_ms": max(short_gaps),
            "long_ttft_ms": long_req["ttft_ms"],
            "ttft_mean_ms": st["ttft_mean_ms"],
            "prefill_time_ms": st["prefill_time_ms"],
            "prefill_chunks": st["prefill_chunks"],
            "throughput_tok_s": st["throughput_tok_s"],
            "max_compiles_per_step": max(compiles.values()),
            "compiles": compiles,
        }
        emit(f"serving/long_prompt/{name}/inflight_max_gap",
             max(short_gaps) * 1e3,
             f"long_ttft_ms={long_req['ttft_ms']:.1f};"
             f"tpot_mean_ms={st['tpot_mean_ms']:.3f};"
             f"max_compiles_per_step={max(compiles.values())}")
    out["chunked_over_monolithic"] = {
        "inflight_gap_reduction": (out["monolithic"]["inflight_max_gap_ms"]
                                   / max(out["chunked"]["inflight_max_gap_ms"],
                                         1e-9)),
        "tpot_ratio": (out["chunked"]["tpot_mean_ms"]
                       / max(out["monolithic"]["tpot_mean_ms"], 1e-9)),
    }
    emit("serving/long_prompt/chunked_gap_reduction",
         out["chunked_over_monolithic"]["inflight_gap_reduction"],
         f"tpot_ratio={out['chunked_over_monolithic']['tpot_ratio']:.3f}")
    return out


WA_PREFILL_CHUNK = 8         # WA scenario: chunked admission, 2 chunks/prompt


def _wa_backend_scenario(api, params, ctx):
    """Colocated vs WA-disaggregated backend on the staggered workload:
    same scheduler, same admissions, every program swapped for its routed
    twin — TPOT/TTFT/sync parity plus the measured W↔A traffic."""
    from repro.runtime.serving import ServingEngine
    cfg = api.config
    out = {"config": {"prompt_len": PROMPT_LEN, "batch_slots": SLOTS,
                      "max_new_cap": MAX_NEW_CAP, "block_size": BLOCK_SIZE,
                      "kv_bucket_chunk": KV_BUCKET_CHUNK,
                      "prefill_chunk": WA_PREFILL_CHUNK}}
    for backend in ("colocated", "wa"):
        eng = ServingEngine(api, ctx, SLOTS, PROMPT_LEN, mode="continuous",
                            max_new_cap=MAX_NEW_CAP, block_size=BLOCK_SIZE,
                            kv_bucket_chunk=KV_BUCKET_CHUNK,
                            prefill_chunk=WA_PREFILL_CHUNK, backend=backend)
        eng.run(params, _workload(cfg), max_steps=1000)   # warm (compiles)
        st = eng.run(params, _workload(cfg), max_steps=1000)
        compiles = {k: v["compiles"] for k, v in st["runtime"].items()}
        rec = {
            "completed": st["completed"],
            "tpot_mean_ms": st["tpot_mean_ms"],
            "tpot_p99_ms": st["tpot_p99_ms"],
            "ttft_mean_ms": st["ttft_mean_ms"],
            "throughput_tok_s": st["throughput_tok_s"],
            "decode_tokens": st["decode_tokens"],
            "host_syncs": st["host_syncs"],
            "syncs_per_token": st["syncs_per_token"],
            "max_compiles_per_step": max(compiles.values()),
            "compiles": compiles,
        }
        if backend == "wa":
            rec["routing_bytes_per_token"] = st["wa"]["routing_bytes_per_token"]
            rec["routing_total_bytes"] = st["wa"]["routing_total_bytes"]
            rec["routing_bytes_per_decode_token"] = \
                st["wa"]["routing_bytes_per_decode_token"]
        out[backend] = rec
        derived = (f"ttft_mean_ms={st['ttft_mean_ms']:.1f};"
                   f"host_syncs={st['host_syncs']};"
                   f"max_compiles_per_step={max(compiles.values())}")
        if backend == "wa":
            derived += (f";routing_bytes_per_token="
                        f"{st['wa']['routing_bytes_per_token']}")
        emit(f"serving/wa_backend/{backend}/tpot",
             st["tpot_mean_ms"] * 1e3, derived)
    out["wa_over_colocated"] = {
        "tpot_ratio": (out["wa"]["tpot_mean_ms"]
                       / max(out["colocated"]["tpot_mean_ms"], 1e-9)),
        "host_sync_parity": (out["wa"]["host_syncs"]
                             == out["colocated"]["host_syncs"]),
    }
    emit("serving/wa_backend/routing_bytes_per_token",
         float(out["wa"]["routing_bytes_per_token"]),
         f"total_bytes={out['wa']['routing_total_bytes']};"
         f"tpot_ratio={out['wa_over_colocated']['tpot_ratio']:.3f}")
    return out


def run():
    import jax
    from repro.configs.registry import get_config
    from repro.models import build_model
    from repro.models.sharding import ShardingCtx, sub_operator
    from repro.runtime.serving import ServingEngine

    cfg = get_config("qwen2-0.5b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    ctx = ShardingCtx(None, sub_operator())

    modes = {
        "per_step": dict(block_size=1),
        "macro_step": dict(block_size=BLOCK_SIZE,
                           kv_bucket_chunk=KV_BUCKET_CHUNK),
    }
    report = {"config": {"arch": "qwen2-0.5b (reduced)",
                         "prompt_len": PROMPT_LEN, "batch_slots": SLOTS,
                         "max_new_cap": MAX_NEW_CAP,
                         "block_size": BLOCK_SIZE,
                         "kv_bucket_chunk": KV_BUCKET_CHUNK}}
    for name, kw in modes.items():
        eng = ServingEngine(api, ctx, SLOTS, PROMPT_LEN, mode="continuous",
                            max_new_cap=MAX_NEW_CAP, **kw)
        eng.run(params, _workload(cfg), max_steps=1000)   # warm (compiles)
        st = eng.run(params, _workload(cfg), max_steps=1000)
        compiles = {k: v["compiles"] for k, v in st["runtime"].items()}
        report[name] = {
            "completed": st["completed"],
            "tpot_mean_ms": st["tpot_mean_ms"],
            "tpot_p50_ms": st["tpot_p50_ms"],
            "tpot_p99_ms": st["tpot_p99_ms"],
            "ttft_mean_ms": st["ttft_mean_ms"],
            "ttft_p99_ms": st["ttft_p99_ms"],
            "throughput_tok_s": st["throughput_tok_s"],
            "decode_tokens": st["decode_tokens"],
            "host_syncs": st["host_syncs"],
            "syncs_per_token": st["syncs_per_token"],
            "tokens_per_macro_step_mean": st["tokens_per_macro_step_mean"],
            "max_compiles_per_step": max(compiles.values()),
            "compiles": compiles,
        }
        emit(f"serving/{name}/tpot", st["tpot_mean_ms"] * 1e3,
             f"p50_ms={st['tpot_p50_ms']:.3f};p99_ms={st['tpot_p99_ms']:.3f};"
             f"throughput_tok_s={st['throughput_tok_s']:.1f}")
        emit(f"serving/{name}/ttft", st["ttft_mean_ms"] * 1e3,
             f"p99_ms={st['ttft_p99_ms']:.1f}")
        emit(f"serving/{name}/host_syncs_per_token",
             st["syncs_per_token"] * 1e6,
             f"host_syncs={st['host_syncs']};"
             f"decode_tokens={st['decode_tokens']};"
             f"max_compiles_per_step={max(compiles.values())}")
    speedup = (report["per_step"]["tpot_mean_ms"]
               / max(report["macro_step"]["tpot_mean_ms"], 1e-9))
    sync_drop = (report["per_step"]["syncs_per_token"]
                 / max(report["macro_step"]["syncs_per_token"], 1e-9))
    report["macro_over_per_step"] = {
        "tpot_speedup": speedup,
        "host_sync_reduction": sync_drop,
    }
    emit("serving/macro_over_per_step", speedup,
         f"tpot_speedup={speedup:.2f};host_sync_reduction={sync_drop:.1f}")
    report["long_prompt"] = _long_prompt_scenario(api, params, ctx)
    report["wa_backend"] = _wa_backend_scenario(api, params, ctx)
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {JSON_PATH}")


if __name__ == "__main__":
    run()
