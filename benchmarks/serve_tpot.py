"""Serving TPOT/TTFT: per-step vs macro-step decode (BENCH_serving.json).

The macro-step engine's claim (ISSUE 3 / DESIGN.md §7): moving the host
sync from every token to every ``block_size`` tokens removes per-token
dispatch + transfer stalls from the decode critical path — the step-axis
analogue of the paper's sub-operator dependency relaxation (§5). This
benchmark measures exactly that on the CPU dry-run config:

- the SAME staggered-arrival workload through the per-step engine
  (block_size=1) and the macro-step engine (block_size=8, chunk-bucketed
  length-aware KV),
- per-mode TPOT (mean/p50/p99 per micro-step), TTFT, decode-token
  throughput, host syncs per generated token, and compile counts (every
  program must compile exactly once),
- results go to the CSV contract AND to ``BENCH_serving.json`` at the repo
  root — the committed perf-trajectory artifact.

Each engine is run twice and the SECOND run is reported: AOT compiles all
land in ``prepare`` (first run), so run 2 is the steady-state the paper's
§4.3 regime cares about.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit

BLOCK_SIZE = 8
KV_BUCKET_CHUNK = 32
PROMPT_LEN = 16
SLOTS = 2
MAX_NEW_CAP = 64
JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serving.json")


def _workload(cfg, seed=0):
    # one LONG request holding a slot + shorts arriving mid-serve — the
    # continuous-scheduler scenario of benchmarks/table2_end_to_end.py
    rng = np.random.default_rng(seed)
    from repro.runtime.serving import Request
    plan = [(48, 0)] + [(8, 4 * i) for i in range(1, 6)]
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                        dtype=np.int32),
                    max_new_tokens=new, arrival_step=arr)
            for i, (new, arr) in enumerate(plan)]


def run():
    import jax
    from repro.configs.registry import get_config
    from repro.models import build_model
    from repro.models.sharding import ShardingCtx, sub_operator
    from repro.runtime.serving import ServingEngine

    cfg = get_config("qwen2-0.5b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    ctx = ShardingCtx(None, sub_operator())

    modes = {
        "per_step": dict(block_size=1),
        "macro_step": dict(block_size=BLOCK_SIZE,
                           kv_bucket_chunk=KV_BUCKET_CHUNK),
    }
    report = {"config": {"arch": "qwen2-0.5b (reduced)",
                         "prompt_len": PROMPT_LEN, "batch_slots": SLOTS,
                         "max_new_cap": MAX_NEW_CAP,
                         "block_size": BLOCK_SIZE,
                         "kv_bucket_chunk": KV_BUCKET_CHUNK}}
    for name, kw in modes.items():
        eng = ServingEngine(api, ctx, SLOTS, PROMPT_LEN, mode="continuous",
                            max_new_cap=MAX_NEW_CAP, **kw)
        eng.run(params, _workload(cfg), max_steps=1000)   # warm (compiles)
        st = eng.run(params, _workload(cfg), max_steps=1000)
        compiles = {k: v["compiles"] for k, v in st["runtime"].items()}
        report[name] = {
            "completed": st["completed"],
            "tpot_mean_ms": st["tpot_mean_ms"],
            "tpot_p50_ms": st["tpot_p50_ms"],
            "tpot_p99_ms": st["tpot_p99_ms"],
            "ttft_mean_ms": st["ttft_mean_ms"],
            "ttft_p99_ms": st["ttft_p99_ms"],
            "throughput_tok_s": st["throughput_tok_s"],
            "decode_tokens": st["decode_tokens"],
            "host_syncs": st["host_syncs"],
            "syncs_per_token": st["syncs_per_token"],
            "tokens_per_macro_step_mean": st["tokens_per_macro_step_mean"],
            "max_compiles_per_step": max(compiles.values()),
            "compiles": compiles,
        }
        emit(f"serving/{name}/tpot", st["tpot_mean_ms"] * 1e3,
             f"p50_ms={st['tpot_p50_ms']:.3f};p99_ms={st['tpot_p99_ms']:.3f};"
             f"throughput_tok_s={st['throughput_tok_s']:.1f}")
        emit(f"serving/{name}/ttft", st["ttft_mean_ms"] * 1e3,
             f"p99_ms={st['ttft_p99_ms']:.1f}")
        emit(f"serving/{name}/host_syncs_per_token",
             st["syncs_per_token"] * 1e6,
             f"host_syncs={st['host_syncs']};"
             f"decode_tokens={st['decode_tokens']};"
             f"max_compiles_per_step={max(compiles.values())}")
    speedup = (report["per_step"]["tpot_mean_ms"]
               / max(report["macro_step"]["tpot_mean_ms"], 1e-9))
    sync_drop = (report["per_step"]["syncs_per_token"]
                 / max(report["macro_step"]["syncs_per_token"], 1e-9))
    report["macro_over_per_step"] = {
        "tpot_speedup": speedup,
        "host_sync_reduction": sync_drop,
    }
    emit("serving/macro_over_per_step", speedup,
         f"tpot_speedup={speedup:.2f};host_sync_reduction={sync_drop:.1f}")
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {JSON_PATH}")


if __name__ == "__main__":
    run()
