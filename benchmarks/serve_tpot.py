"""Serving TPOT/TTFT: per-step vs macro-step decode, chunked vs monolithic
prefill, and colocated vs WA-disaggregated backends (BENCH_serving.json).

Three claims are measured on the CPU dry-run config:

1. Macro-step decode (ISSUE 3 / DESIGN.md §7): moving the host sync from
   every token to every ``block_size`` tokens removes per-token dispatch +
   transfer stalls from the decode critical path — the step-axis analogue
   of the paper's sub-operator dependency relaxation (§5). Measured as the
   SAME staggered-arrival workload through the per-step engine
   (block_size=1) and the macro-step engine (block_size=8, chunk-bucketed
   length-aware KV).

2. Chunked prefill (ISSUE 4 / DESIGN.md §7): a LONG prompt admitted
   mid-serve stalls every in-flight decoder for its whole monolithic
   prefill; the chunked-prefill lane bounds that stall to one fixed-(1,C)
   chunk per block boundary. Measured as a long-prompt staggered arrival
   into a live decode batch: **max inter-token gap** (the decode-stall each
   in-flight request observes) and the long request's TTFT, chunked vs
   monolithic admission — the acceptance claim is max gap strictly lower
   with TPOT no worse.

3. WA backend (ISSUE 5 / DESIGN.md §3): the SAME staggered-arrival
   workload served by ``backend="colocated"`` and ``backend="wa"`` — the
   weight–attention disaggregated layer loop with the W→A→W routing
   compiled into every step program. Measured: TPOT, TTFT, host syncs,
   compile counts, and the ``routing_bytes``-derived W↔A traffic per token
   (the paper's "only embeddings move" as a number). On the single-host
   dry-run the routing constraints are no-ops, so the delta is the routed
   layer-loop program structure (python-unrolled layers vs the colocated
   ``lax.scan``), not transfer cost — the committed numbers are the
   regression baseline for the routed program path, exercised by
   ``make bench-smoke`` on every PR.

4. Preemptible serving under pressure (DESIGN.md §7): a bursty open-loop
   heavy-tailed workload (``FaultPlan.requests`` — Pareto lengths, arrivals
   in bursts that overcommit the slots, mixed priorities, half the
   requests carrying TTFT deadlines) through the non-preemptible engine
   and the preemptible one. Measured: p50/p99 TTFT and TPOT, preemption /
   restore / rejection / deadline-miss counts, swap-lane wall time, and
   **goodput-under-deadline** — decode tok/s counting only completed
   requests that met their TTFT deadline. The preemptible lane may
   complete FEWER requests (it sheds on priority) but its deadline-met
   goodput and tail TTFT are the SLO story the failure model §7 claims.

5. Sub-operator W/A overlap (DESIGN.md §3): the SAME staggered workload
   through the WA backend at overlap depth {1, 2, 4} — depth 1 is the
   sequential layer loop (today's exact programs), depth D splits each
   macro-step's batch into D micro-batches software-pipelined across the
   W/A boundary so both domains hold work at almost every schedule tick.
   Measured: TPOT per depth, the schedule's overlap efficiency and
   per-domain idle time per macro-step (``stats()['wa']``), and the
   depth-D/depth-1 TPOT ratio. Token streams are asserted identical
   across depths before timing is trusted. Two numbers are committed per
   depth, and they answer different questions: ``tpot_mean_ms`` is the
   wall-clock on THIS host — on a single-core CI container the W and A
   domains share one execution stream, every tick serializes, and depth
   D can only pay its micro-batching overhead (the committed value is
   that overhead, the regression fence for the pipelined program).
   ``projected_two_domain_tpot_ms`` is the same measurement pushed
   through the exact schedule occupancy — ``d1_tpot × 0.5 /
   overlap_efficiency(D)`` — i.e. the wall-clock on a host where W and A
   are disjoint resources and op cost is row-proportional, which is
   precisely the paper's cache-resident regime (weights LLC-resident →
   W ops scale with rows, and the per-row KV walk always did). The
   projection, not the single-core serialization, is the depth curve the
   tentpole claims; the win condition is projected depth {2,4} beating
   the measured depth-1 TPOT.

6. Tiered KV cache (DESIGN.md §7): every slot's KV splits into a hot ring
   at the resident dtype and a quantized cold prefix demoted in fixed
   blocks inside the compiled programs. Two measurements: (a) the
   ALLOCATION model at the full qwen2-0.5b geometry and a 32k-token slot —
   exact byte accounting via ``jax.eval_shape`` of the real
   ``init_kv_cache`` for flat bf16 vs tiering with each cold dtype
   {bf16, int8, int4}, reported as slots-at-equal-bytes and
   context-at-equal-bytes multipliers (the acceptance claim: ≥ 2× for the
   packed-int4 cold tier); (b) a LIVE serve sweep on the reduced config
   proving each swept lane actually serves — bf16-cold streams must equal
   the flat cache bit-for-bit, the arbiter must observe in-program
   demotions, and compiles must stay 1. Every other scenario additionally
   records its engine's allocated ``kv_bytes_per_slot`` / total cache
   bytes so each committed latency is priced against the KV bytes it was
   achieved with.

7. Split-KV flash decode (ISSUE 6 / DESIGN.md §3): at 8k–32k context the
   per-token attention walk dominates decode, and sharding one slot's KV
   along the sequence axis over the A submesh divides it by the A-width.
   Measured as the per-device critical path (one C/w shard-local partial
   flash pass + the w-way LSE merge) for contexts {8k, 16k, 32k} ×
   A-widths {1, 2, 4}, equivalence-checked against the sequential walk at
   every point — attention latency must fall as the width grows.

Per mode: TPOT (mean/p50/p99 per micro-step), TTFT, decode-token
throughput, host syncs per generated token, compile counts (every program
must compile exactly once). Results go to the CSV contract AND to
``BENCH_serving.json`` at the repo root — the committed perf-trajectory
artifact.

Each engine is run twice and the SECOND run is reported: AOT compiles all
land in ``prepare`` (first run), so run 2 is the steady-state the paper's
§4.3 regime cares about.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit

BLOCK_SIZE = 8
KV_BUCKET_CHUNK = 32
PROMPT_LEN = 16
SLOTS = 2
MAX_NEW_CAP = 64
# -- long-prompt (chunked-prefill) scenario --------------------------------
LP_PROMPT_LEN = 256          # static width = the long prompt's true length
LP_SHORT_LEN = 8             # in-flight decoders hold short prompts
LP_CHUNK = 32                # chunked lane: 256-token prompt = 8 chunks
LP_KV_BUCKET = 64            # coarser buckets (extent 320 → 5 programs)
JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serving.json")


def _workload(cfg, seed=0):
    # one LONG request holding a slot + shorts arriving mid-serve — the
    # continuous-scheduler scenario of benchmarks/table2_end_to_end.py
    rng = np.random.default_rng(seed)
    from repro.runtime.serving import Request
    plan = [(48, 0)] + [(8, 4 * i) for i in range(1, 6)]
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                        dtype=np.int32),
                    max_new_tokens=new, arrival_step=arr)
            for i, (new, arr) in enumerate(plan)]


def _cache_footprint(eng):
    """Allocated KV bytes of the engine's slot caches, computed exactly
    from the cache aval (every leaf: k/v stores, quantization scales, the
    tiered hot ring, cursors). ``cache_bytes_total`` is also the peak — the
    slot caches are allocated once per run at full extent."""
    import jax
    leaves = jax.tree_util.tree_leaves(eng._caches_aval)
    total = int(sum(int(np.prod(leaf.shape, dtype=np.int64))
                    * np.dtype(leaf.dtype).itemsize for leaf in leaves))
    return {"cache_bytes_total": total,
            "kv_bytes_per_slot": total // max(eng.slots, 1)}


def _long_prompt_workload(cfg, seed=0):
    # two short requests decoding when a LONG-prompt request lands mid-serve:
    # its admission prefill is the decode-stall the chunked lane bounds
    rng = np.random.default_rng(seed)
    from repro.runtime.serving import Request
    plan = [(48, 0, LP_SHORT_LEN), (8, 0, LP_SHORT_LEN),
            (24, 8, LP_PROMPT_LEN)]
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, plen,
                                        dtype=np.int32),
                    max_new_tokens=new, arrival_step=arr)
            for i, (new, arr, plen) in enumerate(plan)]


def _long_prompt_scenario(api, params, ctx):
    from repro.runtime.serving import ServingEngine
    cfg = api.config
    out = {"config": {"prompt_len": LP_PROMPT_LEN,
                      "short_prompt_len": LP_SHORT_LEN,
                      "prefill_chunk": LP_CHUNK,
                      "block_size": BLOCK_SIZE,
                      "kv_bucket_chunk": LP_KV_BUCKET,
                      "batch_slots": SLOTS}}
    for name, pc in (("monolithic", 0), ("chunked", LP_CHUNK)):
        eng = ServingEngine(api, ctx, SLOTS, LP_PROMPT_LEN,
                            mode="continuous", max_new_cap=MAX_NEW_CAP,
                            block_size=BLOCK_SIZE,
                            kv_bucket_chunk=LP_KV_BUCKET,
                            prefill_chunk=pc)
        eng.run(params, _long_prompt_workload(cfg), max_steps=2000)  # warm
        st = eng.run(params, _long_prompt_workload(cfg), max_steps=2000)
        compiles = {k: v["compiles"] for k, v in st["runtime"].items()}
        long_req = next(m for m in st["per_request"] if m["rid"] == 2)
        short_gaps = [m["max_gap_ms"] for m in st["per_request"]
                      if m["rid"] != 2]
        out[name] = {
            "completed": st["completed"],
            "tpot_mean_ms": st["tpot_mean_ms"],
            "tpot_p99_ms": st["tpot_p99_ms"],
            "max_inter_token_gap_ms": st["max_inter_token_gap_ms"],
            "inflight_max_gap_ms": max(short_gaps),
            "long_ttft_ms": long_req["ttft_ms"],
            "ttft_mean_ms": st["ttft_mean_ms"],
            "prefill_time_ms": st["prefill_time_ms"],
            "prefill_chunks": st["prefill_chunks"],
            "throughput_tok_s": st["throughput_tok_s"],
            "max_compiles_per_step": max(compiles.values()),
            "compiles": compiles,
            **_cache_footprint(eng),
        }
        emit(f"serving/long_prompt/{name}/inflight_max_gap",
             max(short_gaps) * 1e3,
             f"long_ttft_ms={long_req['ttft_ms']:.1f};"
             f"tpot_mean_ms={st['tpot_mean_ms']:.3f};"
             f"max_compiles_per_step={max(compiles.values())}")
    out["chunked_over_monolithic"] = {
        "inflight_gap_reduction": (out["monolithic"]["inflight_max_gap_ms"]
                                   / max(out["chunked"]["inflight_max_gap_ms"],
                                         1e-9)),
        "tpot_ratio": (out["chunked"]["tpot_mean_ms"]
                       / max(out["monolithic"]["tpot_mean_ms"], 1e-9)),
    }
    emit("serving/long_prompt/chunked_gap_reduction",
         out["chunked_over_monolithic"]["inflight_gap_reduction"],
         f"tpot_ratio={out['chunked_over_monolithic']['tpot_ratio']:.3f}")
    return out


# -- preemptible-serving pressure scenario ---------------------------------
PR_SEED = 3                  # this seed's priority mix triggers preemption
PR_REQUESTS = 12
PR_PROMPT_LEN = 8            # static prefill width (chunked lane admits longer)
PR_SLOTS = 2                 # bursts of 4 over 2 slots = sustained overcommit


def _pressure_workload():
    from repro.runtime.faults import FaultPlan
    return FaultPlan(seed=PR_SEED, n_requests=PR_REQUESTS, burst_size=4,
                     burst_gap=10, max_new_lo=4, max_new_hi=24,
                     deadline_frac=0.5, ttft_deadline_ms=250.0)


def _pressure_scenario(api, params, ctx):
    from repro.runtime.faults import clone_requests
    from repro.runtime.serving import ServingEngine
    cfg = api.config
    plan = _pressure_workload()
    base = plan.requests(cfg.vocab_size, prompt_lo=4,
                         prompt_hi=PR_PROMPT_LEN + 8)
    out = {"config": {"seed": PR_SEED, "n_requests": PR_REQUESTS,
                      "burst_size": plan.burst_size,
                      "burst_gap": plan.burst_gap,
                      "max_new_hi": plan.max_new_hi,
                      "deadline_frac": plan.deadline_frac,
                      "ttft_deadline_ms": plan.ttft_deadline_ms,
                      "batch_slots": PR_SLOTS, "block_size": BLOCK_SIZE,
                      "prompt_len": PR_PROMPT_LEN}}
    for name, preempt in (("fifo", False), ("preemptible", True)):
        eng = ServingEngine(api, ctx, PR_SLOTS, PR_PROMPT_LEN,
                            mode="continuous", max_new_cap=32,
                            block_size=BLOCK_SIZE, kv_bucket_chunk=16,
                            prefill_chunk=4, preemptible=preempt,
                            max_queue=16)
        eng.run(params, clone_requests(base), max_steps=4000)   # warm
        reqs = clone_requests(base)
        st = eng.run(params, reqs, max_steps=4000)
        compiles = {k: v["compiles"] for k, v in st["runtime"].items()}
        # goodput-under-deadline: decode tokens of completed requests that
        # met their TTFT deadline, over the same decode wall-clock the raw
        # throughput uses (scale by the token fraction)
        met = [m for m in st["per_request"] if m["ttft_deadline_met"]]
        met_tokens = sum(m["tokens"] for m in met)
        goodput = st["throughput_tok_s"] * met_tokens\
            / max(sum(m["tokens"] for m in st["per_request"]), 1)
        frac = len(met) / max(st["completed"], 1)
        ttfts = sorted(m["ttft_ms"] for m in st["per_request"])
        out[name] = {
            "completed": st["completed"],
            "rejections": st["rejections"],
            "deadline_misses": st["deadline_misses"],
            "preemptions": st["preemptions"],
            "restores": st["restores"],
            "swap_time_ms": st["swap_time_ms"],
            "tpot_mean_ms": st["tpot_mean_ms"],
            "tpot_p50_ms": st["tpot_p50_ms"],
            "tpot_p99_ms": st["tpot_p99_ms"],
            "ttft_mean_ms": st["ttft_mean_ms"],
            "ttft_p50_ms": ttfts[len(ttfts) // 2] if ttfts else 0.0,
            "ttft_p99_ms": st["ttft_p99_ms"],
            "throughput_tok_s": st["throughput_tok_s"],
            "goodput_under_deadline_tok_s": goodput,
            "deadline_met_completed": sum(
                1 for m in st["per_request"] if m["ttft_deadline_met"]),
            "deadline_met_fraction": frac,
            "max_compiles_per_step": max(compiles.values()),
            "compiles": compiles,
            **_cache_footprint(eng),
        }
        emit(f"serving/pressure/{name}/goodput_under_deadline",
             goodput,
             f"completed={st['completed']};preempt={st['preemptions']};"
             f"restore={st['restores']};rej={st['rejections']};"
             f"miss={st['deadline_misses']};"
             f"ttft_p99_ms={st['ttft_p99_ms']:.1f};"
             f"max_compiles_per_step={max(compiles.values())}")
    out["preemptible_over_fifo"] = {
        "goodput_ratio": (out["preemptible"]["goodput_under_deadline_tok_s"]
                          / max(out["fifo"]["goodput_under_deadline_tok_s"],
                                1e-9)),
        "ttft_p99_ratio": (out["preemptible"]["ttft_p99_ms"]
                           / max(out["fifo"]["ttft_p99_ms"], 1e-9)),
    }
    emit("serving/pressure/preemptible_goodput_ratio",
         out["preemptible_over_fifo"]["goodput_ratio"],
         f"ttft_p99_ratio={out['preemptible_over_fifo']['ttft_p99_ratio']:.3f}")
    return out


# -- sub-operator overlap sweep --------------------------------------------
OV_DEPTHS = (1, 2, 4)
OV_SLOTS = 4                 # divides by every depth; 4-deep decode batch


def _overlap_workload(cfg, seed=0):
    # staggered arrivals over 4 slots: mid-serve admissions + retirements
    # so the micro-batches carry mixed active masks, like real serving
    rng = np.random.default_rng(seed)
    from repro.runtime.serving import Request
    plan = [(48, 0), (40, 0), (32, 2), (24, 4), (16, 8), (16, 12)]
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                        dtype=np.int32),
                    max_new_tokens=new, arrival_step=arr)
            for i, (new, arr) in enumerate(plan)]


def _overlap_sweep_scenario(api, params, ctx):
    """WA backend at overlap depth {1, 2, 4}, same workload/scheduler/
    program names — the sweep isolates the software-pipelined layer loop.
    Streams must be identical across depths (token-exactness is the
    precondition for comparing the timings at all)."""
    import os

    from repro.runtime.serving import ServingEngine
    cfg = api.config
    out = {"config": {"prompt_len": PROMPT_LEN, "batch_slots": OV_SLOTS,
                      "max_new_cap": MAX_NEW_CAP, "block_size": BLOCK_SIZE,
                      "kv_bucket_chunk": KV_BUCKET_CHUNK,
                      "prefill_chunk": WA_PREFILL_CHUNK,
                      "depths": list(OV_DEPTHS),
                      "host_cpus": os.cpu_count(),
                      "single_execution_stream": os.cpu_count() == 1}}
    streams = {}
    for depth in OV_DEPTHS:
        eng = ServingEngine(api, ctx, OV_SLOTS, PROMPT_LEN,
                            mode="continuous", max_new_cap=MAX_NEW_CAP,
                            block_size=BLOCK_SIZE,
                            kv_bucket_chunk=KV_BUCKET_CHUNK,
                            prefill_chunk=WA_PREFILL_CHUNK, backend="wa",
                            overlap=depth)
        eng.run(params, _overlap_workload(cfg), max_steps=1000)  # warm
        reqs = _overlap_workload(cfg)
        st = eng.run(params, reqs, max_steps=1000)
        streams[depth] = [list(r.generated) for r in reqs]
        compiles = {k: v["compiles"] for k, v in st["runtime"].items()}
        wa = st["wa"]
        out[f"depth{depth}"] = {
            "completed": st["completed"],
            "tpot_mean_ms": st["tpot_mean_ms"],
            "tpot_p50_ms": st["tpot_p50_ms"],
            "tpot_p99_ms": st["tpot_p99_ms"],
            "throughput_tok_s": st["throughput_tok_s"],
            "overlap_efficiency": wa["overlap_efficiency"],
            "w_idle_ms_per_macro_step": wa["w_idle_ms_per_macro_step"],
            "a_idle_ms_per_macro_step": wa["a_idle_ms_per_macro_step"],
            "micro_batch_occupancy": wa["micro_batch_occupancy"],
            "routing_total_bytes": wa["routing_total_bytes"],
            "max_compiles_per_step": max(compiles.values()),
            "compiles": compiles,
            **_cache_footprint(eng),
        }
        emit(f"serving/wa_overlap/depth{depth}/tpot",
             st["tpot_mean_ms"] * 1e3,
             f"p99_ms={st['tpot_p99_ms']:.3f};"
             f"efficiency={wa['overlap_efficiency']:.3f};"
             f"w_idle_ms={wa['w_idle_ms_per_macro_step']:.3f};"
             f"a_idle_ms={wa['a_idle_ms_per_macro_step']:.3f};"
             f"max_compiles_per_step={max(compiles.values())}")
    assert all(streams[d] == streams[OV_DEPTHS[0]] for d in OV_DEPTHS), \
        "overlap depths produced different token streams"
    base = out["depth1"]["tpot_mean_ms"]
    out["tokens_identical_across_depths"] = True
    # projection: depth-1 measures one domain working at a time (W + A in
    # sequence); on disjoint W/A resources the same schedule costs
    # 0.5 / efficiency(D) of that — the exact occupancy model, fed by the
    # MEASURED depth-1 TPOT (row-proportional op cost: the paper's
    # cache-resident regime)
    for d in OV_DEPTHS:
        eff = out[f"depth{d}"]["overlap_efficiency"]
        out[f"depth{d}"]["projected_two_domain_tpot_ms"] = base * 0.5 / eff
    out["measured_tpot_ratio_over_depth1"] = {
        f"depth{d}": out[f"depth{d}"]["tpot_mean_ms"] / max(base, 1e-9)
        for d in OV_DEPTHS[1:]}
    out["projected_speedup_over_depth1"] = {
        f"depth{d}": base / out[f"depth{d}"]["projected_two_domain_tpot_ms"]
        for d in OV_DEPTHS[1:]}
    for d in OV_DEPTHS[1:]:
        emit(f"serving/wa_overlap/projected_speedup_d{d}",
             out["projected_speedup_over_depth1"][f"depth{d}"],
             f"d1_tpot_ms={base:.3f};"
             f"projected_d{d}_tpot_ms="
             f"{out[f'depth{d}']['projected_two_domain_tpot_ms']:.3f};"
             f"measured_d{d}_tpot_ms="
             f"{out[f'depth{d}']['tpot_mean_ms']:.3f};"
             "measured_is_single_stream_serialization="
             f"{out['config']['single_execution_stream']}")
    return out


# -- tiered-KV 32k scenario ------------------------------------------------
TK_CONTEXT = 32768           # one slot's KV extent at the full geometry
TK_HOT_WINDOW = 1024         # resident-dtype hot ring
TK_COLD_BLOCK = 128          # demotion granularity (build-time static)
TK_COLD_DTYPES = ("bfloat16", "int8", "int4")
TK_LIVE_HOT = 8              # live sweep on the reduced config
TK_LIVE_BLOCK = 8


def _tiered_kv_32k_scenario(ctx):
    """Tiered KV cache at 32k context (DESIGN.md §7). The allocation model
    prices one slot's KV at the FULL qwen2-0.5b geometry — flat bf16 vs a
    hot ring + quantized cold prefix per cold dtype — with exact byte
    accounting via ``jax.eval_shape`` of the real ``init_kv_cache`` (the
    same constructor serving allocates through; scales, packed int4 lanes
    and the hot ring all priced). The committed claim is the equal-bytes
    win: how many tiered slots fit in one flat slot's bytes, and how far
    one slot's context stretches on the flat byte budget. A live serve
    sweep on the reduced config then proves each swept lane SERVES:
    bf16-cold streams equal the flat cache bit-for-bit, the arbiter
    observes in-program demotions, compiles stay 1."""
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_config
    from repro.kv.cache import init_kv_cache
    from repro.models import build_model
    from repro.runtime.serving import ServingEngine

    def nbytes(tree):
        return int(sum(int(np.prod(leaf.shape, dtype=np.int64))
                       * np.dtype(leaf.dtype).itemsize
                       for leaf in jax.tree_util.tree_leaves(tree)))

    full = get_config("qwen2-0.5b")
    L, n_kv, hd = full.n_layers, full.n_kv_heads, full.head_dim
    out = {"config": {"arch": "qwen2-0.5b (full geometry)",
                      "n_layers": L, "n_kv_heads": n_kv, "head_dim": hd,
                      "context": TK_CONTEXT, "hot_window": TK_HOT_WINDOW,
                      "cold_block": TK_COLD_BLOCK,
                      "cold_dtypes": list(TK_COLD_DTYPES)}}
    flat_aval = jax.eval_shape(lambda: init_kv_cache(
        L, 1, n_kv, TK_CONTEXT, hd, dtype=jnp.bfloat16))
    flat_bytes = nbytes(flat_aval)
    out["flat_bf16"] = {"kv_bytes_per_slot": flat_bytes}
    for cold in TK_COLD_DTYPES:
        aval = jax.eval_shape(lambda c=cold: init_kv_cache(
            L, 1, n_kv, TK_CONTEXT, hd, dtype=jnp.bfloat16,
            hot_window=TK_HOT_WINDOW, cold_block=TK_COLD_BLOCK,
            cold_dtype=c))
        tb = nbytes(aval)
        hot_bytes = nbytes((aval.hot_k, aval.hot_v))
        cold_per_tok = (tb - hot_bytes) / TK_CONTEXT
        rec = {
            "kv_bytes_per_slot": tb,
            "hot_ring_bytes": hot_bytes,
            "cold_bytes_per_token": cold_per_tok,
            "slots_at_equal_bytes": flat_bytes / tb,
            "context_at_equal_bytes": int((flat_bytes - hot_bytes)
                                          / cold_per_tok),
        }
        rec["context_multiplier"] =\
            rec["context_at_equal_bytes"] / TK_CONTEXT
        out[cold] = rec
        emit(f"serving/tiered_kv_32k/{cold}/kv_bytes_per_slot", float(tb),
             f"slots_at_equal_bytes={rec['slots_at_equal_bytes']:.2f};"
             f"context_at_equal_bytes={rec['context_at_equal_bytes']};"
             f"flat_bf16_bytes={flat_bytes}")
    best = max(out[c]["slots_at_equal_bytes"] for c in TK_COLD_DTYPES)
    out["best_slots_at_equal_bytes"] = best
    out["best_context_multiplier"] = max(
        out[c]["context_multiplier"] for c in TK_COLD_DTYPES)

    # -- live sweep: the swept lane must actually serve --------------------
    rcfg = get_config("qwen2-0.5b").reduced()
    live = {"config": {"arch": "qwen2-0.5b (reduced)",
                       "prompt_len": PROMPT_LEN, "batch_slots": SLOTS,
                       "hot_window": TK_LIVE_HOT,
                       "cold_block": TK_LIVE_BLOCK,
                       "prefill_chunk": WA_PREFILL_CHUNK,
                       "block_size": BLOCK_SIZE,
                       "kv_bucket_chunk": KV_BUCKET_CHUNK}}
    api0 = build_model(rcfg)
    params0 = api0.init(jax.random.key(0))
    eng0 = ServingEngine(api0, ctx, SLOTS, PROMPT_LEN, mode="continuous",
                         max_new_cap=MAX_NEW_CAP, block_size=BLOCK_SIZE,
                         kv_bucket_chunk=KV_BUCKET_CHUNK,
                         prefill_chunk=WA_PREFILL_CHUNK)
    eng0.run(params0, _workload(rcfg), max_steps=1000)           # warm
    flat_reqs = _workload(rcfg)
    st0 = eng0.run(params0, flat_reqs, max_steps=1000)
    flat_streams = [list(r.generated) for r in flat_reqs]
    live["flat_bf16"] = {"tpot_mean_ms": st0["tpot_mean_ms"],
                         "completed": st0["completed"],
                         **_cache_footprint(eng0)}
    for cold in TK_COLD_DTYPES:
        tcfg = rcfg.replace(hot_window=TK_LIVE_HOT, kv_cold_dtype=cold,
                            kv_cold_block=TK_LIVE_BLOCK)
        tapi = build_model(tcfg)
        tparams = tapi.init(jax.random.key(0))
        eng = ServingEngine(tapi, ctx, SLOTS, PROMPT_LEN,
                            mode="continuous", max_new_cap=MAX_NEW_CAP,
                            block_size=BLOCK_SIZE,
                            kv_bucket_chunk=KV_BUCKET_CHUNK,
                            prefill_chunk=WA_PREFILL_CHUNK)
        eng.run(tparams, _workload(rcfg), max_steps=1000)        # warm
        reqs = _workload(rcfg)
        st = eng.run(tparams, reqs, max_steps=1000)
        compiles = {k: v["compiles"] for k, v in st["runtime"].items()}
        t = st["tiered"]
        rec = {
            "completed": st["completed"],
            "tpot_mean_ms": st["tpot_mean_ms"],
            "throughput_tok_s": st["throughput_tok_s"],
            "demotions": t["demotions"],
            "peak_kv_bytes": t["peak_kv_bytes"],
            "cold_bytes_saved": t["cold_bytes_saved"],
            "max_compiles_per_step": max(compiles.values()),
            "compiles": compiles,
            **_cache_footprint(eng),
        }
        if cold == "bfloat16":
            # the bf16 cold tier is a pure relayout — streams must equal
            # the flat cache exactly before any quantized point is trusted
            rec["streams_match_flat"] =\
                [list(r.generated) for r in reqs] == flat_streams
            assert rec["streams_match_flat"],\
                "bf16-cold tiered serve diverged from the flat cache"
        live[cold] = rec
        emit(f"serving/tiered_kv_32k/live/{cold}/tpot",
             st["tpot_mean_ms"] * 1e3,
             f"demotions={t['demotions']};"
             f"kv_bytes_per_slot={rec['kv_bytes_per_slot']};"
             f"max_compiles_per_step={max(compiles.values())}")
    out["live"] = live
    emit("serving/tiered_kv_32k/best_slots_at_equal_bytes", best,
         f"best_context_multiplier={out['best_context_multiplier']:.2f};"
         f"int8_slots={out['int8']['slots_at_equal_bytes']:.2f};"
         f"int4_slots={out['int4']['slots_at_equal_bytes']:.2f}")
    return out


# -- split-KV long-context scenario ----------------------------------------
SK_CONTEXTS = (8192, 16384, 32768)   # KV positions attended per decode token
SK_WIDTHS = (1, 2, 4)                # A-domain shard counts
SK_HEADS, SK_KV_HEADS, SK_HEAD_DIM = 16, 4, 64
SK_REPS = 30                         # min-of-N timing per point


def _split_kv_long_context_scenario():
    """Split-KV flash decode (ISSUE 6 / DESIGN.md §3): at long context the
    per-token attention walk is the decode critical path, and sharding one
    slot's KV along the sequence axis over the A submesh divides that walk
    by the A-width. On this single-host CPU run the w shards cannot
    actually execute concurrently, so the measured quantity is the
    PER-DEVICE critical path a w-wide A domain executes: ONE shard-local
    partial flash pass over C/w positions plus the w-way LSE merge of the
    (o, m, l) stat triples — the only cross-device traffic. Equivalence is
    checked against the sequential full-context walk at every point before
    timing it."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_decode.ops import (combine_partial_stats,
                                                flash_decode,
                                                flash_decode_partial)

    B, Hq, n_kv, hd = 1, SK_HEADS, SK_KV_HEADS, SK_HEAD_DIM
    rng = np.random.default_rng(0)
    out = {"config": {
        "batch": B, "q_heads": Hq, "kv_heads": n_kv, "head_dim": hd,
        "contexts": list(SK_CONTEXTS), "a_widths": list(SK_WIDTHS),
        "reps": SK_REPS, "dtype": "float32",
        "method": "per-device critical path: one shard-local partial flash "
                  "pass over C/w KV positions + the w-way LSE merge of "
                  "(o, m, l) stat triples; shards execute concurrently "
                  "across the A submesh, so this is the wall-clock a "
                  "w-wide A domain pays per decode token",
    }}
    max_err = 0.0
    for C in SK_CONTEXTS:
        q = jnp.asarray(rng.standard_normal((B, Hq, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, n_kv, C, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, n_kv, C, hd)), jnp.float32)
        mask = jnp.ones((B, C), bool)
        full = np.asarray(flash_decode(q, k, v, mask))
        rec = {}
        for w in SK_WIDTHS:
            Sb = C // w
            # equivalence: the REAL w distinct shards, partial + merge,
            # must match the sequential full-context walk
            ks = k.reshape(B, n_kv, w, Sb, hd)
            vs = v.reshape(B, n_kv, w, Sb, hd)
            parts = [flash_decode_partial(q, ks[:, :, s], vs[:, :, s],
                                          jnp.ones((B, Sb), bool))
                     for s in range(w)]
            merged = combine_partial_stats(
                jnp.stack([p[0] for p in parts]),
                jnp.stack([p[1] for p in parts]),
                jnp.stack([p[2] for p in parts]), axis=0)
            err = float(np.abs(np.asarray(merged) - full).max())
            max_err = max(max_err, err)
            assert err < 1e-4, (C, w, err)

            # timing: ONE shard's pass + the w-way merge (stat triples
            # replicated w-wide — on the mesh each device contributes one)
            def step(q, k1, v1, m1, _w=w):
                o, mm, ll = flash_decode_partial(q, k1, v1, m1)
                os = jnp.broadcast_to(o[None], (_w,) + o.shape)
                ms = jnp.broadcast_to(mm[None], (_w,) + mm.shape)
                ls = jnp.broadcast_to(ll[None], (_w,) + ll.shape)
                return combine_partial_stats(os, ms, ls, axis=0)

            fn = jax.jit(step)
            k1, v1 = ks[:, :, 0], vs[:, :, 0]
            m1 = jnp.ones((B, Sb), bool)
            fn(q, k1, v1, m1).block_until_ready()      # compile + warm
            best = float("inf")
            for _ in range(SK_REPS):
                t0 = time.perf_counter()
                fn(q, k1, v1, m1).block_until_ready()
                best = min(best, time.perf_counter() - t0)
            rec[f"w{w}_attn_ms"] = best * 1e3
            emit(f"serving/split_kv/c{C}/w{w}", best * 1e3,
                 f"shard_len={Sb};equiv_max_abs_err={err:.2e}")
        for w in SK_WIDTHS[1:]:
            rec[f"speedup_w{w}"] = rec["w1_attn_ms"] / max(
                rec[f"w{w}_attn_ms"], 1e-9)
        out[f"c{C}"] = rec
    out["equivalence_max_abs_err"] = max_err
    emit("serving/split_kv/speedup_w4_at_32k",
         out["c32768"]["speedup_w4"],
         f"w1_ms={out['c32768']['w1_attn_ms']:.3f};"
         f"w4_ms={out['c32768']['w4_attn_ms']:.3f};"
         f"equiv_max_abs_err={max_err:.2e}")
    return out


WA_PREFILL_CHUNK = 8         # WA scenario: chunked admission, 2 chunks/prompt


def _wa_backend_scenario(api, params, ctx):
    """Colocated vs WA-disaggregated backend on the staggered workload:
    same scheduler, same admissions, every program swapped for its routed
    twin — TPOT/TTFT/sync parity plus the measured W↔A traffic."""
    from repro.runtime.serving import ServingEngine
    cfg = api.config
    out = {"config": {"prompt_len": PROMPT_LEN, "batch_slots": SLOTS,
                      "max_new_cap": MAX_NEW_CAP, "block_size": BLOCK_SIZE,
                      "kv_bucket_chunk": KV_BUCKET_CHUNK,
                      "prefill_chunk": WA_PREFILL_CHUNK}}
    for backend in ("colocated", "wa"):
        eng = ServingEngine(api, ctx, SLOTS, PROMPT_LEN, mode="continuous",
                            max_new_cap=MAX_NEW_CAP, block_size=BLOCK_SIZE,
                            kv_bucket_chunk=KV_BUCKET_CHUNK,
                            prefill_chunk=WA_PREFILL_CHUNK, backend=backend)
        eng.run(params, _workload(cfg), max_steps=1000)   # warm (compiles)
        st = eng.run(params, _workload(cfg), max_steps=1000)
        compiles = {k: v["compiles"] for k, v in st["runtime"].items()}
        rec = {
            "completed": st["completed"],
            "tpot_mean_ms": st["tpot_mean_ms"],
            "tpot_p99_ms": st["tpot_p99_ms"],
            "ttft_mean_ms": st["ttft_mean_ms"],
            "throughput_tok_s": st["throughput_tok_s"],
            "decode_tokens": st["decode_tokens"],
            "host_syncs": st["host_syncs"],
            "syncs_per_token": st["syncs_per_token"],
            "max_compiles_per_step": max(compiles.values()),
            "compiles": compiles,
            **_cache_footprint(eng),
        }
        if backend == "wa":
            rec["routing_bytes_per_token"] = st["wa"]["routing_bytes_per_token"]
            rec["routing_total_bytes"] = st["wa"]["routing_total_bytes"]
            rec["routing_bytes_per_decode_token"] =\
                st["wa"]["routing_bytes_per_decode_token"]
        out[backend] = rec
        derived = (f"ttft_mean_ms={st['ttft_mean_ms']:.1f};"
                   f"host_syncs={st['host_syncs']};"
                   f"max_compiles_per_step={max(compiles.values())}")
        if backend == "wa":
            derived += (";routing_bytes_per_token="
                        f"{st['wa']['routing_bytes_per_token']}")
        emit(f"serving/wa_backend/{backend}/tpot",
             st["tpot_mean_ms"] * 1e3, derived)
    out["wa_over_colocated"] = {
        "tpot_ratio": (out["wa"]["tpot_mean_ms"]
                       / max(out["colocated"]["tpot_mean_ms"], 1e-9)),
        "host_sync_parity": (out["wa"]["host_syncs"]
                             == out["colocated"]["host_syncs"]),
    }
    emit("serving/wa_backend/routing_bytes_per_token",
         float(out["wa"]["routing_bytes_per_token"]),
         f"total_bytes={out['wa']['routing_total_bytes']};"
         f"tpot_ratio={out['wa_over_colocated']['tpot_ratio']:.3f}")
    return out


def run():
    import jax
    from repro.configs.registry import get_config
    from repro.models import build_model
    from repro.models.sharding import ShardingCtx, sub_operator
    from repro.runtime.serving import ServingEngine

    cfg = get_config("qwen2-0.5b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    ctx = ShardingCtx(None, sub_operator())

    modes = {
        "per_step": dict(block_size=1),
        "macro_step": dict(block_size=BLOCK_SIZE,
                           kv_bucket_chunk=KV_BUCKET_CHUNK),
    }
    report = {"config": {"arch": "qwen2-0.5b (reduced)",
                         "prompt_len": PROMPT_LEN, "batch_slots": SLOTS,
                         "max_new_cap": MAX_NEW_CAP,
                         "block_size": BLOCK_SIZE,
                         "kv_bucket_chunk": KV_BUCKET_CHUNK}}
    for name, kw in modes.items():
        eng = ServingEngine(api, ctx, SLOTS, PROMPT_LEN, mode="continuous",
                            max_new_cap=MAX_NEW_CAP, **kw)
        eng.run(params, _workload(cfg), max_steps=1000)   # warm (compiles)
        st = eng.run(params, _workload(cfg), max_steps=1000)
        compiles = {k: v["compiles"] for k, v in st["runtime"].items()}
        report[name] = {
            "completed": st["completed"],
            "tpot_mean_ms": st["tpot_mean_ms"],
            "tpot_p50_ms": st["tpot_p50_ms"],
            "tpot_p99_ms": st["tpot_p99_ms"],
            "ttft_mean_ms": st["ttft_mean_ms"],
            "ttft_p99_ms": st["ttft_p99_ms"],
            "throughput_tok_s": st["throughput_tok_s"],
            "decode_tokens": st["decode_tokens"],
            "host_syncs": st["host_syncs"],
            "syncs_per_token": st["syncs_per_token"],
            "tokens_per_macro_step_mean": st["tokens_per_macro_step_mean"],
            "max_compiles_per_step": max(compiles.values()),
            "compiles": compiles,
            **_cache_footprint(eng),
        }
        emit(f"serving/{name}/tpot", st["tpot_mean_ms"] * 1e3,
             f"p50_ms={st['tpot_p50_ms']:.3f};p99_ms={st['tpot_p99_ms']:.3f};"
             f"throughput_tok_s={st['throughput_tok_s']:.1f}")
        emit(f"serving/{name}/ttft", st["ttft_mean_ms"] * 1e3,
             f"p99_ms={st['ttft_p99_ms']:.1f}")
        emit(f"serving/{name}/host_syncs_per_token",
             st["syncs_per_token"] * 1e6,
             f"host_syncs={st['host_syncs']};"
             f"decode_tokens={st['decode_tokens']};"
             f"max_compiles_per_step={max(compiles.values())}")
    speedup = (report["per_step"]["tpot_mean_ms"]
               / max(report["macro_step"]["tpot_mean_ms"], 1e-9))
    sync_drop = (report["per_step"]["syncs_per_token"]
                 / max(report["macro_step"]["syncs_per_token"], 1e-9))
    report["macro_over_per_step"] = {
        "tpot_speedup": speedup,
        "host_sync_reduction": sync_drop,
    }
    emit("serving/macro_over_per_step", speedup,
         f"tpot_speedup={speedup:.2f};host_sync_reduction={sync_drop:.1f}")
    report["long_prompt"] = _long_prompt_scenario(api, params, ctx)
    report["wa_backend"] = _wa_backend_scenario(api, params, ctx)
    report["wa_overlap"] = _overlap_sweep_scenario(api, params, ctx)
    report["pressure"] = _pressure_scenario(api, params, ctx)
    report["tiered_kv_32k"] = _tiered_kv_32k_scenario(ctx)
    report["split_kv_long_context"] = _split_kv_long_context_scenario()
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {JSON_PATH}")


if __name__ == "__main__":
    run()
