"""Macro-step decode tests (DESIGN.md §7 "macro-step scheduling").

Covers the invariants the macro-step ISSUE demands:
- ``decode_block(T)`` is token-EXACT against T sequential ``decode_slotted``
  steps (transformer + ssm families, int8 KV on/off),
- per-slot on-device halting stops exactly at the token budget / EOS id,
- the chunk-bucketed (length-aware) decode matches full-extent numerics,
- the block program compiles exactly once across staggered admissions,
- host syncs per generated token drop from 1 to 1/T (counted hook),
- engine reuse across ``run()`` calls starts from clean state,
- ``debug_reset_slots`` zeroes retired slots.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED
from repro.models import NULL_CTX, build_model
from repro.models.attention import (bucket_for, decode_attention,
                                    decode_attention_bucketed, kv_buckets)
from repro.runtime.serving import Request, ServingEngine
from repro.runtime.static_runtime import StaticRuntime

PROMPT_LEN = 8
T = 8


@pytest.fixture(scope="module")
def dense():
    cfg = ASSIGNED["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


@pytest.fixture(scope="module")
def dense_int8():
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(kv_dtype="int8")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


@pytest.fixture(scope="module")
def ssm():
    cfg = ASSIGNED["mamba2-1.3b"].reduced()
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


def _requests(cfg, plan, seed=0):
    """plan: list of (max_new, arrival_step). Seeded per call so identical
    plans produce identical prompts across engines."""
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                        dtype=np.int32),
                    max_new_tokens=new, arrival_step=arr)
            for i, (new, arr) in enumerate(plan)]


def _sequential_reference(api, params, caches, cur, pos, act, rem, steps):
    """T single slotted steps with the SAME halt logic the block runs on
    device — the oracle decode_block must match token-for-token."""
    toks, emits = [], []
    for _ in range(steps):
        caches, logits = api.decode_slotted(params, caches, cur, pos, act,
                                            NULL_CTX)
        nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        nxt = jnp.where(act, nxt, 0)
        toks.append(np.asarray(nxt))
        emits.append(np.asarray(act))
        pos = pos + act.astype(jnp.int32)
        rem = rem - act.astype(jnp.int32)
        act = act & (rem > 0)
        cur = nxt
    return caches, np.stack(toks), np.stack(emits)


# ---------------------------------------------------------------------------
# decode_block == T sequential slotted steps (token-exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture", ["dense", "dense_int8", "ssm"])
def test_decode_block_token_exact(fixture, request):
    cfg, api, params = request.getfixturevalue(fixture)
    toks = jax.random.randint(jax.random.key(1), (2, PROMPT_LEN), 0,
                              cfg.vocab_size)
    c0, logits = api.prefill(params, {"tokens": toks}, NULL_CTX)
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    pos = jnp.full((2,), PROMPT_LEN, jnp.int32)
    act = jnp.array([True, True])
    rem = jnp.array([T, T - 3], jnp.int32)       # row 1 halts mid-block
    eos = jnp.full((2,), -1, jnp.int32)
    c_ref, want_toks, want_emit = _sequential_reference(
        api, params, c0, cur, pos, act, rem, T)
    c1, logits1 = api.prefill(params, {"tokens": toks}, NULL_CTX)
    c_blk, blk_toks, emitted, last, pos_o, act_o, rem_o = jax.jit(
        lambda *xs: api.decode_block(*xs, NULL_CTX, block_size=T))(
        params, c1, cur, pos, act, rem, eos)
    np.testing.assert_array_equal(np.asarray(blk_toks), want_toks)
    np.testing.assert_array_equal(np.asarray(emitted), want_emit)
    assert np.asarray(pos_o).tolist() == [PROMPT_LEN + T,
                                          PROMPT_LEN + T - 3]
    assert np.asarray(rem_o).tolist() == [0, 0]
    assert np.asarray(act_o).tolist() == [False, False]
    # cache state equal too (KV families: byte-identical stored buffers)
    ref_leaves = jax.tree_util.tree_leaves(c_ref)
    blk_leaves = jax.tree_util.tree_leaves(c_blk)
    for a, b in zip(ref_leaves, blk_leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_block_halts_exactly_at_budget(dense):
    """remaining=k emits exactly k tokens then idles: token id 0, no
    position advance, no emission bit — regardless of how many micro-steps
    the block still runs."""
    cfg, api, params = dense
    toks = jnp.ones((2, PROMPT_LEN), jnp.int32)
    c0, logits = api.prefill(params, {"tokens": toks}, NULL_CTX)
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    rem = jnp.array([2, 5], jnp.int32)
    _, toks_o, emitted, _, pos_o, act_o, _ = jax.jit(
        lambda *xs: api.decode_block(*xs, NULL_CTX, block_size=T))(
        params, c0, cur, jnp.full((2,), PROMPT_LEN, jnp.int32),
        jnp.array([True, True]), rem, jnp.full((2,), -1, jnp.int32))
    emitted = np.asarray(emitted)
    assert emitted[:, 0].sum() == 2 and emitted[:, 1].sum() == 5
    assert emitted[:2, 0].all() and not emitted[2:, 0].any()
    assert np.asarray(toks_o)[2:, 0].tolist() == [0] * (T - 2)
    assert np.asarray(pos_o).tolist() == [PROMPT_LEN + 2, PROMPT_LEN + 5]
    assert not np.asarray(act_o).any()


def test_decode_block_eos_halts_on_device(dense):
    """Generate without EOS, pick the token emitted at micro-step 3, rerun
    with that id as the slot's EOS operand: the slot must emit it and halt
    — entirely on device, no host intervention."""
    cfg, api, params = dense
    toks = jax.random.randint(jax.random.key(2), (2, PROMPT_LEN), 0,
                              cfg.vocab_size)
    c0, logits = api.prefill(params, {"tokens": toks}, NULL_CTX)
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    args = (cur, jnp.full((2,), PROMPT_LEN, jnp.int32),
            jnp.array([True, True]), jnp.full((2,), T, jnp.int32))
    blk = jax.jit(lambda *xs: api.decode_block(*xs, NULL_CTX, block_size=T))
    _, toks_free, _, _, _, _, _ = blk(params, c0, *args,
                                      jnp.full((2,), -1, jnp.int32))
    stop = int(np.asarray(toks_free)[3, 0])
    c1, _ = api.prefill(params, {"tokens": toks}, NULL_CTX)
    _, toks_eos, emitted, _, _, act_o, _ = blk(
        params, c1, *args, jnp.array([stop, -1], jnp.int32))
    emitted = np.asarray(emitted)
    assert emitted[:, 0].sum() == 4                 # halted after the EOS
    assert int(np.asarray(toks_eos)[3, 0]) == stop
    assert not np.asarray(act_o)[0]
    assert emitted[:, 1].all()                      # row 1 unaffected


# ---------------------------------------------------------------------------
# length-aware (chunk-bucketed) KV walking
# ---------------------------------------------------------------------------

def test_kv_bucket_helpers():
    assert kv_buckets(136, 64) == (64, 128, 136)
    assert kv_buckets(128, 64) == (64, 128)
    assert kv_buckets(64, 0) == (64,)
    assert kv_buckets(32, 64) == (32,)
    assert bucket_for(10, (64, 128, 136)) == 64
    assert bucket_for(65, (64, 128, 136)) == 128
    assert bucket_for(999, (64, 128, 136)) == 136
    # split-KV (shards > 1): every bucket must cut into equal shard blocks
    # — the chunk stride rounds UP to a shard multiple, never truncates
    assert kv_buckets(128, 64, shards=2) == (64, 128)
    assert kv_buckets(128, 24, shards=8) == (24, 48, 72, 96, 120, 128)
    assert kv_buckets(128, 20, shards=8) == (24, 48, 72, 96, 120, 128)
    assert kv_buckets(40, 16, shards=4) == (16, 32, 40)
    assert kv_buckets(64, 60, shards=8) == (64,)
    with pytest.raises(ValueError, match="not divisible"):
        kv_buckets(130, 64, shards=4)


def test_decode_attention_bucketed_matches_full():
    key = jax.random.key(0)
    B, Hq, n_kv, S, hd = 2, 8, 4, 96, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, n_kv, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, n_kv, S, hd), jnp.float32)
    mask = jnp.arange(S)[None, :] < jnp.array([[20], [31]])
    want = decode_attention(q, k, v, mask, NULL_CTX)
    got = decode_attention_bucketed(q, k, v, mask, NULL_CTX, kv_bucket=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # identity buckets
    for b in (0, S, S + 32):
        same = decode_attention_bucketed(q, k, v, mask, NULL_CTX, kv_bucket=b)
        np.testing.assert_array_equal(np.asarray(same), np.asarray(want))


@pytest.mark.parametrize("fixture", ["dense", "dense_int8"])
def test_bucketed_slotted_decode_matches_full_extent(fixture, request):
    """decode_slotted under a covering kv_bucket equals the full-extent
    walk bit-for-bit on logits AND stored cache (the bucket only trims the
    attended read, never the append)."""
    cfg, api, params = request.getfixturevalue(fixture)
    toks = jax.random.randint(jax.random.key(3), (2, PROMPT_LEN), 0,
                              cfg.vocab_size)
    c0, logits = api.prefill(params, {"tokens": toks}, NULL_CTX)
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    pos = jnp.full((2,), PROMPT_LEN, jnp.int32)
    act = jnp.array([True, True])
    c_full, lg_full = jax.jit(lambda *xs: api.decode_slotted(*xs, NULL_CTX))(
        params, c0, cur, pos, act)
    c1, _ = api.prefill(params, {"tokens": toks}, NULL_CTX)
    c_bkt, lg_bkt = jax.jit(lambda *xs: api.decode_slotted(
        *xs, NULL_CTX, kv_bucket=16))(params, c1, cur, pos, act)
    np.testing.assert_allclose(np.asarray(lg_bkt), np.asarray(lg_full),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(c_bkt.k), np.asarray(c_full.k))
    np.testing.assert_array_equal(np.asarray(c_bkt.v), np.asarray(c_full.v))


# ---------------------------------------------------------------------------
# engine: macro-step loop
# ---------------------------------------------------------------------------

PLAN = [(9, 0), (13, 0), (5, 2), (9, 6)]


def test_engine_block_tokens_equal_per_step_engine(dense):
    cfg, api, params = dense
    r1 = _requests(cfg, PLAN)
    ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                  max_new_cap=32).run(params, r1, max_steps=400)
    rT = _requests(cfg, PLAN)
    stats = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                          max_new_cap=32, block_size=4,
                          kv_bucket_chunk=16).run(params, rT, max_steps=400)
    assert stats["completed"] == len(PLAN)
    for a, b in zip(r1, rT):
        assert a.generated == b.generated, a.rid


@pytest.mark.parametrize("a_shards", [1, 2])
def test_block_programs_compile_once_across_admissions(dense, a_shards):
    """Zero retracing (§4.3 invariant) extends to the macro-step regime:
    prefill1, admit, and EVERY decode-block bucket compile exactly once
    while calls grow across staggered admissions. Split-KV decode
    (a_shards > 1) keeps the SAME program names and the same bucket set —
    the shard count is a build-time static baked into each program, so the
    invariant (and this assertion set) cannot drift with the width."""
    cfg, api, params = dense
    rt = StaticRuntime()
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, runtime=rt,
                        mode="continuous", max_new_cap=32, block_size=4,
                        kv_bucket_chunk=16, a_shards=a_shards)
    stats = eng.run(params, _requests(cfg, PLAN), max_steps=400)
    assert stats["completed"] == len(PLAN)
    rs = stats["runtime"]
    # buckets fixed at prepare: s_max = 8 + 32 = 40, chunk 16 → 16/32/40
    # (every bucket divides by a_shards=2, so the set is width-invariant)
    assert {"serve_prefill1", "serve_admit", "serve_decode_block_s16",
            "serve_decode_block_s32", "serve_decode_block_s40"} <= set(rs)
    for name, rec in rs.items():
        assert rec["compiles"] == 1, (name, rec)
    assert sum(rec["calls"] for n, rec in rs.items()
               if n.startswith("serve_decode_block")) == stats["macro_steps"]


def test_block_programs_compile_once_across_shard_resident_lengths(dense):
    """Cursor positions that land inside different shard blocks (shard 0
    only, mid-shard 1, the full extent) must all route through the SAME
    per-bucket programs — shard-resident length is traced state, never a
    compile key. Two runs with different length mixes: still one compile
    per program."""
    cfg, api, params = dense
    rt = StaticRuntime()
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, runtime=rt,
                        mode="continuous", max_new_cap=32, block_size=4,
                        kv_bucket_chunk=16, a_shards=2)
    # short run: cursors stay inside shard 0 (extent 40 → blocks of 20)
    s1 = eng.run(params, _requests(cfg, [(4, 0), (4, 0)]), max_steps=400)
    # long run: cursors cross into shard 1 (8 + 24 = 32 > 20)
    s2 = eng.run(params, _requests(cfg, [(24, 0), (13, 2)]), max_steps=400)
    assert s1["completed"] == 2 and s2["completed"] == 2
    for name, rec in s2["runtime"].items():
        assert rec["compiles"] == 1, (name, rec)


def test_host_syncs_drop_by_block_size(dense):
    """The counted hook: syncs per generated token fall from 1/batch (per
    decode step) to 1/(T·batch) — exactly a T× reduction on an aligned
    workload."""
    cfg, api, params = dense
    plan = [(9, 0), (9, 0)]                      # 8 decode tokens each
    r1 = _requests(cfg, plan)
    e1 = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                      max_new_cap=32)
    s1 = e1.run(params, r1, max_steps=100)
    rT = _requests(cfg, plan)
    eT = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                       max_new_cap=32, block_size=4)
    sT = eT.run(params, rT, max_steps=100)
    assert s1["decode_tokens"] == sT["decode_tokens"] == 16
    assert e1.host_syncs == 8                    # one per decode step
    assert eT.host_syncs == 2                    # one per block of T=4
    assert eT.host_syncs * 4 == e1.host_syncs
    assert sT["syncs_per_token"] == pytest.approx(s1["syncs_per_token"] / 4)


def test_engine_reuse_starts_clean(dense):
    """Satellite: ``run()`` on a used engine must not leak tpot samples,
    sync counts or cache state from the previous run."""
    cfg, api, params = dense
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                        max_new_cap=32, block_size=4)
    ra = _requests(cfg, PLAN)
    sa = eng.run(params, ra, max_steps=400)
    rb = _requests(cfg, PLAN)
    sb = eng.run(params, rb, max_steps=400)
    assert sb["completed"] == sa["completed"]
    assert sb["host_syncs"] == sa["host_syncs"]          # not accumulated
    assert sb["decode_tokens"] == sa["decode_tokens"]
    assert len(eng.tpot_samples) == sa["macro_steps"]
    for a, b in zip(ra, rb):
        assert a.generated == b.generated                # fresh caches


def test_throughput_counts_only_decode_tokens(dense):
    """Satellite: prefill-produced first tokens are excluded from the
    decode-throughput numerator (their cost is not in the denominator)."""
    cfg, api, params = dense
    reqs = _requests(cfg, PLAN)
    stats = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                          max_new_cap=32, block_size=4).run(
        params, reqs, max_steps=400)
    n_dec = sum(len(r.generated) - 1 for r in reqs)      # minus prefill token
    assert stats["decode_tokens"] == n_dec
    assert stats["tokens_per_macro_step_mean"] == pytest.approx(
        n_dec / stats["macro_steps"])
    assert stats["throughput_tok_s"] > 0


def test_debug_reset_slots_zeroes_retired(dense):
    cfg, api, params = dense
    # include a 1-token request: it retires AT admission (prefill-only) but
    # its prompt KV was written — reset must cover that path too
    plan = PLAN + [(1, 4)]
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                        max_new_cap=32, block_size=4, debug_reset_slots=True)
    stats = eng.run(params, _requests(cfg, plan), max_steps=400)
    assert stats["completed"] == len(plan)
    assert stats["runtime"]["serve_reset"]["compiles"] == 1
    assert stats["runtime"]["serve_reset"]["calls"] == len(plan)
    # every request retired → every slot zeroed (clean dumps)
    assert not np.asarray(eng._caches.k).any()
    assert not np.asarray(eng._caches.v).any()


def test_ssm_family_serves_in_block_mode(ssm):
    """Attention-free families run the same macro-step loop (single
    full-extent block program — no KV length axis to bucket)."""
    cfg, api, params = ssm
    plan = [(6, 0), (10, 0), (6, 2)]
    r1 = _requests(cfg, plan)
    ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                  max_new_cap=32).run(params, r1, max_steps=200)
    rT = _requests(cfg, plan)
    rt = StaticRuntime()
    stats = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, runtime=rt,
                          mode="continuous", max_new_cap=32, block_size=4,
                          kv_bucket_chunk=16).run(params, rT, max_steps=200)
    assert stats["completed"] == 3
    assert stats["runtime"]["serve_decode_block"]["compiles"] == 1
    for a, b in zip(r1, rT):
        assert a.generated == b.generated, a.rid


def test_engine_eos_request_halts_early(dense):
    cfg, api, params = dense
    probe = _requests(cfg, [(9, 0)])
    ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                  max_new_cap=32).run(params, probe, max_steps=100)
    stop = probe[0].generated[3]
    reqs = _requests(cfg, [(9, 0)])
    reqs[0].eos_id = stop
    ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                  max_new_cap=32, block_size=4).run(params, reqs,
                                                    max_steps=100)
    assert reqs[0].generated == probe[0].generated[:4]


def test_one_token_requests_do_not_idle_the_slot(dense):
    """A request that completes at its first (prefill) token must not park
    the slot until the next block boundary: admission retries the same slot
    within the boundary."""
    cfg, api, params = dense
    reqs = _requests(cfg, [(1, 0), (1, 0), (5, 0)])
    stats = ServingEngine(api, NULL_CTX, 1, PROMPT_LEN, mode="continuous",
                          max_new_cap=32, block_size=4).run(
        params, reqs, max_steps=100)
    assert stats["completed"] == 3
    assert [r.admit_step for r in reqs] == [0, 0, 0]
    assert len(reqs[2].generated) == 5


def test_invalid_block_size_rejected(dense):
    cfg, api, params = dense
    with pytest.raises(ValueError):
        ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, block_size=0)
    with pytest.raises(ValueError):
        ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, prefill_chunk=-1)
