"""Seeded chaos schedules through the fault-injection harness (§7).

Each schedule is one ``FaultPlan.generate(seed)``: a deterministic mix of
injected dispatch failures/slowdowns, a KV-pressure square wave, and a
bursty heavy-tailed arrival workload. ``run_chaos`` drives a clean
reference run then the chaos run on the SAME engine and audits the result
with ``check_invariants`` — terminal accounting, occupancy consistency,
emission-log contiguity (no duplicated/lost/reordered token), and
token-byte equality of every completed request against the clean run.

The acceptance bar (ISSUE): ≥ 25 seeded schedules green. 20 run on the
colocated backend, 5 on WA — the engines are module-scoped so the AOT
programs compile once per backend and serve every seed.
"""
import jax
import pytest

from repro.configs.registry import ASSIGNED
from repro.models import NULL_CTX, build_model
from repro.runtime.faults import FaultInjector, FaultPlan, run_chaos
from repro.runtime.serving import ServingEngine

PROMPT_LEN = 8
COLO_SEEDS = list(range(20))
WA_SEEDS = list(range(100, 105))        # disjoint from the colocated set


@pytest.fixture(scope="module")
def model():
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(dtype="float32")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


def _engine(api, backend):
    return ServingEngine(api, NULL_CTX, 3, PROMPT_LEN, mode="continuous",
                         block_size=8, prefill_chunk=4, preemptible=True,
                         max_queue=16, max_retries=2,
                         strict_invariants=True, backend=backend)


@pytest.fixture(scope="module")
def colo_engine(model):
    _cfg, api, _params = model
    return _engine(api, "colocated")


@pytest.fixture(scope="module")
def wa_engine(model):
    _cfg, api, _params = model
    return _engine(api, "wa")


def _run_seed(engine, model, seed):
    cfg, _api, params = model
    plan = FaultPlan.generate(seed)
    reqs = plan.requests(cfg.vocab_size, prompt_lo=4,
                         prompt_hi=PROMPT_LEN + 8)
    report = run_chaos(engine, params, plan, reqs)
    assert report["violations"] == [], \
        f"seed {seed}: " + "; ".join(report["violations"])
    # every request is terminally accounted — the sum closes the books
    n = report["completed"] + report["rejections"]\
        + report["deadline_misses"]
    assert n == plan.n_requests
    return report


@pytest.mark.parametrize("seed", COLO_SEEDS)
def test_chaos_schedule_colocated(colo_engine, model, seed):
    _run_seed(colo_engine, model, seed)


@pytest.mark.parametrize("seed", WA_SEEDS)
def test_chaos_schedule_wa(wa_engine, model, seed):
    _run_seed(wa_engine, model, seed)


def test_chaos_is_deterministic(colo_engine, model):
    """Same seed → same injected fault sequence AND same outcomes: the
    whole point of a seeded harness is that a red run replays exactly."""
    a = _run_seed(colo_engine, model, 7)
    b = _run_seed(colo_engine, model, 7)
    assert a == b


def test_plan_generation_is_seed_pure():
    assert FaultPlan.generate(3) == FaultPlan.generate(3)
    assert FaultPlan.generate(3) != FaultPlan.generate(4)
    p = FaultPlan.generate(3)
    r1 = p.requests(1000, 4, 16)
    r2 = p.requests(1000, 4, 16)
    assert [(r.rid, r.prompt.tolist(), r.max_new_tokens, r.arrival_step,
             r.priority, r.ttft_deadline_ms) for r in r1]\
        == [(r.rid, r.prompt.tolist(), r.max_new_tokens, r.arrival_step,
             r.priority, r.ttft_deadline_ms) for r in r2]


def test_injector_stream_is_seed_pure():
    plan = FaultPlan.generate(5)
    seq = []
    for _ in range(2):
        inj = FaultInjector(plan)
        draws = []
        for i in range(200):
            try:
                inj.on_dispatch(f"serve_x_{i}")
                draws.append(0)
            except Exception:
                draws.append(1)
        seq.append((draws, inj.counters()))
    assert seq[0] == seq[1]


def test_pressure_wave_always_lifts():
    """duty < 1 ⇒ within every period there are steps with zero slots
    withheld — pressure can never livelock admission."""
    for seed in range(10):
        plan = FaultPlan.generate(seed)
        inj = FaultInjector(plan)
        period = max(plan.pressure_period, 1)
        assert any(inj.slots_held(s) == 0 for s in range(2 * period))
