"""Shared fixtures. NOTE: no XLA device-count flags here — tests must see the
host's single device (multi-device behaviour is tested via subprocesses that
set the flag themselves; see test_distributed.py)."""
import jax
import numpy as np
import pytest



@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_batch(cfg, B=2, S=32, key=0):
    k = jax.random.key(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.key(key + 1), (B, cfg.encoder.n_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.key(key + 2), (B, cfg.n_vision_tokens, cfg.d_model))
    return batch
