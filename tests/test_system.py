"""End-to-end behaviour tests for the paper's system."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ASSIGNED, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.models import NULL_CTX, build_model


def test_train_driver_checkpoint_resume(tmp_path):
    """Train a tiny model, checkpoint, 'crash', resume — the restarted job
    continues from the saved step (fault-tolerance loop)."""
    from repro.launch.train import train
    from repro.checkpoint.checkpointer import latest_step
    ck = str(tmp_path / "ckpt")
    train("qwen2-0.5b", steps=12, batch=4, seq=64, reduced=True,
          ckpt_dir=ck, ckpt_every=6, log_every=6)
    assert latest_step(ck) == 12
    # resume: as if the job restarted; must pick up at step 12, not 0
    _, opt, _ = train("qwen2-0.5b", steps=16, batch=4, seq=64, reduced=True,
                      ckpt_dir=ck, ckpt_every=100, log_every=4)
    assert int(opt.step) == 16


def test_training_reduces_loss():
    from repro.launch.train import train
    _, _, losses = train("internlm2-1.8b", steps=60, batch=8, seq=64,
                         reduced=True, log_every=10)
    first, last = losses[0][1], losses[-1][1]
    assert last < first, (first, last)


def test_serve_driver_end_to_end():
    from repro.launch.serve import serve
    stats = serve("qwen2-0.5b", n_requests=4, batch_slots=2, prompt_len=8,
                  max_new=4)
    assert stats["completed"] == 4
    assert stats["throughput_tok_s"] > 0


def test_greedy_decode_is_deterministic():
    cfg = ASSIGNED["internlm2-1.8b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)

    def gen():
        caches, logits = api.prefill(params, {"tokens": toks}, NULL_CTX)
        out = []
        cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        for _ in range(5):
            out.append(np.asarray(cur).copy())
            caches, logits = api.decode(params, caches, cur, NULL_CTX)
            cur = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        return np.stack(out)

    np.testing.assert_array_equal(gen(), gen())


def test_shape_applicability_policy():
    """long_500k runs ONLY for sub-quadratic archs; everything else is a
    documented skip (DESIGN.md §6)."""
    runnable = {a for a in ASSIGNED
                if applicable(ASSIGNED[a], SHAPES["long_500k"])[0]}
    assert runnable == {"mamba2-1.3b", "recurrentgemma-9b"}
    for a in ASSIGNED:
        ok, why = applicable(ASSIGNED[a], SHAPES["long_500k"])
        assert ok or "quadratic" in why


def test_wa_plan_policy_matches_paper_fig9():
    """WA separation: inapplicable for attention-free archs; profitable for
    the high-pressure 70B regime (paper Fig 9)."""
    from jax.sharding import Mesh
    from repro.core.wa import wa_plan
    devs = np.array([jax.devices()[0]] * 4).reshape(4, 1)
    mesh = Mesh(devs, ("data", "model"))
    assert not wa_plan(ASSIGNED["mamba2-1.3b"], SHAPES["decode_32k"],
                       mesh).separate
    big = wa_plan(get_config("llama2-70b"), SHAPES["decode_32k"], mesh)
    assert big.separate
