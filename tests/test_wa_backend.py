"""WA-disaggregated serving-backend tests (DESIGN.md §3/§7).

Covers the invariants the pluggable-backend ISSUE demands:
- the WA backend serves a full staggered-arrival workload with token
  streams BYTE-IDENTICAL to the colocated backend — dense and int8-KV,
  per-step (T=1) and macro-step (T=8), chunked and monolithic admission,
- ragged TRUE prompt lengths (incl. longer than the static width) admit
  through the WA chunk program and match the colocated chunk lane,
- ``compiles == 1`` for EVERY WA step program (decode block per bucket,
  prefill chunk, admission) across a staggered serve AND across engine
  reuse — the §4.3 pinned-pool invariant extends to the routed programs,
- the scheduler is backend-agnostic: only ``serve_wa_*`` programs compile
  under the WA backend (no colocated program sneaks in),
- ``stats()["wa"]`` reports the measured W↔A routing bytes
  (``core/wa.py::routing_bytes`` — the "only embeddings move" number),
- backend validation: drain mode, attention-free families and unknown
  backend names are rejected; the retired ``raw_decode`` hook is gone.

Fixtures run in float32 for the same reason as test_chunked_prefill.py:
token equality must test scheduling/routing semantics, not bf16
accumulation-order luck between the routed python layer loop and the
colocated ``lax.scan``.
"""
import inspect

import jax
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED
from repro.core.wa import WADisaggregated, routing_bytes
from repro.models import NULL_CTX, build_model
from repro.runtime.serving import Request, ServingEngine
from repro.runtime.static_runtime import StaticRuntime

PROMPT_LEN = 8


@pytest.fixture(scope="module")
def dense():
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(dtype="float32")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


@pytest.fixture(scope="module")
def dense_int8():
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(dtype="float32",
                                                   kv_dtype="int8")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


def _requests(cfg, plan, seed=0):
    """plan: (max_new, arrival_step[, prompt_len]) — seeded per call so
    identical plans produce identical prompts across engines."""
    rng = np.random.default_rng(seed)
    out = []
    for i, entry in enumerate(plan):
        new, arr, plen = entry if len(entry) == 3 else entry + (PROMPT_LEN,)
        out.append(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, plen,
                                               dtype=np.int32),
                           max_new_tokens=new, arrival_step=arr))
    return out


STAGGERED = [(9, 0), (13, 0), (5, 2), (9, 6)]


def _serve(api, params, plan, backend, T, chunk, rt=None, slots=2,
           a_shards=1):
    reqs = _requests(api.config, plan)
    eng = ServingEngine(api, NULL_CTX, slots, PROMPT_LEN,
                        runtime=rt or StaticRuntime(), mode="continuous",
                        max_new_cap=32, block_size=T,
                        kv_bucket_chunk=16 if T > 1 else 0,
                        prefill_chunk=chunk, backend=backend,
                        a_shards=a_shards)
    stats = eng.run(params, reqs, max_steps=400)
    return reqs, stats, eng


# ---------------------------------------------------------------------------
# token-exactness: WA backend == colocated backend through a staggered serve
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,chunk", [(1, 0), (1, 3), (8, 0), (8, 3)])
def test_wa_matches_colocated_staggered_dense(dense, T, chunk):
    cfg, api, params = dense
    r_co, s_co, _ = _serve(api, params, STAGGERED, "colocated", T, chunk)
    r_wa, s_wa, _ = _serve(api, params, STAGGERED, "wa", T, chunk)
    assert s_co["completed"] == s_wa["completed"] == len(STAGGERED)
    assert s_wa["backend"] == "wa" and s_co["backend"] == "colocated"
    for a, b in zip(r_co, r_wa):
        assert a.generated == b.generated, (a.rid, T, chunk)


@pytest.mark.parametrize("T,chunk", [(1, 0), (8, 3)])
def test_wa_matches_colocated_staggered_int8(dense_int8, T, chunk):
    """int8-KV: the WA chunk program stores pre-dequant int8 + scales and
    the decode blocks dequantize only the bucket — same bytes, same tokens
    as the colocated engine."""
    cfg, api, params = dense_int8
    r_co, s_co, _ = _serve(api, params, STAGGERED, "colocated", T, chunk)
    r_wa, s_wa, _ = _serve(api, params, STAGGERED, "wa", T, chunk)
    assert s_co["completed"] == s_wa["completed"] == len(STAGGERED)
    for a, b in zip(r_co, r_wa):
        assert a.generated == b.generated, (a.rid, T, chunk)


def test_wa_ragged_true_lengths_match_colocated(dense):
    """Length-true cursors are A-side state: ragged prompts (3/5/8/11, the
    11 > static width admissible only through the chunk walk) produce the
    colocated chunk lane's exact streams through the WA chunk program."""
    cfg, api, params = dense
    plan = [(6, 0, 5), (6, 0, 8), (6, 2, 11), (6, 4, 3)]
    r_co, s_co, _ = _serve(api, params, plan, "colocated", 4, 4)
    r_wa, s_wa, _ = _serve(api, params, plan, "wa", 4, 4)
    assert s_co["completed"] == s_wa["completed"] == len(plan)
    assert s_wa["prefill_chunks"] == s_co["prefill_chunks"] \
        == sum(-(-p // 4) for _, _, p in plan)
    for a, b in zip(r_co, r_wa):
        assert a.generated == b.generated, a.rid


# ---------------------------------------------------------------------------
# zero retracing: compiles == 1 for every WA step program (§4.3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("a_shards", [1, 2])
def test_wa_programs_compile_once_across_staggered_serve(dense, a_shards):
    """Split-KV decode (a_shards > 1) bakes the shard count into the SAME
    routed programs — the strict program-name set and the compiles == 1
    invariant are width-invariant."""
    cfg, api, params = dense
    rt = StaticRuntime()
    plan = [(4, 0, 5), (4, 0, 8), (4, 1, 11), (4, 3, 2), (4, 5, 7)]
    reqs, stats, eng = _serve(api, params, plan, "wa", 4, 4, rt=rt,
                              a_shards=a_shards)
    assert stats["completed"] == len(plan)
    rs = stats["runtime"]
    # only routed programs — the scheduler/executor split means switching
    # backend swaps EVERY program without touching the boundary loop
    assert set(rs) == {"serve_wa_prefill_chunk", "serve_wa_decode_block_s16",
                       "serve_wa_decode_block_s32",
                       "serve_wa_decode_block_s40"}
    for name, rec in rs.items():
        assert rec["compiles"] == 1, (name, rec)   # zero retracing
    assert rs["serve_wa_prefill_chunk"]["calls"] == \
        sum(-(-p // 4) for _, _, p in plan)
    # engine reuse + a different shard-resident length mix: a second run
    # (cursors crossing shard boundaries the first never reached)
    # recompiles nothing
    plan2 = [(24, 0, 5), (13, 0, 8), (4, 1, 11), (4, 3, 2), (4, 5, 7)]
    stats2 = eng.run(params, _requests(cfg, plan2), max_steps=400)
    assert stats2["completed"] == len(plan2)
    assert all(rec["compiles"] == 1 for rec in stats2["runtime"].values())


def test_wa_monolithic_admission_is_one_program(dense):
    """Monolithic WA admission is the degenerate full-width chunk: ONE
    serve_wa_admit program (KV lands directly in the slot on the A side —
    no separate write-slot copy) reused across every admission."""
    cfg, api, params = dense
    rt = StaticRuntime()
    reqs, stats, _ = _serve(api, params, [(4, 0), (4, 0), (4, 1), (4, 3)],
                            "wa", 1, 0, rt=rt)
    assert stats["completed"] == 4
    rs = stats["runtime"]
    assert set(rs) == {"serve_wa_admit", "serve_wa_decode"}
    assert rs["serve_wa_admit"]["compiles"] == 1
    assert rs["serve_wa_admit"]["calls"] == 4


# ---------------------------------------------------------------------------
# routing-bytes stats: "only embeddings move" as a measured number
# ---------------------------------------------------------------------------

def test_wa_stats_report_routing_bytes(dense):
    cfg, api, params = dense
    reqs, stats, _ = _serve(api, params, [(6, 0), (6, 1)], "wa", 4, 3)
    wa = stats["wa"]
    # f32 activations: 4 bytes/el, 2 hops × L × d_model per routed token row
    assert wa["routing_bytes_per_token"] == routing_bytes(cfg, 1, 4) \
        == 2 * cfg.n_layers * cfg.d_model * 4
    assert wa["routing_total_bytes"] > 0
    assert wa["routing_bytes_per_decode_token"] >= wa["routing_bytes_per_token"]
    # colocated runs carry no wa section
    _, s_co, _ = _serve(api, params, [(4, 0)], "colocated", 1, 0)
    assert "wa" not in s_co


# ---------------------------------------------------------------------------
# validation + the retired raw_decode hook
# ---------------------------------------------------------------------------

def test_wa_backend_rejects_drain_and_attention_free():
    ssm = build_model(ASSIGNED["mamba2-1.3b"].reduced())
    with pytest.raises(ValueError, match="WA-disaggregated"):
        ServingEngine(ssm, NULL_CTX, 2, PROMPT_LEN, backend="wa")
    dense_api = build_model(ASSIGNED["qwen2-0.5b"].reduced())
    with pytest.raises(ValueError, match="drain"):
        ServingEngine(dense_api, NULL_CTX, 2, PROMPT_LEN, mode="drain",
                      backend="wa")
    with pytest.raises(ValueError, match="unknown backend"):
        ServingEngine(dense_api, NULL_CTX, 2, PROMPT_LEN, backend="nope")


def test_wa_auto_mode_resolves_to_continuous():
    api = build_model(ASSIGNED["qwen2-0.5b"].reduced())
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="auto",
                        backend="wa")
    assert eng.mode == "continuous"


def test_raw_decode_hook_is_retired():
    """The WA path is a first-class backend now; the per-step eager escape
    hatch must be gone from the engine's surface."""
    assert "raw_decode" not in inspect.signature(
        ServingEngine.__init__).parameters


def test_wa_aot_entry_points_require_sharding_routing():
    """decode_block / prefill_chunk trace the routing into ONE program —
    the eager device_put submesh hops cannot be staged and must be refused
    up front, not die inside XLA."""
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(dtype="float32")
    # a device_put-mode instance without materializing submeshes: the guard
    # is pure python and must fire before any tracing happens
    wa = WADisaggregated.__new__(WADisaggregated)
    wa.cfg, wa.routing = cfg, "device_put"
    with pytest.raises(ValueError, match="sharding"):
        wa._require_aot("decode_block")
