"""flash_attention (train/prefill path) vs naive reference + gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (band_pairs, flash_attention,
                                    flash_attention_padded)


def naive(q, k, v, causal=True, window=0, kv_limit=0):
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,btkh->bkgqt", qg,
                   k.astype(jnp.float32)) / np.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    m = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        m &= kpos <= qpos
    if window:
        m &= kpos > qpos - window
    if kv_limit:
        m &= kpos < kv_limit
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, hd)


def mk(B=2, S=64, Hq=4, Hkv=2, hd=16, Sk=None):
    Sk = Sk or S
    q = jax.random.normal(jax.random.key(1), (B, S, Hq, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (B, Sk, Hkv, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (B, Sk, Hkv, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal,window,qc", [
    (True, 0, 16), (True, 0, 32), (False, 0, 16), (True, 24, 16),
    (True, 8, 8),
])
def test_flash_matches_naive(causal, window, qc):
    q, k, v = mk()
    got = flash_attention(q, k, v, causal, window, qc, qc)
    want = naive(q, k, v, causal, window)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_flash_cross_attention_padded():
    q, k, v = mk(S=48, Sk=50)            # non-divisible KV length
    got = flash_attention_padded(q, k, v, causal=False, q_chunk=16,
                                 kv_chunk=16)
    want = naive(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_flash_gradients_match_naive():
    q, k, v = mk(B=1, S=32, Hq=4, Hkv=2, hd=8)

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, 0, 8, 8) ** 2)

    def loss_naive(q, k, v):
        return jnp.sum(naive(q, k, v, True, 0) ** 2)

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_band_pairs_causal_coverage():
    """Every (q,kv) chunk pair with any unmasked entry appears exactly once,
    and no fully-masked pair appears (exact causal FLOPs — no 2× waste)."""
    pairs = band_pairs(4, 4, 16, 16, causal=True, window=0)
    assert pairs == [(i, j) for i in range(4) for j in range(i + 1)]
    wpairs = band_pairs(4, 4, 16, 16, causal=True, window=16)
    for i, j in wpairs:
        assert j in (i - 1, i)           # window 16 spans ≤ 2 blocks


def test_flash_window_equals_full_when_window_ge_seq():
    q, k, v = mk(S=32)
    a = flash_attention(q, k, v, True, 64, 8, 8)
    b = flash_attention(q, k, v, True, 0, 8, 8)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
