"""Multi-device behaviour via SUBPROCESSES that set the host-device-count
flag themselves (the main test process must keep seeing 1 device)."""
import os
import subprocess
import sys
import textwrap


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    prelude = ("import os\n"
               "os.environ['XLA_FLAGS'] = "
               f"'--xla_force_host_platform_device_count={devices}'\n")
    out = subprocess.run([sys.executable, "-c", prelude + textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_main_process_sees_one_device():
    import jax
    assert len(jax.devices()) == 1


def test_executors_differ_operator_centric_pays_in_bytes():
    """The paper's Challenge 2, as it manifests on TPU (EXPERIMENTS §Perf
    cell 1): operator-boundary materialization costs strictly more HLO
    bytes/flops (redundant replicated execution), while the sub-operator
    schedule keeps work on the owning shard. Measured from compiled HLO."""
    out = run_py("""
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.configs.registry import get_config
    from repro.configs.shapes import ShapeConfig
    from repro.core.compat import cost_analysis
    from repro.core.execution import make_step

    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    cfg = get_config("internlm2-1.8b").reduced()
    shape = ShapeConfig("t", seq_len=64, global_batch=4, mode="prefill")
    res = {}
    for ex in ("operator_centric", "sub_operator"):
        b = make_step(cfg, shape, mesh, executor=ex)
        comp = b.lower().compile()
        res[ex] = cost_analysis(comp).get("bytes accessed", 0.0)
    print("RESULT", res["operator_centric"], res["sub_operator"])
    assert res["operator_centric"] >= res["sub_operator"], res
    """)
    assert "RESULT" in out


def test_sharded_decode_matches_single_device():
    """GSPMD-sharded decode (2×4 mesh) is numerically identical to the
    unsharded execution."""
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.configs.registry import get_config
    from repro.models import NULL_CTX, build_model
    from repro.models.sharding import ShardingCtx, sub_operator

    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    B, S = 4, 12
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                              cfg.vocab_size)
    c0, _ = api.prefill(params, {"tokens": toks[:, :S]}, NULL_CTX)
    _, want = api.decode(params, c0, toks[:, S], NULL_CTX)

    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    ctx = ShardingCtx(mesh, sub_operator())
    with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") \
            else mesh:
        c1, _ = jax.jit(lambda p, b: api.prefill(p, b, ctx))(
            params, {"tokens": toks[:, :S]})
        _, got = jax.jit(lambda p, c, t: api.decode(p, c, t, ctx))(
            params, c1, toks[:, S])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    print("OK")
    """)


def test_hierarchical_psum_correct_and_cheaper_cross_pod():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core.collectives import hierarchical_psum
    from repro.core.compat import shard_map
    from repro.launch.hlo_analysis import parse_collectives

    mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2),
                ("pod", "data", "model"))

    def flat(x):
        return jax.lax.psum(x, ("data", "pod"))

    def hier(x):
        return hierarchical_psum(x, "data", "pod", scatter_dim=0)

    x = jnp.arange(16, dtype=jnp.float32).reshape(8, 2)
    outs = {}
    byts = {}
    for name, fn in (("flat", flat), ("hier", hier)):
        # out stays replicated-per-shard: use full specs
        f = jax.jit(shard_map(fn, mesh=mesh,
                              in_specs=P(("pod", "data"), None),
                              out_specs=P(),
                              check_vma=False))
        lowered = f.lower(x)
        comp = lowered.compile()
        outs[name] = np.asarray(comp(x))
        coll = parse_collectives(comp.as_text(), mesh.devices.shape,
                                 mesh.axis_names)
        byts[name] = sum(o.operand_bytes for o in coll.ops
                         if "pod" in o.axes)
    np.testing.assert_allclose(outs["flat"], outs["hier"], rtol=1e-6)
    assert byts["hier"] <= byts["flat"], byts
    print("cross-pod bytes:", byts)
    """)


def test_wa_slotted_decode_matches_colocated():
    """Slot admission in the weight/attention-decoupled path: WA
    decode_step_slotted with STAGGERED per-slot cursors is numerically
    identical to the colocated slotted decode (DESIGN.md §7)."""
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.configs.registry import get_config
    from repro.core.wa import WADisaggregated, WAPlan
    from repro.kv.cache import write_slot_kv
    from repro.models import NULL_CTX, build_model

    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    # joint prefill, then ADMIT a fresh batch-1 prefill into slot 1 so the
    # two slots sit at different depths (slot0 at S, slot1 at 6)
    caches, logits = api.prefill(params, {"tokens": toks}, NULL_CTX)
    c1, l1 = api.prefill(params, {"tokens": toks[1:, :6]}, NULL_CTX)
    caches = write_slot_kv(caches, c1, jnp.asarray(1, jnp.int32))
    cur = jnp.stack([jnp.argmax(logits[0, -1]),
                     jnp.argmax(l1[0, -1])]).astype(jnp.int32)
    positions = jnp.array([S, 6], jnp.int32)
    active = jnp.array([True, True])
    _, want = api.decode_slotted(params, caches, cur, positions, active,
                                 NULL_CTX)

    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    wa = WADisaggregated(cfg, mesh, WAPlan(True, 2, 2, "test"))
    _, got = wa.decode_step_slotted(params, caches, cur, positions, active)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    print("OK")
    """)


def test_wa_backend_serves_on_mesh_matches_colocated():
    """The WA serving backend on a REAL (4,2) mesh: the W/A split becomes
    two sharding regimes over the serving mesh with the routing compiled
    into each program (DESIGN.md §3). A staggered chunked-admission serve
    must produce the colocated backend's exact token streams with
    compiles == 1 for every routed program."""
    run_py("""
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.configs.registry import get_config
    from repro.models import build_model
    from repro.models.sharding import ShardingCtx, sub_operator
    from repro.runtime.serving import Request, ServingEngine

    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    ctx = ShardingCtx(mesh, sub_operator())

    def reqs():
        rng = np.random.default_rng(0)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, 8,
                                            dtype=np.int32),
                        max_new_tokens=n, arrival_step=a)
                for i, (n, a) in enumerate([(6, 0), (10, 0), (6, 2)])]

    kw = dict(mode="continuous", max_new_cap=24, block_size=4,
              kv_bucket_chunk=16, prefill_chunk=4)
    r_co, r_wa = reqs(), reqs()
    ServingEngine(api, ctx, 2, 8, **kw).run(params, r_co, max_steps=300)
    st = ServingEngine(api, ctx, 2, 8, backend="wa", **kw).run(
        params, r_wa, max_steps=300)
    assert st["completed"] == 3
    for name, rec in st["runtime"].items():
        assert rec["compiles"] == 1, (name, rec)
        assert name.startswith("serve_wa_"), name
    assert st["wa"]["routing_total_bytes"] > 0
    for a, b in zip(r_co, r_wa):
        assert a.generated == b.generated, a.rid
    print("OK")
    """)


def test_split_kv_serve_on_8_device_mesh_matches_sequential():
    """Split-KV flash decode on a REAL (1,8) mesh (``make test-long``): the
    WA backend with a_shards=4 spreads each slot's four KV sequence shards
    over the 8-wide A-domain model axis (``seq_sharded_kv``'s "kv_shard"
    rule), computes the partial flash statistics shard-locally, and merges
    the (o, m, l) triples across devices. The token streams must equal the
    colocated sequential walk exactly, with compiles == 1 for every routed
    program — distribution is invisible to both the scheduler and the
    emitted tokens."""
    run_py("""
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.configs.registry import get_config
    from repro.models import build_model, NULL_CTX
    from repro.models.sharding import ShardingCtx, sub_operator
    from repro.runtime.serving import Request, ServingEngine

    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    mesh = Mesh(np.array(jax.devices()).reshape(1, 8), ("data", "model"))
    ctx = ShardingCtx(mesh, sub_operator())

    def reqs():
        rng = np.random.default_rng(0)
        # ragged true lengths: one ends inside shard 0 (extent 32 → shard
        # blocks of 8), one crosses a shard boundary mid-decode
        plan = [(6, 0, 5), (10, 0, 8), (6, 2, 7)]
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, p,
                                            dtype=np.int32),
                        max_new_tokens=n, arrival_step=a)
                for i, (n, a, p) in enumerate(plan)]

    # extent 8 + 24 = 32 cuts into 4 shard blocks of 8
    kw = dict(mode="continuous", max_new_cap=24, block_size=4,
              kv_bucket_chunk=16, prefill_chunk=4)
    r_seq, r_spl = reqs(), reqs()
    # sequential baseline needs no mesh: colocated math on NULL_CTX is the
    # token-exact reference the distributed split walk must reproduce
    ServingEngine(api, NULL_CTX, 2, 8, **kw).run(params, r_seq, max_steps=300)
    st = ServingEngine(api, ctx, 2, 8, backend="wa", a_shards=4, **kw).run(
        params, r_spl, max_steps=300)
    assert st["completed"] == 3
    assert st["a_shards"] == 4
    for name, rec in st["runtime"].items():
        assert rec["compiles"] == 1, (name, rec)
        assert name.startswith("serve_wa_"), name
    for a, b in zip(r_seq, r_spl):
        assert a.generated == b.generated, a.rid
    print("OK")
    """)


def test_pp_decode_lowering_small_mesh():
    """Pipelined decode compiles + runs on a (2,2,2) mesh and every stage's
    KV advances by one position per call."""
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.configs.registry import get_config
    from repro.configs.shapes import ShapeConfig
    from repro.core.pipeline import make_pp_step, stage_params
    from repro.models import build_model

    mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2),
                ("pod", "data", "model"))
    cfg = get_config("internlm2-1.8b").reduced().replace(n_layers=4)
    shape = ShapeConfig("t", seq_len=32, global_batch=4, mode="decode")
    bundle = make_pp_step(cfg, shape, mesh)
    compiled = bundle.lower().compile()
    # run it with real (tiny) values, placed per the compiled shardings
    api = build_model(cfg.replace(kv_dtype="int8"))
    params = jax.device_put(stage_params(api.init(jax.random.key(0)), 2),
                            bundle.in_shardings[0])
    caches = jax.tree.map(lambda s, sh: jax.device_put(
        jnp.zeros(s.shape, s.dtype), sh),
        bundle.abstract_args[1], bundle.in_shardings[1])
    toks = jax.device_put(jnp.ones((2, 4), jnp.int32),
                          bundle.in_shardings[2])
    with mesh:
        caches, logits = compiled(params, caches, toks)
        assert np.asarray(caches["lengths"]).tolist() == [1, 1]
        caches, logits = compiled(params, caches, toks)
        assert np.asarray(caches["lengths"]).tolist() == [2, 2]
    assert logits.shape == (2, 4, 1, cfg.vocab_size)
    print("OK")
    """, devices=8, timeout=420)
