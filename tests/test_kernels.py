"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import flash_decode_ref
from repro.kernels.fused_ffn.ops import fused_ffn
from repro.kernels.fused_ffn.ref import fused_ffn_ref
from repro.kernels.gemv.gemv import gemv_int8_pallas
from repro.kernels.gemv.ref import gemv_int8_ref
from repro.quant.int8 import quantize_int8, quantize_kv


@pytest.mark.parametrize("B,K,N,bn,bk", [
    (1, 256, 256, 128, 128),
    (4, 1024, 512, 256, 512),
    (8, 512, 1024, 256, 256),
    (16, 2048, 256, 256, 1024),
])
def test_gemv_int8_sweep(B, K, N, bn, bk):
    x = jax.random.normal(jax.random.key(1), (B, K), jnp.float32)
    w = jax.random.normal(jax.random.key(2), (K, N), jnp.float32) * 0.05
    wq = quantize_int8(w, axis=0)
    xq = quantize_int8(x, axis=-1)
    got = gemv_int8_pallas(xq.values, xq.scale, wq.values,
                           wq.scale.reshape(1, -1), block_n=bn, block_k=bk,
                           interpret=True)
    want = gemv_int8_ref(xq.values, xq.scale, wq.values, wq.scale.reshape(1, -1))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,n_kv,S,hd,bs", [
    (1, 4, 4, 128, 32, 64),     # MHA
    (2, 8, 2, 256, 64, 64),     # GQA
    (3, 16, 1, 192, 32, 64),    # MQA, non-pow2 batch
])
def test_flash_decode_sweep(B, Hq, n_kv, S, hd, bs, dtype):
    q = jax.random.normal(jax.random.key(1), (B, Hq, hd), dtype)
    k = jax.random.normal(jax.random.key(2), (B, n_kv, S, hd), dtype)
    v = jax.random.normal(jax.random.key(3), (B, n_kv, S, hd), dtype)
    lens = jnp.arange(B) * (S // (B + 1)) + S // 2
    mask = jnp.arange(S)[None, :] < lens[:, None]
    got = flash_decode(q, k, v, mask, interpret=True, block_s=bs)
    want = flash_decode_ref(q, k, v, mask)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_decode_kv_limit_matches_full_walk():
    """A kv_limit covering every masked position is a pure fast path — the
    tile early-out must not change numerics; a CUTTING limit equals the ref
    with the limit folded into the mask."""
    B, Hq, n_kv, S, hd = 2, 8, 4, 256, 32
    q = jax.random.normal(jax.random.key(1), (B, Hq, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (B, n_kv, S, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (B, n_kv, S, hd), jnp.float32)
    lens = jnp.array([70, 100])
    mask = jnp.arange(S)[None, :] < lens[:, None]
    want = flash_decode_ref(q, k, v, mask)
    got = flash_decode(q, k, v, mask, interpret=True, block_s=64,
                       kv_limit=jnp.asarray(100))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    got_cut = flash_decode(q, k, v, mask, interpret=True, block_s=64,
                           kv_limit=jnp.asarray(64))
    want_cut = flash_decode_ref(q, k, v, mask, kv_limit=64)
    np.testing.assert_allclose(np.asarray(got_cut), np.asarray(want_cut),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_kv_limit_is_traced_not_static():
    """Advancing cursors must NOT retrace: the same jitted kernel serves
    every limit value (limit is an operand, not a static arg)."""
    B, Hq, n_kv, S, hd = 1, 4, 4, 128, 32
    q = jax.random.normal(jax.random.key(1), (B, Hq, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (B, n_kv, S, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (B, n_kv, S, hd), jnp.float32)
    traces = []

    def fn(q, k, v, mask, lim):
        traces.append(1)
        return flash_decode(q, k, v, mask, interpret=True, block_s=32,
                            kv_limit=lim)

    jfn = jax.jit(fn)
    for lim in (32, 64, 96):
        mask = jnp.arange(S)[None, :] < lim
        got = jfn(q, k, v, mask, jnp.asarray(lim))
        want = flash_decode_ref(q, k, v, mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
    assert len(traces) == 1, "kv_limit change retraced the kernel"


def test_flash_decode_int8_kv():
    B, Hq, n_kv, S, hd = 2, 8, 2, 256, 64
    q = jax.random.normal(jax.random.key(1), (B, Hq, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (B, n_kv, S, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (B, n_kv, S, hd), jnp.float32)
    mask = jnp.ones((B, S), bool)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    got = flash_decode(q, kq, vq, mask, ks, vs, interpret=True, block_s=64)
    want = flash_decode_ref(q, kq.astype(jnp.float32) * ks,
                            vq.astype(jnp.float32) * vs, mask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("act", ["silu", "gelu"])
@pytest.mark.parametrize("B,D,F,bf", [
    (2, 64, 256, 128),
    (4, 128, 512, 512),
    (8, 256, 384, 128),
])
def test_fused_ffn_sweep(B, D, F, bf, act):
    x = jax.random.normal(jax.random.key(4), (B, D), jnp.float32)
    wg = jax.random.normal(jax.random.key(5), (D, F), jnp.float32) * 0.1
    wu = jax.random.normal(jax.random.key(6), (D, F), jnp.float32) * 0.1
    wd = jax.random.normal(jax.random.key(7), (F, D), jnp.float32) * 0.1
    got = fused_ffn(x, wg, wu, wd, act=act, interpret=True, block_f=bf,
                    out_dtype=jnp.float32)
    want = fused_ffn_ref(x, wg, wu, wd, act=act)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
