"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle.

The partial-softmax combine tests run twice when ``hypothesis`` is
installed (CI — requirements-dev.txt): once property-based over generated
shard statistics, once over a fixed seeded sweep. Without hypothesis the
seeded sweep alone keeps the coverage (no skips)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode.combine import (NEG_INF, combine_partial_stats,
                                                merge_partial_stats)
from repro.kernels.flash_decode.ops import flash_decode, flash_decode_partial
from repro.kernels.flash_decode.ref import (flash_decode_ref,
                                            flash_decode_ref_partial)
from repro.kernels.fused_ffn.ops import fused_ffn
from repro.kernels.fused_ffn.ref import fused_ffn_ref
from repro.kernels.gemv.gemv import gemv_int8_pallas
from repro.kernels.gemv.ref import gemv_int8_ref
from repro.quant.int8 import quantize_int8, quantize_kv

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # CI installs it; local runs may not
    HAVE_HYPOTHESIS = False


@pytest.mark.parametrize("B,K,N,bn,bk", [
    (1, 256, 256, 128, 128),
    (4, 1024, 512, 256, 512),
    (8, 512, 1024, 256, 256),
    (16, 2048, 256, 256, 1024),
])
def test_gemv_int8_sweep(B, K, N, bn, bk):
    x = jax.random.normal(jax.random.key(1), (B, K), jnp.float32)
    w = jax.random.normal(jax.random.key(2), (K, N), jnp.float32) * 0.05
    wq = quantize_int8(w, axis=0)
    xq = quantize_int8(x, axis=-1)
    got = gemv_int8_pallas(xq.values, xq.scale, wq.values,
                           wq.scale.reshape(1, -1), block_n=bn, block_k=bk,
                           interpret=True)
    want = gemv_int8_ref(xq.values, xq.scale, wq.values, wq.scale.reshape(1, -1))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,n_kv,S,hd,bs", [
    (1, 4, 4, 128, 32, 64),     # MHA
    (2, 8, 2, 256, 64, 64),     # GQA
    (3, 16, 1, 192, 32, 64),    # MQA, non-pow2 batch
])
def test_flash_decode_sweep(B, Hq, n_kv, S, hd, bs, dtype):
    q = jax.random.normal(jax.random.key(1), (B, Hq, hd), dtype)
    k = jax.random.normal(jax.random.key(2), (B, n_kv, S, hd), dtype)
    v = jax.random.normal(jax.random.key(3), (B, n_kv, S, hd), dtype)
    lens = jnp.arange(B) * (S // (B + 1)) + S // 2
    mask = jnp.arange(S)[None, :] < lens[:, None]
    got = flash_decode(q, k, v, mask, interpret=True, block_s=bs)
    want = flash_decode_ref(q, k, v, mask)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_decode_kv_limit_matches_full_walk():
    """A kv_limit covering every masked position is a pure fast path — the
    tile early-out must not change numerics; a CUTTING limit equals the ref
    with the limit folded into the mask."""
    B, Hq, n_kv, S, hd = 2, 8, 4, 256, 32
    q = jax.random.normal(jax.random.key(1), (B, Hq, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (B, n_kv, S, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (B, n_kv, S, hd), jnp.float32)
    lens = jnp.array([70, 100])
    mask = jnp.arange(S)[None, :] < lens[:, None]
    want = flash_decode_ref(q, k, v, mask)
    got = flash_decode(q, k, v, mask, interpret=True, block_s=64,
                       kv_limit=jnp.asarray(100))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    got_cut = flash_decode(q, k, v, mask, interpret=True, block_s=64,
                           kv_limit=jnp.asarray(64))
    want_cut = flash_decode_ref(q, k, v, mask, kv_limit=64)
    np.testing.assert_allclose(np.asarray(got_cut), np.asarray(want_cut),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_kv_limit_is_traced_not_static():
    """Advancing cursors must NOT retrace: the same jitted kernel serves
    every limit value (limit is an operand, not a static arg)."""
    B, Hq, n_kv, S, hd = 1, 4, 4, 128, 32
    q = jax.random.normal(jax.random.key(1), (B, Hq, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (B, n_kv, S, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (B, n_kv, S, hd), jnp.float32)
    traces = []

    def fn(q, k, v, mask, lim):
        traces.append(1)
        return flash_decode(q, k, v, mask, interpret=True, block_s=32,
                            kv_limit=lim)

    jfn = jax.jit(fn)
    for lim in (32, 64, 96):
        mask = jnp.arange(S)[None, :] < lim
        got = jfn(q, k, v, mask, jnp.asarray(lim))
        want = flash_decode_ref(q, k, v, mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
    assert len(traces) == 1, "kv_limit change retraced the kernel"


def test_flash_decode_int8_kv():
    B, Hq, n_kv, S, hd = 2, 8, 2, 256, 64
    q = jax.random.normal(jax.random.key(1), (B, Hq, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (B, n_kv, S, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (B, n_kv, S, hd), jnp.float32)
    mask = jnp.ones((B, S), bool)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    got = flash_decode(q, kq, vq, mask, ks, vs, interpret=True, block_s=64)
    want = flash_decode_ref(q, kq.astype(jnp.float32) * ks,
                            vq.astype(jnp.float32) * vs, mask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# split-KV partial statistics: Pallas partial mode vs the ref oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_partial_matches_ref(dtype):
    B, Hq, n_kv, S, hd = 2, 8, 2, 128, 32
    q = jax.random.normal(jax.random.key(1), (B, Hq, hd), dtype)
    k = jax.random.normal(jax.random.key(2), (B, n_kv, S, hd), dtype)
    v = jax.random.normal(jax.random.key(3), (B, n_kv, S, hd), dtype)
    mask = jnp.arange(S)[None, :] < jnp.array([[70], [128]])
    got = flash_decode_partial(q, k, v, mask, interpret=True, block_s=32,
                               kv_limit=jnp.asarray(128))
    want = flash_decode_ref_partial(q, k, v, mask, kv_limit=128)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    for g, w in zip(got, want):
        assert g.dtype == jnp.float32                # stats always f32
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=tol, atol=tol)


def test_flash_decode_partial_limit_empty_is_exact_identity():
    """A shard whose kv_limit skips every tile must return the merge
    identity (0, NEG_INF, 0) BIT-exactly on both paths — appending it to a
    combine cannot perturb a single bit (test_combine_* prove the merge
    side; this pins the producer side)."""
    B, Hq, n_kv, S, hd = 2, 4, 2, 64, 16
    q = jax.random.normal(jax.random.key(1), (B, Hq, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (B, n_kv, S, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (B, n_kv, S, hd), jnp.float32)
    mask = jnp.ones((B, S), bool)
    for impl in (dict(interpret=True, block_s=32), dict(use_pallas=False)):
        o, m, l = flash_decode_partial(q, k, v, mask,
                                       kv_limit=jnp.asarray(0), **impl)
        assert np.array_equal(np.asarray(o), np.zeros_like(np.asarray(o)))
        assert np.array_equal(np.asarray(m),
                              np.full((B, Hq), NEG_INF, np.float32))
        assert np.array_equal(np.asarray(l), np.zeros((B, Hq), np.float32))


def test_flash_decode_sharded_partials_combine_to_full_walk():
    """Four shard-local partial passes (shard-local clamped limits, ragged
    true lengths → one shard ends mid-tile, two are wholly empty) merged by
    combine_partial_stats equal the sequential full-extent walk."""
    B, Hq, n_kv, S, hd, n = 2, 8, 4, 256, 32, 4
    Sb = S // n
    q = jax.random.normal(jax.random.key(1), (B, Hq, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (B, n_kv, S, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (B, n_kv, S, hd), jnp.float32)
    lens = jnp.array([70, 100])
    mask = jnp.arange(S)[None, :] < lens[:, None]
    want = flash_decode_ref(q, k, v, mask)
    parts = []
    for s in range(n):
        lim = int(np.clip(int(lens.max()) - s * Sb, 0, Sb))
        parts.append(flash_decode_partial(
            q, k[:, :, s * Sb:(s + 1) * Sb], v[:, :, s * Sb:(s + 1) * Sb],
            mask[:, s * Sb:(s + 1) * Sb], interpret=True, block_s=32,
            kv_limit=jnp.asarray(lim)))
    got = combine_partial_stats(jnp.stack([p[0] for p in parts]),
                                jnp.stack([p[1] for p in parts]),
                                jnp.stack([p[2] for p in parts]), axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# partial-softmax combine: property-based (hypothesis when available) +
# seeded sweep vs a single-pass float64 reference
# ---------------------------------------------------------------------------

def _check_combine(shard_spec, dtype, seed):
    """shard_spec: [(n_keys, score_offset)] — one entry per shard; n_keys
    of 0 models a shard fully masked out by its kv_limit (the exact merge
    identity), extreme offsets model pathological running maxes. The
    combined output must match a single-pass float64 softmax over the
    concatenated live keys, and appending identity shards must not flip a
    single output bit."""
    hd = 8
    rng = np.random.default_rng(seed)
    scores, values = [], []
    for n_keys, off in shard_spec:
        s = (rng.standard_normal(n_keys) + off).astype(np.float32)
        scores.append(np.asarray(jnp.asarray(s, dtype), np.float32))
        values.append(np.asarray(
            jnp.asarray(rng.standard_normal((n_keys, hd)), dtype),
            np.float32))
    os, ms, ls = [], [], []
    for s, val in zip(scores, values):
        if len(s) == 0:
            os.append(np.zeros(hd, np.float32))
            ms.append(np.float32(NEG_INF))
            ls.append(np.float32(0.0))
        else:
            m = s.max()
            p = np.exp(s - m, dtype=np.float32)
            os.append(p @ val)
            ms.append(np.float32(m))
            ls.append(p.sum(dtype=np.float32))
    o = jnp.asarray(np.stack(os), dtype)
    m = jnp.asarray(np.stack(ms), dtype)
    l = jnp.asarray(np.stack(ls), dtype)
    got = np.asarray(combine_partial_stats(o, m, l, axis=0))
    assert np.isfinite(got).all(), got
    live = np.concatenate([s for s in scores if len(s)] or
                          [np.zeros(0, np.float32)])
    if len(live) == 0:
        np.testing.assert_array_equal(got, np.zeros(hd, np.float32))
    else:
        vals = np.concatenate([v for v in values if len(v)])
        p = np.exp(live.astype(np.float64) - live.max())
        want = (p[:, None] * vals).sum(0) / p.sum()
        tol = 1e-5 if dtype == jnp.float32 else 4e-2
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)
    # bit-stability: identity shards (empty via kv_limit) are free to append
    o2 = jnp.concatenate([o, jnp.zeros((2, hd), dtype)])
    m2 = jnp.concatenate([m, jnp.full((2,), NEG_INF, dtype)])
    l2 = jnp.concatenate([l, jnp.zeros((2,), dtype)])
    assert np.array_equal(np.asarray(combine_partial_stats(o2, m2, l2)), got)
    # ...and the merge is associative: left-fold == flat combine (bitwise
    # would over-promise across regrouping; the LSE algebra is exact)
    o12, m12, l12 = merge_partial_stats(o[:1 + len(shard_spec) // 2],
                                        m[:1 + len(shard_spec) // 2],
                                        l[:1 + len(shard_spec) // 2])
    ot = jnp.concatenate([o12[None].astype(dtype),
                          o[1 + len(shard_spec) // 2:]])
    mt = jnp.concatenate([m12[None].astype(dtype),
                          m[1 + len(shard_spec) // 2:]])
    lt = jnp.concatenate([l12[None].astype(dtype),
                          l[1 + len(shard_spec) // 2:]])
    tree = np.asarray(combine_partial_stats(ot, mt, lt))
    tol = 1e-6 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(tree, got, rtol=tol, atol=tol)


# a fixed sweep covering the hypothesis search space's corners: empty
# shards first/last/everywhere, extreme maxes both directions, singletons
_COMBINE_CASES = [
    [(4, 0.0), (4, 0.0)],
    [(0, 0.0), (5, 0.0), (3, 0.0)],
    [(6, 1e4), (6, -1e4)],
    [(1, 300.0), (8, 0.0), (0, 0.0), (2, -300.0)],
    [(0, 0.0), (0, 0.0)],
    [(8, -1e4), (0, 0.0), (1, 1e4)],
    [(2, 50.0), (2, 49.0), (2, 48.0)],
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", range(len(_COMBINE_CASES)))
@pytest.mark.parametrize("seed", [0, 1])
def test_combine_seeded_sweep(case, dtype, seed):
    _check_combine(_COMBINE_CASES[case], dtype, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(spec=st.lists(st.tuples(st.integers(0, 8),
                                   st.floats(-1e4, 1e4, allow_nan=False)),
                         min_size=1, max_size=6),
           seed=st.integers(0, 2**31 - 1),
           dtype_idx=st.integers(0, 1))
    def test_combine_property(spec, seed, dtype_idx):
        _check_combine(spec, (jnp.float32, jnp.bfloat16)[dtype_idx], seed)


@pytest.mark.parametrize("act", ["silu", "gelu"])
@pytest.mark.parametrize("B,D,F,bf", [
    (2, 64, 256, 128),
    (4, 128, 512, 512),
    (8, 256, 384, 128),
])
def test_fused_ffn_sweep(B, D, F, bf, act):
    x = jax.random.normal(jax.random.key(4), (B, D), jnp.float32)
    wg = jax.random.normal(jax.random.key(5), (D, F), jnp.float32) * 0.1
    wu = jax.random.normal(jax.random.key(6), (D, F), jnp.float32) * 0.1
    wd = jax.random.normal(jax.random.key(7), (F, D), jnp.float32) * 0.1
    got = fused_ffn(x, wg, wu, wd, act=act, interpret=True, block_f=bf,
                    out_dtype=jnp.float32)
    want = fused_ffn_ref(x, wg, wu, wd, act=act)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
