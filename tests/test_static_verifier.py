"""Static program verifier (repro.analysis): positive runs over real cells
plus one NEGATIVE test per pass — each deliberately-broken program must
produce an actionable diagnostic naming the program and the operand.

Residency needs a real multi-device mesh, so its tests run in subprocesses
(the main test process must keep seeing 1 device; see conftest.py). Every
other pass is exercised in-process — on a 1-device mesh the W↔A hops are
still tagged, so even the routing cross-check runs for real.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap
import warnings
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from repro.analysis import compile_once, host_sync, kernel_bounds, residency
from repro.analysis import routing_check
from repro.analysis.findings import Report
from repro.analysis.jaxpr_walk import iter_eqns, literal_value
from repro.analysis.programs import (CellSpec, build_cell, ci_matrix,
                                     classify, full_matrix, make_mesh)
from repro.analysis.verify import verify_cell
from repro.runtime.static_runtime import StaticRuntime

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    prelude = ("import os\n"
               "os.environ['XLA_FLAGS'] = "
               f"'--xla_force_host_platform_device_count={devices}'\n")
    out = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# fixtures: real cells, built once
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def nomesh_cell():
    return build_cell(CellSpec(label="colocated-nomesh"), None)


@pytest.fixture(scope="module")
def wa_cell():
    # 1-device mesh: hops are tagged (mesh non-empty) so the routing
    # cross-check runs for real; residency is vacuously satisfiable
    return build_cell(CellSpec(label="wa-1dev", backend="wa"),
                      make_mesh(1, 1))


# ---------------------------------------------------------------------------
# positive: real cells are clean end to end
# ---------------------------------------------------------------------------

def test_nomesh_cell_verifies_clean(nomesh_cell):
    rep = verify_cell(nomesh_cell)
    assert rep.ok, rep.format(verbose=True)
    assert nomesh_cell.records, "cell built no programs"


def test_wa_cell_verifies_clean(wa_cell):
    rep = verify_cell(wa_cell)
    assert rep.ok, rep.format(verbose=True)
    names = {r.name for r in wa_cell.records}
    assert any(n.startswith("serve_wa_decode_block") for n in names)


def test_routing_confirms_analytic_meter(wa_cell):
    """The bytes identity holds exactly — the pass leaves an INFO record
    with the confirmed per-dispatch analytic bytes for each WA program."""
    rep = Report()
    routing_check.check_routing(wa_cell, rep)
    assert rep.ok, rep.format(verbose=True)
    infos = [f for f in rep.findings if f.severity == "info"]
    assert any("confirmed" in f.message for f in infos),\
        rep.format(verbose=True)


def test_matrices_cover_acceptance_grid():
    ci = ci_matrix()
    assert len(ci) == 12
    assert {s.backend for s in ci} == {"colocated", "wa"}
    assert {s.a_shards for s in ci} == {1, 2, 4}
    # tiered-KV cells gate the hot-ring/cold-tier program variants on both
    # backends, including the monolithic (degenerate-chunk) admission lane
    tiered = [s for s in ci if s.hot_window > 0]
    assert {s.label for s in tiered} == {"colocated-int8cold-mono",
                                         "wa-int4cold-a2"}
    assert {s.kv_cold_dtype for s in tiered} == {"int8", "int4"}
    # sub-operator overlap cells gate the pipelined decode programs; their
    # slot count must split into equal micro-batches
    ov = [s for s in ci if s.overlap > 1]
    assert {s.overlap for s in ov} == {2, 4}
    assert all(s.backend == "wa" and s.slots % s.overlap == 0 for s in ov)
    full = full_matrix()
    labels = {s.label for s in full}
    assert {"colocated-dense-a1-mono", "wa-dense-a2",
            "wa-dense-a1-T1", "wa-dense-a1-T1-ov2"} <= labels


def test_classify_kinds():
    assert classify("serve_prefill_chunk") == "chunk"
    assert classify("serve_wa_admit") == "chunk"
    assert classify("serve_decode_block_s16") == "block"
    assert classify("serve_admit") == "admit"
    assert classify("serve_reset") == "reset"
    assert classify("serve_decode") == "decode"


def test_verify_cli_no_mesh_exits_zero():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.verify", "--no-mesh",
         "--preset", "ci", "--cell", "colocated-dense-a1"],
        capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr[-2000:]
    assert "PASS" in out.stdout


# ---------------------------------------------------------------------------
# pass 2 negatives: compile-once
# ---------------------------------------------------------------------------

def test_compile_once_flags_signature_drift():
    rt = StaticRuntime(None)
    rt.compile_step("serve_x", lambda x: x + 1, (jnp.zeros((2,)),))
    rt.compile_step("serve_x", lambda x: x + 1, (jnp.zeros((4,)),))
    rep = Report()
    compile_once.audit_runtime(rt, rep)
    errs = [f for f in rep.errors if f.program == "serve_x"]
    assert errs, rep.format(verbose=True)
    assert "2 distinct operand signatures" in errs[0].message


def test_compile_once_flags_weak_typed_leaf():
    rt = StaticRuntime(None)
    weak = jnp.asarray(1.0)             # bare python scalar → weak f32
    assert weak.weak_type
    rt.compile_step("serve_weak", lambda x: x * 2, (weak,))
    rep = Report()
    compile_once.audit_runtime(rt, rep)
    errs = [f for f in rep.errors if f.program == "serve_weak"]
    assert errs and "weak-typed" in errs[0].message, rep.format(verbose=True)


def test_compile_once_warns_on_non_serve_name():
    rt = StaticRuntime(None)
    rt.compile_step("adhoc_step", lambda x: x, (jnp.zeros((2,)),))
    rep = Report()
    compile_once.audit_runtime(rt, rep)
    assert any(f.program == "adhoc_step" for f in rep.warnings)


# ---------------------------------------------------------------------------
# pass 3 negatives: host-sync
# ---------------------------------------------------------------------------

def _record(rt, name, fn, args, kind=None, roles=None, **kw):
    from repro.analysis.programs import ProgramRecord
    step = rt.compile_step(name, fn, args, **kw)
    return ProgramRecord(name, step, kind or classify(name), roles or {})


def test_host_sync_flags_compiled_callback():
    def cb_fn(x):
        y = jax.pure_callback(
            lambda a: a, jax.ShapeDtypeStruct((2,), jnp.float32), x)
        return y + 1.0

    rt = StaticRuntime(None)
    rec = _record(rt, "serve_cb_decode", cb_fn, (jnp.zeros((2,)),))
    rep = Report()
    host_sync.check_host_sync(SimpleNamespace(records=[rec]), rep)
    errs = [f for f in rep.errors if f.program == "serve_cb_decode"]
    assert any("pure_callback" in f.operand for f in errs),\
        rep.format(verbose=True)


def test_host_sync_flags_missing_donation(nomesh_cell):
    chunk = next(r for r in nomesh_cell.records if r.kind == "chunk")
    broken = dataclasses.replace(
        chunk, step=dataclasses.replace(chunk.step, donate_argnums=()))
    rep = Report()
    host_sync.check_host_sync(
        SimpleNamespace(records=[broken],
                        caches_aval=nomesh_cell.caches_aval), rep)
    errs = [f for f in rep.errors if f.program == chunk.name]
    assert errs and "does not donate" in errs[0].message,\
        rep.format(verbose=True)


def test_host_sync_flags_dead_donation_alias(nomesh_cell):
    """donate_argnums set but the output never reuses the cache: the alias
    map in the optimized HLO is empty and every leaf must be flagged."""
    caches = nomesh_cell.caches_aval

    def dead(caches, tok):              # consumes the cache, returns a token
        return tok + caches.length.astype(jnp.int32)

    rt = StaticRuntime(None)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # XLA: donated buffers unused
        rec = _record(rt, "serve_dead_decode", dead,
                      (caches, jnp.zeros((), jnp.int32)),
                      roles={"caches": 0}, donate_argnums=(0,))
    rep = Report()
    host_sync.check_host_sync(
        SimpleNamespace(records=[rec], caches_aval=caches), rep)
    errs = [f for f in rep.errors if "alias map" in f.message]
    assert errs, rep.format(verbose=True)
    assert all(f.operand.startswith("caches") for f in errs)


# ---------------------------------------------------------------------------
# pass 4 negatives: routing cross-check
# ---------------------------------------------------------------------------

def test_routing_flags_meter_drift(wa_cell):
    """An expected_routing that over-claims rows breaks the exact bytes
    identity — the meter can no longer drift silently from the program."""
    tampered = SimpleNamespace(
        spec=wa_cell.spec, cfg=wa_cell.cfg, mesh=wa_cell.mesh,
        records=wa_cell.records,
        backend=SimpleNamespace(
            _el=wa_cell.backend._el,
            overlap=wa_cell.backend.overlap,
            expected_routing=lambda name: (
                10 * wa_cell.backend.expected_routing(name)[0],
                wa_cell.backend.expected_routing(name)[1])))
    rep = Report()
    routing_check.check_routing(tampered, rep)
    errs = [f for f in rep.errors if f.operand == "hop bytes"]
    assert errs, rep.format(verbose=True)
    assert "drifted from the program" in errs[0].message


def test_routing_flags_dropped_hops(wa_cell):
    """A WA-named program with NO tagged hops = a layer bypassing the A
    domain; the count audit must fire."""
    rt = StaticRuntime(wa_cell.mesh)
    rec = _record(rt, "serve_wa_decode", lambda t: t + 1,
                  (jnp.zeros((2,), jnp.int32),))
    fake = SimpleNamespace(spec=wa_cell.spec, cfg=wa_cell.cfg,
                           mesh=wa_cell.mesh, backend=wa_cell.backend,
                           records=[rec])
    rep = Report()
    routing_check.check_routing(fake, rep)
    errs = [f for f in rep.errors if f.program == "serve_wa_decode"]
    assert errs and "dropped or duplicated" in errs[0].message,\
        rep.format(verbose=True)


# ---------------------------------------------------------------------------
# pass 5 negatives: kernel bounds
# ---------------------------------------------------------------------------

def test_kernel_bounds_flags_undercovering_grid():
    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def f(x):                            # grid (1,) × block 4 over extent 8
        return pl.pallas_call(
            kern, grid=(1,),
            in_specs=[pl.BlockSpec((4,), lambda i: (i,))],
            out_specs=pl.BlockSpec((4,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((8,), x.dtype))(x)

    jaxpr = jax.make_jaxpr(f)(jnp.zeros((8,)))
    rep = Report()
    n = kernel_bounds.check_pallas_sites(jaxpr, "bad_kernel", rep)
    assert n == 1
    errs = [f for f in rep.errors if f.program == "bad_kernel"]
    assert errs, rep.format(verbose=True)
    assert "cover only 4/8" in errs[0].message


def test_kernel_bounds_flags_dead_kv_limit():
    def kern(x_ref, lim_ref, o_ref):     # lim_ref never read
        o_ref[...] = x_ref[...]

    def f(x, lim):
        return pl.pallas_call(
            kern, grid=(2,),
            in_specs=[pl.BlockSpec((4,), lambda i: (i,)),
                      pl.BlockSpec((1, 1), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((4,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((8,), x.dtype))(x, lim)

    jaxpr = jax.make_jaxpr(f)(jnp.zeros((8,)),
                              jnp.zeros((1, 1), jnp.int32))
    rep = Report()
    kernel_bounds.check_pallas_sites(jaxpr, "dead_lim", rep,
                                     expect_limit=True)
    errs = [f for f in rep.errors if "kv_limit" in f.operand]
    assert errs and "never read" in errs[0].message, rep.format(verbose=True)


def test_kernel_bounds_flags_multi_slot_chunk_write(nomesh_cell):
    """A chunk program whose DUS spans 2 slots at a traced offset can alias
    a neighbour's live KV — must be an ERROR naming the write."""
    caches = nomesh_cell.caches_aval
    k0 = caches.k                        # (L, B, n_kv, S, hd)
    upd = jax.ShapeDtypeStruct((2,) + tuple(k0.shape[2:]), k0.dtype)

    def bad_chunk(caches, upd, slot):
        layer0 = caches.k[0]
        out = jax.lax.dynamic_update_slice(layer0, upd, (slot, 0, 0, 0))
        return out.sum()

    rt = StaticRuntime(None)
    rec = _record(rt, "serve_prefill_chunk", bad_chunk,
                  (caches, upd, jnp.zeros((), jnp.int32)),
                  roles={"caches": 0})
    rep = Report()
    kernel_bounds.check_chunk_writes(
        SimpleNamespace(caches_aval=caches, spec=nomesh_cell.spec),
        rec, rep)
    errs = [f for f in rep.errors if "dynamic_update_slice" in f.operand]
    assert errs, rep.format(verbose=True)
    assert "updates 2 slots" in errs[0].message
    assert "TRACED offset" in errs[0].message


# ---------------------------------------------------------------------------
# jaxpr-walk plumbing the passes stand on
# ---------------------------------------------------------------------------

def test_iter_eqns_multiplies_scan_trips():
    def f(x):
        def body(c, _):
            return c * 2.0, c
        return jax.lax.scan(body, x, None, length=5)

    jaxpr = jax.make_jaxpr(f)(jnp.zeros(()))
    muls = [s for s in iter_eqns(jaxpr) if s.eqn.primitive.name == "mul"]
    assert muls and muls[0].trips == 5


def test_iter_eqns_marks_while_unbounded():
    def f(x):
        return jax.lax.while_loop(lambda c: c < 10.0, lambda c: c + 1.0, x)

    jaxpr = jax.make_jaxpr(f)(jnp.zeros(()))
    adds = [s for s in iter_eqns(jaxpr) if s.eqn.primitive.name == "add"]
    assert adds and all(s.unbounded for s in adds)


def test_literal_value():
    def f(x):
        return jax.lax.dynamic_update_slice(x, jnp.ones((1,)), (3,))

    jaxpr = jax.make_jaxpr(f)(jnp.zeros((8,)))
    dus = [s.eqn for s in iter_eqns(jaxpr)
           if s.eqn.primitive.name == "dynamic_update_slice"]
    assert dus
    assert literal_value(dus[0].invars[2]) == 3


# ---------------------------------------------------------------------------
# pass 1: residency (multi-device → subprocess)
# ---------------------------------------------------------------------------

def test_residency_clean_and_catches_dropped_pins():
    """On a (2,4) mesh the full residency pass is clean for a WA cell, and
    removing the cache-entry pins reintroduces the PR-5 bug class — the
    pass must fail with diagnostics naming program and cache leaf."""
    out = run_py("""
    from repro.analysis.programs import CellSpec, build_cell, make_mesh
    from repro.analysis.findings import Report
    from repro.analysis import residency

    mesh = make_mesh(2, 4)
    cell = build_cell(CellSpec(label="wa", backend="wa", a_shards=4), mesh)
    rep = Report()
    residency.check_residency(cell, rep)
    assert not rep.errors, rep.format(verbose=True)
    print("CLEAN")

    # drop the cache-entry pins: write-slot admission compiles with no
    # sharding anchor at all and the cross-program coherence check fires
    import repro.runtime.serving as serving
    serving._pin_cache_tree = lambda caches, ctx: caches
    cell2 = build_cell(CellSpec(label="mono", backend="colocated",
                                prefill_chunk=0), mesh)
    rep2 = Report()
    residency.check_residency(cell2, rep2)
    errs = rep2.errors
    assert errs, "expected residency errors with the pins removed"
    assert any("caches.k" in f.operand for f in errs), \\
        rep2.format(verbose=True)
    assert any(f.program.startswith("serve_") for f in errs)
    print("CAUGHT", len(errs))
    """)
    assert "CLEAN" in out and "CAUGHT" in out
