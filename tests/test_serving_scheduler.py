"""Continuous-batching scheduler tests (DESIGN.md §7).

Covers the four scheduler invariants the ISSUE demands:
- staggered arrivals fill freed slots WITHOUT a batch drain,
- late arrivals see strictly earlier admission (and therefore better TTFT)
  than under the drain-then-refill baseline,
- the active-slot mask keeps retired slots from writing KV / emitting tokens,
- zero retracing across admissions (StaticRuntime.stats(): compiles == 1).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED
from repro.models import NULL_CTX, build_model
from repro.runtime.serving import Request, ServingEngine
from repro.runtime.static_runtime import StaticRuntime

PROMPT_LEN = 8


@pytest.fixture(scope="module")
def dense():
    cfg = ASSIGNED["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    return cfg, api, params


def _requests(cfg, plan, seed=0):
    """plan: list of (max_new, arrival_step)."""
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                        dtype=np.int32),
                    max_new_tokens=new, arrival_step=arr)
            for i, (new, arr) in enumerate(plan)]


# ---------------------------------------------------------------------------
# admission without drain
# ---------------------------------------------------------------------------

def test_staggered_arrivals_fill_freed_slots_without_drain(dense):
    cfg, api, params = dense
    # rid0 short, rid1 long, rid2 arrives mid-serve: rid2 must take rid0's
    # freed slot WHILE rid1 is still decoding (no drain).
    reqs = _requests(cfg, [(3, 0), (12, 0), (3, 2)])
    eng = ServingEngine(api, NULL_CTX, batch_slots=2, prompt_len=PROMPT_LEN,
                        mode="continuous")
    stats = eng.run(params, reqs, max_steps=200)
    assert stats["completed"] == 3
    assert stats["overlapped_admissions"] >= 1
    long_done_step = reqs[1].admit_step + reqs[1].max_new_tokens
    assert reqs[2].admit_step < long_done_step, \
        "late request waited for the batch to drain"
    for r in reqs:
        assert len(r.generated) == r.max_new_tokens


def test_continuous_beats_drain_admission_for_late_arrivals(dense):
    cfg, api, params = dense
    plan = [(2, 0), (14, 0), (2, 3)]
    cont = _requests(cfg, plan)
    drain = _requests(cfg, plan)
    s_cont = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN,
                           mode="continuous").run(params, cont, max_steps=300)
    s_drain = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN,
                            mode="drain").run(params, drain, max_steps=300)
    assert s_cont["completed"] == s_drain["completed"] == 3
    # drain: rid2 waits until BOTH initial requests finish; continuous: it
    # takes rid0's slot as soon as it frees
    assert cont[2].admit_step < drain[2].admit_step
    assert drain[2].admit_step >= drain[1].max_new_tokens - 1
    # both modes produce identical greedy tokens for identical prompts
    for a, b in zip(cont, drain):
        assert a.generated == b.generated


def test_generation_matches_standalone_greedy_decode(dense):
    """Admission into a mid-serve slot must not perturb the math: every
    request's tokens equal a standalone batch-1 prefill+decode."""
    cfg, api, params = dense
    reqs = _requests(cfg, [(5, 0), (5, 0), (5, 2), (5, 4)])

    def ref(prompt):
        caches, logits = jax.jit(lambda p, b: api.prefill(p, b, NULL_CTX))(
            params, {"tokens": jnp.asarray(prompt[None])})
        cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        out = [int(cur[0])]
        step = jax.jit(lambda p, c, t: api.decode(p, c, t, NULL_CTX))
        for _ in range(4):
            caches, logits = step(params, caches, cur)
            cur = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
            out.append(int(cur[0]))
        return out

    refs = [ref(r.prompt) for r in reqs]
    eng = ServingEngine(api, NULL_CTX, batch_slots=2, prompt_len=PROMPT_LEN,
                        mode="continuous")
    stats = eng.run(params, reqs, max_steps=200)
    assert stats["completed"] == 4
    for r, want in zip(reqs, refs):
        assert r.generated == want, r.rid


# ---------------------------------------------------------------------------
# active-slot masking
# ---------------------------------------------------------------------------

def test_active_mask_freezes_retired_slot_kv(dense):
    """decode_slotted with active=[True, False]: row 1's KV slice must stay
    byte-identical (retired slots write nothing)."""
    cfg, api, params = dense
    toks = jnp.ones((2, PROMPT_LEN), jnp.int32)
    caches, logits = api.prefill(params, {"tokens": toks}, NULL_CTX)
    positions = jnp.array([PROMPT_LEN, PROMPT_LEN], jnp.int32)
    active = jnp.array([True, False])
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    new, _ = jax.jit(lambda p, c, t: api.decode_slotted(
        p, c, t, positions, active, NULL_CTX))(params, caches, cur)
    k0, k1 = np.asarray(caches.k), np.asarray(new.k)
    v0, v1 = np.asarray(caches.v), np.asarray(new.v)
    # retired row frozen…
    np.testing.assert_array_equal(k0[:, 1], k1[:, 1])
    np.testing.assert_array_equal(v0[:, 1], v1[:, 1])
    # …while the active row appended at its cursor
    assert not np.array_equal(k0[:, 0], k1[:, 0])


def test_finished_requests_emit_exactly_max_new(dense):
    cfg, api, params = dense
    reqs = _requests(cfg, [(2, 0), (9, 0)])
    eng = ServingEngine(api, NULL_CTX, batch_slots=2, prompt_len=PROMPT_LEN,
                        mode="continuous")
    eng.run(params, reqs, max_steps=100)
    # rid0 retires at step 1 but the loop runs to step 8 — the mask must
    # keep it from accumulating tokens past its budget
    assert len(reqs[0].generated) == 2
    assert len(reqs[1].generated) == 9


def test_slotted_decode_equals_joint_decode_when_uniform(dense):
    """With one shared cursor and all rows active, decode_slotted IS
    decode — the continuous path costs nothing in fidelity."""
    cfg, api, params = dense
    toks = jax.random.randint(jax.random.key(1), (2, PROMPT_LEN), 0,
                              cfg.vocab_size)
    c0, logits = api.prefill(params, {"tokens": toks}, NULL_CTX)
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    c_ref, want = api.decode(params, c0, cur, NULL_CTX)
    c1, logits2 = api.prefill(params, {"tokens": toks}, NULL_CTX)
    positions = jnp.full((2,), PROMPT_LEN, jnp.int32)
    c_got, got = api.decode_slotted(params, c1, cur, positions,
                                    jnp.array([True, True]), NULL_CTX)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(np.asarray(c_ref.k), np.asarray(c_got.k))


# ---------------------------------------------------------------------------
# zero retracing across admissions (§4.3 pinned-pool invariant)
# ---------------------------------------------------------------------------

def test_no_retrace_across_admissions(dense):
    cfg, api, params = dense
    rt = StaticRuntime()
    reqs = _requests(cfg, [(4, 0), (4, 0), (4, 1), (4, 3), (4, 5)])
    eng = ServingEngine(api, NULL_CTX, batch_slots=2, prompt_len=PROMPT_LEN,
                        runtime=rt, mode="continuous")
    stats = eng.run(params, reqs, max_steps=200)
    assert stats["completed"] == 5
    assert stats["admissions"] == 5
    rs = stats["runtime"]
    assert set(rs) == {"serve_prefill1", "serve_admit", "serve_decode"}
    for name, rec in rs.items():
        assert rec["compiles"] == 1, (name, rec)   # zero retracing
    assert rs["serve_prefill1"]["calls"] == 5
    assert rs["serve_admit"]["calls"] == 5
    assert rs["serve_decode"]["calls"] == stats["decode_steps"]


# ---------------------------------------------------------------------------
# per-request accounting + ssm family
# ---------------------------------------------------------------------------

def test_per_request_metrics_present(dense):
    cfg, api, params = dense
    reqs = _requests(cfg, [(3, 0), (3, 2)])
    stats = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN).run(
        params, reqs, max_steps=100)
    assert stats["mode"] == "continuous"
    assert len(stats["per_request"]) == 2
    for m in stats["per_request"]:
        assert m["ttft_ms"] > 0
        assert m["tpot_ms"] >= 0
        assert m["queue_delay_ms"] >= 0
        assert m["admit_step"] >= 0


def test_ssm_family_serves_continuously():
    """Attention-free states admit per-slot too (write_slot_tree); tokens
    must match standalone generation despite staggered admission."""
    cfg = ASSIGNED["mamba2-1.3b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, PROMPT_LEN, dtype=np.int32)
               for _ in range(3)]

    def ref(prompt):
        state, logits = jax.jit(lambda p, b: api.prefill(p, b, NULL_CTX))(
            params, {"tokens": jnp.asarray(prompt[None])})
        cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        out = [int(cur[0])]
        step = jax.jit(lambda p, c, t: api.decode(p, c, t, NULL_CTX))
        for _ in range(3):
            state, logits = step(params, state, cur)
            cur = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
            out.append(int(cur[0]))
        return out

    refs = [ref(p) for p in prompts]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4, arrival_step=2 * i)
            for i, p in enumerate(prompts)]
    eng = ServingEngine(api, NULL_CTX, batch_slots=2, prompt_len=PROMPT_LEN)
    stats = eng.run(params, reqs, max_steps=100)
    assert stats["mode"] == "continuous"
    assert stats["completed"] == 3
    for r, want in zip(reqs, refs):
        assert r.generated == want, r.rid


def test_reset_slot_zeroes_one_slot_only(dense):
    cfg, api, params = dense
    toks = jnp.ones((2, PROMPT_LEN), jnp.int32)
    caches, _ = api.prefill(params, {"tokens": toks}, NULL_CTX)
    out = jax.jit(lambda c: api.reset_slot(c, jnp.asarray(1, jnp.int32)))(
        caches)
    assert not np.asarray(out.k[:, 1]).any()
    np.testing.assert_array_equal(np.asarray(out.k[:, 0]),
                                  np.asarray(caches.k[:, 0]))


def test_reset_slot_tree_zeroes_recurrent_state():
    cfg = ASSIGNED["mamba2-1.3b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    toks = jnp.ones((2, PROMPT_LEN), jnp.int32)
    state, _ = api.prefill(params, {"tokens": toks}, NULL_CTX)
    out = jax.jit(lambda s: api.reset_slot(s, jnp.asarray(0, jnp.int32)))(
        state)
    assert not np.asarray(out.h[:, 0]).any()
    np.testing.assert_array_equal(np.asarray(out.h[:, 1]),
                                  np.asarray(state.h[:, 1]))


def test_unsupported_family_falls_back_to_drain():
    cfg = ASSIGNED["recurrentgemma-9b"].reduced()
    api = build_model(cfg)
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="auto")
    assert eng.mode == "drain"
    with pytest.raises(ValueError):
        ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous")
