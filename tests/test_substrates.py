"""Optimizer, data pipeline, checkpointing, serving engine, elastic controller."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer, latest_step, save_pytree
from repro.configs.registry import ASSIGNED
from repro.data.synthetic import SyntheticLMData
from repro.models import NULL_CTX, build_model
from repro.optim.adamw import adamw_init, adamw_update, cosine_lr
from repro.runtime.elastic import ElasticController
from repro.runtime.serving import Request, ServingEngine


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw_update(params, grads, opt, lr=0.05,
                                      weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    grads = {"w": jnp.full(4, 1e6)}
    _, _, info = adamw_update(params, grads, opt, lr=1.0, clip_norm=1.0)
    assert float(info["grad_norm"]) > 1e5      # raw norm reported


def test_cosine_lr_shape():
    lrs = [float(cosine_lr(jnp.int32(s), 1.0, warmup=10, total=100))
           for s in range(0, 100, 10)]
    assert lrs[0] < lrs[1]                      # warmup rises
    assert lrs[-1] < lrs[2]                     # decays later


# --------------------------------------------------------------------------
# data pipeline determinism (resume semantics)
# --------------------------------------------------------------------------

def test_data_deterministic_across_restart():
    cfg = ASSIGNED["qwen2-0.5b"].reduced()
    d1 = SyntheticLMData(cfg, batch=2, seq=16, seed=7)
    d2 = SyntheticLMData(cfg, batch=2, seq=16, seed=7)
    b_a = d1.batch_at(13)
    b_b = d2.batch_at(13)
    for k in b_a:
        np.testing.assert_array_equal(b_a[k], b_b[k])
    assert not np.array_equal(d1.batch_at(14)["tokens"], b_a["tokens"])


def test_data_is_learnable_structure():
    cfg = ASSIGNED["qwen2-0.5b"].reduced()
    d = SyntheticLMData(cfg, batch=4, seq=64, seed=0, noise=0.0)
    b = d.batch_at(0)
    a = 31337 % cfg.vocab_size or 1
    bb = 917 % cfg.vocab_size
    pred = (b["tokens"].astype(np.int64) * a + bb) % cfg.vocab_size
    np.testing.assert_array_equal(pred, b["labels"])   # noiseless → exact


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (5, 10, 15):
        ck.save(s, **tree)
    assert latest_step(str(tmp_path)) == 15
    assert not os.path.exists(tmp_path / "step_00000005")   # GC'd
    step, restored = ck.restore(dict(tree))
    assert step == 15
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"], np.float32),
                                  np.asarray(tree["b"]["c"], np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_pytree({"w": jnp.zeros((2, 2))}, str(tmp_path), 1)
    from repro.checkpoint.checkpointer import restore_pytree
    with pytest.raises(ValueError):
        restore_pytree({"w": jnp.zeros((3, 3))}, str(tmp_path), 1)


def test_checkpoint_atomicity_no_done_marker_ignored(tmp_path):
    p = save_pytree({"w": jnp.zeros(2)}, str(tmp_path), 1)
    os.remove(os.path.join(p.replace("step_00000001", "step_00000001"),
                           "DONE"))
    assert latest_step(str(tmp_path)) is None   # incomplete ckpt invisible


# --------------------------------------------------------------------------
# serving engine
# --------------------------------------------------------------------------

def test_serving_completes_and_swaps_slots():
    cfg = ASSIGNED["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8,
                                               dtype=np.int32),
                    max_new_tokens=3) for i in range(5)]
    eng = ServingEngine(api, NULL_CTX, batch_slots=2, prompt_len=8)
    stats = eng.run(params, reqs, max_steps=200)
    assert stats["completed"] == 5
    assert stats["tpot_mean_ms"] > 0
    for r in reqs:
        assert len(r.generated) == 3


# --------------------------------------------------------------------------
# elastic controller
# --------------------------------------------------------------------------

def test_elastic_failure_and_remesh():
    ec = ElasticController(n_data=16, n_model=16)
    assert ec.mesh_shape() == (16, 16)
    ec.inject_failure(3)
    d, m = ec.mesh_shape()
    assert d < 16 and 16 % d == 0 and m == 16
    assert any("FAIL" in e for e in ec.events)


def test_elastic_straggler_eviction():
    ec = ElasticController(n_data=8, n_model=4, patience=2)
    ec.observe_step(1.0)
    evicted = None
    for _ in range(5):
        evicted = ec.observe_step(10.0, slow_domain=5) or evicted
    assert evicted == 5
    assert 5 in ec.failed_domains


def test_elastic_recover_loop_resumes():
    ec = ElasticController(n_data=4, n_model=2)
    ec.inject_failure(0)
    calls = {}

    def make_mesh(shape):
        calls["mesh"] = shape
        return f"mesh{shape}"

    def recompile(mesh):
        calls["compiled_on"] = mesh
        return "exe"

    def restore(mesh):
        calls["restored_on"] = mesh
        return 42, {"params": "state"}

    mesh, step, state, exe = ec.recover(make_mesh, recompile, restore)
    assert step == 42 and exe == "exe"
    assert calls["mesh"][0] in (1, 2)          # data axis shrank to a divisor
    assert any("RESUME" in e for e in ec.events)
