"""Unit tests for the HLO collective parser (the roofline's data source)."""
import numpy as np

from repro.launch.hlo_analysis import (CollectiveSummary, _axes_of_group,
                                       _group_info, _shape_bytes,
                                       parse_collectives, ring_traffic_bytes)

HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[16,512]{1,0} parameter(0)
  %ag = bf16[16,8192]{1,0} all-gather(%p0), channel_id=1, replica_groups=[16,16]<=[256], dimensions={1}
  %ar = f32[1024]{0} all-reduce(%x), channel_id=2, replica_groups=[1,256]<=[256], to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%y), channel_id=3, replica_groups={{0,16,32,48},{1,17,33,49}}, dimensions={0}, to_apply=%add
  %cp = bf16[4,128]{1,0} collective-permute(%z), channel_id=4, source_target_pairs={{0,16},{16,0}}
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,512]") == 16 * 512 * 2
    assert _shape_bytes("f32[1024]") == 4096
    assert _shape_bytes("(f32[8], bf16[4])") == 32 + 8
    assert _shape_bytes("s8[10,10]") == 100


def test_group_info_iota_and_explicit():
    g, groups = _group_info("replica_groups=[16,16]<=[256], dims")
    assert g == 16 and groups[0] == list(range(16))
    g, groups = _group_info("replica_groups={{0,16,32},{1,17,33}}, x")
    assert g == 3 and groups[0] == [0, 16, 32]


def test_axes_classification():
    # mesh (pod=2, data=16, model=16): strides pod=256, data=16, model=1
    shape, names = (2, 16, 16), ("pod", "data", "model")
    assert _axes_of_group(list(range(16)), shape, names) == ("model",)
    assert _axes_of_group([0, 16, 32, 48], shape, names) == ("data",)
    assert _axes_of_group([0, 256], shape, names) == ("pod",)
    assert _axes_of_group([0, 16, 256, 272], shape, names) == ("pod", "data")


def test_parse_collectives_end_to_end():
    s = parse_collectives(HLO, (2, 16, 16), ("pod", "data", "model"))
    kinds = {o.kind for o in s.ops}
    assert kinds == {"all-gather", "all-reduce", "reduce-scatter",
                     "collective-permute"}
    ag = next(o for o in s.ops if o.kind == "all-gather")
    # operand = result / group_size
    assert ag.operand_bytes == 16 * 8192 * 2 / 16
    assert ag.axes == ("model",)
    rs = next(o for o in s.ops if o.kind == "reduce-scatter")
    assert rs.operand_bytes == 64 * 4 * 4          # result × group_size
    assert rs.axes == ("data",)
    assert s.total_operand_bytes > 0
    assert ring_traffic_bytes(s) > 0


def test_bytes_by_axes_accumulates():
    s = parse_collectives(HLO, (2, 16, 16), ("pod", "data", "model"))
    by = s.bytes_by_axes()
    # permutes carry source_target_pairs (not replica_groups) → "?" bucket
    assert "model" in by and "data" in by and "?" in by
