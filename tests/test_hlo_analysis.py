"""Unit tests for the HLO collective parser (the roofline's data source)."""

from repro.launch.hlo_analysis import (_axes_of_group, _group_info,
    _shape_bytes, parse_collectives, parse_host_ops, parse_input_output_alias,
    ring_traffic_bytes)

HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[16,512]{1,0} parameter(0)
  %ag = bf16[16,8192]{1,0} all-gather(%p0), channel_id=1, replica_groups=[16,16]<=[256], dimensions={1}
  %ar = f32[1024]{0} all-reduce(%x), channel_id=2, replica_groups=[1,256]<=[256], to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%y), channel_id=3, replica_groups={{0,16,32,48},{1,17,33,49}}, dimensions={0}, to_apply=%add
  %cp = bf16[4,128]{1,0} collective-permute(%z), channel_id=4, source_target_pairs={{0,16},{16,0}}
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,512]") == 16 * 512 * 2
    assert _shape_bytes("f32[1024]") == 4096
    assert _shape_bytes("(f32[8], bf16[4])") == 32 + 8
    assert _shape_bytes("s8[10,10]") == 100


def test_group_info_iota_and_explicit():
    g, groups = _group_info("replica_groups=[16,16]<=[256], dims")
    assert g == 16 and groups[0] == list(range(16))
    g, groups = _group_info("replica_groups={{0,16,32},{1,17,33}}, x")
    assert g == 3 and groups[0] == [0, 16, 32]


def test_axes_classification():
    # mesh (pod=2, data=16, model=16): strides pod=256, data=16, model=1
    shape, names = (2, 16, 16), ("pod", "data", "model")
    assert _axes_of_group(list(range(16)), shape, names) == ("model",)
    assert _axes_of_group([0, 16, 32, 48], shape, names) == ("data",)
    assert _axes_of_group([0, 256], shape, names) == ("pod",)
    assert _axes_of_group([0, 16, 256, 272], shape, names) == ("pod", "data")


def test_parse_collectives_end_to_end():
    s = parse_collectives(HLO, (2, 16, 16), ("pod", "data", "model"))
    kinds = {o.kind for o in s.ops}
    assert kinds == {"all-gather", "all-reduce", "reduce-scatter",
                     "collective-permute"}
    ag = next(o for o in s.ops if o.kind == "all-gather")
    # operand = result / group_size
    assert ag.operand_bytes == 16 * 8192 * 2 / 16
    assert ag.axes == ("model",)
    rs = next(o for o in s.ops if o.kind == "reduce-scatter")
    assert rs.operand_bytes == 64 * 4 * 4          # result × group_size
    assert rs.axes == ("data",)
    assert s.total_operand_bytes > 0
    assert ring_traffic_bytes(s) > 0


def test_bytes_by_axes_accumulates():
    s = parse_collectives(HLO, (2, 16, 16), ("pod", "data", "model"))
    by = s.bytes_by_axes()
    # permutes carry source_target_pairs (not replica_groups) → "?" bucket
    assert "model" in by and "data" in by and "?" in by


# ---------------------------------------------------------------------------
# _shape_bytes edge cases
# ---------------------------------------------------------------------------

def test_shape_bytes_scalar():
    # HLO prints rank-0 as "f32[]" — empty dims means ONE element, not zero
    assert _shape_bytes("f32[]") == 4.0
    assert _shape_bytes("s32[]") == 4.0
    assert _shape_bytes("pred[]") == 1.0


def test_shape_bytes_sub_byte_dtypes():
    assert _shape_bytes("s4[16]") == 8.0           # half a byte per element
    assert _shape_bytes("u4[3]") == 1.5            # fractional is fine
    assert _shape_bytes("(s4[8], u4[8])") == 8.0


def test_shape_bytes_tuple_with_scalars():
    assert _shape_bytes("(f32[], f32[8], bf16[])") == 4.0 + 32.0 + 2.0


def test_shape_bytes_unknown_dtype_ignored():
    # opaque/token results must not crash or contribute bytes
    assert _shape_bytes("token[]") == 0.0
    assert _shape_bytes("(token[], f32[2])") == 8.0


def test_parse_collectives_tuple_result():
    hlo = """
HloModule t
ENTRY main {
  %ar = (f32[8]{0}, f32[]) all-reduce(%a, %b), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
}
"""
    s = parse_collectives(hlo, (2, 4), ("data", "model"))
    assert s.count() == 1
    op = s.ops[0]
    assert op.kind == "all-reduce"
    assert op.result_bytes == 8 * 4 + 4           # both tuple members
    assert op.group_size == 4


# ---------------------------------------------------------------------------
# donation alias map + host-op scan (the host-sync pass's data source)
# ---------------------------------------------------------------------------

ALIAS_HLO = """
HloModule serve, input_output_alias={ {0}: (12, {}, may-alias), {1}: (13, {}, may-alias), {2, 0}: (14, {}, must-alias) }, entry_computation_layout={...}
ENTRY main {
  %p = f32[4]{0} parameter(0)
}
"""


def test_parse_input_output_alias():
    m = parse_input_output_alias(ALIAS_HLO)
    assert m == {(0,): 12, (1,): 13, (2, 0): 14}


def test_parse_input_output_alias_absent():
    assert parse_input_output_alias("HloModule bare\nENTRY main {}") == {}


HOST_HLO = """
HloModule h
ENTRY main {
  %p0 = f32[2]{0} parameter(0)
  %t = token[] after-all()
  %inf = (f32[2]{0}, token[]) infeed(%t)
  %cb = f32[2]{0} custom-call(%p0), custom_call_target="xla_python_cpu_callback", api_version=API_VERSION_STATUS_RETURNING
  %ok = f32[2]{0} custom-call(%p0), custom_call_target="__cublas$gemm"
  %add = f32[2]{0} add(%p0, %p0)
}
"""


def test_parse_host_ops_finds_infeed_and_callbacks():
    hits = parse_host_ops(HOST_HLO)
    assert len(hits) == 2
    assert any("infeed" in h for h in hits)
    assert any("xla_python_cpu_callback" in h for h in hits)


def test_parse_host_ops_clean_program():
    clean = """
HloModule c
ENTRY main {
  %p0 = f32[2]{0} parameter(0)
  %add = f32[2]{0} add(%p0, %p0)
  %mm = f32[2]{0} custom-call(%p0), custom_call_target="__cublas$gemm"
}
"""
    assert parse_host_ops(clean) == []
