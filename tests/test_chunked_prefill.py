"""Chunked-prefill lane tests (DESIGN.md §7 "chunked-prefill lane").

Covers the invariants the chunked-prefill ISSUE demands:
- chunked prefill is token-exact against monolithic prefill (transformer +
  ssm families, fp and int8-KV) through the full serving engine,
- ragged TRUE prompt lengths — shorter AND longer than the static prompt
  width — admit correctly, each matching its standalone greedy reference,
- ``StaticRuntime.stats()`` shows ONE compile for ``serve_prefill_chunk``
  (and every other program) across many admissions of many lengths,
- the silent-truncation regression: non-chunked/drain paths REJECT a
  too-long prompt with ``ValueError`` at enqueue, never cut it,
- slot reuse under chunked admission starts from clean per-slot state
  (stale KV is masked by cursors; stale recurrent state is overwritten),
- TTFT spans chunk boundaries and chunk-prefill wall-time is excluded from
  decode throughput (the stats-fix satellite).

Fixtures run in float32: chunk attention (plain masked softmax over the
cache, traced offsets) and monolithic flash attention (static-banded online
softmax) are the same math but different reduction orders, so under bf16
their ~3e-2 rounding skew can flip argmax near-ties on random tiny-config
weights. In f32 the skew is ~1e-6 and token equality tests the lane's
scheduling semantics, not accumulation-order luck.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED
from repro.models import NULL_CTX, build_model
from repro.runtime.serving import Request, ServingEngine
from repro.runtime.static_runtime import StaticRuntime

PROMPT_LEN = 8
CHUNK = 3                      # deliberately not a divisor of PROMPT_LEN


@pytest.fixture(scope="module")
def dense():
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(dtype="float32")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


@pytest.fixture(scope="module")
def dense_int8():
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(dtype="float32",
                                                   kv_dtype="int8")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


@pytest.fixture(scope="module")
def ssm():
    cfg = ASSIGNED["mamba2-1.3b"].reduced().replace(dtype="float32")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


def _requests(cfg, plan, seed=0):
    """plan: list of (max_new, arrival_step) with full-width prompts, or
    (max_new, arrival_step, prompt_len) for ragged lengths."""
    rng = np.random.default_rng(seed)
    out = []
    for i, entry in enumerate(plan):
        new, arr, plen = entry if len(entry) == 3 else entry + (PROMPT_LEN,)
        out.append(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, plen,
                                               dtype=np.int32),
                           max_new_tokens=new, arrival_step=arr))
    return out


def _standalone(api, params, prompt, n):
    """Greedy reference on the TRUE-length prompt: batch-1 prefill + n-1
    decode steps."""
    caches, logits = jax.jit(lambda p, b: api.prefill(p, b, NULL_CTX))(
        params, {"tokens": jnp.asarray(prompt[None])})
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    out = [int(cur[0])]
    step = jax.jit(lambda p, c, t: api.decode(p, c, t, NULL_CTX))
    for _ in range(n - 1):
        caches, logits = step(params, caches, cur)
        cur = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        out.append(int(cur[0]))
    return out


# ---------------------------------------------------------------------------
# token-exactness: chunked == monolithic through the engine
# ---------------------------------------------------------------------------

PLAN = [(9, 0), (13, 0), (5, 2), (9, 6)]


@pytest.mark.parametrize("fixture", ["dense", "dense_int8", "ssm"])
def test_chunked_equals_monolithic_prefill(fixture, request):
    """Full-width prompts (padding never enters): the chunked engine's
    token streams equal the monolithic engine's, fp and int8-KV, dense and
    ssm — the lane changes WHEN prefill compute runs, not what it computes."""
    cfg, api, params = request.getfixturevalue(fixture)
    r_mono = _requests(cfg, PLAN)
    ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                  max_new_cap=32, block_size=4).run(
        params, r_mono, max_steps=400)
    r_chk = _requests(cfg, PLAN)
    stats = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                          max_new_cap=32, block_size=4, kv_bucket_chunk=16,
                          prefill_chunk=CHUNK).run(
        params, r_chk, max_steps=400)
    assert stats["completed"] == len(PLAN)
    assert stats["prefill_mode"] == "chunked"
    # ceil(8/3) == 3 chunks per admission
    assert stats["prefill_chunks"] == 3 * len(PLAN)
    for a, b in zip(r_mono, r_chk):
        assert a.generated == b.generated, a.rid


@pytest.mark.parametrize("fixture", ["dense", "dense_int8"])
def test_prefill_chunk_cache_matches_monolithic(fixture, request):
    """Direct program-level check: walking a prompt through prefill_chunk
    writes the same prompt KV (dequantized) into the slot as a monolithic
    batch-1 prefill, and yields the same first token."""
    cfg, api, params = request.getfixturevalue(fixture)
    rng = np.random.default_rng(3)
    L = PROMPT_LEN
    prompt = rng.integers(0, cfg.vocab_size, L, dtype=np.int32)
    c_ref, lg_ref = api.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                                NULL_CTX)
    caches = api.init_caches(2, 40)
    fn = jax.jit(lambda *xs: api.prefill_chunk(*xs, NULL_CTX))
    start = 0
    while start < L:
        n = min(CHUNK, L - start)
        row = np.zeros((CHUNK,), np.int32)
        row[:n] = prompt[start:start + n]
        caches, logits = fn(params, caches, jnp.asarray(row[None]),
                            jnp.asarray(1, jnp.int32),
                            jnp.asarray(start, jnp.int32),
                            jnp.asarray(n, jnp.int32))
        start += n
    assert int(np.argmax(np.asarray(logits[0, -1]))) == \
        int(np.argmax(np.asarray(lg_ref[0, -1])))
    from repro.kv.cache import layer_read
    for layer in range(cfg.n_layers):
        want_k, _ = layer_read(c_ref.k[layer], c_ref.v[layer],
                               None if c_ref.k_scale is None
                               else c_ref.k_scale[layer],
                               None if c_ref.v_scale is None
                               else c_ref.v_scale[layer], jnp.float32)
        got_k, _ = layer_read(caches.k[layer], caches.v[layer],
                              None if caches.k_scale is None
                              else caches.k_scale[layer],
                              None if caches.v_scale is None
                              else caches.v_scale[layer], jnp.float32)
        np.testing.assert_allclose(np.asarray(got_k[1, :, :L]),
                                   np.asarray(want_k[0, :, :L]),
                                   rtol=2e-2, atol=2e-2)
        # untouched rows/positions stay zero: the masked chunk write never
        # spills past valid_len or into other slots
        assert not np.asarray(caches.k[layer, 0]).any()
        assert not np.asarray(got_k[1, :, L:]).any()


# ---------------------------------------------------------------------------
# ragged TRUE lengths (incl. prompts LONGER than the static prompt width)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture", ["dense", "ssm"])
def test_ragged_prompt_lengths_admit_correctly(fixture, request):
    """Length-true cursors: prompts of 3/5/8/11 tokens (11 > static width
    8 — impossible to admit monolithically) each match their standalone
    greedy reference through staggered chunked admission."""
    cfg, api, params = request.getfixturevalue(fixture)
    plan = [(6, 0, 5), (6, 0, 8), (6, 2, 11), (6, 4, 3)]
    reqs = _requests(cfg, plan)
    refs = [_standalone(api, params, r.prompt, r.max_new_tokens)
            for r in reqs]
    stats = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                          max_new_cap=32, block_size=4, kv_bucket_chunk=16,
                          prefill_chunk=4).run(params, reqs, max_steps=400)
    assert stats["completed"] == len(plan)
    assert stats["prefill_chunks"] == sum(-(-p // 4) for _, _, p in plan)
    for r, want in zip(reqs, refs):
        assert r.generated == want, r.rid


def test_final_chunk_window_never_clamps_out_of_bounds(dense):
    """Regression: a prompt whose last chunk's fixed (1,C) window would
    overrun the KV extent (dynamic_update_slice CLAMPS out-of-bounds starts
    instead of erroring — silent cache corruption) must shift the window
    left over already-written positions instead. L=33, C=16, extent=40:
    the naive final window [32,48) clamps to [24,40) and lands token 32's
    KV at position 24; the shifted window recomputes [24,40) correctly."""
    cfg, api, params = dense
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 33, dtype=np.int32)
    reqs = [Request(rid=0, prompt=prompt, max_new_tokens=7)]
    want = _standalone(api, params, prompt, 7)
    stats = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                          max_new_cap=32, block_size=4,
                          prefill_chunk=16).run(params, reqs, max_steps=200)
    assert stats["completed"] == 1
    assert reqs[0].generated == want
    # chunk width larger than the cache extent can never fit: reject early
    with pytest.raises(ValueError, match="fixed \\(1,C\\) window"):
        ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                      max_new_cap=32, prefill_chunk=64)


def test_short_prompt_starts_in_small_bucket(dense):
    """Cursor starts at the TRUE length: a 4-token prompt under a 16-chunk
    bucket set must run its first decode blocks in the SMALLEST bucket, not
    the one covering the padded width."""
    cfg, api, params = dense
    rt = StaticRuntime()
    reqs = _requests(cfg, [(8, 0, 4)])
    stats = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, runtime=rt,
                          mode="continuous", max_new_cap=32, block_size=4,
                          kv_bucket_chunk=16, prefill_chunk=4).run(
        params, reqs, max_steps=100)
    assert stats["completed"] == 1
    rs = stats["runtime"]
    # positions 4..11 + T=4 ≤ 16 → every block runs in the s16 bucket
    assert rs["serve_decode_block_s16"]["calls"] == stats["macro_steps"]
    assert rs["serve_decode_block_s32"]["calls"] == 0


# ---------------------------------------------------------------------------
# silent-truncation regression (satellite): reject, never cut
# ---------------------------------------------------------------------------

def test_monolithic_rejects_overlong_prompt_at_enqueue(dense):
    cfg, api, params = dense
    long = _requests(cfg, [(4, 0, PROMPT_LEN + 1)])
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                        max_new_cap=32)
    with pytest.raises(ValueError, match="truncat"):
        eng.submit(long[0])
    with pytest.raises(ValueError, match="truncat"):
        eng.run(params, long, max_steps=10)


def test_drain_rejects_overlong_prompt_at_enqueue(dense):
    cfg, api, params = dense
    long = _requests(cfg, [(4, 0, PROMPT_LEN + 1)])
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="drain",
                        max_new_cap=32)
    with pytest.raises(ValueError, match="truncat"):
        eng.run(params, long, max_steps=10)


def test_chunked_rejects_prompt_beyond_kv_extent(dense):
    cfg, api, params = dense
    # extent = 8 + 32 = 40; L=38 + max_new=4 > 40 → reject, never truncate
    reqs = _requests(cfg, [(4, 0, 38)])
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                        max_new_cap=32, prefill_chunk=4)
    with pytest.raises(ValueError, match="KV extent"):
        eng.run(params, reqs, max_steps=10)


def test_zero_token_budget_rejected_at_enqueue(dense):
    """Every admission produces a first token: a 0- (or negative-) budget
    request would silently receive one anyway — reject it instead."""
    cfg, api, params = dense
    r = _requests(cfg, [(4, 0)])[0]
    r.max_new_tokens = 0
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                        max_new_cap=32, prefill_chunk=4)
    with pytest.raises(ValueError, match="must be >= 1"):
        eng.submit(r)


def test_drain_mode_refuses_chunked_prefill(dense):
    cfg, api, params = dense
    with pytest.raises(ValueError):
        ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="drain",
                      prefill_chunk=4)


def test_auto_mode_warns_when_dropping_chunk_lane():
    """mode="auto" falls back to monolithic admission when the family has
    no prefill_chunk — but LOUDLY: a benchmark config that asked for the
    chunk lane must never quietly measure the monolithic one."""
    api = build_model(ASSIGNED["recurrentgemma-9b"].reduced())
    with pytest.warns(UserWarning, match="monolithic admission"):
        eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="auto",
                            prefill_chunk=4)
    assert eng.prefill_chunk == 0
    # an explicit mode="continuous" request still hard-errors instead
    with pytest.raises(ValueError):
        ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                      prefill_chunk=4)


# ---------------------------------------------------------------------------
# zero retracing across chunked admissions (§4.3 pinned-pool invariant)
# ---------------------------------------------------------------------------

def test_chunk_program_compiles_once_across_admissions(dense):
    """ONE serve_prefill_chunk program serves every chunk of every prompt of
    every length in every slot; monolithic admission programs are not even
    compiled in chunk mode."""
    cfg, api, params = dense
    rt = StaticRuntime()
    plan = [(4, 0, 5), (4, 0, 8), (4, 1, 11), (4, 3, 2), (4, 5, 7)]
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, runtime=rt,
                        mode="continuous", max_new_cap=32, block_size=4,
                        kv_bucket_chunk=16, prefill_chunk=4)
    stats = eng.run(params, _requests(cfg, plan), max_steps=400)
    assert stats["completed"] == len(plan)
    rs = stats["runtime"]
    assert "serve_prefill1" not in rs and "serve_admit" not in rs
    for name, rec in rs.items():
        assert rec["compiles"] == 1, (name, rec)   # zero retracing
    n_chunks = sum(-(-p // 4) for _, _, p in plan)
    assert rs["serve_prefill_chunk"]["calls"] == n_chunks
    assert stats["prefill_chunks"] == n_chunks
    # reuse: a second run recompiles nothing
    stats2 = eng.run(params, _requests(cfg, plan), max_steps=400)
    assert all(rec["compiles"] == 1
               for rec in stats2["runtime"].values())


def test_chunk_lane_with_per_step_engine(dense):
    """The lane is block-size independent: T == 1 interleaves one chunk per
    decode step through the same serve_decode program."""
    cfg, api, params = dense
    rt = StaticRuntime()
    reqs = _requests(cfg, [(5, 0), (5, 0), (5, 2)])
    refs = [_standalone(api, params, r.prompt, 5) for r in reqs]
    stats = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, runtime=rt,
                          mode="continuous", max_new_cap=32,
                          prefill_chunk=CHUNK).run(params, reqs,
                                                   max_steps=200)
    assert stats["completed"] == 3
    assert set(stats["runtime"]) == {"serve_prefill_chunk", "serve_decode"}
    for r, want in zip(reqs, refs):
        assert r.generated == want, r.rid


# ---------------------------------------------------------------------------
# slot reuse, halting, stats
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture", ["dense", "ssm"])
def test_slot_reuse_is_clean_under_chunked_admission(fixture, request):
    """One slot serving requests back to back: the second admission must not
    see the first's KV (masked by cursors) or recurrent state (zeroed at
    chunk 0)."""
    cfg, api, params = request.getfixturevalue(fixture)
    plan = [(5, 0, 7), (5, 0, 5), (5, 0, 6)]
    reqs = _requests(cfg, plan, seed=7)
    refs = [_standalone(api, params, r.prompt, 5) for r in reqs]
    stats = ServingEngine(api, NULL_CTX, 1, PROMPT_LEN, mode="continuous",
                          max_new_cap=32, block_size=4,
                          prefill_chunk=4).run(params, reqs, max_steps=400)
    assert stats["completed"] == 3
    for r, want in zip(reqs, refs):
        assert r.generated == want, r.rid


def test_one_token_request_completes_on_final_chunk(dense):
    """A max_new_tokens == 1 request is done at its first (chunk-produced)
    token; the slot frees for the next boundary's admission."""
    cfg, api, params = dense
    reqs = _requests(cfg, [(1, 0), (1, 0), (5, 0)])
    stats = ServingEngine(api, NULL_CTX, 1, PROMPT_LEN, mode="continuous",
                          max_new_cap=32, block_size=4,
                          prefill_chunk=4).run(params, reqs, max_steps=200)
    assert stats["completed"] == 3
    assert len(reqs[0].generated) == 1
    assert len(reqs[2].generated) == 5


def test_eos_on_first_chunk_token_retires_slot(dense):
    cfg, api, params = dense
    probe = _requests(cfg, [(6, 0)])
    ServingEngine(api, NULL_CTX, 1, PROMPT_LEN, mode="continuous",
                  max_new_cap=32, prefill_chunk=4).run(params, probe,
                                                       max_steps=100)
    stop = probe[0].generated[0]                 # the prefill-produced token
    reqs = _requests(cfg, [(6, 0)])
    reqs[0].eos_id = stop
    stats = ServingEngine(api, NULL_CTX, 1, PROMPT_LEN, mode="continuous",
                          max_new_cap=32, prefill_chunk=4).run(
        params, reqs, max_steps=100)
    assert stats["completed"] == 1
    assert reqs[0].generated == [stop]


def test_ttft_spans_chunk_boundaries_and_stats_fields(dense):
    """Stats-fix satellite: TTFT covers enqueue → final chunk (not just the
    last program call), chunk wall-time is excluded from decode throughput,
    and the gap metric is populated."""
    cfg, api, params = dense
    reqs = _requests(cfg, [(9, 0), (9, 0), (9, 2)])
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                        max_new_cap=32, block_size=4, prefill_chunk=3)
    stats = eng.run(params, reqs, max_steps=400)
    assert stats["completed"] == 3
    assert stats["prefill_chunks"] == 9          # 3 chunks each
    assert stats["prefill_time_ms"] > 0
    # decode throughput counts decode-produced tokens over decode time only
    n_dec = sum(len(r.generated) - 1 for r in reqs)
    assert stats["decode_tokens"] == n_dec
    assert stats["throughput_tok_s"] > 0
    assert stats["max_inter_token_gap_ms"] > 0
    for r, m in zip(reqs, stats["per_request"]):
        # first token only exists once ALL chunks ran: TTFT ≥ queue delay,
        # and for the engine it is enqueue → first token
        assert m["ttft_ms"] >= m["queue_delay_ms"]
        assert r.t_first_token >= r.t_admitted
        assert m["max_gap_ms"] > 0
        assert m["prompt_tokens"] == len(r.prompt)


def test_presubmitted_requests_are_served_not_dropped(dense):
    """submit() before run() must serve the request, not reset it away —
    the no-silent-loss contract covers the queue, not just prompt widths."""
    cfg, api, params = dense
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                        max_new_cap=32, prefill_chunk=4)
    pre = _requests(cfg, [(4, 0)])[0]
    eng.submit(pre)
    stats = eng.run(params, [], max_steps=100)
    assert stats["completed"] == 1
    assert len(pre.generated) == 4
    # passing the same object to run() too must not serve it twice
    eng.submit(pre2 := _requests(cfg, [(4, 0)])[0])
    stats = eng.run(params, [pre2], max_steps=100)
    assert stats["completed"] == 1


def test_debug_reset_slots_with_chunked_admission(dense):
    cfg, api, params = dense
    plan = [(4, 0, 5), (4, 0, 8), (1, 2, 6)]
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                        max_new_cap=32, block_size=4, prefill_chunk=4,
                        debug_reset_slots=True)
    stats = eng.run(params, _requests(cfg, plan), max_steps=400)
    assert stats["completed"] == len(plan)
    assert stats["runtime"]["serve_reset"]["calls"] == len(plan)
    assert not np.asarray(eng._caches.k).any()
