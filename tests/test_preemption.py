"""Preemptible serving tests (DESIGN.md §7, failure model).

Covers the invariants the preemption ISSUE demands:
- ``export_slot_kv`` / ``import_slot_kv`` round-trip one slot's stored
  bytes VERBATIM up to the true length (dense and int8+scales),
- a preempt-then-restore serve is token-byte-identical to an
  uninterrupted serve — colocated and WA backends, dense and int8 KV,
  split-KV (a_shards=2) included — with ``compiles == 1`` per program
  (the swap pair joins the compile-once set),
- slot retirement/reuse races: a mid-block EOS retirement followed at the
  next admission point by re-admission of a PREEMPTED request into the
  same slot, both backends × T ∈ {1, 8} (stale victim KV beyond the
  restored length must stay masked out),
- enqueue rejections are ``RequestRejected`` carrying rid / offending
  length / per-mode limit as fields (actionable from a fleet log),
- SLO policies: expired-TTFT queued requests shed as deadline misses;
  ``max_queue`` sheds lowest-priority work as structured rejections,
- dispatch hardening: an injected persistent dispatch failure demotes to
  a structured rejection + slot quarantine WITHOUT corrupting surviving
  slots; a failed swap-out leaves the victim decoding; retries are
  counted and transient faults are absorbed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED
from repro.kv.cache import KVCache, export_slot_kv, import_slot_kv
from repro.models import NULL_CTX, build_model
from repro.runtime.serving import (Request, RequestRejected, ServingEngine)
from repro.runtime.static_runtime import DispatchError

PROMPT_LEN = 8
CAP = 32


@pytest.fixture(scope="module")
def dense():
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(dtype="float32")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


@pytest.fixture(scope="module")
def dense_int8():
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(dtype="float32",
                                                   kv_dtype="int8")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


def _preempt_plan(cfg, seed=3):
    """Two low-priority long decoders + one HIGH-priority late arrival:
    with 2 slots the arrival must preempt a victim; with 3 slots nothing
    preempts (the uninterrupted reference)."""
    rng = np.random.default_rng(seed)
    rs = [Request(rid=i,
                  prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                      dtype=np.int32),
                  max_new_tokens=20, arrival_step=0, priority=0)
          for i in range(2)]
    rs.append(Request(rid=2,
                      prompt=rng.integers(0, cfg.vocab_size, 6,
                                          dtype=np.int32),
                      max_new_tokens=6, arrival_step=8, priority=5))
    return rs


def _engine(api, slots, *, T=8, chunk=4, backend="colocated", a_shards=1,
            **kw):
    return ServingEngine(api, NULL_CTX, slots, PROMPT_LEN,
                         mode="continuous", max_new_cap=CAP,
                         block_size=T, kv_bucket_chunk=16 if T > 1 else 0,
                         prefill_chunk=chunk, backend=backend,
                         a_shards=a_shards, **kw)


# ---------------------------------------------------------------------------
# KV-level: export/import round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture", ["dense", "dense_int8"])
def test_export_import_roundtrip_bytes(fixture, request):
    """One slot's stored bytes survive export → zero → import VERBATIM up
    to the true length; positions past it keep whatever the cache held
    (masked out by cursors, exactly the chunk lane's contract)."""
    _, api, _ = request.getfixturevalue(fixture)
    caches = api.init_caches(3, 24)
    rng = np.random.default_rng(0)

    def fill(a):
        if a is None:
            return None
        if a.dtype == jnp.int8:
            return jnp.asarray(rng.integers(-127, 127, a.shape), jnp.int8)
        return jnp.asarray(rng.normal(size=a.shape), a.dtype)

    caches = caches._replace(k=fill(caches.k), v=fill(caches.v),
                             k_scale=fill(caches.k_scale),
                             v_scale=fill(caches.v_scale))
    slot, valid = 1, 11
    saved = export_slot_kv(caches, jnp.asarray(slot, jnp.int32))
    assert (saved[2] is None) == (caches.k_scale is None)
    zeroed = api.reset_slot(caches, jnp.asarray(slot, jnp.int32))
    back = import_slot_kv(zeroed, saved, jnp.asarray(slot, jnp.int32),
                          jnp.asarray(valid, jnp.int32))

    for name in ("k", "v", "k_scale", "v_scale"):
        want, got = getattr(caches, name), getattr(back, name)
        if want is None:
            continue
        np.testing.assert_array_equal(
            np.asarray(want[:, slot, :, :valid]),
            np.asarray(got[:, slot, :, :valid]),
            err_msg=f"{name}: restored bytes differ within valid length")
        assert not np.asarray(got[:, slot, :, valid:]).any(), \
            f"{name}: import wrote past the true length"
        # untouched slots must stay untouched
        other = [s for s in range(3) if s != slot]
        np.testing.assert_array_equal(np.asarray(want[:, other]),
                                      np.asarray(got[:, other]))


# ---------------------------------------------------------------------------
# Serve-level: preempt-then-restore == uninterrupted, both backends
# ---------------------------------------------------------------------------

CELLS = [
    ("dense", "colocated", 1, 1),
    ("dense", "colocated", 8, 1),
    ("dense_int8", "colocated", 8, 1),
    ("dense", "wa", 8, 1),
    ("dense_int8", "wa", 8, 2),          # split-KV shard layout covered
]


@pytest.mark.parametrize("fixture,backend,T,a_shards", CELLS)
def test_preempt_restore_token_identical(fixture, backend, T, a_shards,
                                         request):
    cfg, api, params = request.getfixturevalue(fixture)
    base = _preempt_plan(cfg)
    _engine(api, 3, T=T, backend=backend, a_shards=a_shards)\
        .run(params, base, max_steps=600)
    ref = {r.rid: list(r.generated) for r in base}
    assert all(ref.values())

    test = _preempt_plan(cfg)
    eng = _engine(api, 2, T=T, backend=backend, a_shards=a_shards,
                  preemptible=True, strict_invariants=True)
    stats = eng.run(params, test, max_steps=600)
    got = {r.rid: list(r.generated) for r in test}

    assert stats["preemptions"] >= 1, "the high-priority arrival must preempt"
    assert stats["restores"] >= 1, "the victim must be restored"
    assert got == ref, "preempt-then-restore diverged from uninterrupted"
    for name, rec in stats["runtime"].items():
        assert rec["compiles"] == 1, (name, rec)
    prefix = "serve_wa_" if backend == "wa" else "serve_"
    assert f"{prefix}swap_out" in stats["runtime"]
    assert f"{prefix}swap_in" in stats["runtime"]
    assert all(r.status == "completed" for r in test)
    assert all(r.preemptions >= 1 for r in test if r.rid == 0 or r.rid == 1)\
        or stats["preemptions"] >= 1


@pytest.mark.parametrize("backend", ["colocated", "wa"])
@pytest.mark.parametrize("T", [1, 8])
def test_midblock_eos_then_preempted_readmission_race(dense, backend, T):
    """The retirement/reuse race: victim A is preempted for high-priority
    B; B halts MID-BLOCK (budget 5 with T=8 stops inside the block); the
    freed slot is reused at the very next admission point to RESTORE A.
    A's restored decode must mask out B's stale KV beyond A's true
    length — token equality against the uninterrupted serve proves it."""
    cfg, api, params = dense
    rng = np.random.default_rng(7)
    mk = lambda: [
        Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                           dtype=np.int32).copy(),
                max_new_tokens=18, arrival_step=0, priority=0),
        Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, 5,
                                           dtype=np.int32).copy(),
                max_new_tokens=5, arrival_step=6, priority=3)]
    rng = np.random.default_rng(7)
    base = mk()
    rng = np.random.default_rng(7)
    test = mk()

    _engine(api, 2, T=T, backend=backend).run(params, base, max_steps=600)
    ref = {r.rid: list(r.generated) for r in base}

    eng = _engine(api, 1, T=T, backend=backend, preemptible=True,
                  strict_invariants=True)
    stats = eng.run(params, test, max_steps=600)
    assert stats["preemptions"] == 1 and stats["restores"] == 1
    assert {r.rid: list(r.generated) for r in test} == ref
    assert all(r.status == "completed" for r in test)


# ---------------------------------------------------------------------------
# Structured rejections / SLO policies
# ---------------------------------------------------------------------------

def test_rejection_fields_name_rid_length_and_limit(dense):
    _, api, _ = dense
    eng = _engine(api, 2, chunk=0)
    long = Request(rid=77, prompt=np.arange(PROMPT_LEN + 1, dtype=np.int32),
                   max_new_tokens=4)
    with pytest.raises(RequestRejected) as ei:
        eng.submit(long)
    e = ei.value
    assert isinstance(e, ValueError)                 # backwards compatible
    assert (e.rid, e.length, e.limit, e.limit_name)\
        == (77, PROMPT_LEN + 1, PROMPT_LEN, "prompt_len")
    assert "request 77" in str(e) and "truncat" in str(e)

    chunked = _engine(api, 2, chunk=4)
    big = Request(rid=5, prompt=np.zeros(PROMPT_LEN + CAP, dtype=np.int32),
                  max_new_tokens=4)
    with pytest.raises(RequestRejected) as ei:
        chunked.submit(big)
    assert ei.value.limit_name == "kv_extent"
    assert ei.value.limit == PROMPT_LEN + CAP

    with pytest.raises(RequestRejected) as ei:
        chunked.submit(Request(rid=9, prompt=np.zeros(4, dtype=np.int32),
                               max_new_tokens=0))
    assert ei.value.rid == 9 and ei.value.limit_name == "min max_new_tokens"


def test_expired_ttft_deadline_sheds_as_deadline_missed(dense):
    cfg, api, params = dense
    rng = np.random.default_rng(0)
    slow = Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                              dtype=np.int32),
                   max_new_tokens=10, arrival_step=0)
    doomed = Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, 4,
                                                dtype=np.int32),
                     max_new_tokens=4, arrival_step=0,
                     ttft_deadline_ms=1e-4)          # expires instantly
    eng = _engine(api, 1)
    stats = eng.run(params, [slow, doomed], max_steps=400)
    assert slow.status == "completed" and len(slow.generated) == 10
    assert doomed.status == "deadline_missed"
    assert "ttft_deadline_ms" in doomed.reject_reason
    assert stats["deadline_misses"] == 1
    assert [e["rid"] for e in stats["rejected"]] == [1]


def test_bounded_queue_sheds_lowest_priority(dense):
    cfg, api, params = dense
    rng = np.random.default_rng(1)
    mk = lambda rid, arr, pri, new=6: Request(
        rid=rid, prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                     dtype=np.int32),
        max_new_tokens=new, arrival_step=arr, priority=pri)
    first = mk(0, 0, 0, new=16)
    late = [mk(1, 4, 2), mk(2, 4, 1), mk(3, 4, 0)]
    eng = _engine(api, 1, max_queue=1)
    stats = eng.run(params, [first] + late, max_steps=400)
    assert first.status == "completed"
    assert late[0].status == "completed"             # highest priority kept
    assert {r.status for r in late[1:]} == {"rejected"}
    assert all("queue_full" in r.reject_reason for r in late[1:])
    assert stats["rejections"] == 2
    assert stats["completed"] == 2


# ---------------------------------------------------------------------------
# Dispatch hardening
# ---------------------------------------------------------------------------

class _ScriptedInjector:
    """Deterministically fail the [start, stop) window of dispatches whose
    name contains one of ``targets`` (counting MATCHING dispatches only,
    so the window always lands on the target program)."""

    def __init__(self, targets, start, stop):
        self.targets, self.start, self.stop = targets, start, stop
        self.matches = 0

    def on_dispatch(self, name):
        if not any(t in name for t in self.targets):
            return
        self.matches += 1
        if self.start <= self.matches - 1 < self.stop:
            raise DispatchError(f"scripted failure #{self.matches} "
                                f"for {name}")


def test_transient_dispatch_fault_absorbed_by_retry(dense):
    """A fault window shorter than the retry budget is invisible except
    in the retry counter — every request still completes, tokens exact."""
    cfg, api, params = dense
    rng = np.random.default_rng(2)
    mk = lambda: [Request(rid=i,
                          prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                              dtype=np.int32),
                          max_new_tokens=8, arrival_step=0)
                  for i in range(2)]
    rng = np.random.default_rng(2)
    base = mk()
    rng = np.random.default_rng(2)
    test = mk()
    _engine(api, 2).run(params, base, max_steps=400)
    inj = _ScriptedInjector(["decode"], start=1, stop=2)   # ONE failure
    eng = _engine(api, 2, max_retries=2, fault_injector=inj)
    stats = eng.run(params, test, max_steps=400)
    assert stats["retries"] == 1 and stats["rejections"] == 0
    assert {r.rid: r.generated for r in test}\
        == {r.rid: r.generated for r in base}


def test_persistent_dispatch_failure_demotes_not_hangs(dense):
    """A persistently failing decode dispatch must shed ONE victim as a
    structured rejection (slot quarantined) and keep serving the
    survivor — whose tokens stay byte-identical to a clean run."""
    cfg, api, params = dense
    rng = np.random.default_rng(4)
    mk = lambda: [Request(rid=i,
                          prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                              dtype=np.int32),
                          max_new_tokens=10, arrival_step=0, priority=i)
                  for i in range(2)]
    rng = np.random.default_rng(4)
    base = mk()
    rng = np.random.default_rng(4)
    test = mk()
    _engine(api, 2).run(params, base, max_steps=400)
    ref = {r.rid: list(r.generated) for r in base}

    # fail 4 consecutive decode dispatches: the retry budget (2) exhausts
    # mid-window, whichever request is decoding when the window lands is
    # shed (pop_queue admits the HIGHER-priority rid 1 first, so it is the
    # sole decoder and the only possible victim), and the window's tail is
    # absorbed by the survivor's own retry
    inj = _ScriptedInjector(["decode"], start=1, stop=5)
    eng = _engine(api, 2, max_retries=2, fault_injector=inj,
                  strict_invariants=True)
    stats = eng.run(params, test, max_steps=400)

    victim = next(r for r in test if r.status == "rejected")
    survivor = next(r for r in test if r.status == "completed")
    assert "dispatch_failed" in victim.reject_reason
    assert stats["rejections"] == 1 and stats["quarantined_slots"]
    assert survivor.generated == ref[survivor.rid], \
        "survivor tokens corrupted by the demotion"


def test_failed_swap_out_leaves_victim_decoding(dense):
    """Swap-out is read-only: when ITS dispatch fails, the preemption is
    abandoned and the victim keeps decoding — nobody loses tokens."""
    cfg, api, params = dense
    base = _preempt_plan(cfg)
    _engine(api, 3).run(params, base, max_steps=600)
    ref = {r.rid: list(r.generated) for r in base}

    test = _preempt_plan(cfg)
    inj = _ScriptedInjector(["swap_out"], start=0, stop=10_000)
    eng = _engine(api, 2, preemptible=True, max_retries=1,
                  fault_injector=inj, strict_invariants=True)
    stats = eng.run(params, test, max_steps=600)
    assert stats["preemptions"] == 0 and stats["restores"] == 0
    assert all(r.status == "completed" for r in test)
    assert {r.rid: list(r.generated) for r in test} == ref
