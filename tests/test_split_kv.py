"""Split-KV flash decode tests (DESIGN.md §3 "split-KV flash decode").

Covers the invariants the split-KV ISSUE demands:
- ``decode_attention_split[_bucketed]`` matches the sequential bucketed
  walk for ragged true lengths, including slots whose KV ends mid-shard
  and shards that are entirely past a slot's live extent,
- the serve-level equivalence MATRIX: split-KV decode produces token
  streams byte-identical to the sequential walk for dense and int8-KV ×
  backend {colocated, wa} × block size {1, 8} × a_shards {1, 2, 4} on a
  staggered ragged-length workload,
- the shard-local KV layout helpers (``kv/cache.py``): shard extents,
  clamped shard-local limits, and the pre-dequantization sharded read
  agreeing with the bucketed read it wraps,
- the overlong-prompt left-shift path (``SlotScheduler.next_chunk``)
  stays bit-identical under sequence sharding — the shifted window
  recompute uses GLOBAL positions and shards are a read-time reshape,
- engine validation: a_shards < 1, non-dividing extents, attention-free
  families and drain mode are rejected up front.

Fixtures run in float32 (as in test_wa_backend.py): token equality must
test the LSE-merge semantics, not bf16 accumulation-order luck.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED
from repro.kv.cache import (layer_read_bucket, layer_read_shards,
                            shard_extent, shard_kv_limits)
from repro.models import NULL_CTX, build_model
from repro.models.attention import (decode_attention_bucketed,
                                    decode_attention_split,
                                    decode_attention_split_bucketed)
from repro.runtime.serving import Request, ServingEngine
from repro.runtime.static_runtime import StaticRuntime

PROMPT_LEN = 8


@pytest.fixture(scope="module")
def dense():
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(dtype="float32")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


@pytest.fixture(scope="module")
def dense_int8():
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(dtype="float32",
                                                   kv_dtype="int8")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


def _requests(cfg, plan, seed=0):
    """plan: (max_new, arrival_step[, prompt_len]) — seeded per call so
    identical plans produce identical prompts across engines."""
    rng = np.random.default_rng(seed)
    out = []
    for i, entry in enumerate(plan):
        new, arr, plen = entry if len(entry) == 3 else entry + (PROMPT_LEN,)
        out.append(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, plen,
                                               dtype=np.int32),
                           max_new_tokens=new, arrival_step=arr))
    return out


# true lengths 5/8/11/3: mid-shard ends at every width (extent 40 → shard
# blocks of 40, 20, 10), one prompt past the static width (chunk lane)
RAGGED = [(6, 0, 5), (6, 0, 8), (6, 2, 11), (6, 4, 3)]


def _serve(api, params, plan, backend, T, a_shards, chunk=4, rt=None):
    reqs = _requests(api.config, plan)
    eng = ServingEngine(api, NULL_CTX, 2, PROMPT_LEN,
                        runtime=rt or StaticRuntime(), mode="continuous",
                        max_new_cap=32, block_size=T,
                        kv_bucket_chunk=16 if T > 1 else 0,
                        prefill_chunk=chunk, backend=backend,
                        a_shards=a_shards)
    stats = eng.run(params, reqs, max_steps=400)
    return reqs, stats, eng


# ---------------------------------------------------------------------------
# attention-level: split walk == sequential walk under ragged lengths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_decode_attention_split_bucketed_matches_sequential(n_shards):
    """Ragged live lengths against a 96-wide extent, bucket 48: one row's
    KV ends mid-shard, one exactly at a shard boundary, one within shard 0
    only (every later shard fully masked → merge identity weight)."""
    key = jax.random.key(0)
    B, Hq, n_kv, S, hd = 3, 8, 4, 96, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, n_kv, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, n_kv, S, hd), jnp.float32)
    mask = jnp.arange(S)[None, :] < jnp.array([[20], [24], [7]])
    want = decode_attention_bucketed(q, k, v, mask, NULL_CTX, kv_bucket=48)
    got = decode_attention_split_bucketed(q, k, v, mask, NULL_CTX,
                                          n_shards=n_shards, kv_bucket=48)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # full-extent (kv_bucket=0) identity too
    want0 = decode_attention_bucketed(q, k, v, mask, NULL_CTX)
    got0 = decode_attention_split_bucketed(q, k, v, mask, NULL_CTX,
                                           n_shards=n_shards)
    np.testing.assert_allclose(np.asarray(got0), np.asarray(want0),
                               rtol=1e-6, atol=1e-6)


def test_decode_attention_split_accepts_shard_major_mask():
    """The (B, n_shards, Sb) mask form is the same walk as the flat
    (B, n_shards*Sb) form — serving hands the flat one, the WA layer the
    shard-major one."""
    key = jax.random.key(1)
    B, Hq, n_kv, S, hd, n = 2, 4, 2, 64, 16, 4
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, n_kv, n, S // n, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, n_kv, n, S // n, hd), jnp.float32)
    mask = jnp.arange(S)[None, :] < jnp.array([[37], [64]])
    flat = decode_attention_split(q, k, v, mask, NULL_CTX)
    shaped = decode_attention_split(q, k, v, mask.reshape(B, n, S // n),
                                    NULL_CTX)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(shaped))


def test_split_rejects_non_dividing_extent():
    q = jnp.zeros((1, 4, 16), jnp.float32)
    k = v = jnp.zeros((1, 2, 40, 16), jnp.float32)
    mask = jnp.ones((1, 40), bool)
    with pytest.raises(ValueError, match="not divisible"):
        decode_attention_split_bucketed(q, k, v, mask, NULL_CTX, n_shards=3)


# ---------------------------------------------------------------------------
# cache-level: shard-local KV layout helpers
# ---------------------------------------------------------------------------

def test_shard_extent_and_limits():
    assert shard_extent(40, 1) == 40
    assert shard_extent(40, 4) == 10
    with pytest.raises(ValueError, match="not divisible"):
        shard_extent(40, 3)
    with pytest.raises(ValueError, match=">= 1"):
        shard_extent(40, 0)
    # clamp(global - s*block, 0, block): 17 over 4 blocks of 10
    np.testing.assert_array_equal(np.asarray(shard_kv_limits(17, 4, 10)),
                                  [10, 7, 0, 0])
    np.testing.assert_array_equal(np.asarray(shard_kv_limits(0, 4, 10)),
                                  [0, 0, 0, 0])
    np.testing.assert_array_equal(np.asarray(shard_kv_limits(40, 4, 10)),
                                  [10, 10, 10, 10])


@pytest.mark.parametrize("fixture", ["dense", "dense_int8"])
def test_layer_read_shards_matches_bucketed_read(fixture, request):
    """The sharded read is the bucketed read + a contiguous shard-major
    reshape — byte-identical positions, including the int8 dequantization
    path (scales applied before the reshape)."""
    cfg, api, params = request.getfixturevalue(fixture)
    caches = api.init_caches(2, 40)
    toks = jax.random.randint(jax.random.key(2), (2, PROMPT_LEN), 0,
                              cfg.vocab_size)
    caches, _ = api.prefill(params, {"tokens": toks}, NULL_CTX)
    k_l, v_l = caches.k[0], caches.v[0]
    ks_l = caches.k_scale[0] if caches.k_scale is not None else None
    vs_l = caches.v_scale[0] if caches.v_scale is not None else None
    kb, vb = layer_read_bucket(k_l, v_l, ks_l, vs_l, 16, jnp.float32)
    for n in (1, 2, 4):
        ks, vs = layer_read_shards(k_l, v_l, ks_l, vs_l, 16, n, jnp.float32)
        assert ks.shape == (kb.shape[0], kb.shape[1], n, 16 // n, kb.shape[3])
        np.testing.assert_array_equal(
            np.asarray(ks.reshape(kb.shape)), np.asarray(kb))
        np.testing.assert_array_equal(
            np.asarray(vs.reshape(vb.shape)), np.asarray(vb))


# ---------------------------------------------------------------------------
# serve-level equivalence matrix: split-KV == sequential walk, both backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["colocated", "wa"])
@pytest.mark.parametrize("T", [1, 8])
def test_split_kv_serve_matches_sequential_dense(dense, backend, T):
    cfg, api, params = dense
    base, s_base, _ = _serve(api, params, RAGGED, backend, T, 1)
    assert s_base["completed"] == len(RAGGED)
    for sh in (2, 4):
        split, s_split, _ = _serve(api, params, RAGGED, backend, T, sh)
        assert s_split["completed"] == len(RAGGED)
        assert s_split["a_shards"] == sh
        for a, b in zip(base, split):
            assert a.generated == b.generated, (a.rid, backend, T, sh)


@pytest.mark.parametrize("backend", ["colocated", "wa"])
@pytest.mark.parametrize("T", [1, 8])
def test_split_kv_serve_matches_sequential_int8(dense_int8, backend, T):
    """int8 KV: shards dequantize the same bucketed bytes the sequential
    walk reads — the merge sees identical shard-local values."""
    cfg, api, params = dense_int8
    base, s_base, _ = _serve(api, params, RAGGED, backend, T, 1)
    assert s_base["completed"] == len(RAGGED)
    split, s_split, _ = _serve(api, params, RAGGED, backend, T, 4)
    assert s_split["completed"] == len(RAGGED)
    for a, b in zip(base, split):
        assert a.generated == b.generated, (a.rid, backend, T)


# ---------------------------------------------------------------------------
# overlong-prompt left-shift (PR 3 fix) under sequence sharding
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["colocated", "wa"])
def test_overlong_prompt_left_shift_is_shard_invariant(dense, backend):
    """A 35-token prompt against extent 40 with chunk 16 forces the final
    window to left-shift (start 32 → 24) and recompute positions 24..34.
    The shift math uses GLOBAL kv_extent and shards are a read-time
    reshape over absolute positions, so the recompute must stay
    bit-identical at every width: same token streams AND byte-identical
    PROMPT KV (the decode-appended tail of deeper layers legitimately
    differs in low-order float bits — it sits downstream of the merge's
    different summation order)."""
    cfg, api, params = dense
    plan = [(5, 0, 35), (4, 0, 6)]
    streams, caches = {}, {}
    for sh in (1, 2, 4):
        reqs, stats, eng = _serve(api, params, plan, backend, 8, sh,
                                  chunk=16)
        assert stats["completed"] == len(plan)
        # the 35-token prompt runs chunks at 0/16 then the SHIFTED 24
        assert stats["prefill_chunks"] == 3 + 1
        streams[sh] = [list(r.generated) for r in reqs]
        caches[sh] = (np.asarray(eng._caches.k), np.asarray(eng._caches.v))
    assert streams[1] == streams[2] == streams[4]
    for sh in (2, 4):
        # slot 0 held the 35-token prompt, slot 1 the 6-token one; chunk
        # prefill (incl. the shifted recompute) must not feel the width
        for buf in (0, 1):
            np.testing.assert_array_equal(
                caches[sh][buf][:, 0, :, :35], caches[1][buf][:, 0, :, :35])
            np.testing.assert_array_equal(
                caches[sh][buf][:, 1, :, :6], caches[1][buf][:, 1, :, :6])


# ---------------------------------------------------------------------------
# engine validation
# ---------------------------------------------------------------------------

def test_engine_rejects_invalid_a_shards():
    api = build_model(ASSIGNED["qwen2-0.5b"].reduced())
    with pytest.raises(ValueError, match=">= 1"):
        ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, a_shards=0)
    # extent 8 + 32 = 40 does not cut into 3 equal shard blocks
    with pytest.raises(ValueError, match="not divisible"):
        ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                      max_new_cap=32, a_shards=3)
    with pytest.raises(ValueError, match="drain"):
        ServingEngine(api, NULL_CTX, 2, PROMPT_LEN, mode="drain",
                      a_shards=2)
    ssm = build_model(ASSIGNED["mamba2-1.3b"].reduced())
    with pytest.raises(ValueError, match="KV sequence axis"):
        ServingEngine(ssm, NULL_CTX, 2, PROMPT_LEN, mode="continuous",
                      a_shards=2)


def test_wa_split_requires_sharding_routing():
    """a_shards > 1 is an AOT sharded read; the eager device_put routing
    cannot stage it and must refuse at construction."""
    from repro.core.wa import WADisaggregated
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(dtype="float32")
    with pytest.raises(ValueError, match="sharding"):
        WADisaggregated(cfg, None, routing="device_put", a_shards=2)
    with pytest.raises(ValueError, match=">= 1"):
        WADisaggregated(cfg, None, routing="sharding", a_shards=0)
