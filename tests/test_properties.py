"""Hypothesis property tests on system invariants.

Skipped wholesale when ``hypothesis`` is not installed (it is a dev extra —
see requirements-dev.txt), so the tier-1 suite stays runnable from a bare
environment.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.analytical import (EPYC_9684X, baseline_llama_cpp,
                                   paper_system, stage_latency)
from repro.core.residency import paradox_table
from repro.configs.registry import ASSIGNED
from repro.kv.cache import slot_valid_mask
from repro.quant.int4 import (dequantize_kv_int4, pack_int4,
                              quantize_kv_int4, unpack_int4)
from repro.quant.int8 import (dequantize, dequantize_kv, int8_matmul,
                              quantize_int8, quantize_kv)

SETTINGS = dict(max_examples=25, deadline=None)


# --------------------------------------------------------------------------
# INT8 quantization
# --------------------------------------------------------------------------

@given(st.integers(1, 6), st.integers(1, 48), st.floats(0.1, 100.0))
@settings(**SETTINGS)
def test_int8_roundtrip_error_bound(r, c, scale):
    x = np.linspace(-scale, scale, r * c, dtype=np.float32).reshape(r, c)
    q = quantize_int8(jnp.asarray(x), axis=-1)
    back = np.asarray(dequantize(q, jnp.float32))
    # symmetric int8: error ≤ amax/127 per row (half-step ⇒ /254, keep slack)
    amax = np.abs(x).max(axis=1, keepdims=True)
    assert np.all(np.abs(back - x) <= amax / 127.0 + 1e-6)


@given(st.integers(1, 4), st.integers(8, 64), st.integers(4, 32))
@settings(**SETTINGS)
def test_int8_matmul_relative_error(b, k, n):
    key = jax.random.key(b * 1000 + k * 10 + n)
    x = jax.random.normal(key, (b, k), jnp.float32)
    w = jax.random.normal(jax.random.key(7), (k, n), jnp.float32)
    wq = quantize_int8(w, axis=0)
    got = np.asarray(int8_matmul(x, wq, out_dtype=jnp.float32))
    want = np.asarray(x @ w)
    denom = np.maximum(np.abs(want).max(), 1e-3)
    assert np.abs(got - want).max() / denom < 0.05


# int8 KV round-trip: extreme magnitudes, all-zero rows, empty slices ------

@given(st.integers(0, 5), st.integers(1, 4), st.integers(1, 32),
       st.sampled_from([1e-30, 1e-3, 1.0, 1e4, 1e30]),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_int8_kv_roundtrip_bounded_and_zero_exact(rows, heads, hd, mag,
                                                  seed):
    """``quantize_kv`` → ``dequantize_kv`` stays within amax/127 per row at
    ANY magnitude (1e-30 to 1e30 — the hardened scale never divides by
    zero or denormals), all-zero rows come back EXACTLY zero, and empty
    slices (0 rows — a drained tier) round-trip without error."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((heads, rows, hd)) * mag).astype(np.float32)
    if rows:
        x[:, 0] = 0.0                    # at least one all-zero row
    q, s = quantize_kv(jnp.asarray(x))
    assert q.shape == x.shape and s.shape == x.shape[:-1] + (1,)
    back = np.asarray(dequantize_kv(q, s, jnp.float32))
    amax = np.abs(x).max(axis=-1, keepdims=True)
    # the hardened scale floors at 1e-8 (denormal-proof), so sub-1e-8 rows
    # may round to zero — the bound carries the floor
    bound = np.maximum(amax, 1e-8) / 127.0 + 1e-6 * amax
    assert np.all(np.abs(back - x) <= bound)
    if rows:
        assert not back[:, 0].any(), "all-zero row must dequantize to zero"


# int4 pack/unpack + KV round-trip ------------------------------------------

@given(st.integers(0, 6), st.integers(0, 16), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_int4_pack_unpack_identity(rows, pairs, seed):
    """``unpack_int4 ∘ pack_int4`` is the identity on every int in [-8, 7]
    at any even length — zero-length slices included — and an ODD last
    axis is rejected loudly (the packed tier stores hd // 2 bytes; a
    silent truncation would drop a lane)."""
    rng = np.random.default_rng(seed)
    q = rng.integers(-8, 8, size=(rows, 2 * pairs)).astype(np.int8)
    packed = pack_int4(jnp.asarray(q))
    assert packed.shape == (rows, pairs) and packed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed)), q)
    with pytest.raises(ValueError, match="even"):
        pack_int4(jnp.zeros((rows, 2 * pairs + 1), jnp.int8))


@given(st.integers(0, 5), st.integers(1, 16),
       st.sampled_from([1e-30, 1e-3, 1.0, 1e4, 1e30]),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_int4_kv_roundtrip_bounded_and_zero_exact(rows, pairs, mag, seed):
    """``quantize_kv_int4`` → ``dequantize_kv_int4`` stays within amax/7
    per row at any magnitude, all-zero rows come back exactly zero, empty
    slices round-trip, and the packed container halves head_dim."""
    rng = np.random.default_rng(seed)
    hd = 2 * pairs
    x = (rng.standard_normal((2, rows, hd)) * mag).astype(np.float32)
    if rows:
        x[:, 0] = 0.0
    q, s = quantize_kv_int4(jnp.asarray(x))
    assert q.shape == (2, rows, pairs) and q.dtype == jnp.int8
    assert s.shape == (2, rows, 1) and s.dtype == jnp.float32
    back = np.asarray(dequantize_kv_int4(q, s, jnp.float32))
    assert back.shape == x.shape
    amax = np.abs(x).max(axis=-1, keepdims=True)
    bound = np.maximum(amax, 1e-8) / 7.0 + 1e-6 * amax
    assert np.all(np.abs(back - x) <= bound)
    if rows:
        assert not back[:, 0].any(), "all-zero row must dequantize to zero"


# --------------------------------------------------------------------------
# Online-softmax (flash) merge is order-independent & matches full softmax
# --------------------------------------------------------------------------

@given(st.integers(2, 6), st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_online_softmax_merge(n_blocks, blk, seed):
    rng = np.random.default_rng(seed)
    s = rng.normal(size=(n_blocks, blk)).astype(np.float32) * 5
    v = rng.normal(size=(n_blocks, blk, 3)).astype(np.float32)
    # full softmax
    flat = s.reshape(-1)
    p = np.exp(flat - flat.max())
    p /= p.sum()
    want = p @ v.reshape(-1, 3)
    # online merge over blocks, in a shuffled order
    order = rng.permutation(n_blocks)
    m, l, o = -np.inf, 0.0, np.zeros(3)
    for i in order:
        mb = s[i].max()
        mn = max(m, mb)
        pb = np.exp(s[i] - mn)
        corr = np.exp(m - mn)
        l = l * corr + pb.sum()
        o = o * corr + pb @ v[i]
        m = mn
    np.testing.assert_allclose(o / l, want, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# Ring-buffer cache semantics vs a python simulation
# --------------------------------------------------------------------------

@given(st.integers(1, 40), st.integers(2, 12), st.integers(2, 12))
@settings(**SETTINGS)
def test_ring_buffer_mask_matches_simulation(n_tokens, size, window):
    window = max(window, size)  # ring must be ≥ window... size ≤ window
    size = min(size, window)
    mask = np.asarray(slot_valid_mask(size, window, jnp.int32(n_tokens - 1)))
    # python sim: slot s holds the largest p < n_tokens with p % size == s
    for s in range(size):
        ps = [p for p in range(n_tokens) if p % size == s]
        p = ps[-1] if ps else None
        expect = (p is not None and p > (n_tokens - 1) - window)
        assert mask[s] == expect, (n_tokens, size, window, s, p)


# --------------------------------------------------------------------------
# RG-LRU associative scan == sequential recurrence
# --------------------------------------------------------------------------

@given(st.integers(2, 32), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_linear_recurrence_scan_equals_sequential(T, C, seed):
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.5, 1.0, size=(1, T, C)).astype(np.float32)
    b = rng.normal(size=(1, T, C)).astype(np.float32)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    _, h_scan = jax.lax.associative_scan(combine, (jnp.asarray(a),
                                                   jnp.asarray(b)), axis=1)
    h = np.zeros((1, C), np.float32)
    for t in range(T):
        h = a[:, t] * h + b[:, t]
    np.testing.assert_allclose(np.asarray(h_scan)[:, -1], h, rtol=2e-4,
                               atol=2e-5)


# --------------------------------------------------------------------------
# Analytical model invariants (§2.3, §6.2)
# --------------------------------------------------------------------------

@given(st.sampled_from(sorted(ASSIGNED)), st.sampled_from([1024, 4096]),
       st.sampled_from([1, 8, 32]))
@settings(max_examples=15, deadline=None)
def test_paper_system_never_slower_than_operator_centric(arch, ctx_len, batch):
    cfg = ASSIGNED[arch]
    ours = paper_system(cfg, batch=batch, ctx_len=ctx_len, n_stages=4)
    base = baseline_llama_cpp(cfg, batch=batch, ctx_len=ctx_len, n_stages=4)
    assert ours["tpot_s"] <= base["tpot_s"] * 1.001


def test_kv_pressure_paradox_depth_invariance():
    """§2.3: per-domain KV pressure is pipeline-depth invariant."""
    cfg = ASSIGNED["internlm2-1.8b"]
    tab = paradox_table(cfg, ctx_len=4096, batch=8)
    vals = list(tab.values())
    assert max(vals) - min(vals) < 1e-6 * max(vals)


def test_stage_latency_monotone_in_context():
    cfg = ASSIGNED["granite-3-2b"]
    ls = [stage_latency(cfg, EPYC_9684X, batch=8, ctx_len=c, n_stages=2)
          for c in (512, 2048, 8192)]
    assert ls[0] <= ls[1] <= ls[2]


# --------------------------------------------------------------------------
# MoE dispatch conservation
# --------------------------------------------------------------------------

@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_moe_matches_dense_loop_reference(seed):
    import dataclasses
    from repro.models.moe import make_moe_params, moe_ffn
    from repro.models import NULL_CTX
    cfg = ASSIGNED["phi3.5-moe-42b-a6.6b"].reduced()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=0.0),
                      dtype="float32")
    p = make_moe_params(jax.random.key(seed % 1000), cfg)
    x = jax.random.normal(jax.random.key(seed % 997), (1, 5, cfg.d_model),
                          jnp.float32)
    got, _ = moe_ffn(p, x, cfg, NULL_CTX, train=False)
    # dense loop reference
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, cfg.moe.experts_per_token)
    vals = vals / vals.sum(-1, keepdims=True)
    want = np.zeros_like(np.asarray(xf))
    for t in range(xf.shape[0]):
        for j in range(cfg.moe.experts_per_token):
            e = int(idx[t, j])
            g = np.asarray(jax.nn.silu(xf[t] @ p["w_gate"][e]))
            u = np.asarray(xf[t] @ p["w_up"][e])
            want[t] += float(vals[t, j]) * (g * u) @ np.asarray(p["w_down"][e])
    np.testing.assert_allclose(np.asarray(got).reshape(-1, cfg.d_model),
                               want, rtol=2e-3, atol=2e-3)
