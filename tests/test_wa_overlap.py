"""Sub-operator W/A overlap tests (DESIGN.md §3, overlapped micro-batch
decode).

The pipelined layer loop (``core/wa.py::_layer_loop_pipelined``) splits
each decode dispatch's batch into ``overlap`` micro-batches and runs them
skewed across the W/A boundary — W computes QKV/FFN for one micro-batch
while A attends another. Every op is row-wise over the batch, so the split
must be TOKEN-EXACT, and the schedule is static, so the program set and
the compiles == 1 invariant must not change. Covered here:

- token-exactness matrix: overlapped vs sequential WA vs colocated,
  dense/int8 × T ∈ {1, 8} × overlap ∈ {1, 2, 4} × a_shards ∈ {1, 2},
  chunked AND monolithic admission,
- compiles == 1 per program across engine reuse at depth > 1, with the
  depth surfaced as program metadata and the SAME program names as
  depth 1,
- preempt-then-restore at overlap=2 matches the uninterrupted streams,
- schedule/occupancy arithmetic (``core.pipeline``): depth 1 degenerates
  to the sequential loop (efficiency 0.5), adjacent micro-batches always
  occupy opposite domains at depth >= 2,
- the scheduler's micro-batch occupancy view and the layer loop's row
  split share ONE helper (``core.wa.micro_batch_slices``),
- validation: overlap needs the WA backend, an evenly-dividing slot
  count, and AOT sharding routing.

Float32 fixtures for the same reason as test_wa_backend.py: token equality
must test the schedule's semantics, not bf16 accumulation-order luck.
"""
import jax
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED
from repro.core.pipeline import skewed_schedule, wa_schedule_occupancy
from repro.core.wa import WADisaggregated, micro_batch_slices
from repro.models import NULL_CTX, build_model
from repro.runtime.serving import Request, ServingEngine, SlotScheduler
from repro.runtime.static_runtime import StaticRuntime

PROMPT_LEN = 8
SLOTS = 4                       # divides by every overlap depth under test
CAP = 32

# staggered plan: mid-serve admissions + retirements so micro-batches see
# mixed active masks (idle rows MUST still be token-exact pass-throughs)
PLAN = [(9, 0), (13, 0), (5, 2), (9, 6), (7, 9), (6, 12)]


@pytest.fixture(scope="module")
def dense():
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(dtype="float32")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


@pytest.fixture(scope="module")
def dense_int8():
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(dtype="float32",
                                                   kv_dtype="int8")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


def _requests(cfg, plan, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                        dtype=np.int32),
                    max_new_tokens=new, arrival_step=arr)
            for i, (new, arr) in enumerate(plan)]


def _serve(api, params, backend, T, chunk, overlap=1, a_shards=1, rt=None,
           slots=SLOTS):
    reqs = _requests(api.config, PLAN)
    eng = ServingEngine(api, NULL_CTX, slots, PROMPT_LEN,
                        runtime=rt or StaticRuntime(), mode="continuous",
                        max_new_cap=CAP, block_size=T,
                        kv_bucket_chunk=16 if T > 1 else 0,
                        prefill_chunk=chunk, backend=backend,
                        a_shards=a_shards, overlap=overlap)
    stats = eng.run(params, reqs, max_steps=400)
    assert stats["completed"] == len(PLAN)
    return [list(r.generated) for r in reqs], stats, eng


# one serve per distinct config across the whole matrix (the baselines are
# shared by many cells) — keyed streams, module-lifetime
_STREAMS = {}


def _streams(request, kv, backend, T, chunk, overlap=1, a_shards=1):
    key = (kv, backend, T, chunk, overlap, a_shards)
    if key not in _STREAMS:
        _, api, params = request.getfixturevalue(
            "dense" if kv == "dense" else "dense_int8")
        _STREAMS[key] = _serve(api, params, backend, T, chunk,
                               overlap=overlap, a_shards=a_shards)[0]
    return _STREAMS[key]


# ---------------------------------------------------------------------------
# schedule arithmetic (core.pipeline stage-skew machinery)
# ---------------------------------------------------------------------------

def test_micro_batch_slices_partition_the_batch():
    for batch, depth in [(4, 1), (4, 2), (4, 4), (8, 2), (2, 2)]:
        sls = micro_batch_slices(batch, depth)
        assert len(sls) == depth
        rows = [i for sl in sls for i in range(batch)[sl]]
        assert rows == list(range(batch)), "slices must tile the batch"
    with pytest.raises(ValueError, match="not divide|does not divide"):
        micro_batch_slices(4, 3)
    with pytest.raises(ValueError, match=">= 1"):
        micro_batch_slices(4, 0)


def test_skewed_schedule_shape_and_parity():
    """At any tick the live micro-batches hold CONSECUTIVE op indices, so
    for the alternating W/A chain adjacent micro-batches sit in opposite
    domains — the property the overlap win rests on."""
    for n_ops, depth in [(7, 1), (7, 2), (7, 4), (9, 2)]:
        sched = skewed_schedule(n_ops, depth)
        assert len(sched) == n_ops + depth - 1
        done = {m: [] for m in range(depth)}
        for _t, live in sched:
            ops = [op for _m, op in live]
            assert ops == sorted(ops, reverse=True) or \
                sorted(ops) == list(range(min(ops), min(ops) + len(ops)))
            if len(ops) >= 2:
                assert {op % 2 for op in ops} == {0, 1}
            for m, op in live:
                done[m].append(op)
        # every micro-batch runs its FULL chain in order
        assert all(done[m] == list(range(n_ops)) for m in range(depth))
    with pytest.raises(ValueError):
        skewed_schedule(0, 2)


def test_wa_schedule_occupancy_depth_one_is_sequential():
    L = 3
    occ = wa_schedule_occupancy(L, 1)
    assert occ["total_ticks"] == 2 * L + 1
    assert occ["w_busy_ticks"] == L + 1 and occ["a_busy_ticks"] == L
    assert occ["overlap_efficiency"] == pytest.approx(0.5)
    # efficiency grows strictly with depth toward 1
    effs = [wa_schedule_occupancy(L, d)["overlap_efficiency"]
            for d in (1, 2, 4)]
    assert effs == sorted(effs) and effs[0] < effs[1] < effs[2] < 1.0
    # depth >= 2: only the fill/drain edge ticks leave a domain idle
    occ2 = wa_schedule_occupancy(L, 2)
    assert occ2["w_busy_ticks"] == occ2["total_ticks"]
    assert occ2["a_busy_ticks"] == occ2["total_ticks"] - 2


# ---------------------------------------------------------------------------
# token-exactness matrix: overlapped vs sequential WA vs colocated
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("a_shards", [1, 2])
@pytest.mark.parametrize("overlap", [1, 2, 4])
@pytest.mark.parametrize("T", [1, 8])
@pytest.mark.parametrize("kv", ["dense", "int8"])
def test_overlap_token_exact_chunked(request, kv, T, overlap, a_shards):
    """Chunked admission: every overlap depth must reproduce the
    sequential WA streams (bit-exact — same row-wise math on row slices)
    and the colocated streams (token-exact) on the staggered workload."""
    got = _streams(request, kv, "wa", T, 3, overlap, a_shards)
    seq = _streams(request, kv, "wa", T, 3, 1, a_shards)
    co = _streams(request, kv, "colocated", T, 3)
    assert got == seq, f"overlap={overlap} diverged from sequential WA"
    assert got == co, f"overlap={overlap} diverged from colocated"


@pytest.mark.parametrize("overlap", [2, 4])
def test_overlap_token_exact_monolithic(request, overlap):
    """Monolithic admission (serve_wa_admit full-width chunk) composes
    with the pipelined decode blocks."""
    got = _streams(request, "dense", "wa", 8, 0, overlap)
    seq = _streams(request, "dense", "wa", 8, 0, 1)
    co = _streams(request, "dense", "colocated", 8, 0)
    assert got == seq and got == co


# ---------------------------------------------------------------------------
# compiles == 1 and the unchanged program set
# ---------------------------------------------------------------------------

def test_overlap_compiles_once_same_program_names(dense):
    """Depth is a build-time static: the pipelined engine compiles
    EXACTLY the sequential program names, once each, across engine reuse;
    the depth shows up only as program metadata in stats()."""
    cfg, api, params = dense
    rt = StaticRuntime()
    _, stats, eng = _serve(api, params, "wa", 8, 3, overlap=2, rt=rt)
    assert set(stats["runtime"]) == {
        "serve_wa_prefill_chunk", "serve_wa_decode_block_s16",
        "serve_wa_decode_block_s32", "serve_wa_decode_block_s40"}
    for name, rec in stats["runtime"].items():
        assert rec["compiles"] == 1, (name, rec)
        if "decode_block" in name:
            assert rec["overlap"] == 2     # static_runtime meta plumbing
        else:
            assert "overlap" not in rec    # chunk lane never pipelines
    # engine reuse: a second run recompiles nothing
    stats2 = eng.run(params, _requests(cfg, PLAN), max_steps=400)
    assert stats2["completed"] == len(PLAN)
    assert all(r["compiles"] == 1 for r in stats2["runtime"].values())


def test_depth_one_has_no_meta_key(dense):
    """overlap=1 must compile to today's exact program set — stats records
    carry no overlap annotation at depth 1."""
    _, api, params = dense
    _, stats, _ = _serve(api, params, "wa", 8, 3, overlap=1)
    assert all("overlap" not in rec for rec in stats["runtime"].values())


# ---------------------------------------------------------------------------
# preempt-then-restore at overlap=2
# ---------------------------------------------------------------------------

def test_overlap_preempt_restore_token_identical(dense):
    """The swap pair is cache-only (no layer loop → nothing to pipeline):
    preempt + restore under overlap=2 reproduces the uninterrupted
    streams, and the swap programs join the compile-once set unchanged."""
    cfg, api, params = dense

    def plan(seed=3):
        rng = np.random.default_rng(seed)
        rs = [Request(rid=i,
                      prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                          dtype=np.int32),
                      max_new_tokens=20, arrival_step=0, priority=0)
              for i in range(2)]
        rs.append(Request(rid=2,
                          prompt=rng.integers(0, cfg.vocab_size, 6,
                                              dtype=np.int32),
                          max_new_tokens=6, arrival_step=8, priority=5))
        return rs

    def engine(slots, **kw):
        return ServingEngine(api, NULL_CTX, slots, PROMPT_LEN,
                             mode="continuous", max_new_cap=CAP,
                             block_size=8, kv_bucket_chunk=16,
                             prefill_chunk=4, backend="wa", overlap=2, **kw)

    base = plan()
    engine(4).run(params, base, max_steps=600)      # roomy: no preemption
    ref = {r.rid: list(r.generated) for r in base}
    assert all(ref.values())

    test = plan()
    stats = engine(2, preemptible=True, strict_invariants=True)\
        .run(params, test, max_steps=600)
    assert stats["preemptions"] >= 1 and stats["restores"] >= 1
    assert {r.rid: list(r.generated) for r in test} == ref
    assert {"serve_wa_swap_out", "serve_wa_swap_in"} <= set(stats["runtime"])
    assert all(r["compiles"] == 1 for r in stats["runtime"].values())


# ---------------------------------------------------------------------------
# stall accounting + the scheduler's micro-batch view
# ---------------------------------------------------------------------------

def test_overlap_stats_report_stall_accounting(dense):
    _, api, params = dense
    _, s2, _ = _serve(api, params, "wa", 8, 3, overlap=2)
    wa = s2["wa"]
    L = api.config.n_layers
    occ = wa_schedule_occupancy(L, 2)
    assert wa["overlap"] == 2
    assert wa["overlap_efficiency"] == pytest.approx(
        occ["overlap_efficiency"])
    assert wa["schedule_ticks"] == occ["total_ticks"]
    assert wa["w_idle_ms_per_macro_step"] >= 0.0
    assert wa["a_idle_ms_per_macro_step"] > 0.0   # drain edge ticks
    assert 0.0 < wa["micro_batch_occupancy"] <= 1.0
    # sequential engine reports the degenerate schedule, same keys
    _, s1, _ = _serve(api, params, "wa", 8, 3, overlap=1)
    assert s1["wa"]["overlap"] == 1
    assert s1["wa"]["overlap_efficiency"] == pytest.approx(0.5)
    # routing bytes are depth-invariant: D× hops of B/D rows each
    assert s1["wa"]["routing_total_bytes"] == wa["routing_total_bytes"]


def test_scheduler_micro_batch_view_single_source_of_truth():
    """The scheduler's per-micro-batch membership must be EXACTLY the
    layer loop's row split — both route through micro_batch_slices."""
    sched = SlotScheduler(4, [], [])
    sched.phase = [sched.DECODE, sched.FREE, sched.DECODE, sched.DECODE]
    view = sched.micro_batch_view(2)
    sls = micro_batch_slices(4, 2)
    assert [slots for slots, _ in view] == \
        [list(range(sl.start, sl.stop)) for sl in sls]
    acts = [a.tolist() for _, a in view]
    assert acts == [[True, False], [True, True]]
    # explicit mask override (the dispatch-time mask, not phase-derived)
    view2 = sched.micro_batch_view(4, np.array([False, False, True, False]))
    assert [a.tolist() for _, a in view2] == [[False], [False], [True],
                                              [False]]


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_overlap_validation(dense):
    _, api, _ = dense
    with pytest.raises(ValueError, match="no W↔A hops"):
        ServingEngine(api, NULL_CTX, 4, PROMPT_LEN, backend="colocated",
                      overlap=2)
    with pytest.raises(ValueError, match="does not divide"):
        ServingEngine(api, NULL_CTX, 3, PROMPT_LEN, backend="wa", overlap=2)
    with pytest.raises(ValueError, match=">= 1"):
        ServingEngine(api, NULL_CTX, 4, PROMPT_LEN, backend="wa", overlap=0)
    with pytest.raises(ValueError, match="sharding"):
        WADisaggregated(api.config, None, routing="device_put", overlap=2)
