"""Per-arch smoke tests (REDUCED configs): forward/train step on CPU with
shape + no-NaN assertions, plus prefill→decode consistency for every family.
The FULL configs are exercised only by the dry-run (ShapeDtypeStruct)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs.registry import ASSIGNED
from repro.models import NULL_CTX, build_model

ARCHS = sorted(ASSIGNED)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = ASSIGNED[arch].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    batch = make_batch(cfg)

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: api.loss(p, batch, NULL_CTX)))(params)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads)
             if g.dtype != jnp.int8)
    assert np.isfinite(gn) and gn > 0, f"{arch}: degenerate grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = ASSIGNED[arch].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    B, S = 2, 24
    batch = make_batch(cfg, B=B, S=S)
    batch.pop("labels")
    caches, logits = jax.jit(lambda p, b: api.prefill(p, b, NULL_CTX))(
        params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    caches, logits2 = jax.jit(lambda p, c, t: api.decode(p, c, t, NULL_CTX))(
        params, caches, jnp.zeros((B,), jnp.int32))
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits2))), f"{arch}: NaN decode logits"


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "granite-3-2b",
                                  "qwen2-0.5b", "phi3-medium-14b",
                                  "internvl2-76b", "whisper-medium"])
def test_prefill_decode_equals_full_forward(arch):
    """Exact for attention archs (same math, same dtype path)."""
    cfg = ASSIGNED[arch].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    B, S = 2, 17
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)
    batch = make_batch(cfg, B=B, S=S)
    batch.pop("labels")
    batch["tokens"] = toks[:, :S]
    full = dict(batch, tokens=toks)
    caches, _ = api.prefill(params, batch, NULL_CTX)
    caches, lg = api.decode(params, caches, toks[:, S], NULL_CTX)
    _, lg_full = api.prefill(params, full, NULL_CTX)
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(lg_full[:, 0], np.float32),
                               rtol=3e-2, atol=5e-2)   # bf16 p·v flash path


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "recurrentgemma-9b"])
def test_recurrent_prefill_decode_consistency(arch):
    """f32 exactness for the recurrent families (bf16 adds state noise)."""
    cfg = ASSIGNED[arch].reduced().replace(dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    B, S = 2, 40                        # beyond the reduced window (32)
    toks = jax.random.randint(jax.random.key(1), (B, S + 3), 0, cfg.vocab_size)
    caches, _ = api.prefill(params, {"tokens": toks[:, :S]}, NULL_CTX)
    for i in range(3):
        caches, lg = api.decode(params, caches, toks[:, S + i], NULL_CTX)
    _, lg_full = api.prefill(params, {"tokens": toks}, NULL_CTX)
    a, b = np.asarray(lg[:, 0]), np.asarray(lg_full[:, 0])
    rel = np.max(np.abs(a - b)) / max(np.max(np.abs(b)), 1e-6)
    assert rel < 1e-4, f"{arch}: rel_err {rel}"


@pytest.mark.parametrize("arch", ["qwen3-moe-235b-a22b", "phi3.5-moe-42b-a6.6b"])
def test_moe_nodrop_consistency(arch):
    cfg = ASSIGNED[arch].reduced()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=0.0))
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    B, S = 2, 17
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)
    caches, _ = api.prefill(params, {"tokens": toks[:, :S]}, NULL_CTX)
    caches, lg = api.decode(params, caches, toks[:, S], NULL_CTX)
    _, lg_full = api.prefill(params, {"tokens": toks}, NULL_CTX)
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(lg_full[:, 0], np.float32),
                               rtol=3e-2, atol=5e-2)   # bf16 routing-order noise


def test_int8_kv_cache_decode_close_to_bf16():
    cfg = ASSIGNED["internlm2-1.8b"].reduced()
    api16 = build_model(cfg)
    api8 = build_model(cfg.replace(kv_dtype="int8"))
    params = api16.init(jax.random.key(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)
    c16, _ = api16.prefill(params, {"tokens": toks[:, :S]}, NULL_CTX)
    c8, _ = api8.prefill(params, {"tokens": toks[:, :S]}, NULL_CTX)
    _, l16 = api16.decode(params, c16, toks[:, S], NULL_CTX)
    _, l8 = api8.decode(params, c8, toks[:, S], NULL_CTX)
    a, b = np.asarray(l8[:, 0], np.float32), np.asarray(l16[:, 0], np.float32)
    rel = np.max(np.abs(a - b)) / max(np.max(np.abs(b)), 1e-6)
    assert rel < 0.08, f"int8 KV deviates too much: {rel}"


def test_param_counts_are_sane():
    from repro.models.registry import count_params
    n = count_params(ASSIGNED["qwen3-moe-235b-a22b"])
    na = count_params(ASSIGNED["qwen3-moe-235b-a22b"], active_only=True)
    assert 2.0e11 < n < 2.7e11, n            # ≈235B
    assert 1.5e10 < na < 3.0e10, na          # ≈22B active
    n2 = count_params(ASSIGNED["qwen2-0.5b"])
    assert 3e8 < n2 < 7e8, n2
