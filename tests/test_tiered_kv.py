"""Tiered KV cache tests (DESIGN.md §7).

Covers the tiered-KV ISSUE's invariants:
- ``layer_read_tiered`` resolves every position EXACTLY to the reference:
  the quantize-roundtrip value below the cold boundary, the bit-exact hot
  value at/above it — for bf16, int8 and packed-int4 cold tiers,
- serve-level token exactness: a bf16 cold tier is a pure relayout (streams
  equal the flat cache bit-for-bit, chunked AND monolithic admission), and
  quantized cold tiers produce IDENTICAL streams across every serving lane
  (colocated/WA × T ∈ {1, 8} × a_shards ∈ {1, 2}; monolithic lanes agree
  with each other) — with compiles == 1 while demotions happen in-program,
- tier-spanning preemption: export → import round-trips BOTH tiers'
  stored bytes verbatim (packed int4 nibbles + f32 scales + the hot ring),
  preempt-then-restore serves are token-identical to uninterrupted ones
  (int4 cold under split-KV a_shards=2 included), and a preempted sequence
  re-admitted into the SAME slot after demotion stays exact,
- the host-side KVArbiter: demotions counted from cursor watermarks, tier
  occupancy/live-byte accounting, byte-budget preemption, and the
  engine-level validation errors for invalid tier configs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED
from repro.kv.cache import (cold_boundary, export_slot_kv, import_slot_kv,
                            init_kv_cache, layer_append_tiered,
                            layer_read_tiered)
from repro.models import NULL_CTX, build_model
from repro.quant.int4 import dequantize_kv_int4, quantize_kv_int4
from repro.quant.int8 import dequantize_kv, quantize_kv
from repro.runtime.serving import KVArbiter, Request, ServingEngine

PROMPT_LEN = 8
CAP = 24                     # KV extent 32 — divides by a_shards ∈ {1, 2}
HOT, BLOCK = 4, 4            # hot ring H = 8; boundary advances every 4


def _cfg(cold=None):
    cfg = ASSIGNED["qwen2-0.5b"].reduced().replace(dtype="float32")
    if cold is not None:
        cfg = cfg.replace(hot_window=HOT, kv_cold_dtype=cold,
                          kv_cold_block=BLOCK)
    return cfg


@pytest.fixture(scope="module")
def flat():
    cfg = _cfg()
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


@pytest.fixture(scope="module")
def t_bf16():
    cfg = _cfg("bfloat16")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


@pytest.fixture(scope="module")
def t_int8():
    cfg = _cfg("int8")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


@pytest.fixture(scope="module")
def t_int4():
    cfg = _cfg("int4")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.key(0))


_FX = {"bfloat16": "t_bf16", "int8": "t_int8", "int4": "t_int4"}


def _plan(cfg, seed=0, new=(20, 12, 8)):
    """Staggered arrivals over 2 slots; the longest request crosses the
    cold boundary several times (prompt 8 + 20 tokens, hot 4 / block 4
    → boundary reaches 24: demotions are active mid-serve)."""
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                        dtype=np.int32),
                    max_new_tokens=n, arrival_step=4 * i)
            for i, n in enumerate(new)]


def _engine(api, slots=2, *, T=8, chunk=4, backend="colocated", a_shards=1,
            **kw):
    return ServingEngine(api, NULL_CTX, slots, PROMPT_LEN,
                         mode="continuous", max_new_cap=CAP,
                         block_size=T, kv_bucket_chunk=16 if T > 1 else 0,
                         prefill_chunk=chunk, backend=backend,
                         a_shards=a_shards, **kw)


def _streams(api, params, cfg, **kw):
    reqs = _plan(cfg)
    st = _engine(api, **kw).run(params, reqs, max_steps=800)
    assert all(r.status == "completed" for r in reqs)
    for name, rec in st["runtime"].items():
        assert rec["compiles"] == 1, (name, rec)
    return {r.rid: list(r.generated) for r in reqs}, st


# ---------------------------------------------------------------------------
# Quantizer hardening (deterministic twins of the hypothesis properties)
# ---------------------------------------------------------------------------

def test_quantizers_zero_rows_and_edge_shapes():
    """All-zero rows dequantize to EXACT zero (the hardened scale never
    divides by zero), empty slices round-trip, int4 packing is the
    identity on [-8, 7] and rejects odd lengths."""
    from repro.quant.int4 import pack_int4, unpack_int4
    x = jnp.zeros((2, 3, 8), jnp.float32)
    for quant, dequant in ((quantize_kv, dequantize_kv),
                           (quantize_kv_int4, dequantize_kv_int4)):
        q, s = quant(x)
        back = np.asarray(dequant(q, s, jnp.float32))
        assert not back.any(), "all-zero row must dequantize to zero"
        qe, se = quant(jnp.zeros((2, 0, 8), jnp.float32))
        assert dequant(qe, se, jnp.float32).shape == (2, 0, 8)

    q = jnp.asarray(np.arange(-8, 8, dtype=np.int8).reshape(1, 16))
    np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(q))),
                                  np.asarray(q))
    with pytest.raises(ValueError, match="even"):
        pack_int4(jnp.zeros((1, 3), jnp.int8))


# ---------------------------------------------------------------------------
# KV-level: the tiered read equals the quantize-roundtrip reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cold", ["bfloat16", "int8", "int4"])
def test_layer_read_tiered_matches_roundtrip_reference(cold):
    """Append B rows with different token counts, then check the read
    image position by position: j >= cold_boundary(count) must be the
    BIT-EXACT appended value (hot ring); j < boundary must be the
    quantize-roundtrip of the appended value (cold tier)."""
    B, n_kv, S, hd = 2, 2, 16, 8
    counts = (13, 7)
    cache = init_kv_cache(1, B, n_kv, S, hd, dtype=jnp.float32,
                          hot_window=HOT, cold_block=BLOCK, cold_dtype=cold)
    k_l, v_l = cache.k[0], cache.v[0]
    ks_l = None if cache.k_scale is None else cache.k_scale[0]
    vs_l = None if cache.v_scale is None else cache.v_scale[0]
    hk_l, hv_l = cache.hot_k[0], cache.hot_v[0]

    rng = np.random.default_rng(0)
    ks_raw = rng.normal(size=(max(counts), B, n_kv, hd)).astype(np.float32)
    vs_raw = rng.normal(size=(max(counts), B, n_kv, hd)).astype(np.float32)
    for t in range(max(counts)):
        active = jnp.asarray([t < c for c in counts])
        pos = jnp.full((B,), t, jnp.int32)
        k_l, v_l, ks_l, vs_l, hk_l, hv_l = layer_append_tiered(
            k_l, v_l, ks_l, vs_l, hk_l, hv_l,
            jnp.asarray(ks_raw[t]), jnp.asarray(vs_raw[t]), pos, cold,
            active=active)

    got_k, got_v = layer_read_tiered(
        k_l, v_l, ks_l, vs_l, hk_l, hv_l,
        jnp.asarray(counts, jnp.int32), 0, HOT, BLOCK, cold,
        dtype=jnp.float32)

    def roundtrip(x):
        x = jnp.asarray(x)
        if cold == "int8":
            return np.asarray(dequantize_kv(*quantize_kv(x), jnp.float32))
        if cold == "int4":
            return np.asarray(
                dequantize_kv_int4(*quantize_kv_int4(x), jnp.float32))
        return np.asarray(x)

    for b, count in enumerate(counts):
        cb = int(cold_boundary(np.int32(count), HOT, BLOCK))
        for j in range(count):
            want = ks_raw[j, b] if j >= cb else roundtrip(ks_raw[j, b])
            np.testing.assert_array_equal(
                np.asarray(got_k[b, :, j]), want,
                err_msg=f"k row {b} pos {j} (boundary {cb}, {cold})")
            wantv = vs_raw[j, b] if j >= cb else roundtrip(vs_raw[j, b])
            np.testing.assert_array_equal(
                np.asarray(got_v[b, :, j]), wantv,
                err_msg=f"v row {b} pos {j} (boundary {cb}, {cold})")


# ---------------------------------------------------------------------------
# Serve-level token exactness across lanes
# ---------------------------------------------------------------------------

def test_bf16_cold_streams_equal_flat(flat, t_bf16):
    """The bf16 cold tier stores verbatim — tiering is a pure relayout and
    the served streams must equal the flat cache bit-for-bit, through both
    the chunked lane and the degenerate full-width monolithic admission."""
    cfg, api, params = flat
    _, tapi, tparams = t_bf16
    for kw in (dict(T=8, chunk=4), dict(T=1, chunk=4), dict(T=8, chunk=0)):
        ref, _ = _streams(api, params, cfg, **kw)
        got, st = _streams(tapi, tparams, cfg, **kw)
        assert got == ref, f"bf16-cold diverged from flat under {kw}"
        assert st["tiered"]["demotions"] > 0, "no demotion ever happened"


@pytest.mark.parametrize("cold", ["int8", "int4"])
def test_quantized_cold_streams_identical_across_lanes(cold, request):
    """Every serving lane compiles the same cold_boundary arithmetic, so
    the quantized streams must agree EXACTLY across colocated/WA,
    T ∈ {1, 8} and a_shards ∈ {1, 2} (chunked admission), and the two
    monolithic lanes must agree with each other (monolithic admission
    attends the padded prompt width — a different, internally consistent
    stream)."""
    cfg, api, params = request.getfixturevalue(_FX[cold])
    chunked_lanes = [dict(T=8, chunk=4),
                     dict(T=1, chunk=4),
                     dict(T=8, chunk=4, backend="wa"),
                     dict(T=8, chunk=4, backend="wa", a_shards=2)]
    ref, st = _streams(api, params, cfg, **chunked_lanes[0])
    assert st["tiered"]["demotions"] > 0
    for kw in chunked_lanes[1:]:
        got, _ = _streams(api, params, cfg, **kw)
        assert got == ref, f"{cold} stream diverged under {kw}"
    mono_ref, _ = _streams(api, params, cfg, T=8, chunk=0)
    mono_wa, _ = _streams(api, params, cfg, T=8, chunk=0, backend="wa")
    assert mono_wa == mono_ref, f"{cold} monolithic lanes disagree"


# ---------------------------------------------------------------------------
# Tier-spanning preemption
# ---------------------------------------------------------------------------

def test_tiered_export_import_roundtrip_bytes(t_int4):
    """One slot's BOTH tiers survive export → reset → import verbatim:
    packed int4 cold bytes and f32 scales up to the true length, the hot
    ring at full width, neighbours untouched."""
    _, api, _ = t_int4
    caches = api.init_caches(3, 24)
    rng = np.random.default_rng(0)

    def fill(a):
        if a is None:
            return None
        if a.dtype == jnp.int8:
            return jnp.asarray(rng.integers(-127, 127, a.shape), jnp.int8)
        return jnp.asarray(rng.normal(size=a.shape), a.dtype)

    caches = caches._replace(k=fill(caches.k), v=fill(caches.v),
                             k_scale=fill(caches.k_scale),
                             v_scale=fill(caches.v_scale),
                             hot_k=fill(caches.hot_k),
                             hot_v=fill(caches.hot_v))
    slot, valid = 1, 11
    saved = export_slot_kv(caches, jnp.asarray(slot, jnp.int32))
    assert saved[4] is not None and saved[5] is not None
    zeroed = api.reset_slot(caches, jnp.asarray(slot, jnp.int32))
    assert not np.asarray(zeroed.hot_k[:, slot]).any()
    back = import_slot_kv(zeroed, saved, jnp.asarray(slot, jnp.int32),
                          jnp.asarray(valid, jnp.int32))

    for name in ("k", "v", "k_scale", "v_scale"):
        want, got = getattr(caches, name), getattr(back, name)
        np.testing.assert_array_equal(
            np.asarray(want[:, slot, :, :valid]),
            np.asarray(got[:, slot, :, :valid]),
            err_msg=f"{name}: restored cold bytes differ within valid")
        assert not np.asarray(got[:, slot, :, valid:]).any(), \
            f"{name}: import wrote past the true length"
    for name in ("hot_k", "hot_v"):                  # ring restores VERBATIM
        np.testing.assert_array_equal(
            np.asarray(getattr(caches, name)[:, slot]),
            np.asarray(getattr(back, name)[:, slot]),
            err_msg=f"{name}: hot ring not byte-identical after restore")
    other = [s for s in range(3) if s != slot]
    for name in ("k", "v", "k_scale", "v_scale", "hot_k", "hot_v"):
        np.testing.assert_array_equal(
            np.asarray(getattr(caches, name)[:, other]),
            np.asarray(getattr(back, name)[:, other]),
            err_msg=f"{name}: neighbouring slots touched")


def _preempt_plan(cfg, seed=3):
    rng = np.random.default_rng(seed)
    rs = [Request(rid=i,
                  prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                      dtype=np.int32),
                  max_new_tokens=20, arrival_step=0, priority=0)
          for i in range(2)]
    rs.append(Request(rid=2,
                      prompt=rng.integers(0, cfg.vocab_size, 6,
                                          dtype=np.int32),
                      max_new_tokens=6, arrival_step=8, priority=5))
    return rs


@pytest.mark.parametrize("cold,backend,a_shards", [
    ("int8", "colocated", 1),
    ("int4", "wa", 2),               # packed nibbles + scales under split-KV
])
def test_tiered_preempt_restore_token_identical(cold, backend, a_shards,
                                                request):
    """Victims export BOTH tiers; restore resumes with the cold prefix and
    hot ring bit-identical — 20-token decoders cross the cold boundary
    before AND after the preemption window."""
    cfg, api, params = request.getfixturevalue(_FX[cold])
    base = _preempt_plan(cfg)
    _engine(api, 3, backend=backend, a_shards=a_shards)\
        .run(params, base, max_steps=600)
    ref = {r.rid: list(r.generated) for r in base}
    assert all(ref.values())

    test = _preempt_plan(cfg)
    eng = _engine(api, 2, backend=backend, a_shards=a_shards,
                  preemptible=True, strict_invariants=True)
    stats = eng.run(params, test, max_steps=600)
    assert stats["preemptions"] >= 1 and stats["restores"] >= 1
    assert {r.rid: list(r.generated) for r in test} == ref, \
        "tiered preempt-then-restore diverged from uninterrupted"
    for name, rec in stats["runtime"].items():
        assert rec["compiles"] == 1, (name, rec)
    assert stats["tiered"]["demotions"] > 0


def test_tiered_same_slot_readmission_after_demotion(t_int8):
    """Single slot: rid 0 demotes past the cold boundary, is preempted for
    a high-priority arrival, then re-admitted into the SAME slot (over the
    arrival's stale bytes in both tiers) — tokens must equal the
    uninterrupted serve."""
    cfg, api, params = t_int8
    mk = lambda rng: [
        Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN,
                                           dtype=np.int32).copy(),
                max_new_tokens=18, arrival_step=0, priority=0),
        Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, 5,
                                           dtype=np.int32).copy(),
                max_new_tokens=5, arrival_step=6, priority=3)]
    base = mk(np.random.default_rng(7))
    test = mk(np.random.default_rng(7))

    _engine(api, 2).run(params, base, max_steps=600)
    ref = {r.rid: list(r.generated) for r in base}

    eng = _engine(api, 1, preemptible=True, strict_invariants=True)
    stats = eng.run(params, test, max_steps=600)
    assert stats["preemptions"] == 1 and stats["restores"] == 1
    assert {r.rid: list(r.generated) for r in test} == ref
    assert all(r.status == "completed" for r in test)


# ---------------------------------------------------------------------------
# Host-side arbiter
# ---------------------------------------------------------------------------

def test_arbiter_accounting(t_int8):
    _, api, _ = t_int8
    aval = jax.eval_shape(lambda: api.init_caches(2, PROMPT_LEN + CAP))
    arb = KVArbiter(aval)
    assert arb.kv_bytes_per_slot > 0
    assert arb.cold_bytes_per_token < arb.hot_bytes_per_token

    arb.observe(0, 6)                    # below hot_window: nothing cold
    assert arb.demotions == 0
    assert arb.slot_occupancy(0) == {
        "slot": 0, "tokens": 6, "hot_tokens": 6, "cold_tokens": 0,
        "kv_bytes": 6 * arb.hot_bytes_per_token}
    arb.observe(0, 20)                   # boundary 16 → 4 blocks of 4
    assert arb.demotions == 4
    occ = arb.slot_occupancy(0)
    assert (occ["hot_tokens"], occ["cold_tokens"]) == (4, 16)
    arb.observe(0, 20)                   # no boundary move → no recount
    assert arb.demotions == 4
    arb.observe(1, 10)                   # boundary 4 → one more block
    assert arb.demotions == 5
    live = arb.live_bytes()
    assert live == occ["kv_bytes"] + arb.slot_occupancy(1)["kv_bytes"]
    assert arb.peak_bytes >= live
    # cold tokens live: 16 (slot 0) + 4 (slot 1, boundary of cursor 10)
    assert arb.cold_bytes_saved() == 20 * (arb.hot_bytes_per_token
                                           - arb.cold_bytes_per_token)

    arb.budget = live - 1
    assert arb.over_budget()
    arb.release(1)
    assert not arb.over_budget()

    arb.release(0)
    assert arb.live_bytes() == 0
    assert arb.demotions == 5            # cumulative counters survive
    s = arb.stats()
    assert s["demotions"] == 5 and s["peak_kv_bytes"] == live
    assert s["cold_bytes_saved"] > 0     # peak survives the drain

    # swap-in seeding must NOT recount the restored prefix as demotions
    arb.seed(0, 20)
    arb.observe(0, 24)                   # boundary 16 → 20: ONE new block
    assert arb.demotions == 6


def test_kv_budget_preempts_under_pressure(t_int8):
    """A byte budget below two live slots' occupancy forces the arbiter's
    pressure loop to preempt victims — and every request still completes
    token-exactly via restore."""
    cfg, api, params = t_int8
    base = _plan(cfg)
    _engine(api, 2, preemptible=True).run(params, base, max_steps=800)
    ref = {r.rid: list(r.generated) for r in base}

    aval = jax.eval_shape(lambda: api.init_caches(2, PROMPT_LEN + CAP))
    # below the observed two-busy-slot occupancy (≈ 14.8 KB at the check
    # boundaries of this plan) but far above one slot's — the arbiter must
    # preempt exactly under real pressure, not wedge the run
    budget = KVArbiter(aval).hot_bytes_per_token * 8
    test = _plan(cfg)
    eng = _engine(api, 2, preemptible=True, kv_budget_bytes=budget)
    stats = eng.run(params, test, max_steps=1500)
    assert stats["preemptions"] >= 1, "budget pressure never preempted"
    assert all(r.status == "completed" for r in test)
    assert {r.rid: list(r.generated) for r in test} == ref
    assert stats["tiered"]["kv_budget_bytes"] == budget


def test_tiered_stats_surface(t_int4):
    cfg, api, params = t_int4
    _, st = _streams(api, params, cfg, T=8, chunk=4)
    t = st["tiered"]
    assert t["hot_window"] == HOT and t["cold_block"] == BLOCK
    assert t["cold_dtype"] == "int4"
    assert t["demotions"] > 0
    assert t["kv_bytes_per_slot"] > 0 and t["peak_kv_bytes"] > 0
    assert t["cold_bytes_saved"] > 0
    assert isinstance(t["recommendation"], str) and t["recommendation"]
    # final stats are taken AFTER the drain — the live per-slot view is
    # empty, which is exactly why peaks/recommendation are cached
    assert t["per_slot"] == []
    assert t["live_kv_bytes"] == 0 and t["peak_kv_bytes"] > 0


def test_tier_validation_errors(flat, t_int8):
    cfg, api, params = flat
    _, tapi, _ = t_int8
    with pytest.raises(ValueError, match="continuous"):
        ServingEngine(tapi, NULL_CTX, 2, PROMPT_LEN, mode="drain",
                      max_new_cap=CAP)
    with pytest.raises(ValueError, match="tiered"):
        _engine(api, 2, kv_budget_bytes=1 << 20)        # budget w/o tiers
    with pytest.raises(ValueError, match="kv_budget_bytes"):
        _engine(tapi, 2, kv_budget_bytes=-1)
    with pytest.raises(ValueError, match="subsumes"):
        init_kv_cache(1, 1, 2, 16, 8, quantized=True, hot_window=4,
                      cold_block=4, cold_dtype="int8")
    with pytest.raises(ValueError, match="window"):
        init_kv_cache(1, 1, 2, 16, 8, window=8, hot_window=4,
                      cold_block=4, cold_dtype="int8")
    with pytest.raises(ValueError, match="even head_dim"):
        init_kv_cache(1, 1, 2, 16, 7, hot_window=4, cold_block=4,
                      cold_dtype="int4")
