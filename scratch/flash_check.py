import jax, jax.numpy as jnp, numpy as np
from repro.models.attention import flash_attention

def naive(q, k, v, causal=True, window=0):
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,btkh->bkgqt", qg, k.astype(jnp.float32)) / np.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    m = jnp.ones((Sq, k.shape[1]), bool)
    if causal: m &= kpos <= qpos
    if window: m &= kpos > qpos - window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, hd)

key = jax.random.key(0)
B, S, Hq, Hkv, hd = 2, 64, 4, 2, 16
q = jax.random.normal(jax.random.key(1), (B, S, Hq, hd), jnp.float32)
k = jax.random.normal(jax.random.key(2), (B, S, Hkv, hd), jnp.float32)
v = jax.random.normal(jax.random.key(3), (B, S, Hkv, hd), jnp.float32)
for causal, window, qc in [(True,0,16),(True,0,64),(False,0,16),(True,24,16)]:
    o1 = flash_attention(q, k, v, causal, window, qc, qc)
    o2 = naive(q, k, v, causal, window)
    err = float(jnp.max(jnp.abs(o1 - o2)))
    print(f"causal={causal} window={window} qc={qc}: max_err={err:.2e}")
