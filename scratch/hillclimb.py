"""Hillclimb runner: compile selected (arch × shape) cells under named
variants and append records to artifacts/dryrun/hillclimb.jsonl.

Usage: python scratch/hillclimb.py <cell> <variant>
  cells:   qwen2-decode | moe-train | phi3-decode
  variants: see VARIANTS below
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import sys
sys.path.insert(0, "src")

from repro.configs import registry as R
from repro.launch.dryrun import run_cell

OUT = "artifacts/dryrun/hillclimb.jsonl"

CELLS = {
    "qwen2-decode": ("qwen2-0.5b", "decode_32k"),
    "moe-train": ("qwen3-moe-235b-a22b", "train_4k"),
    "phi3-decode": ("phi3-medium-14b", "decode_32k"),
    "phi3-prefill": ("phi3-medium-14b", "prefill_32k"),
    "internvl-train": ("internvl2-76b", "train_4k"),
    "mamba-long": ("mamba2-1.3b", "long_500k"),
}


def with_cfg(arch, **kw):
    R.REGISTRY[arch] = R.REGISTRY[arch].replace(**kw)
    if arch in R.ASSIGNED:
        R.ASSIGNED[arch] = R.REGISTRY[arch]


def main():
    cell, variant = sys.argv[1], sys.argv[2]
    arch, shape = CELLS[cell]
    executor = "sub_operator"
    pod = "dp"
    multi = "--multi" in sys.argv
    tag = variant

    if variant == "baseline":
        pass
    elif variant == "operator_centric":
        executor = "operator_centric"
    elif variant == "seqkv":
        executor = "sub_operator+seqkv"
    elif variant == "seqkv+int8w":
        executor = "sub_operator+seqkv"
        with_cfg(arch, weight_int8=True)
    elif variant == "int8w":
        with_cfg(arch, weight_int8=True)
    elif variant == "pp":
        executor = "sub_operator+seqkv"
        pod = "pp"
        multi = True
    elif variant == "moe-noembedw":
        # expert weights already 2-axis sharded (experts×mlp_shard); FSDP's
        # embed_w on D forces a (E,C,F) cross-data partial-sum per layer
        import repro.models.param_specs as ps
        ps._RULES = [
            (m, tuple("embed" if (x == "embed_w" and "moe" in m) else x
                      for x in log))
            for m, log in ps._RULES
        ]
    elif variant == "moe-microbatch":
        # gradient accumulation: 4 microbatches — quarters activation temps
        os.environ["REPRO_GRAD_MICROBATCH"] = "4"
    else:
        raise SystemExit(f"unknown variant {variant}")

    rec = run_cell(arch, shape, multi_pod=multi, executor=executor,
                   pod_strategy=pod)
    rec["variant"] = tag
    rec["cell"] = cell
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "a") as f:
        f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
