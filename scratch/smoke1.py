"""Quick CPU sanity: reduced configs, forward + loss + prefill + decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs.registry import REGISTRY
from repro.models import NULL_CTX, build_model

which = sys.argv[1:] or ["internlm2-1.8b"]
for name in which:
    cfg = REGISTRY[name].reduced()
    api = build_model(cfg)
    key = jax.random.key(0)
    params = api.init(key)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    B, S = 2, 32
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((B, cfg.encoder.n_frames, cfg.d_model),
                                   jnp.float32)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.ones((B, cfg.n_vision_tokens, cfg.d_model),
                                          jnp.float32)
    loss = jax.jit(lambda p, b: api.loss(p, b, NULL_CTX))(params, batch)
    caches, logits = jax.jit(lambda p, b: api.prefill(p, b, NULL_CTX))(params, batch)
    tok = jnp.ones((B,), jnp.int32)
    caches, logits2 = jax.jit(lambda p, c, t: api.decode(p, c, t, NULL_CTX))(
        params, caches, tok)
    print(f"{name}: params={n} loss={float(loss):.3f} "
          f"prefill_logits={logits.shape} decode_logits={logits2.shape} "
          f"nan={bool(jnp.isnan(loss)) or bool(jnp.any(jnp.isnan(logits2)))}")
