"""prefill(S tokens) + decode(token S) must equal forward(S+1 tokens) logits."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import REGISTRY
from repro.models import NULL_CTX, build_model
from repro.models import common

for name in (sys.argv[1:] or ["internlm2-1.8b"]):
    cfg = REGISTRY[name].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    B, S = 2, 17
    key = jax.random.key(1)
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    batch_full = {"tokens": toks}
    if cfg.family == "audio":
        fr = jax.random.normal(jax.random.key(2),
                               (B, cfg.encoder.n_frames, cfg.d_model))
        batch["frames"] = fr
        batch_full["frames"] = fr
    if cfg.family == "vlm":
        ve = jax.random.normal(jax.random.key(3),
                               (B, cfg.n_vision_tokens, cfg.d_model))
        batch["vision_embeds"] = ve
        batch_full["vision_embeds"] = ve
    caches, lg_prefill = api.prefill(params, batch, NULL_CTX)
    caches, lg_decode = api.decode(params, caches, toks[:, S], NULL_CTX)
    _, lg_full = api.prefill(params, batch_full, NULL_CTX)
    a = np.asarray(lg_decode[:, 0], np.float32)
    b = np.asarray(lg_full[:, 0], np.float32)
    err = np.max(np.abs(a - b)) / max(np.max(np.abs(b)), 1e-6)
    print(f"{name}: rel_err={err:.2e} {'OK' if err < 3e-2 else 'FAIL'}")
