import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys, time
sys.path.insert(0, "src")
from repro.configs.registry import ASSIGNED
from repro.configs.shapes import ALL_SHAPES
from repro.launch.dryrun import run_cell

multi = "--multi" in sys.argv
out = f"artifacts/dryrun/baseline_{'multi' if multi else 'single'}.jsonl"
os.makedirs(os.path.dirname(out), exist_ok=True)
done = set()
if os.path.exists(out):
    for line in open(out):
        r = json.loads(line)
        done.add((r["arch"], r["shape"], r["executor"]))

t0 = time.time()
for arch in ASSIGNED:
    for shape in ALL_SHAPES:
        execs = ["sub_operator"]
        if shape.mode == "decode":
            execs.append("sub_operator+seqkv")
        for ex in execs:
            if (arch, shape.name, ex) in done:
                continue
            rec = run_cell(arch, shape.name, multi_pod=multi, executor=ex)
            with open(out, "a") as f:
                f.write(json.dumps(rec) + "\n")
            print(f"  [{time.time()-t0:7.0f}s elapsed]", flush=True)
print("SWEEP DONE", time.time() - t0)
