# Single gate every PR runs. `make test` is the tier-1 verify from ROADMAP.md.
PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-fast test-long test-chaos bench-smoke bench-serve verify-static lint check

test:            ## tier-1 verify (full suite, fail fast)
	python -m pytest -x -q

test-fast:       ## skip the slow multi-device subprocess tests
	python -m pytest -x -q --ignore=tests/test_distributed.py

test-long:       ## 8-device split-KV serve (long-context A-domain matrix)
	python -m pytest -x -q tests/test_distributed.py -k split_kv

test-chaos:      ## seeded fault-injection schedules (25+ deterministic chaos runs + preemption suite)
	python -m pytest -x -q tests/test_chaos.py tests/test_preemption.py

bench-smoke:     ## fast benchmark subset (CSV sanity; serve_tpot exercises the colocated-vs-WA backend scenario on every PR)
	python -m benchmarks.run table2_end_to_end fig10_runtime serve_tpot

bench-serve:     ## serving TPOT/TTFT per-step vs macro-step (BENCH_serving.json)
	python -m benchmarks.run serve_tpot

verify-static:   ## static program verifier: every serving program, full matrix, dry-run mesh
	python -m repro.analysis.verify --preset full --mesh 2,4

lint:            ## ruff (pinned in requirements-dev.txt); compileall fallback when absent
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks examples && \
		python -m compileall -q scratch; \
	else \
		echo "ruff not installed -- falling back to the syntax gate"; \
		python -m compileall -q src tests benchmarks examples scratch; \
	fi

check: lint test verify-static
