"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention, pattern 2 recurrent : 1 local-attn.
[arXiv:2402.19427; unverified]

Sub-quadratic: local attention window (2048) bounds the KV working set and the
RG-LRU state is O(1) in context — so long_500k decode IS runnable.
"""
from repro.configs.base import LOCAL_ATTN, RGLRU, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    norm="rmsnorm",
    act="geglu",
    rope_theta=10000.0,
    rglru=RGLRUConfig(lru_width=4096, conv_width=4,
                      block_pattern=(RGLRU, RGLRU, LOCAL_ATTN), window=2048),
    subquadratic=True,
    tie_embeddings=True,
    source="[arXiv:2402.19427; unverified]",
)
