"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536(expert)
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,                 # per-expert intermediate size (moe_intermediate_size)
    vocab_size=151936,
    head_dim=128,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1000000.0,
    moe=MoEConfig(num_experts=128, experts_per_token=8, expert_d_ff=1536),
    source="[hf:Qwen/Qwen3-30B-A3B; hf]",
)
