"""Model / run configuration system.

Every architecture in the assigned pool is expressed as a ``ModelConfig``.
The config is a plain frozen dataclass (hashable → usable as an AOT compile-cache
key in runtime/static_runtime.py, mirroring the paper's static shard→core maps).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds for heterogeneous stacks (recurrentgemma interleaves RG-LRU and
# local attention; mamba2 is all-SSD; everything else is uniform attention).
# ---------------------------------------------------------------------------
ATTN = "attn"            # full (global) GQA attention
LOCAL_ATTN = "local"     # sliding-window GQA attention
RGLRU = "rglru"          # RG-LRU recurrent block (Griffin)
SSD = "ssd"              # Mamba-2 state-space-duality block


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int          # top-k
    expert_d_ff: int                # per-expert hidden size
    # capacity factor for expert-parallel dispatch (tokens per expert slot)
    capacity_factor: float = 1.25
    # number of dense (shared) ffn units run for every token, 0 for pure MoE
    num_shared_experts: int = 0
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2                 # d_inner = expand * d_model
    conv_width: int = 4
    n_groups: int = 1
    chunk: int = 256                # SSD chunk length for train/prefill

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0              # 0 → d_model
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = (RGLRU, RGLRU, LOCAL_ATTN)
    window: int = 2048              # local attention sliding window


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper) / frontend (vlm) archs."""
    n_layers: int = 0
    n_frames: int = 1500            # precomputed frame/patch embeddings (stub frontend)
    is_causal: bool = False


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- normalization / activation / position ---
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | geglu | gelu_mlp
    rope_theta: float = 10000.0
    pos: str = "rope"               # rope | learned | sinusoidal
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # --- optional sub-configs ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    # --- vlm stub ---
    n_vision_tokens: int = 0        # prepended precomputed patch embeddings
    # --- numerics ---
    dtype: str = "bfloat16"         # activation/weight compute dtype
    kv_dtype: str = "bfloat16"      # "int8" enables quantized KV (paper default)
    weight_int8: bool = False       # int8 weight storage (paper default INT8)
    # --- tiered KV cache (DESIGN.md §7): hot_window > 0 splits each slot's
    # KV into a hot ring (most recent tokens, compute dtype, exact) and a
    # cold tier (older tokens, kv_cold_dtype, demoted in kv_cold_block
    # chunks). Geometry is a build-time static — like a_shards, it is baked
    # into the compiled programs and never retraces.
    hot_window: int = 0             # 0 → flat (untiered) KV cache
    kv_cold_dtype: str = "int8"     # cold tier storage: bfloat16 | int8 | int4
    kv_cold_block: int = 16         # demotion granularity (tokens)
    # --- long-context capability flag (sub-quadratic decoding) ---
    subquadratic: bool = False
    # --- source provenance: [source; verified-tier] from the assignment ---
    source: str = ""

    # ------------------------------------------------------------------
    def block_kinds(self) -> Tuple[str, ...]:
        """Per-layer temporal-mixing kind for the decoder stack."""
        if self.family == "ssm":
            return tuple([SSD] * self.n_layers)
        if self.family == "hybrid":
            pat = self.rglru.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        return tuple([ATTN] * self.n_layers)

    # --- parameter counting (exact, from shapes) -----------------------
    def param_count(self) -> int:
        from repro.models.registry import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, active_only=True)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # --- reduced config for CPU smoke tests ----------------------------
    def reduced(self) -> "ModelConfig":
        """Same family/shape *structure*, tiny sizes — for smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 3 if self.family != "hybrid" else 3),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4,
                experts_per_token=min(self.moe.experts_per_token, 2),
                expert_d_ff=64)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=32, chunk=16)
        if self.rglru is not None:
            kw["rglru"] = dataclasses.replace(self.rglru, lru_width=0, window=32)
        if self.encoder is not None:
            kw["encoder"] = dataclasses.replace(self.encoder, n_layers=2, n_frames=16)
        if self.n_vision_tokens:
            kw["n_vision_tokens"] = 4
        return self.replace(name=self.name + "-reduced", **kw)
