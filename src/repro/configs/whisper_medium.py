"""whisper-medium [audio] — 24L d_model=1024 16H (kv=16, full MHA) d_ff=4096
vocab=51865 — enc-dec, conv frontend (stub). [arXiv:2212.04356; unverified]

Frontend stub: ``input_specs()`` supplies precomputed log-mel frame embeddings
(batch, n_frames=1500, d_model) in place of the conv1d/mel pipeline.
Decode shapes lower the DECODER serve_step (enc-dec archs do have decode).
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,               # decoder layers; encoder is a separate 24L stack
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    norm="layernorm",
    act="gelu_mlp",
    pos="learned",
    qkv_bias=True,
    encoder=EncoderConfig(n_layers=24, n_frames=1500, is_causal=False),
    source="[arXiv:2212.04356; unverified]",
)
