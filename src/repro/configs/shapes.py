"""Assigned input-shape set (identical across the 10 LM-family archs).

Each cell is (arch × shape); ``mode`` selects which step function is lowered:
  train   -> train_step   (tokens+labels, optimizer update)
  prefill -> prefill_step (context encode, build KV/state)
  decode  -> serve_step   (ONE new token against a seq_len-deep KV/state)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


TRAIN_4K = ShapeConfig("train_4k", seq_len=4096, global_batch=256, mode="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, mode="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32768, global_batch=128, mode="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524288, global_batch=1, mode="decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def applicable(config, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch × shape) cell is runnable, and why not if skipped.

    long_500k decode requires sub-quadratic attention (SSM / hybrid); pure
    full-attention archs skip it per the assignment, and the skip is recorded.
    """
    if shape.name == "long_500k" and not config.subquadratic:
        return False, ("skip: pure full-attention arch — 512k dense-KV decode is "
                       "the quadratic regime this shape excludes (DESIGN.md §6)")
    return True, ""
