"""mamba2-1.3b [ssm] — 48L d_model=2048 (attn-free) d_ff=0 vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060; unverified]

Attention-free: decode state is O(1) in context, so long_500k runs.
WA separation is inapplicable (no growing KV to decouple) — see DESIGN.md §6.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,                 # attention-free
    n_kv_heads=0,
    d_ff=0,                    # no separate MLP; mixing lives in the SSD block
    vocab_size=50280,
    head_dim=64,
    norm="rmsnorm",
    act="swiglu",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, n_groups=1),
    subquadratic=True,
    tie_embeddings=True,
    source="[arXiv:2405.21060; unverified]",
)
