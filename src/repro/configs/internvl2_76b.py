"""internvl2-76b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT + (Llama3-70B-style) LM backbone. [arXiv:2404.16821; unverified]

Modality frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (batch, n_vision_tokens, d_model); only the
transformer backbone is implemented/lowered.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=500000.0,
    n_vision_tokens=256,
    source="[arXiv:2404.16821; unverified]",
)
