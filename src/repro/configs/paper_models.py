"""The paper's own evaluated models (Table 1) — used by the benchmark harness to
reproduce Tables 1/2 and Figures 8/9/10/11. All are standard Llama/Qwen dense
decoders; the paper deploys them fully INT8 (weights AND KV), which we mirror
via ``weight_int8=True, kv_dtype="int8"``.
"""
from repro.configs.base import ModelConfig

LLAMA32_3B = ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192,
    vocab_size=128256, head_dim=128, rope_theta=500000.0, tie_embeddings=True,
    weight_int8=True, kv_dtype="int8",
    source="[paper Table 1]",
)

LLAMA2_7B = ModelConfig(
    name="llama2-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11008,
    vocab_size=32000, head_dim=128, rope_theta=10000.0,
    weight_int8=True, kv_dtype="int8",
    source="[paper Table 1]",
)

QWEN3_8B = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12288,
    vocab_size=151936, head_dim=128, rope_theta=1000000.0,
    weight_int8=True, kv_dtype="int8",
    source="[paper Table 1]",
)

LLAMA2_70B = ModelConfig(
    name="llama2-70b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab_size=32000, head_dim=128, rope_theta=10000.0,
    weight_int8=True, kv_dtype="int8",
    source="[paper Table 1]",
)

PAPER_MODELS = {m.name: m for m in (LLAMA32_3B, LLAMA2_7B, QWEN3_8B, LLAMA2_70B)}
