from repro.configs.base import (  # noqa: F401
    ATTN, LOCAL_ATTN, RGLRU, SSD,
    EncoderConfig, ModelConfig, MoEConfig, RGLRUConfig, SSMConfig,
)
from repro.configs.shapes import (  # noqa: F401
    ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
    ShapeConfig, applicable,
)
