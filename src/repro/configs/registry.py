"""Architecture registry: ``--arch <id>`` → ModelConfig.

The 10 assigned pool archs + the paper's own 4 deployments.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig
from repro.configs import (
    qwen3_moe_235b, phi35_moe_42b, whisper_medium, internlm2_1p8b,
    granite3_2b, phi3_medium_14b, qwen2_0p5b, internvl2_76b,
    recurrentgemma_9b, mamba2_1p3b,
)
from repro.configs.paper_models import PAPER_MODELS

# Assigned pool (ids exactly as in the assignment).
ASSIGNED: Dict[str, ModelConfig] = {
    "qwen3-moe-235b-a22b": qwen3_moe_235b.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b.CONFIG,
    "whisper-medium": whisper_medium.CONFIG,
    "internlm2-1.8b": internlm2_1p8b.CONFIG,
    "granite-3-2b": granite3_2b.CONFIG,
    "phi3-medium-14b": phi3_medium_14b.CONFIG,
    "qwen2-0.5b": qwen2_0p5b.CONFIG,
    "internvl2-76b": internvl2_76b.CONFIG,
    "recurrentgemma-9b": recurrentgemma_9b.CONFIG,
    "mamba2-1.3b": mamba2_1p3b.CONFIG,
}

REGISTRY: Dict[str, ModelConfig] = dict(ASSIGNED)
REGISTRY.update(PAPER_MODELS)


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch]


def list_archs(assigned_only: bool = False):
    return sorted(ASSIGNED if assigned_only else REGISTRY)
