"""repro — TPU-native reproduction of "Cache-Resident LLM Inference in
GB-Scale Last-Level Caches" (Zhang et al., 2026).

Subpackages:
    core        the paper's contribution: execution models (operator-centric
                vs sub-operator), WA disaggregation, residency planning,
                hierarchical collectives, PP-over-pods, analytical model
    models      the architecture zoo (dense/MoE/enc-dec/SSM/hybrid/VLM)
    kernels     Pallas TPU kernels (int8 GEMV, flash decode, fused FFN)
    kv, quant, optim, data, checkpoint, runtime    substrates
    configs     assigned archs + paper models + input shapes
    launch      mesh, dry-run, roofline, train/serve drivers
"""
__version__ = "1.0.0"
