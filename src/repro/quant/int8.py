"""INT8 quantization (paper §5: fully INT8 weights *and* KV cache).

Symmetric per-channel quantization. On TPU the int8×int8→int32 MXU path gives
2× peak (394 TOP/s on v5e) and halves HBM/ICI bytes — both roofline terms move.

``QuantizedTensor`` is a pytree so it flows through jit/shard_map/scan and can
be sharded like any other parameter.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class QuantizedTensor(NamedTuple):
    """int8 values + f32 scale broadcastable against ``values``."""
    values: jax.Array      # int8
    scale: jax.Array       # float32, shape = values.shape with quantized axes size-1

    @property
    def shape(self):
        return self.values.shape

    @property
    def dtype(self):
        return self.values.dtype


def quantize_int8(x: jax.Array, axis=None) -> QuantizedTensor:
    """Symmetric int8 quantization; ``axis`` = reduction axes for the scale
    (i.e. one scale per remaining channel). axis=None → per-tensor."""
    if axis is None:
        axis = tuple(range(x.ndim))
    elif isinstance(axis, int):
        axis = (axis,)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return QuantizedTensor(q, scale)


def dequantize(q: QuantizedTensor, dtype=jnp.bfloat16) -> jax.Array:
    return (q.values.astype(jnp.float32) * q.scale).astype(dtype)


def int8_matmul(x: jax.Array, w: QuantizedTensor,
                out_dtype=jnp.bfloat16) -> jax.Array:
    """x @ w for int8 weights: activation quantized per-row on the fly
    (SmoothQuant-style W8A8), accumulation in int32 — the VNNI analogue the
    paper uses; on TPU this hits the int8 MXU path.

    x: (..., K) float; w.values: (K, N) int8 with per-output-channel scale (1, N).
    """
    xq = quantize_int8(x, axis=-1)                       # per-row scale (..., 1)
    acc = jax.lax.dot_general(
        xq.values, w.values,
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * xq.scale * w.scale.reshape(1, -1)).astype(out_dtype)


# ---------------------------------------------------------------------------
# KV-cache quantization: one scale per (batch, position, kv_head) row so late
# tokens don't inherit early tokens' dynamic range.
# ---------------------------------------------------------------------------

def quantize_kv(kv: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """kv: (..., head_dim) → (int8 values, f32 scales broadcastable).

    All-zero rows (reset slots, padded chunk tails) take scale 1.0: the
    quantized values are zeros either way, and the scale stays strictly
    positive on every backend — including flush-to-zero denormal handling,
    where a tiny floor could silently become 0 and dequantize to NaN."""
    amax = jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0.0, jnp.maximum(amax, 1e-8), 127.0) / 127.0
    q = jnp.clip(jnp.round(kv.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(values: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (values.astype(jnp.float32) * scale).astype(dtype)


def maybe_quantize_weight(w: jax.Array, enabled: bool,
                          axis: Optional[int] = 0):
    """Config-driven weight quantization at init/checkpoint-load time."""
    if not enabled:
        return w
    return quantize_int8(w, axis=axis)
