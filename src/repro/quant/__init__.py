from repro.quant.int8 import (  # noqa: F401
    QuantizedTensor, dequantize, quantize_int8, int8_matmul,
    quantize_kv, dequantize_kv,
)
