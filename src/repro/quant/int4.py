"""INT4 KV quantization: two nibbles packed per int8 byte along head_dim.

The tiered KV cache's coldest storage format (DESIGN.md §7): symmetric
4-bit quantization with one f32 scale per (batch, head, position) row —
the same scale shape as the int8 path ``(..., S, 1)`` so every piece of
scale plumbing (export/import, buffers, sharding pins) is format-agnostic.
Packing halves the stored head_dim: a ``(..., S, hd)`` bf16 tier becomes a
``(..., S, hd // 2)`` int8 container + ``(..., S, 1)`` f32 scales — 0.25×
the bytes of bf16 (plus the amortized scale column).

Packing layout: byte ``i`` holds elements ``2i`` (low nibble) and
``2i + 1`` (high nibble), both stored as two's-complement 4-bit values in
[-8, 7]. head_dim must be even (every config in the registry is).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# symmetric 4-bit range: [-8, 7] — we clip to ±7 so the grid is symmetric
# around zero (the same choice the int8 path makes with ±127)
_QMAX = 7


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int8 values in [-8, 7] pairwise along the last axis:
    ``(..., 2n)`` int8 → ``(..., n)`` int8, byte i = (q[2i] & 0xF) |
    (q[2i+1] << 4). The arithmetic runs in uint8 (shifts on values > 127
    are well-defined) and the container is bitcast back to int8 so the
    packed tier shares the int8 cold-storage dtype."""
    if q.shape[-1] % 2:
        raise ValueError(f"pack_int4 needs an even last axis, got {q.shape}")
    u = jax.lax.bitcast_convert_type(q.astype(jnp.int8), jnp.uint8)
    lo = u[..., 0::2] & jnp.uint8(0x0F)
    hi = u[..., 1::2] & jnp.uint8(0x0F)
    packed = lo | (hi << jnp.uint8(4))
    return jax.lax.bitcast_convert_type(packed, jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of ``pack_int4``: ``(..., n)`` int8 → ``(..., 2n)`` int8 with
    each nibble sign-extended back to [-8, 7]."""
    u = jax.lax.bitcast_convert_type(packed.astype(jnp.int8), jnp.uint8)
    lo = (u & jnp.uint8(0x0F)).astype(jnp.int32)
    hi = ((u >> jnp.uint8(4)) & jnp.uint8(0x0F)).astype(jnp.int32)
    sext = lambda x: jnp.where(x >= 8, x - 16, x)
    pair = jnp.stack([sext(lo), sext(hi)], axis=-1)       # (..., n, 2)
    return pair.reshape(*packed.shape[:-1],
                        packed.shape[-1] * 2).astype(jnp.int8)


def quantize_kv_int4(kv: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """kv: (..., head_dim) → (packed int8 (..., head_dim // 2), f32 scales
    (..., 1)). One scale per (batch, head, position) row, exactly like
    ``quantize_kv`` — all-zero rows take scale 1.0 so dequantization is an
    exact zero, never 0/0."""
    amax = jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0.0, jnp.maximum(amax, 1e-8), float(_QMAX))\
        / float(_QMAX)
    q = jnp.clip(jnp.round(kv.astype(jnp.float32) / scale),
                 -_QMAX, _QMAX).astype(jnp.int8)
    return pack_int4(q), scale


def dequantize_kv_int4(packed: jax.Array, scale: jax.Array,
                       dtype=jnp.bfloat16) -> jax.Array:
    """(..., head_dim // 2) int8 + (..., 1) f32 → (..., head_dim) values."""
    return (unpack_int4(packed).astype(jnp.float32) * scale).astype(dtype)
