"""JAX version-compat shims.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
(and renamed ``check_rep`` → ``check_vma``, replaced the ``auto`` axis set with
its complement ``axis_names``). The repo targets both API generations: library
code and subprocess test snippets import :func:`shard_map` from here instead of
touching ``jax`` directly.
"""
from __future__ import annotations

from typing import Callable, FrozenSet, Optional

import jax


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a per-device LIST of dicts on
    0.4.x and a plain dict on newer JAX; normalize to one dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca


def axis_size(axis_name) -> jax.Array:
    """``jax.lax.axis_size`` appeared after 0.4.x; fall back to psum(1)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f: Callable, mesh, in_specs, out_specs,
              axis_names: Optional[FrozenSet[str]] = None,
              check_vma: Optional[bool] = None,
              check_rep: Optional[bool] = None):
    """Dispatch to ``jax.shard_map`` when present, else the experimental one.

    ``axis_names``: the MANUAL axes (new-API convention). Omitted → manual over
    every mesh axis. ``check_vma``/``check_rep`` are aliases (new/old names).
    """
    check = check_vma if check_vma is not None else check_rep
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = frozenset(axis_names)
        if check is not None:
            kw["check_vma"] = check
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    if check is not None:
        kw["check_rep"] = check
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
