"""Weight–Attention (WA) disaggregated execution (paper §3.1 / §4.1).

The paper splits each transformer layer across two sockets: a *weight node*
(QKV proj + FFN, weights resident, no KV) and an *attention node* (owns KV
state, runs attention). Activations — "only embeddings" — hop W→A→W per
layer. TPU instantiation: two SUBMESHES of the pod with two AOT-compiled
programs and device_put routing between them (the honest JAX analogue of two
pinned per-socket thread pools; on hardware the transfer lowers to ICI).

The split is decided by ``core.residency.plan`` — WA separation is *optional*
and only pays under cache pressure (paper Fig 9: 1.00× at 3B, 1.16× at 70B);
``wa_plan`` encodes that policy.

This module provides:
  - ``split_mesh``        : carve (data) rows into weight/attention groups,
  - ``wa_plan``           : profitability policy from the residency report,
  - ``WADisaggregated``   : a decode engine running weight-ops on the W
                            submesh and attention on the A submesh with
                            explicit activation routing (runnable on CPU
                            devices; unit-tested for equivalence with the
                            colocated executor),
  - ``routing_bytes``     : per-token W↔A traffic for the roofline
                            collective term (2 hops × B × d_model / layer).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.core.residency import plan as residency_plan
from repro.models import common
from repro.models.attention import decode_attention, qkv_project
from repro.models.sharding import ShardingCtx, sub_operator
from repro.kv.cache import layer_append, layer_read, slot_valid_mask


# ---------------------------------------------------------------------------
# Mesh split + policy
# ---------------------------------------------------------------------------

def split_mesh(mesh: Mesh, weight_rows: int) -> Tuple[Mesh, Mesh]:
    """Split the data axis: first ``weight_rows`` rows → weight submesh,
    rest → attention submesh (paper: CPU1=weight socket, CPU2=attn socket)."""
    devs = mesh.devices
    assert devs.ndim == 2, "split on the single-pod (data, model) mesh"
    w = Mesh(devs[:weight_rows], mesh.axis_names)
    a = Mesh(devs[weight_rows:], mesh.axis_names)
    return w, a


@dataclass(frozen=True)
class WAPlan:
    separate: bool
    weight_rows: int
    attention_rows: int
    reason: str


def wa_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> WAPlan:
    n_rows = mesh.devices.shape[0]
    n_chips = int(np.prod(mesh.devices.shape))
    if cfg.family == "ssm":
        return WAPlan(False, n_rows, 0,
                      "attention-free: no growing KV to decouple "
                      "(DESIGN.md §6 — WA inapplicable)")
    rep = residency_plan(cfg, shape, n_chips)
    if not rep.wa_profitable:
        return WAPlan(False, n_rows, 0,
                      "co-located hot set within budget; separation would "
                      "waste sockets (paper Fig 9 small-model regime)")
    half = n_rows // 2
    return WAPlan(True, half, n_rows - half, rep.notes)


def routing_bytes(cfg: ModelConfig, batch: int, bytes_per_el: int = 2) -> int:
    """Per-decoded-token W↔A activation traffic: 2 hops per layer of the
    (B, d_model) embedding — the paper's 'only embeddings move'."""
    return 2 * cfg.n_layers * batch * cfg.d_model * bytes_per_el


# ---------------------------------------------------------------------------
# Disaggregated decode engine (dense family)
# ---------------------------------------------------------------------------

class WADisaggregated:
    """Two-program decode: weight program (QKV+FFN halves) on the W submesh,
    attention program on the A submesh, activations routed per layer.

    Layer split (paper Fig 5b):
        W: x → ln1 → QKV proj ───route q,k,v───→ A: append KV, attention
        W: o·Wo + residual + ln2 + FFN ←──route o──┘
    """

    def __init__(self, cfg: ModelConfig, mesh: Mesh, plan: WAPlan):
        self.cfg = cfg
        self.plan = plan
        self.w_mesh, self.a_mesh = split_mesh(mesh, plan.weight_rows)
        self.w_ctx = ShardingCtx(self.w_mesh, sub_operator(False))
        self.a_ctx = ShardingCtx(self.a_mesh, sub_operator(False))

    # -- single layer pieces (weight side) ------------------------------
    def _w_qkv(self, lp, x):
        cfg, ctx = self.cfg, self.w_ctx
        h = common.apply_norm(cfg.norm, lp["ln1"], x, cfg.norm_eps)
        pos = self._pos
        B = x.shape[0]
        return qkv_project(lp["attn"], h, cfg, ctx,
                           jnp.full((B, 1), pos, jnp.int32))

    def _w_post(self, lp, x, o):
        from repro.models.transformer import ffn_apply
        cfg, ctx = self.cfg, self.w_ctx
        B = x.shape[0]
        o = common.linear(lp["attn"]["wo"], o.reshape(B, 1, -1))
        x = x + o
        h = common.apply_norm(cfg.norm, lp["ln2"], x, cfg.norm_eps)
        return x + ffn_apply(lp["ffn"], h, cfg, ctx)

    # -- attention side ---------------------------------------------------
    def _a_attend(self, kv_slices, q, k, v, pos, window=0):
        k_l, v_l, ks_l, vs_l = kv_slices
        k_l, v_l, ks_l, vs_l = layer_append(k_l, v_l, ks_l, vs_l,
                                            k[:, 0], v[:, 0], pos, window)
        kc, vc = layer_read(k_l, v_l, ks_l, vs_l, dtype=q.dtype)
        mask = slot_valid_mask(k_l.shape[2], window, pos)
        o = decode_attention(q[:, 0], kc, vc, mask, self.a_ctx)
        return (k_l, v_l, ks_l, vs_l), o

    # -- route helpers ------------------------------------------------------
    def _to_a(self, x):
        return jax.device_put(x, NamedSharding(self.a_mesh,
                                               P("data", None, None)))

    def _to_w(self, x):
        return jax.device_put(x, NamedSharding(self.w_mesh,
                                               P("data", None, None)))

    # -- decode step --------------------------------------------------------
    def decode_step(self, params, caches, tokens):
        """Python-orchestrated per-layer routing. params live on W (weights
        resident, no KV there); caches live on A. Used for correctness and
        for the Fig 11 breakdown; the analytical model covers scaling."""
        cfg = self.cfg
        self._pos = caches["length"]
        pos = self._pos
        x = common.embed(params["embed"], tokens[:, None], self.w_ctx)
        L = cfg.n_layers
        for i in range(L):
            lp = jax.tree.map(lambda a: a[i], params["blocks"])
            q, k, v = self._w_qkv(lp, x)
            # W → A : route per-head activations (the "embeddings move" hop)
            q, k, v = self._to_a(q), self._to_a(k), self._to_a(v)
            kv_i = tuple(None if c is None else c[i]
                         for c in (caches["k"], caches["v"],
                                   caches["k_scale"], caches["v_scale"]))
            kv_i, o = self._a_attend(kv_i, q, k, v, pos)
            caches["k"] = caches["k"].at[i].set(kv_i[0])
            caches["v"] = caches["v"].at[i].set(kv_i[1])
            if kv_i[2] is not None:
                caches["k_scale"] = caches["k_scale"].at[i].set(kv_i[2])
                caches["v_scale"] = caches["v_scale"].at[i].set(kv_i[3])
            # A → W
            o = self._to_w(o[:, None])
            x = self._w_post(lp, x, o)
        x = common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
        from repro.models.transformer import unembed_table
        logits = common.unembed_logits(unembed_table(params, cfg), x, self.w_ctx)
        caches["length"] = pos + 1
        return caches, logits
