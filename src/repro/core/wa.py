"""Weight–Attention (WA) disaggregated execution (paper §3.1 / §4.1).

The paper splits each transformer layer across two sockets: a *weight node*
(QKV proj + FFN, weights resident, no KV) and an *attention node* (owns KV
state, runs attention). Activations — "only embeddings" — hop W→A→W per
layer. TPU instantiation: two SUBMESHES of the pod with two AOT-compiled
programs and device_put routing between them (the honest JAX analogue of two
pinned per-socket thread pools; on hardware the transfer lowers to ICI).

The split is decided by ``core.residency.plan`` — WA separation is *optional*
and only pays under cache pressure (paper Fig 9: 1.00× at 3B, 1.16× at 70B);
``wa_plan`` encodes that policy.

This module provides:
  - ``split_mesh``        : carve (data) rows into weight/attention groups,
  - ``wa_plan``           : profitability policy from the residency report,
  - ``WADisaggregated``   : a decode engine running weight-ops on the W
                            submesh and attention on the A submesh with
                            explicit activation routing (runnable on CPU
                            devices; unit-tested for equivalence with the
                            colocated executor),
  - ``routing_bytes``     : per-token W↔A traffic for the roofline
                            collective term (2 hops × B × d_model / layer).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.core.residency import plan as residency_plan
from repro.models import common
from repro.models.attention import decode_attention, qkv_project
from repro.models.sharding import ShardingCtx, sub_operator
from repro.kv.cache import (KVCache, batch_valid_mask, layer_append,
                            layer_append_slotted, layer_read, slot_valid_mask)


# ---------------------------------------------------------------------------
# Mesh split + policy
# ---------------------------------------------------------------------------

def split_mesh(mesh: Mesh, weight_rows: int) -> Tuple[Mesh, Mesh]:
    """Split the data axis: first ``weight_rows`` rows → weight submesh,
    rest → attention submesh (paper: CPU1=weight socket, CPU2=attn socket)."""
    devs = mesh.devices
    assert devs.ndim == 2, "split on the single-pod (data, model) mesh"
    w = Mesh(devs[:weight_rows], mesh.axis_names)
    a = Mesh(devs[weight_rows:], mesh.axis_names)
    return w, a


@dataclass(frozen=True)
class WAPlan:
    separate: bool
    weight_rows: int
    attention_rows: int
    reason: str


def wa_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> WAPlan:
    n_rows = mesh.devices.shape[0]
    n_chips = int(np.prod(mesh.devices.shape))
    if cfg.family == "ssm":
        return WAPlan(False, n_rows, 0,
                      "attention-free: no growing KV to decouple "
                      "(DESIGN.md §6 — WA inapplicable)")
    rep = residency_plan(cfg, shape, n_chips)
    if not rep.wa_profitable:
        return WAPlan(False, n_rows, 0,
                      "co-located hot set within budget; separation would "
                      "waste sockets (paper Fig 9 small-model regime)")
    half = n_rows // 2
    return WAPlan(True, half, n_rows - half, rep.notes)


def routing_bytes(cfg: ModelConfig, batch: int, bytes_per_el: int = 2) -> int:
    """Per-decoded-token W↔A activation traffic: 2 hops per layer of the
    (B, d_model) embedding — the paper's 'only embeddings move'."""
    return 2 * cfg.n_layers * batch * cfg.d_model * bytes_per_el


# ---------------------------------------------------------------------------
# Disaggregated decode engine (dense family)
# ---------------------------------------------------------------------------

class WADisaggregated:
    """Two-program decode: weight program (QKV+FFN halves) on the W submesh,
    attention program on the A submesh, activations routed per layer.

    Layer split (paper Fig 5b):
        W: x → ln1 → QKV proj ───route q,k,v───→ A: append KV, attention
        W: o·Wo + residual + ln2 + FFN ←──route o──┘
    """

    def __init__(self, cfg: ModelConfig, mesh: Mesh, plan: WAPlan):
        self.cfg = cfg
        self.plan = plan
        self.w_mesh, self.a_mesh = split_mesh(mesh, plan.weight_rows)
        self.w_ctx = ShardingCtx(self.w_mesh, sub_operator(False))
        self.a_ctx = ShardingCtx(self.a_mesh, sub_operator(False))

    # -- single layer pieces (weight side) ------------------------------
    def _w_qkv(self, lp, x, positions):
        """positions: (B,1) int32 — per-row RoPE phase (continuous batching
        admits rows at different depths, so the W side must rotate per-row)."""
        cfg, ctx = self.cfg, self.w_ctx
        h = common.apply_norm(cfg.norm, lp["ln1"], x, cfg.norm_eps)
        return qkv_project(lp["attn"], h, cfg, ctx, positions)

    def _w_post(self, lp, x, o):
        from repro.models.transformer import ffn_apply
        cfg, ctx = self.cfg, self.w_ctx
        B = x.shape[0]
        o = common.linear(lp["attn"]["wo"], o.reshape(B, 1, -1))
        x = x + o
        h = common.apply_norm(cfg.norm, lp["ln2"], x, cfg.norm_eps)
        return x + ffn_apply(lp["ffn"], h, cfg, ctx)

    # -- attention side ---------------------------------------------------
    def _a_attend(self, kv_slices, q, k, v, pos, window=0):
        k_l, v_l, ks_l, vs_l = kv_slices
        k_l, v_l, ks_l, vs_l = layer_append(k_l, v_l, ks_l, vs_l,
                                            k[:, 0], v[:, 0], pos, window)
        kc, vc = layer_read(k_l, v_l, ks_l, vs_l, dtype=q.dtype)
        mask = slot_valid_mask(k_l.shape[2], window, pos)
        o = decode_attention(q[:, 0], kc, vc, mask, self.a_ctx)
        return (k_l, v_l, ks_l, vs_l), o

    def _a_attend_slotted(self, kv_slices, q, k, v, positions, active,
                          window=0):
        """Per-slot cursors live WITH the KV on the attention node — the
        weight node never tracks who occupies which slot (admission is an
        A-side state change, matching the paper's ownership split)."""
        k_l, v_l, ks_l, vs_l = kv_slices
        k_l, v_l, ks_l, vs_l = layer_append_slotted(
            k_l, v_l, ks_l, vs_l, k[:, 0], v[:, 0], positions, window, active)
        kc, vc = layer_read(k_l, v_l, ks_l, vs_l, dtype=q.dtype)
        mask = batch_valid_mask(k_l.shape[2], window, positions)
        o = decode_attention(q[:, 0], kc, vc, mask, self.a_ctx)
        return (k_l, v_l, ks_l, vs_l), o

    # -- route helpers ------------------------------------------------------
    def _to_a(self, x):
        return jax.device_put(x, NamedSharding(self.a_mesh,
                                               P("data", None, None)))

    def _to_w(self, x):
        return jax.device_put(x, NamedSharding(self.w_mesh,
                                               P("data", None, None)))

    # -- decode step --------------------------------------------------------
    def _layer_loop(self, params, cache: KVCache, tokens, positions, attend):
        """Shared per-layer W→A→W routing. ``positions``: (B,1) per-row RoPE
        phase; ``attend(kv_slices, q, k, v)`` runs the A-side program and
        returns (updated slices, o). Returns (new k/v/scale stacks, logits)."""
        cfg = self.cfg
        x = common.embed(params["embed"], tokens[:, None], self.w_ctx)
        k_st, v_st = cache.k, cache.v
        ks_st, vs_st = cache.k_scale, cache.v_scale
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["blocks"])
            q, k, v = self._w_qkv(lp, x, positions)
            # W → A : route per-head activations (the "embeddings move" hop)
            q, k, v = self._to_a(q), self._to_a(k), self._to_a(v)
            kv_i = tuple(None if c is None else c[i]
                         for c in (k_st, v_st, ks_st, vs_st))
            kv_i, o = attend(kv_i, q, k, v)
            k_st = k_st.at[i].set(kv_i[0])
            v_st = v_st.at[i].set(kv_i[1])
            if kv_i[2] is not None:
                ks_st = ks_st.at[i].set(kv_i[2])
                vs_st = vs_st.at[i].set(kv_i[3])
            # A → W
            o = self._to_w(o[:, None])
            x = self._w_post(lp, x, o)
        x = common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
        from repro.models.transformer import unembed_table
        logits = common.unembed_logits(unembed_table(params, cfg), x,
                                       self.w_ctx)
        return (k_st, v_st, ks_st, vs_st), logits

    def decode_step(self, params, cache: KVCache, tokens):
        """Python-orchestrated per-layer routing. params live on W (weights
        resident, no KV there); KV lives on A. Used for correctness and
        for the Fig 11 breakdown; the analytical model covers scaling."""
        pos = cache.length
        B = tokens.shape[0]
        (k, v, ks, vs), logits = self._layer_loop(
            params, cache, tokens, jnp.full((B, 1), pos, jnp.int32),
            lambda kv_i, q, kk, vv: self._a_attend(kv_i, q, kk, vv, pos,
                                                   window=cache.window))
        return cache._replace(k=k, v=v, k_scale=ks, v_scale=vs,
                              length=pos + 1), logits

    def decode_step_slotted(self, params, cache: KVCache, tokens,
                            positions, active):
        """Continuous-batching decode in the WA-decoupled path: per-slot
        cursors + active mask (DESIGN.md §7). Slot admission itself is the
        same ``write_slot_kv`` the colocated engine uses — the A node owns
        the KV, so admission touches only A-side state."""
        (k, v, ks, vs), logits = self._layer_loop(
            params, cache, tokens, positions[:, None],
            lambda kv_i, q, kk, vv: self._a_attend_slotted(
                kv_i, q, kk, vv, positions, active, window=cache.window))
        new_len = jnp.maximum(
            cache.length, jnp.max(jnp.where(active, positions, 0)) + 1)
        return cache._replace(k=k, v=v, k_scale=ks, v_scale=vs,
                              length=new_len), logits
