"""Weight–Attention (WA) disaggregated execution (paper §3.1 / §4.1).

The paper splits each transformer layer across two sockets: a *weight node*
(QKV proj + FFN, weights resident, no KV) and an *attention node* (owns KV
state, runs attention). Activations — "only embeddings" — hop W→A→W per
layer.

TPU instantiation, in two routing modes:

- ``routing="device_put"`` (eager, two SUBMESHES): carve the pod into a
  weight submesh and an attention submesh and move the per-layer activations
  between them with explicit ``jax.device_put`` — the honest JAX analogue of
  two pinned per-socket thread pools (on hardware the transfer lowers to
  ICI). Python-orchestrated per layer; used for the Fig 11 breakdown and the
  equivalence demos. A ``device_put`` across disjoint device sets cannot be
  staged into ONE compiled program, so this mode stays per-step/eager.

- ``routing="sharding"`` (AOT, one mesh): the serving path. The W and A
  domains become two *sharding regimes* over the single serving mesh — the W
  domain keeps the sub-operator rules (weights + per-head activations on the
  model axis), the A domain keeps the KV-sequence-sharded rules
  (``seq_sharded_kv``: the cache's positions live distributed, attention
  reductions are the LSE-merge collectives — the paper's "add attention
  nodes" axis). The W→A / A→W hops are ``with_sharding_constraint``
  boundaries inside the compiled program (``jax.device_put``-free inner
  loop), so ``StaticRuntime`` can AOT-compile whole macro-step blocks and
  prefill chunks around the routed layer loop — compiles == 1 across a
  staggered serve. With ``mesh=None`` (single-device dry-run) the
  constraints are no-ops and the math is the colocated math exactly.

The split is decided by ``core.residency.plan`` — WA separation is *optional*
and only pays under cache pressure (paper Fig 9: 1.00× at 3B, 1.16× at 70B);
``wa_plan`` encodes that policy.

This module provides:
  - ``split_mesh``        : carve (data) rows into weight/attention groups,
  - ``wa_plan``           : profitability policy from the residency report,
  - ``WADisaggregated``   : the W/A decode engine — eager per-step routing
                            (device_put mode) plus the AOT serving programs
                            ``decode_step_slotted`` / ``decode_block`` /
                            ``prefill_chunk`` (sharding mode) consumed by
                            ``runtime.serving.WABackend``,
  - ``routing_bytes``     : per-token W↔A traffic for the roofline
                            collective term (2 hops × B × d_model / layer).

Per-slot cursors, KV buckets and halt masks are all A-SIDE state: admission
(`prefill_chunk` KV writes), the length-aware bucket walk
(``layer_read_bucket``) and retirement masks live with the KV; the W side
only ever sees routed activations and per-row RoPE phases (DESIGN.md §3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.core.pipeline import skewed_schedule
from repro.core.residency import plan as residency_plan
from repro.models import common
from repro.models.attention import chunk_attention, chunk_attention_tiered,\
    decode_attention, decode_attention_split, qkv_project
from repro.models.registry import make_decode_block
from repro.models.sharding import ShardingCtx, seq_sharded_kv, sub_operator
from repro.kv.cache import (KVCache, batch_valid_mask, chunk_hot_image,
                            cold_boundary, export_slot_kv, import_slot_kv,
                            layer_append, layer_append_slotted,
                            layer_append_tiered, layer_read,
                            layer_read_bucket, layer_read_shards,
                            layer_read_slot, layer_read_slot_cold,
                            layer_read_tiered, layer_read_tiered_shards,
                            layer_write_chunk, layer_write_chunk_tiered,
                            slot_valid_mask)

# canonical order of a WA program's per-layer cache stacks; scale and hot
# entries are None for flat/unquantized caches and flow through untouched
_STACK_FIELDS = ("k", "v", "k_scale", "v_scale", "hot_k", "hot_v")


# ---------------------------------------------------------------------------
# Mesh split + policy
# ---------------------------------------------------------------------------

def split_mesh(mesh: Mesh, weight_rows: int) -> Tuple[Mesh, Mesh]:
    """Split the data axis: first ``weight_rows`` rows → weight submesh,
    rest → attention submesh (paper: CPU1=weight socket, CPU2=attn socket)."""
    devs = mesh.devices
    assert devs.ndim == 2, "split on the single-pod (data, model) mesh"
    w = Mesh(devs[:weight_rows], mesh.axis_names)
    a = Mesh(devs[weight_rows:], mesh.axis_names)
    return w, a


@dataclass(frozen=True)
class WAPlan:
    separate: bool
    weight_rows: int
    attention_rows: int
    reason: str


def wa_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> WAPlan:
    n_rows = mesh.devices.shape[0]
    n_chips = int(np.prod(mesh.devices.shape))
    if cfg.family == "ssm":
        return WAPlan(False, n_rows, 0,
                      "attention-free: no growing KV to decouple "
                      "(DESIGN.md §6 — WA inapplicable)")
    rep = residency_plan(cfg, shape, n_chips)
    if not rep.wa_profitable:
        return WAPlan(False, n_rows, 0,
                      "co-located hot set within budget; separation would "
                      "waste sockets (paper Fig 9 small-model regime)")
    half = n_rows // 2
    return WAPlan(True, half, n_rows - half, rep.notes)


def routing_bytes(cfg: ModelConfig, batch: int, bytes_per_el: int = 2) -> int:
    """Per-decoded-token W↔A activation traffic: 2 hops per layer of the
    (B, d_model) embedding — the paper's 'only embeddings move'. Invariant
    under ``overlap``: depth D routes D× as many hops each carrying B/D
    rows, so the analytic total is the same at every depth."""
    return 2 * cfg.n_layers * batch * cfg.d_model * bytes_per_el


def micro_batch_slices(batch: int, depth: int) -> Tuple[slice, ...]:
    """Contiguous per-micro-batch row slices for overlap depth ``depth`` —
    the SINGLE source of truth for per-micro-batch slot membership, shared
    by the pipelined layer loop below and the ``SlotScheduler``'s occupancy
    view (``runtime/serving.py``), so the overlap path cannot drift from
    the scheduler's idea of which slots ride which micro-batch."""
    if depth < 1:
        raise ValueError(f"overlap depth must be >= 1, got {depth}")
    if batch % depth:
        raise ValueError(
            f"batch {batch} does not divide into overlap depth {depth} "
            "equal micro-batches (pick slots divisible by overlap)")
    m = batch // depth
    return tuple(slice(i * m, (i + 1) * m) for i in range(depth))


# ---------------------------------------------------------------------------
# Statically-identifiable hop markers
# ---------------------------------------------------------------------------
# The sharding-mode W↔A hops are plain with_sharding_constraint boundaries,
# which on reduced test configs can degrade to a replicated spec (e.g. 4
# heads on an 8-wide model axis) and become indistinguishable from any other
# annotation in the jaxpr. Wrapping each hop in a named inner jit gives the
# static verifier (repro.analysis.routing_check) a stable anchor: a ``pjit``
# eqn whose name is WA_HOP_TO_A / WA_HOP_TO_W, regardless of how the spec
# degraded. Semantically identical to the bare constraint.

WA_HOP_TO_A = "wa_hop_to_a"
WA_HOP_TO_W = "wa_hop_to_w"


def _make_hop(tag: str):
    def hop(x, sharding):
        return jax.lax.with_sharding_constraint(x, sharding)
    hop.__name__ = tag
    return jax.jit(hop, static_argnums=(1,))


_hop_to_a = _make_hop(WA_HOP_TO_A)
_hop_to_w = _make_hop(WA_HOP_TO_W)


def _tagged_ann(hop, ctx: ShardingCtx, x, logical):
    """ctx.ann with the constraint routed through a named hop marker."""
    if ctx.mesh is None or ctx.mesh.empty:
        return x
    spec = ctx.spec(tuple(logical), x.shape)
    return hop(x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Disaggregated decode engine (dense family)
# ---------------------------------------------------------------------------

class WADisaggregated:
    """Weight-ops on the W domain, attention on the A domain, activations
    routed per layer.

    Layer split (paper Fig 5b):
        W: x → ln1 → QKV proj ───route q,k,v───→ A: append KV, attention
        W: o·Wo + residual + ln2 + FFN ←──route o──┘

    ``routing="device_put"``: W/A are disjoint submeshes (``plan`` required)
    and the hops are eager ``jax.device_put`` transfers — per-step only.
    ``routing="sharding"``: W/A are two sharding regimes over ONE mesh
    (``mesh`` may be None for the single-device dry-run) and the hops are
    ``with_sharding_constraint`` boundaries — jit-safe, so
    ``decode_block``/``prefill_chunk`` AOT-compile (the serving backend).
    """

    def __init__(self, cfg: ModelConfig, mesh: Optional[Mesh],
                 plan: Optional[WAPlan] = None, *,
                 routing: str = "device_put", a_shards: int = 1,
                 overlap: int = 1):
        if routing not in ("device_put", "sharding"):
            raise ValueError(routing)
        if a_shards < 1:
            raise ValueError(f"a_shards must be >= 1, got {a_shards}")
        if a_shards > 1 and routing != "sharding":
            raise ValueError(
                "split-KV decode (a_shards > 1) is an AOT sharded read — "
                "build WADisaggregated(routing='sharding')")
        if overlap < 1:
            raise ValueError(f"overlap must be >= 1, got {overlap}")
        if overlap > 1 and routing != "sharding":
            raise ValueError(
                "sub-operator overlap (overlap > 1) software-pipelines the "
                "layer loop inside ONE compiled program — build "
                "WADisaggregated(routing='sharding')")
        self.cfg = cfg
        self.plan = plan
        self.routing = routing
        # a_shards > 1: split-KV flash decode — each slot's KV walk splits
        # into a_shards contiguous blocks along the sequence axis (the
        # "kv_shard" logical axis, mapped onto the A submesh), with the
        # LSE merge combining the per-shard partial softmax statistics
        self.a_shards = a_shards
        # overlap > 1: sub-operator pipelining — the slotted decode step
        # splits its batch into `overlap` micro-batches and runs the
        # skewed two-domain schedule (_layer_loop_pipelined) so W and A
        # are concurrently busy on DIFFERENT micro-batches. Depth 1 keeps
        # the sequential _layer_loop verbatim (today's exact programs).
        self.overlap = overlap
        if routing == "device_put":
            if plan is None:
                raise ValueError("device_put routing needs a WAPlan (submesh "
                                 "row split)")
            self.w_mesh, self.a_mesh = split_mesh(mesh, plan.weight_rows)
            self.w_ctx = ShardingCtx(self.w_mesh, sub_operator(False))
            self.a_ctx = ShardingCtx(self.a_mesh, sub_operator(False))
        else:
            # ONE mesh, two rule tables: W = sub-operator (weights/heads on
            # the model axis), A = KV-sequence-sharded (the cache's length
            # axis owns the model axis — "add attention nodes"). mesh=None →
            # every constraint is a no-op (single-device dry-run).
            self.w_ctx = ShardingCtx(mesh, sub_operator(False))
            self.a_ctx = ShardingCtx(mesh, seq_sharded_kv(sub_operator(False)))
        # macro-step block: the registry lift of the slotted WA step — the
        # same on-device halt masks / cursors every colocated family gets
        self.decode_block = make_decode_block(self._decode_slotted_api)

    def _require_aot(self, what: str):
        if self.routing != "sharding":
            raise ValueError(
                f"{what} must compile into ONE program; eager device_put "
                "routing cannot cross submeshes inside a jit trace — build "
                "WADisaggregated(routing='sharding') for the AOT path")

    # -- single layer pieces (weight side) ------------------------------
    def _w_qkv(self, lp, x, positions):
        """positions: (B,S) int32 — per-row RoPE phase (continuous batching
        admits rows at different depths, so the W side must rotate per-row)."""
        cfg, ctx = self.cfg, self.w_ctx
        h = common.apply_norm(cfg.norm, lp["ln1"], x, cfg.norm_eps)
        return qkv_project(lp["attn"], h, cfg, ctx, positions)

    def _w_post(self, lp, x, o):
        from repro.models.transformer import _mix_ffn
        cfg, ctx = self.cfg, self.w_ctx
        B, S = x.shape[0], x.shape[1]
        o = common.linear(lp["attn"]["wo"], o.reshape(B, S, -1))
        x = x + o
        h = common.apply_norm(cfg.norm, lp["ln2"], x, cfg.norm_eps)
        f, _ = _mix_ffn(lp, h, cfg, ctx, train=False)
        return x + f

    # -- attention side ---------------------------------------------------
    def _a_attend(self, kv_slices, q, k, v, pos, window=0):
        k_l, v_l, ks_l, vs_l = kv_slices[:4]
        k_l, v_l, ks_l, vs_l = layer_append(k_l, v_l, ks_l, vs_l,
                                            k[:, 0], v[:, 0], pos, window)
        kc, vc = layer_read(k_l, v_l, ks_l, vs_l, dtype=q.dtype)
        mask = slot_valid_mask(k_l.shape[2], window, pos)
        o = decode_attention(q[:, 0], kc, vc, mask, self.a_ctx)
        return (k_l, v_l, ks_l, vs_l) + tuple(kv_slices[4:]), o

    def _a_attend_slotted(self, kv_slices, q, k, v, positions, active,
                          window=0, kv_bucket=0):
        """Per-slot cursors live WITH the KV on the attention node — the
        weight node never tracks who occupies which slot (admission is an
        A-side state change, matching the paper's ownership split).
        ``kv_bucket`` > 0: the length-aware walk — read and attend only the
        first ``kv_bucket`` STORED positions (int8 caches dequantize just
        the bucket), exactly ``transformer.block_decode_slotted``'s slice.
        Tiered caches (6-entry ``kv_slices``) stage the append into both
        tiers and read the hot/cold-resolved image — the demotion boundary
        lives entirely in this A-side read (DESIGN.md §7)."""
        cfg = self.cfg
        k_l, v_l, ks_l, vs_l, hk_l, hv_l = kv_slices
        tiered = hk_l is not None
        if tiered:
            k_l, v_l, ks_l, vs_l, hk_l, hv_l = layer_append_tiered(
                k_l, v_l, ks_l, vs_l, hk_l, hv_l, k[:, 0], v[:, 0],
                positions, cfg.kv_cold_dtype, active)
            counts = positions + 1
        else:
            k_l, v_l, ks_l, vs_l = layer_append_slotted(
                k_l, v_l, ks_l, vs_l, k[:, 0], v[:, 0], positions, window,
                active)
        if window:
            kv_bucket = 0                   # ring order has no prefix to cut
        out = (k_l, v_l, ks_l, vs_l, hk_l, hv_l)
        if self.a_shards > 1 and not window:
            # split-KV flash decode: shard-major bucketed read (same stored
            # prefix, reshaped to a_shards contiguous blocks); the per-shard
            # partial softmax statistics reduce locally and ONE LSE merge
            # routes the combined output back toward W.
            # Pin the resident cache to the SAME kv_seq layout the chunk
            # program emits: GSPMD cannot back-propagate the shard-major
            # annotation through the reshape, and an unconstrained cache
            # input would compile replicated — mismatching the live buffers.
            ann = self.a_ctx.ann
            k_l = ann(k_l, "batch", "kv_heads", "kv_seq", "head_dim")
            v_l = ann(v_l, "batch", "kv_heads", "kv_seq", "head_dim")
            if ks_l is not None:
                ks_l = ann(ks_l, "batch", "kv_heads", "kv_seq", None)
                vs_l = ann(vs_l, "batch", "kv_heads", "kv_seq", None)
            if tiered:
                hk_l = ann(hk_l, "batch", "kv_heads", None, "head_dim")
                hv_l = ann(hv_l, "batch", "kv_heads", None, "head_dim")
                kc, vc = layer_read_tiered_shards(
                    k_l, v_l, ks_l, vs_l, hk_l, hv_l, counts, kv_bucket,
                    self.a_shards, cfg.hot_window, cfg.kv_cold_block,
                    cfg.kv_cold_dtype, dtype=q.dtype)
            else:
                kc, vc = layer_read_shards(k_l, v_l, ks_l, vs_l, kv_bucket,
                                           self.a_shards, dtype=q.dtype)
            mask = batch_valid_mask(kc.shape[2] * kc.shape[3], window,
                                    positions)
            o = decode_attention_split(q[:, 0], kc, vc, mask, self.a_ctx)
            return (k_l, v_l, ks_l, vs_l, hk_l, hv_l), o
        if tiered:
            kc, vc = layer_read_tiered(
                k_l, v_l, ks_l, vs_l, hk_l, hv_l, counts, kv_bucket,
                cfg.hot_window, cfg.kv_cold_block, cfg.kv_cold_dtype,
                dtype=q.dtype)
        else:
            kc, vc = layer_read_bucket(k_l, v_l, ks_l, vs_l, kv_bucket,
                                       dtype=q.dtype)
        mask = batch_valid_mask(kc.shape[2], window, positions)
        o = decode_attention(q[:, 0], kc, vc, mask, self.a_ctx)
        return out, o

    def _pin_cache_stacks(self, k_st, v_st, ks_st, vs_st,
                          hk_st=None, hv_st=None):
        """Pin the resident KV stacks to the A-domain layout at program
        ENTRY. GSPMD infers each program's cache placement independently —
        on a data-sharded mesh the chunk program used to compile its cache
        input batch-REPLICATED while the decode block compiled it
        batch-sharded, so the donated buffer resharded at every admission
        boundary (found by the repro.analysis residency pass; invisible on
        data=1 test meshes). The entry pin makes every WA program agree on
        the planned A-domain layout. Hot rings carry no kv_seq axis (the
        ring extent is H, not the shard-cut cache extent) — they pin
        batch/kv_heads only and replicate along the ring."""
        if self.routing != "sharding":
            return k_st, v_st, ks_st, vs_st, hk_st, hv_st
        ann = self.a_ctx.ann
        k_st = ann(k_st, None, "batch", "kv_heads", "kv_seq", "head_dim")
        v_st = ann(v_st, None, "batch", "kv_heads", "kv_seq", "head_dim")
        if ks_st is not None:
            ks_st = ann(ks_st, None, "batch", "kv_heads", "kv_seq", None)
            vs_st = ann(vs_st, None, "batch", "kv_heads", "kv_seq", None)
        if hk_st is not None:
            hk_st = ann(hk_st, None, "batch", "kv_heads", None, "head_dim")
            hv_st = ann(hv_st, None, "batch", "kv_heads", None, "head_dim")
        return k_st, v_st, ks_st, vs_st, hk_st, hv_st

    # -- preemption swap (A-domain slot state ops) -------------------------
    def swap_out_slot(self, cache: KVCache, slot):
        """Preemption export of one slot's stored KV ON the A domain: the
        resident stacks are pinned to the planned A layout first (same entry
        pin as every other WA cache program — the swap pair must not give
        GSPMD a program that disagrees on cache placement). The stored
        extent stays CONTIGUOUS under split-KV (a_shards > 1 is a read-time
        view, DESIGN.md §3), so the exported host buffer is shard-agnostic:
        it restores bit-identically under any shard width."""
        k, v, ks, vs, hk, hv = self._pin_cache_stacks(
            cache.k, cache.v, cache.k_scale, cache.v_scale,
            cache.hot_k, cache.hot_v)
        return export_slot_kv(
            cache._replace(k=k, v=v, k_scale=ks, v_scale=vs,
                           hot_k=hk, hot_v=hv), slot)

    def swap_in_slot(self, cache: KVCache, saved, slot, valid_len):
        """Preemption restore on the A domain: masked true-length write of
        an exported slot image (``import_slot_kv`` — the chunk lane's
        keep-past-valid semantics at full width), entry- and exit-pinned so
        the donated cache keeps the agreed A layout."""
        k, v, ks, vs, hk, hv = self._pin_cache_stacks(
            cache.k, cache.v, cache.k_scale, cache.v_scale,
            cache.hot_k, cache.hot_v)
        cache = import_slot_kv(
            cache._replace(k=k, v=v, k_scale=ks, v_scale=vs,
                           hot_k=hk, hot_v=hv), saved, slot, valid_len)
        k, v, ks, vs, hk, hv = self._pin_cache_stacks(
            cache.k, cache.v, cache.k_scale, cache.v_scale,
            cache.hot_k, cache.hot_v)
        return cache._replace(k=k, v=v, k_scale=ks, v_scale=vs,
                              hot_k=hk, hot_v=hv)

    # -- route helpers ------------------------------------------------------
    def _to_a(self, x):
        """W → A hop. Eager: a cross-submesh device_put (lowers to ICI).
        AOT: a sharding-constraint boundary — heads leave the W domain's
        model-axis shards and replicate onto the A domain, whose owned axis
        is the KV sequence ("only embeddings move", now inside the
        program)."""
        if self.routing == "device_put":
            return jax.device_put(x, NamedSharding(self.a_mesh,
                                                   P("data", None, None)))
        return _tagged_ann(_hop_to_a, self.a_ctx, x,
                           ("batch", "seq", "act_heads", "head_dim"))

    def _to_w(self, x):
        """A → W hop: the attention output re-shards onto the W domain's
        head axis before the output projection / FFN."""
        if self.routing == "device_put":
            return jax.device_put(x, NamedSharding(self.w_mesh,
                                                   P("data", None, None)))
        return _tagged_ann(_hop_to_w, self.w_ctx, x,
                           ("batch", "seq", "act_heads", "head_dim"))

    # -- decode step --------------------------------------------------------
    def _layer_loop(self, params, cache: KVCache, tokens, positions, attend):
        """Shared per-layer W→A→W routing. ``positions``: (B,1) per-row RoPE
        phase; ``attend(kv_slices, q, k, v)`` runs the A-side program and
        returns (updated slices, o). Returns (new k/v/scale stacks, logits)."""
        cfg = self.cfg
        x = common.embed(params["embed"], tokens[:, None], self.w_ctx)
        if cfg.pos == "learned":
            x = x + jnp.take(params["pos_embed"], positions[:, 0],
                             axis=0)[:, None].astype(x.dtype)
        stacks = list(self._pin_cache_stacks(
            cache.k, cache.v, cache.k_scale, cache.v_scale,
            cache.hot_k, cache.hot_v))
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["blocks"])
            q, k, v = self._w_qkv(lp, x, positions)
            # W → A : route per-head activations (the "embeddings move" hop)
            q, k, v = self._to_a(q), self._to_a(k), self._to_a(v)
            kv_i = tuple(None if c is None else c[i] for c in stacks)
            kv_i, o = attend(kv_i, q, k, v)
            for n, piece in enumerate(kv_i):
                if piece is not None:
                    stacks[n] = stacks[n].at[i].set(piece)
            # A → W
            o = self._to_w(o[:, None])
            x = self._w_post(lp, x, o)
        x = common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
        from repro.models.transformer import unembed_table
        logits = common.unembed_logits(unembed_table(params, cfg), x,
                                       self.w_ctx)
        return tuple(stacks), logits

    def _layer_loop_pipelined(self, params, cache: KVCache, tokens,
                              positions, attend):
        """Software-pipelined W→A→W layer loop (``overlap`` > 1, the
        paper's §3.2 sub-operator dependency relaxation applied to the WA
        boundary). The batch splits into ``overlap`` contiguous
        micro-batches; each runs the SAME chain of 2L+1 alternating ops
        (even = W: embed/QKV/FFN/unembed, odd = A: attention), skewed one
        tick per micro-batch (``core.pipeline.skewed_schedule``). At any
        tick the live micro-batches hold consecutive op indices — adjacent
        micro-batches always occupy OPPOSITE domains, so while A attends
        micro-batch m at layer l, W already runs QKV/FFN for micro-batch
        m+1 at the same layer, and m's layer l+1 W work starts the tick
        its A result lands. The routed q/k/v and attention outputs are
        held in per-micro-batch double buffers (``routed``/``backed``)
        whose producers and consumers sit one tick apart, so XLA's latency
        hiding can overlap the W-regime and A-regime collectives instead
        of serializing them at a per-layer barrier. The schedule is STATIC
        (python ints only): one compiled program per cell, same program
        names as depth 1.

        Token-exact by construction: every op is row-wise over the batch
        (per-slot KV, per-row cursors/masks), so splitting rows into
        micro-batches reorders no per-row reduction. ``attend(kv_slices,
        q, k, v, sl)`` must run the A-side program on micro-batch rows
        ``sl``. Returns (new k/v/scale stacks, logits) like
        ``_layer_loop``."""
        cfg, D = self.cfg, self.overlap
        L = cfg.n_layers
        from repro.models.transformer import unembed_table
        slices = micro_batch_slices(tokens.shape[0], D)
        stacks = self._pin_cache_stacks(
            cache.k, cache.v, cache.k_scale, cache.v_scale,
            cache.hot_k, cache.hot_v)
        lps = [jax.tree.map(lambda a, _i=i: a[_i], params["blocks"])
               for i in range(L)]
        xs = [None] * D          # per-micro-batch residual stream (W side)
        routed = [None] * D      # in-flight W→A (q,k,v) double buffers
        backed = [None] * D      # in-flight A→W attention-output buffers
        logits = [None] * D
        # per-(layer, micro-batch) updated KV pieces. The micro-batch
        # chains must stay INDEPENDENT dataflow: threading the stacks
        # through per-micro-batch scatter updates would version the whole
        # cache through every A op — a serial chain re-coupling the very
        # chains the schedule decoupled (and a full-stack copy per scatter
        # wherever XLA cannot prove slice disjointness). So all reads are
        # gathers from the ENTRY stacks (each micro-batch reads only its
        # own rows, no other micro-batch writes them — value-identical to
        # the sequential loop) and the updated stacks are assembled ONCE
        # at the end, concat over micro-batches, stack over layers.
        new_kv = [[None] * D for _ in range(L)]
        for _t, live in skewed_schedule(2 * L + 1, D):
            for m, op in live:
                sl = slices[m]
                j = op // 2
                if op % 2:
                    # -- A-domain op: attend layer j for micro-batch m ----
                    q, k, v = routed[m]
                    routed[m] = None
                    kv_i = tuple(None if c is None else c[j, sl]
                                 for c in stacks)
                    new_kv[j][m], o = attend(kv_i, q, k, v, sl)
                    # route toward W the tick it lands (A's send side)
                    backed[m] = self._to_w(o[:, None])
                    continue
                # -- W-domain op j: finish layer j-1, start layer j -------
                if j == 0:
                    x = common.embed(params["embed"], tokens[sl][:, None],
                                     self.w_ctx)
                    if cfg.pos == "learned":
                        x = x + jnp.take(params["pos_embed"],
                                         positions[sl, 0],
                                         axis=0)[:, None].astype(x.dtype)
                else:
                    o, backed[m] = backed[m], None
                    x = self._w_post(lps[j - 1], xs[m], o)
                if j < L:
                    q, k, v = self._w_qkv(lps[j], x, positions[sl])
                    routed[m] = (self._to_a(q), self._to_a(k), self._to_a(v))
                    xs[m] = x
                else:
                    xs[m] = None
                    x = common.apply_norm(cfg.norm, params["ln_f"], x,
                                          cfg.norm_eps)
                    logits[m] = common.unembed_logits(
                        unembed_table(params, cfg), x, self.w_ctx)

        def assemble(idx):
            if new_kv[0][0][idx] is None:
                return None
            return jnp.stack([jnp.concatenate([new_kv[j][m][idx]
                                               for m in range(D)], axis=0)
                              for j in range(L)])

        # re-pin: the assembled stacks are NEW buffers and must land on the
        # same A-domain layout the entry pin promised the donation chain
        out = self._pin_cache_stacks(*[assemble(i)
                                       for i in range(len(_STACK_FIELDS))])
        return out, jnp.concatenate(logits, axis=0)

    def decode_step(self, params, cache: KVCache, tokens):
        """Python-orchestrated per-layer routing. params live on W (weights
        resident, no KV there); KV lives on A. Used for correctness and
        for the Fig 11 breakdown; the analytical model covers scaling."""
        if cache.is_tiered:
            raise ValueError(
                "eager WA decode_step does not support tiered caches — the "
                "tiered read is a serving-lane (slotted) program")
        pos = cache.length
        B = tokens.shape[0]
        (k, v, ks, vs, _, _), logits = self._layer_loop(
            params, cache, tokens, jnp.full((B, 1), pos, jnp.int32),
            lambda kv_i, q, kk, vv: self._a_attend(kv_i, q, kk, vv, pos,
                                                   window=cache.window))
        return cache._replace(k=k, v=v, k_scale=ks, v_scale=vs,
                              length=pos + 1), logits

    def decode_step_slotted(self, params, cache: KVCache, tokens,
                            positions, active, kv_bucket: int = 0):
        """Continuous-batching decode in the WA-decoupled path: per-slot
        cursors + active mask (DESIGN.md §7). Slot admission itself is the
        same ``write_slot_kv`` the colocated engine uses — the A node owns
        the KV, so admission touches only A-side state. ``kv_bucket``
        (static) caps the attended extent — the serving engine's
        length-aware walk, applied at the A-side read. ``overlap`` > 1
        runs the software-pipelined schedule over micro-batch row slices
        (every A-side op is row-wise, so the split is token-exact)."""
        def attend(kv_i, q, kk, vv, sl=slice(None)):
            pos, act = (positions, active) if sl == slice(None)\
                else (positions[sl], active[sl])
            return self._a_attend_slotted(kv_i, q, kk, vv, pos, act,
                                          window=cache.window,
                                          kv_bucket=kv_bucket)

        loop = self._layer_loop_pipelined if self.overlap > 1\
            else self._layer_loop
        (k, v, ks, vs, hk, hv), logits = loop(
            params, cache, tokens, positions[:, None], attend)
        new_len = jnp.maximum(
            cache.length, jnp.max(jnp.where(active, positions, 0)) + 1)
        return cache._replace(k=k, v=v, k_scale=ks, v_scale=vs,
                              hot_k=hk, hot_v=hv, length=new_len), logits

    def _decode_slotted_api(self, params, caches, tokens, positions, active,
                            ctx, kv_bucket: int = 0):
        """ModelAPI.decode_slotted-shaped adapter for ``make_decode_block``:
        the WA engine carries its own W/A contexts, so the engine-supplied
        ctx is unused. Traced inside the block scan → AOT routing only."""
        del ctx
        self._require_aot("decode_block")
        return self.decode_step_slotted(params, caches, tokens, positions,
                                        active, kv_bucket=kv_bucket)

    # -- chunked prefill ----------------------------------------------------
    def prefill_chunk(self, params, cache: KVCache, tokens, slot, start,
                      valid_len):
        """WA-split chunked prefill: ONE fixed-(1,C) program per chunk width
        (DESIGN.md §7 chunked-prefill lane), the admission path of the WA
        serving backend. The W side runs embed/ln1/QKV and (after the route
        back) Wo/residual/ln2/FFN — unchanged weight-node work; the A side
        owns every piece of slot state: the chunk's K/V land at the slot's
        offset (``layer_write_chunk``; positions ≥ valid_len never touch the
        cache), the slot's stored prefix is read back (``layer_read_slot``;
        int8 dequantizes the same values decode will attend) and
        ``chunk_attention`` runs under the A-domain rules.
        slot/start/valid_len are traced scalars: zero retracing across
        chunks, prompts and slots. Returns (cache', logits (1,1,V)) at the
        chunk's last valid position."""
        self._require_aot("prefill_chunk")
        if cache.window:
            raise ValueError("chunked prefill requires a non-windowed cache "
                             "(ring order has no per-position write offset)")
        cfg = self.cfg
        x = common.embed(params["embed"], tokens, self.w_ctx)
        C = tokens.shape[1]
        positions = start + jnp.arange(C, dtype=jnp.int32)
        if cfg.pos == "learned":
            x = x + jnp.take(params["pos_embed"], positions,
                             axis=0)[None].astype(x.dtype)
        elif cfg.pos == "sinusoidal":
            table = common.sinusoidal_pos(cache.k.shape[3], cfg.d_model)
            x = x + jnp.take(table, positions, axis=0)[None].astype(x.dtype)
        stacks = list(self._pin_cache_stacks(
            cache.k, cache.v, cache.k_scale, cache.v_scale,
            cache.hot_k, cache.hot_v))
        tiered = cache.is_tiered
        S = cache.k.shape[3]
        # causal over absolute positions: query i attends cache slots
        # <= start+i (padding queries i >= valid_len attend zeros/stale
        # slots — their outputs are discarded)
        mask = jnp.arange(S, dtype=jnp.int32)[None, :]\
            <= positions[:, None]                                      # (C,S)
        if tiered:
            # per-QUERY demotion boundary: query i has start+i+1 tokens
            hot_mask = (jnp.arange(S, dtype=jnp.int32)[None, :] >=
                        cold_boundary(positions + 1, cfg.hot_window,
                                      cfg.kv_cold_block)[:, None])[None]
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["blocks"])
            q, k, v = self._w_qkv(lp, x, positions[None])
            q, k, v = self._to_a(q), self._to_a(k), self._to_a(v)
            kv_i = tuple(None if c is None else c[i] for c in stacks)
            k_ch = jnp.swapaxes(k[0], 0, 1)
            v_ch = jnp.swapaxes(v[0], 0, 1)
            if tiered:
                # exact hot image from the PRE-write ring + incoming chunk
                # (the write below may overwrite exactly the ring slots
                # early queries' hot tails live in)
                kh, vh = chunk_hot_image(kv_i[4], kv_i[5], k_ch, v_ch,
                                         slot, start, valid_len, S,
                                         dtype=x.dtype)
                kv_i = layer_write_chunk_tiered(
                    kv_i[0], kv_i[1], kv_i[2], kv_i[3], kv_i[4], kv_i[5],
                    k_ch, v_ch, slot, start, valid_len, cfg.kv_cold_dtype)
                kc, vc = layer_read_slot_cold(
                    kv_i[0], kv_i[1], kv_i[2], kv_i[3], slot,
                    cfg.kv_cold_dtype, dtype=x.dtype)
                o = chunk_attention_tiered(q, kh, vh, kc, vc, hot_mask,
                                           mask, self.a_ctx)
            else:
                kv_i = layer_write_chunk(
                    kv_i[0], kv_i[1], kv_i[2], kv_i[3], k_ch, v_ch,
                    slot, start, valid_len) + (None, None)
                kc, vc = layer_read_slot(kv_i[0], kv_i[1], kv_i[2],
                                         kv_i[3], slot, dtype=x.dtype)
                o = chunk_attention(q, kc, vc, mask, self.a_ctx)
            for n, piece in enumerate(kv_i):
                if piece is not None:
                    stacks[n] = stacks[n].at[i].set(piece)
            o = self._to_w(o)
            x = self._w_post(lp, x, o)
        x = common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
        from repro.models.transformer import unembed_table
        last = jax.lax.dynamic_slice_in_dim(x, valid_len - 1, 1, axis=1)
        logits = common.unembed_logits(unembed_table(params, cfg), last,
                                       self.w_ctx)
        new_len = jnp.maximum(cache.length, start + valid_len)
        return cache._replace(k=stacks[0], v=stacks[1], k_scale=stacks[2],
                              v_scale=stacks[3], hot_k=stacks[4],
                              hot_v=stacks[5], length=new_len), logits
