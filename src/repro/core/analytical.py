"""The paper's analytical performance model (§6.2), TPU-instantiated.

    TPOT       = #stages × (per-stage latency + network latency) + embed
    Throughput = batch / per-stage latency

Per-stage latency is the roofline service time of one pipeline stage:
    l ≥ max(compute_time, memory_time, collective_time)
with memory time = (weight bytes + KV bytes + activation bytes) / BW of the
memory level that HOLDS the working set — the paper's central observation:
cache-resident working sets run at cache bandwidth, spilled ones at DRAM/HBM
bandwidth.  Our two "machines":

- ``paper_system``: cache-resident regime — per-stage weights/KV held in the
  fast level (paper: LLC @ ~4x DRAM BW/socket; TPU: VMEM-resident hot set,
  HBM-streamed otherwise — both expressed via an effective-bandwidth ratio).
- ``baseline_llama_cpp``: operator-centric, weights streamed from DRAM each
  token, plus a fixed per-operator synchronization overhead (the §6.4
  "tens of microseconds per transformer block" term).

The model is validated against *measured* reduced-config decode on this host
by benchmarks/table2_end_to_end.py (the paper's Meas./Est. ratio methodology).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Hardware descriptions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HW:
    name: str
    fast_bw: float           # B/s — cache/VMEM-class bandwidth per domain
    slow_bw: float           # B/s — DRAM/HBM-class bandwidth per domain
    fast_capacity: float     # bytes of the fast level per domain
    flops: float             # peak FLOP/s per domain (int8 path where used)
    net_latency: float       # s per inter-stage hop
    sync_overhead: float     # s fixed per-operator sync cost (operator-centric)
    n_ops_per_block: int = 4 # QKV, attn-out, FFN-up, FFN-down boundaries


# Paper platform: EPYC 9684X — 1152MB LLC/socket, ~400GB/s DRAM; LLC stream
# bandwidth measured ~3-4x DRAM on Genoa-X; 96 cores AVX512 VNNI.
EPYC_9684X = HW("epyc-9684x", fast_bw=1.6e12, slow_bw=4.0e11,
                fast_capacity=1152e6, flops=9.8e12,   # int8 VNNI-ish
                net_latency=5e-6, sync_overhead=25e-6)

# TPU v5e chip (the roofline constants of the assignment).
TPU_V5E = HW("tpu-v5e", fast_bw=2.0e13, slow_bw=8.19e11,
             fast_capacity=128e6, flops=1.97e14,
             net_latency=1e-6, sync_overhead=5e-6)


# ---------------------------------------------------------------------------
# Working-set accounting (bytes / FLOPs per decoded token per stage)
# ---------------------------------------------------------------------------

def weight_bytes(cfg: ModelConfig, bytes_per_param: float = 1.0) -> float:
    """Transformer-stack weights only (embedding handled by the +1 stage)."""
    from repro.models.registry import count_params
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return (count_params(cfg, active_only=True) - emb) * bytes_per_param


def kv_bytes_per_token(cfg: ModelConfig, ctx_len: int,
                       bytes_per_el: float = 1.0) -> float:
    """KV working set touched to decode ONE token (whole context)."""
    if cfg.family == "ssm":
        nh = cfg.ssm.n_heads(cfg.d_model)
        return cfg.n_layers * nh * cfg.ssm.head_dim * cfg.ssm.d_state * 4.0
    kinds = cfg.block_kinds()
    total = 0.0
    for k in kinds:
        if k == "attn":
            span = ctx_len
        elif k == "local":
            span = min(ctx_len, cfg.rglru.window)
        else:       # rglru state
            total += (cfg.rglru.lru_width or cfg.d_model) * 4.0
            continue
        total += 2 * cfg.n_kv_heads * cfg.head_dim * span * bytes_per_el
    return total


def flops_per_token(cfg: ModelConfig, ctx_len: int) -> float:
    from repro.models.registry import count_params
    n = count_params(cfg, active_only=True)
    attn = kv_bytes_per_token(cfg, ctx_len) * 2.0   # 2 FLOPs per KV element
    return 2.0 * n + attn


# ---------------------------------------------------------------------------
# Stage latency under a residency regime
# ---------------------------------------------------------------------------

def _eff_bw(footprint: float, traffic: float, cap: float, fast: float,
            slow: float) -> float:
    """Effective bandwidth for ``traffic`` given the RESIDENT fraction of the
    ``footprint`` (partial residency: the cache holds the hot fraction)."""
    if footprint <= 0:
        return fast
    f = min(1.0, cap / footprint)
    return f * fast + (1.0 - f) * slow


def stage_latency(cfg: ModelConfig, hw: HW, *, batch: int, ctx_len: int,
                  n_stages: int, domains_per_stage: int = 1,
                  cache_resident: bool = True, wa_separated: bool = False,
                  operator_centric: bool = False,
                  bytes_per_param: float = 1.0,
                  bw_efficiency: float = 1.0) -> float:
    """Service time of one pipeline stage decoding `batch` tokens.

    THE PARADOX (§2.3), faithfully: per-stage *traffic* per step scales with
    (L/p)·B, but the per-stage KV *footprint* scales with (L/p)·(p·B in
    flight) = L·B — pipeline depth cancels. Residency is judged on the
    footprint; service time on the traffic.
    """
    # traffic per stage step
    wb = weight_bytes(cfg, bytes_per_param) / n_stages
    kvb = kv_bytes_per_token(cfg, ctx_len) * batch / n_stages
    fl = flops_per_token(cfg, ctx_len) * batch / n_stages
    # footprints (p in-flight request groups keep the pipeline busy)
    w_foot = wb
    kv_foot = kv_bytes_per_token(cfg, ctx_len) * batch      # ×p/p — invariant

    cap = hw.fast_capacity * domains_per_stage
    fast = hw.fast_bw * domains_per_stage * bw_efficiency
    slow = hw.slow_bw * domains_per_stage * bw_efficiency
    if not cache_resident:
        w_bw = kv_bw = slow
    elif wa_separated:
        # each phase judged on its own domain's footprint
        w_bw = _eff_bw(w_foot, wb, cap, fast, slow)
        kv_bw = _eff_bw(kv_foot, kvb, cap, fast, slow)
    else:
        tot = w_foot + kv_foot
        w_bw = kv_bw = _eff_bw(tot, wb + kvb, cap, fast, slow)

    t_mem = wb / w_bw + kvb / kv_bw
    t_compute = fl / (hw.flops * domains_per_stage)
    t = max(t_mem, t_compute)
    if operator_centric:
        layers = cfg.n_layers / n_stages
        t += layers * hw.n_ops_per_block * hw.sync_overhead
    elif wa_separated:
        # W→A→W routing adds 2 small hops per layer (embeddings only)
        t += (cfg.n_layers / n_stages) * 2 * hw.net_latency
    return t


# ---------------------------------------------------------------------------
# End-to-end model (§6.2)
# ---------------------------------------------------------------------------

def tpot_and_throughput(cfg: ModelConfig, hw: HW, *, batch: int, ctx_len: int,
                        n_stages: int, embed_latency: float = 10e-6,
                        **kw) -> Dict[str, float]:
    l = stage_latency(cfg, hw, batch=batch, ctx_len=ctx_len,
                      n_stages=n_stages, **kw)
    tpot = n_stages * (l + hw.net_latency) + embed_latency
    return {"stage_latency_s": l, "tpot_s": tpot,
            "throughput_tok_s": batch / l}


def paper_system(cfg: ModelConfig, *, batch: int, ctx_len: int,
                 n_stages: int, hw: HW = EPYC_9684X,
                 wa_separated: bool = False) -> Dict[str, float]:
    return tpot_and_throughput(cfg, hw, batch=batch, ctx_len=ctx_len,
                               n_stages=n_stages, cache_resident=True,
                               wa_separated=wa_separated)


LLAMA_CPP_BW_EFF = 0.30   # calibrated vs Table 2 b=1 (llama.cpp sustains
                          # ~30% of DRAM bw: threading + NUMA + op overheads)


def baseline_llama_cpp(cfg: ModelConfig, *, batch: int, ctx_len: int,
                       hw: HW = EPYC_9684X,
                       n_stages: int = 1) -> Dict[str, float]:
    """Operator-centric, DRAM-streamed weights, per-op sync tax, equally
    provisioned (same stage count as ours — paper §6)."""
    return tpot_and_throughput(cfg, hw, batch=batch, ctx_len=ctx_len,
                               n_stages=n_stages, cache_resident=False,
                               operator_centric=True,
                               bw_efficiency=LLAMA_CPP_BW_EFF)


def stages_for(cfg: ModelConfig, hw: HW = EPYC_9684X,
               bytes_per_param: float = 1.0) -> int:
    """Paper Table 1 policy: enough stages that per-stage weights are
    cache-resident; layers split evenly."""
    wb = weight_bytes(cfg, bytes_per_param)
    per = hw.fast_capacity * 0.75        # leave room for KV + activations
    n = max(1, math.ceil(wb / per))
    while cfg.n_layers % n != 0 and n < cfg.n_layers:
        n += 1
    return n
