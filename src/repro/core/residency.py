"""Working-set / residency planner — Challenge 1 (§2.3) made executable.

Answers, per (arch × shape × mesh):
  - per-chip weight / KV / optimizer / activation bytes,
  - whether the weight hot set is VMEM-residency-feasible,
  - the KV-pressure paradox check: per-domain KV under PP depth p,
  - whether WA separation is *profitable* (working set > capacity) or
    neutral/harmful (paper Fig 9: 1.00× at 3B) — drives core/wa.py defaults.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.core.analytical import kv_bytes_per_token, weight_bytes

VMEM_BYTES = 128e6          # v5e per-chip VMEM
HBM_BYTES = 16e9            # v5e per-chip HBM


@dataclass(frozen=True)
class ResidencyReport:
    weight_bytes_per_chip: float
    kv_bytes_per_chip: float
    vmem_weight_resident: bool
    hbm_fits: bool
    wa_profitable: bool
    paradox_invariant: float       # per-domain KV bytes — PP-depth independent
    notes: str


def dtype_bytes(cfg: ModelConfig, kv: bool = False) -> float:
    if kv:
        return 1.0 if cfg.kv_dtype == "int8" else 2.0
    return 1.0 if cfg.weight_int8 else 2.0


def plan(cfg: ModelConfig, shape: ShapeConfig, n_chips: int,
         pp_depth: int = 1, train: bool = None) -> ResidencyReport:
    train = shape.mode == "train" if train is None else train
    bpp = dtype_bytes(cfg)
    wb = weight_bytes(cfg, bpp)
    emb = cfg.vocab_size * cfg.d_model * bpp * (1 if cfg.tie_embeddings else 2)
    wb_total = wb + emb
    w_per_chip = wb_total / n_chips

    ctx = shape.seq_len
    batch = shape.global_batch
    # paradox: in-flight requests ≥ pp_depth ⇒ per-domain KV is depth-invariant
    in_flight = batch * max(pp_depth, 1)
    kv_total = kv_bytes_per_token(cfg, ctx, dtype_bytes(cfg, kv=True))\
        * batch if shape.is_decode else\
        kv_bytes_per_token(cfg, ctx, dtype_bytes(cfg, kv=True)) * batch
    kv_per_chip = kv_total / n_chips
    paradox = kv_bytes_per_token(cfg, ctx, dtype_bytes(cfg, kv=True))\
        * in_flight / max(pp_depth, 1)   # ∝ Layers×Batch×Ctx — p cancels

    opt = 3 * wb_total * 2 if train else 0.0    # f32 master+m+v ≈ 12B/param @bf16
    hot = w_per_chip
    vmem_ok = hot <= VMEM_BYTES
    hbm_ok = (w_per_chip + kv_per_chip + opt / n_chips) <= HBM_BYTES * 0.9
    wa_prof = (w_per_chip + kv_per_chip) > 0.5 * VMEM_BYTES and shape.is_decode
    notes = []
    if not vmem_ok:
        notes.append(f"weights/chip {w_per_chip/1e6:.0f}MB > VMEM — "
                     "HBM-streamed (gemv kernel regime)")
    if wa_prof:
        notes.append("WA separation profitable: co-located hot set exceeds "
                     "fast-memory budget (paper Fig 9 high-pressure regime)")
    return ResidencyReport(w_per_chip, kv_per_chip, vmem_ok, hbm_ok, wa_prof,
                           paradox, "; ".join(notes))


def paradox_table(cfg: ModelConfig, ctx_len: int, batch: int,
                  depths=(1, 2, 4, 8, 16)) -> Dict[int, float]:
    """Reproduces the §2.3 algebra: per-domain KV vs pipeline depth."""
    out = {}
    for p in depths:
        layers_per = cfg.n_layers / p
        in_flight = p * batch
        per_domain = (layers_per / cfg.n_layers) * in_flight *\
            kv_bytes_per_token(cfg, ctx_len, dtype_bytes(cfg, kv=True))
        out[p] = per_domain
    return out
