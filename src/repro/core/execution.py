"""Execution-model assembly: (arch × shape × mesh × executor) → a lowerable,
compilable step function with full sharding trees.

Executors (the paper's §2.4 vs §3.2 dichotomy, expressed as sharding rules —
the math is identical, the collective schedule is not):

  operator_centric  — activations forced replicated/materialized at operator
                      boundaries; the compiler synchronizes (all-gather /
                      all-reduce) after every sharded op.  The llama.cpp/
                      OpenMP-analogue baseline.
  sub_operator      — per-head activations stay on the owning shard through
                      QKV→RoPE→attention→O-partial; residual stream lives
                      reduce-scattered between blocks (one bounded-fan-in
                      ring reduction per true dependency).  Paper-faithful.
  sub_operator+seqkv— beyond-paper §3.1 scaling: KV sequence-sharded over the
                      data axis (distributed flash decode w/ LSE merge);
                      removes GQA head replication for small-kv archs.

Pod strategies for the multi-pod mesh:
  dp — pod axis joins the batch axes (gradient hierarchical all-reduce).
  pp — pod axis is a pipeline dimension (core/pipeline.py; dense family).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.models.param_specs import cache_specs, param_specs
from repro.models.registry import DECODE_SLACK, build_model
from repro.models.sharding import (ExecutionRules, ShardingCtx, fsdp,
                                   operator_centric, seq_sharded_kv,
                                   sub_operator)
from repro.optim.adamw import AdamWState, adamw_init, adamw_update, cosine_lr

EXECUTORS = ("operator_centric", "sub_operator", "sub_operator+seqkv")


def make_rules(executor: str, mesh: Mesh) -> ExecutionRules:
    pod_is_dp = "pod" in mesh.axis_names
    if executor == "operator_centric":
        return operator_centric(pod_is_dp)
    if executor == "sub_operator":
        return sub_operator(pod_is_dp)
    if executor == "sub_operator+seqkv":
        return seq_sharded_kv(sub_operator(pod_is_dp))
    raise ValueError(executor)


@dataclass
class StepBundle:
    """Everything the dry-run / static runtime needs for one cell."""
    name: str
    fn: Callable
    abstract_args: Tuple
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    ctx: ShardingCtx

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        return jitted.lower(*self.abstract_args)


# ---------------------------------------------------------------------------
# sharding trees for inputs
# ---------------------------------------------------------------------------

def _batch_specs(batch_tree, ctx: ShardingCtx):
    def one(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        if name in ("tokens", "labels"):
            logical = ("batch",) + (None,) * (leaf.ndim - 1)
        elif name in ("frames", "vision_embeds"):
            logical = ("batch", None, None)
        else:
            logical = (None,) * leaf.ndim
        return ctx.spec(logical, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def _named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              executor: str = "sub_operator",
              pod_strategy: str = "dp",
              lr: float = 3e-4,
              kv_int8: Optional[bool] = None) -> StepBundle:
    # Serving runs fully INT8 KV by default (paper §5: "fully INT8
    # configuration, including the KV cache") — halves KV HBM + collective
    # bytes; decode/prefill only (training has no KV).
    if kv_int8 is None:
        kv_int8 = shape.mode in ("decode", "prefill")
    if kv_int8 and shape.mode != "train" and cfg.kv_dtype != "int8":
        cfg = cfg.replace(kv_dtype="int8")
    if pod_strategy == "pp" and "pod" in mesh.axis_names:
        from repro.core.pipeline import make_pp_step
        return make_pp_step(cfg, shape, mesh, executor=executor, lr=lr)

    rules = make_rules(executor, mesh)
    if shape.mode == "train":
        rules = fsdp(rules)        # ZeRO-3: params + f32 moments fully shard
    ctx = ShardingCtx(mesh, rules)
    api = build_model(cfg)
    key = jax.random.key(0)

    params_shape = jax.eval_shape(api.init, key)
    p_specs = param_specs(params_shape, ctx)
    p_shard = _named(p_specs, mesh)
    batch_tree = api.input_specs(shape)
    b_shard = _named(_batch_specs(batch_tree, ctx), mesh)

    name = f"{cfg.name}|{shape.name}|{executor}|{'x'.join(map(str, mesh.devices.shape))}"

    if shape.mode == "train":
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        o_specs = AdamWState(step=P(), mu=p_specs, nu=p_specs)
        o_shard = _named(o_specs, mesh)

        def train_step(params, opt_state, batch):
            def lf(p):
                return api.loss(p, batch, ctx)
            loss, grads = jax.value_and_grad(lf)(params)
            lr_t = cosine_lr(opt_state.step, lr, warmup=100, total=10_000)
            new_params, new_opt, info = adamw_update(params, grads, opt_state,
                                                     lr=lr_t)
            return new_params, new_opt, {"loss": loss, **info}

        return StepBundle(name + "|train", train_step,
                          (params_shape, opt_shape, batch_tree),
                          (p_shard, o_shard, b_shard),
                          (p_shard, o_shard, None),
                          donate_argnums=(0, 1), ctx=ctx)

    if shape.mode == "prefill":
        def prefill_step(params, batch):
            return api.prefill(params, batch, ctx)

        return StepBundle(name + "|prefill", prefill_step,
                          (params_shape, batch_tree),
                          (p_shard, b_shard), None,
                          donate_argnums=(), ctx=ctx)

    # decode
    cache_shape = jax.eval_shape(
        lambda: api.init_caches(shape.global_batch,
                                shape.seq_len + DECODE_SLACK))
    c_shard = _named(cache_specs(cache_shape, ctx), mesh)
    tok_shard = _named(ctx.spec(("batch",), (shape.global_batch,)), mesh)

    def decode_step(params, caches, tokens):
        return api.decode(params, caches, tokens, ctx)

    return StepBundle(name + "|decode", decode_step,
                      (params_shape, cache_shape,
                       jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)),
                      (p_shard, c_shard, tok_shard),
                      None,
                      donate_argnums=(1,), ctx=ctx)
