"""Pipeline parallelism over the ``pod`` axis (paper §4.1: "PP across rack
nodes ... only activation tensors are exchanged between rack nodes").

Token-pipelined DECODE for transformer-family archs: the pod axis carries
n_stages pipeline stages; each serve_step call advances every in-flight
request group by one stage and `ppermute`s the (B, 1, d_model) activation to
the next stage — per-call cross-pod traffic is exactly the paper's
"embeddings only" (B·d_model bytes per hop; KV and weights never move).
Steady state matches the paper's analytical model (§6.2):

    TPOT = n_stages × (stage_latency + hop_latency) + embed
    Throughput = one token-batch per call (1/stage_latency)

Training/prefill across pods use pod-DP with hierarchical gradient reduction
(core/collectives.py) — the paper applies PP to decoding, which "is the
long-running steady state"; a GPipe microbatch trainer is the documented
extension point.

State layout (stage dim leads, P("pod") on dim 0):
    KV:      (n_stages, L/n_stages, B, n_kv, S, hd)  int8 + scales
    lengths: (n_stages,)   — each in-flight group's decode position
    x_carry: (n_stages, B, 1, d_model) — activations in flight between calls
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.models import common
from repro.models.param_specs import leaf_logical
from repro.models.registry import DECODE_SLACK, build_model
from repro.models.sharding import ShardingCtx, seq_sharded_kv, sub_operator
from repro.models.transformer import block_decode, unembed_table

_HEAD_KEYS = ("embed", "ln_f", "unembed", "pos_embed")


def _only_pod(spec: P) -> P:
    """shard_map manual-over-pod specs may reference only 'pod'; data/model
    placement comes from the outer jit in_shardings + inner constraints."""
    def keep(e):
        if e == "pod":
            return "pod"
        if isinstance(e, (tuple, list)) and "pod" in e:
            return "pod"
        return None
    return P(*[keep(e) for e in spec])


def _pod_specs(tree):
    return jax.tree.map(_only_pod, tree, is_leaf=lambda x: isinstance(x, P))


def _shard_map(f, mesh, in_specs, out_specs):
    """Partial-manual shard_map: manual over 'pod', auto over data/model —
    inner GSPMD rules keep working while we schedule the pipeline by hand."""
    from repro.core.compat import shard_map
    return shard_map(f, mesh=mesh, in_specs=_pod_specs(in_specs),
                     out_specs=_pod_specs(out_specs),
                     axis_names=frozenset({"pod"}), check_vma=False)


def stage_params(params: Dict[str, Any], n_stages: int) -> Dict[str, Any]:
    """(L, ...) block leaves → (n_stages, L/n_stages, ...)."""
    out = dict(params)
    out["blocks"] = jax.tree.map(
        lambda a: a.reshape(n_stages, a.shape[0] // n_stages, *a.shape[1:]),
        params["blocks"])
    return out


# ---------------------------------------------------------------------------
# Stage-skew schedule machinery
# ---------------------------------------------------------------------------
# The skew pattern of this module's token pipeline — participant m runs work
# item (t - m) at tick t — generalized so ``core/wa.py`` can software-
# pipeline its W/A layer loop over micro-batches (sub-operator overlap,
# DESIGN.md §3): the schedule is STATIC (pure python ints), so the unrolled
# trace compiles into one program per cell regardless of depth.

def skewed_schedule(n_ops: int, depth: int):
    """Static software-pipeline schedule: ``depth`` participants each run
    the same chain of ``n_ops`` ops, participant ``m`` skewed ``m`` ticks
    behind participant 0. Returns ``[(tick, [(m, op), ...]), ...]`` covering
    ``n_ops + depth - 1`` ticks; at each tick the live participants hold
    CONSECUTIVE op indices (op = tick - m), so for an alternating two-domain
    op chain adjacent participants always occupy opposite domains."""
    if n_ops < 1 or depth < 1:
        raise ValueError(f"need n_ops >= 1 and depth >= 1, got "
                         f"({n_ops}, {depth})")
    return [(t, [(m, t - m) for m in range(depth) if 0 <= t - m < n_ops])
            for t in range(n_ops + depth - 1)]


def wa_schedule_occupancy(n_layers: int, depth: int) -> Dict[str, Any]:
    """Per-domain occupancy of the skewed WA decode schedule: the op chain
    is 2L+1 alternating ops (even = W: QKV/FFN, odd = A: attention), so a
    tick is W-busy (A-busy) when any live micro-batch holds an even (odd)
    op. Depth 1 degenerates to the sequential loop — every tick runs
    exactly one domain and ``overlap_efficiency`` is ~0.5; depth >= 2 keeps
    both domains busy on every interior tick (efficiency → 1). Pure
    schedule arithmetic: the SAME numbers for the compiled program and for
    ``stats()['wa']``'s stall accounting, with no wall-clock noise."""
    sched = skewed_schedule(2 * n_layers + 1, depth)
    w_busy = sum(1 for _t, live in sched if any(op % 2 == 0 for _m, op in live))
    a_busy = sum(1 for _t, live in sched if any(op % 2 == 1 for _m, op in live))
    total = len(sched)
    return {
        "total_ticks": total,
        "w_busy_ticks": w_busy,
        "a_busy_ticks": a_busy,
        "w_idle_frac": (total - w_busy) / total,
        "a_idle_frac": (total - a_busy) / total,
        "overlap_efficiency": (w_busy + a_busy) / (2 * total),
    }


def make_pp_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 executor: str = "sub_operator", lr: float = 3e-4):
    from repro.core.execution import StepBundle
    if shape.mode != "decode":
        raise NotImplementedError(
            "PP is implemented for decode (the paper's scenario); train/"
            "prefill scale across pods with pod-DP + hierarchical reduction")
    if cfg.family not in ("dense", "vlm", "moe"):
        raise NotImplementedError("PP decode targets transformer-family archs")

    n_stages = mesh.shape["pod"]
    assert cfg.n_layers % n_stages == 0, (cfg.n_layers, n_stages)
    Lp = cfg.n_layers // n_stages
    B = shape.global_batch
    max_len = shape.seq_len + DECODE_SLACK
    cfg = cfg.replace(kv_dtype="int8")      # paper §5: fully INT8 serving

    rules = sub_operator(pod_is_dp=False)
    if executor.endswith("+seqkv"):
        rules = seq_sharded_kv(rules)
    ctx = ShardingCtx(mesh, rules)

    api = build_model(cfg)
    params_shape = jax.eval_shape(api.init, jax.random.key(0))
    staged_shape = jax.eval_shape(
        lambda: stage_params(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_shape),
            n_stages))

    def spec_of(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
        logical = leaf_logical(path, leaf)
        if "blocks" in keys:
            logical = ("stages",) + tuple(logical)[1:]
        return ctx.spec(tuple(logical), leaf.shape)

    p_specs = jax.tree_util.tree_map_with_path(spec_of, staged_shape)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                           is_leaf=lambda x: isinstance(x, P))

    kv_shape = (n_stages, Lp, B, cfg.n_kv_heads, max_len, cfg.head_dim)
    sc_shape = kv_shape[:-1] + (1,)
    caches_shape = {
        "k": jax.ShapeDtypeStruct(kv_shape, jnp.int8),
        "v": jax.ShapeDtypeStruct(kv_shape, jnp.int8),
        "k_scale": jax.ShapeDtypeStruct(sc_shape, jnp.float32),
        "v_scale": jax.ShapeDtypeStruct(sc_shape, jnp.float32),
        "lengths": jax.ShapeDtypeStruct((n_stages,), jnp.int32),
        "x_carry": jax.ShapeDtypeStruct((n_stages, B, 1, cfg.d_model),
                                        jnp.dtype(cfg.dtype)),
    }
    kv_spec = ctx.spec(("stages", None, "batch", "kv_heads", "kv_seq", None),
                       kv_shape)
    sc_spec = ctx.spec(("stages", None, "batch", "kv_heads", "kv_seq", None),
                       sc_shape)
    c_specs = {"k": kv_spec, "v": kv_spec, "k_scale": sc_spec,
               "v_scale": sc_spec, "lengths": P("pod"),
               # activations ride the wire model-scattered (embed_shard)
               "x_carry": ctx.spec(("stages", "batch", None, "embed_shard"),
                                   caches_shape["x_carry"].shape)}
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                           is_leaf=lambda x: isinstance(x, P))
    tok_shape = jax.ShapeDtypeStruct((n_stages, B), jnp.int32)
    tok_spec = ctx.spec(("stages", "batch"), (n_stages, B))
    logit_spec = ctx.spec(("stages", "batch", None, "vocab"),
                          (n_stages, B, 1, cfg.vocab_size))

    # ------------------- per-stage body (manual over 'pod') ---------------
    # NOTE: the stage index arrives as an explicit P("pod")-sharded iota
    # instead of lax.axis_index("pod") — axis_index under partial-manual
    # shard_map lowers to a PartitionId instruction that SPMD partitioning
    # rejects on older JAX.
    def body(stage_ids, blocks, head, caches, tokens):
        blocks = jax.tree.map(lambda a: a[0], blocks)         # (Lp, ...)
        k = caches["k"][0]                                    # (Lp,B,kv,S,hd)
        v = caches["v"][0]
        ks = caches["k_scale"][0]
        vs = caches["v_scale"][0]
        pos = caches["lengths"][0]
        stage = stage_ids[0]
        emb = common.embed(head["embed"], tokens[0][:, None], ctx)
        x = jnp.where(stage == 0, emb.astype(caches["x_carry"].dtype),
                      caches["x_carry"][0])

        def layer(h, xs):
            lp, k_l, v_l, ks_l, vs_l = xs
            h, upd = block_decode(lp, h, cfg, ctx, (k_l, v_l, ks_l, vs_l), pos)
            return h, upd

        x, (k_n, v_n, ks_n, vs_n) = lax.scan(
            layer, x, (blocks, k, v, ks, vs), unroll=common.scan_unroll())
        xf = common.apply_norm(cfg.norm, head["ln_f"], x, cfg.norm_eps)
        logits = common.unembed_logits(unembed_table(head, cfg), xf, ctx)
        # paper's cross-node hop (embeddings only) happens OUTSIDE the manual
        # region — jnp.roll over the pod-sharded stage axis in `step` — since
        # CollectivePermute inside a manual subgroup crashes the SPMD
        # partitioner on older JAX; the roll lowers to the same permute.
        new_caches = {"k": k_n[None], "v": v_n[None],
                      "k_scale": ks_n[None], "v_scale": vs_n[None],
                      "lengths": (pos + 1)[None], "x_carry": x[None]}
        return new_caches, logits[None].astype(jnp.float32)

    head_keys = [k for k in _HEAD_KEYS if k in staged_shape]
    head_specs = {k: p_specs[k] for k in head_keys}
    f_sharded = _shard_map(
        body, mesh,
        (P("pod"), p_specs["blocks"], head_specs, c_specs, tok_spec),
        ({"k": kv_spec, "v": kv_spec, "k_scale": sc_spec, "v_scale": sc_spec,
          "lengths": P("pod"), "x_carry": c_specs["x_carry"]}, logit_spec))

    def step(params, caches, tokens):
        head = {k: params[k] for k in head_keys}
        stage_ids = jnp.arange(n_stages, dtype=jnp.int32)
        new_caches, logits = f_sharded(stage_ids, params["blocks"], head,
                                       caches, tokens)
        new_caches = dict(new_caches)
        new_caches["x_carry"] = jnp.roll(new_caches["x_carry"], 1, axis=0)
        return new_caches, logits

    name = f"{cfg.name}|{shape.name}|{executor}|pp{n_stages}"
    return StepBundle(
        name + "|decode", step,
        (staged_shape, caches_shape, tok_shape),
        (p_shard, c_shard, NamedSharding(mesh, tok_spec)),
        None, donate_argnums=(1,), ctx=ctx)
