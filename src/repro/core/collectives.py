"""Hierarchical (bounded fan-in) collectives — the paper's §4.3 two-level
CCD synchronization mapped to mesh axes.

Flat all-reduce over (pod × data) moves every byte across the slow inter-pod
links. The hierarchical form:

    1. reduce-scatter within the pod (fast ICI ring, fan-in 2/step),
    2. all-reduce ACROSS pods on the 1/|data|-sized shard (slow link),
    3. all-gather within the pod,

cuts cross-pod bytes by |data|× — "keep highly contended state local and
limit cross-domain ownership transfer" (paper §4.3), with the ICI ring playing
the role of the bounded fan-in tree. Used by the shard_map paths (pipeline,
WA routing) and measurable in the dry-run per-axis collective split.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import axis_size


def hierarchical_psum(x: jax.Array, fast_axis: str, slow_axis: str,
                      scatter_dim: int = 0) -> jax.Array:
    """psum over (fast_axis × slow_axis) with slow-link traffic ÷ fast_size.
    Requires x.shape[scatter_dim] % fast_size == 0 (falls back to flat psum
    otherwise)."""
    fast = axis_size(fast_axis)
    if x.shape[scatter_dim] % fast != 0:
        return lax.psum(x, (fast_axis, slow_axis))
    shard = lax.psum_scatter(x, fast_axis, scatter_dimension=scatter_dim,
                             tiled=True)
    shard = lax.psum(shard, slow_axis)
    return lax.all_gather(shard, fast_axis, axis=scatter_dim, tiled=True)


def hierarchical_pmean(x, fast_axis: str, slow_axis: str, scatter_dim: int = 0):
    total = axis_size(fast_axis) * axis_size(slow_axis)
    return hierarchical_psum(x, fast_axis, slow_axis, scatter_dim) / total


def ring_all_gather(x: jax.Array, axis: str, concat_dim: int = 0) -> jax.Array:
    """Explicit ring all-gather via ppermute (fan-in 2 per step) — the
    shard_map building block when we schedule collectives by hand."""
    n = axis_size(axis)
    idx = lax.axis_index(axis)
    perm = [(i, (i + 1) % n) for i in range(n)]
    pieces = [x]
    cur = x
    for _ in range(n - 1):
        cur = lax.ppermute(cur, axis, perm)
        pieces.append(cur)
    # rotate into rank order: piece j originated at (idx - j) mod n
    ordered = [None] * n
    for j, p in enumerate(pieces):
        ordered[j] = p
    # stack in origin order using static rotation per rank is data-dependent;
    # concatenating in arrival order then rolling by idx keeps it static:
    out = jnp.concatenate(ordered, axis=concat_dim)
    shard = x.shape[concat_dim]
    return jnp.roll(out, shift=idx * shard, axis=concat_dim)


def grad_sync(grads, dp_axes: Sequence[str], pod_axis: Optional[str] = None):
    """Gradient synchronization for the pipeline/shard_map training path:
    hierarchical when a pod axis exists, flat psum otherwise."""
    def one(g):
        if pod_axis is None:
            return lax.pmean(g, tuple(dp_axes))
        return hierarchical_pmean(g, dp_axes[0], pod_axis, scatter_dim=0)
    return jax.tree.map(one, grads)
