"""Training driver: config-driven, fault-tolerant, AOT-compiled.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

Wires every substrate together: synthetic data pipeline (deterministic,
resumable), AdamW, chunked-CE loss, checkpointing (atomic, keep-last-k),
the static AOT runtime (compile once, dispatch forever), and the elastic
controller (failure injection → re-mesh → restore → resume; exercised by
tests/test_elastic.py and examples/train_lm.py).
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.registry import get_config
from repro.configs.shapes import ShapeConfig
from repro.core.execution import make_step
from repro.data.synthetic import SyntheticLMData
from repro.models.sharding import ShardingCtx, operator_centric
from repro.models.registry import build_model
from repro.optim.adamw import adamw_init
from repro.runtime.static_runtime import StaticRuntime


def train(arch: str, steps: int, batch: int, seq: int, *,
          reduced: bool = True, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, mesh=None, executor: str = "sub_operator",
          log_every: int = 10, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("custom", seq_len=seq, global_batch=batch, mode="train")
    api = build_model(cfg)

    if mesh is None:
        ctx = ShardingCtx(None, operator_centric())
        bundle = None
    else:
        bundle = make_step(cfg, shape, mesh, executor=executor)
        ctx = bundle.ctx

    params = api.init(jax.random.key(seed))
    opt = adamw_init(params)
    start_step = 0

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    if ckpt:
        restored_step, state = ckpt.restore({"params": params, "opt": opt})
        if restored_step is not None:
            params, opt = state["params"], state["opt"]
            start_step = restored_step
            print(f"[train] resumed from step {start_step}")

    rt = StaticRuntime(mesh)

    def step_fn(params, opt, batch_):
        from repro.optim.adamw import adamw_update, cosine_lr
        def lf(p):
            return api.loss(p, batch_, ctx)
        loss, grads = jax.value_and_grad(lf)(params)
        lr_t = cosine_lr(opt.step, 3e-4, warmup=20, total=max(steps, 100))
        new_p, new_o, info = adamw_update(params, grads, opt, lr=lr_t)
        return new_p, new_o, {"loss": loss, **info}

    data = SyntheticLMData(cfg, batch, seq, seed=seed).start(from_step=start_step)
    it = iter(data)
    compiled = None
    losses = []
    t0 = time.monotonic()
    for i in range(start_step, steps):
        step_idx, host_batch = next(it)
        dev_batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
        if compiled is None:
            compiled = rt.compile_step("train", step_fn,
                                       (params, opt, dev_batch),
                                       donate_argnums=(0, 1))
            print(f"[train] compiled in {compiled.compile_s:.1f}s")
        params, opt, info = compiled(params, opt, dev_batch)
        if (i + 1) % log_every == 0 or i == start_step:
            loss = float(info["loss"])
            losses.append((i + 1, loss))
            print(f"[train] step {i+1:5d} loss {loss:.4f} "
                  f"gnorm {float(info['grad_norm']):.3f} "
                  f"({(time.monotonic()-t0)/(i-start_step+1)*1e3:.0f} ms/step)")
        if ckpt and (i + 1) % ckpt_every == 0:
            ckpt.save(i + 1, params=params, opt=opt)
    data.stop()
    if ckpt:
        ckpt.save(steps, params=params, opt=opt)
    return params, opt, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--executor", default="sub_operator")
    args = ap.parse_args(argv)
    _, _, losses = train(args.arch, args.steps, args.batch, args.seq,
                         reduced=args.reduced, ckpt_dir=args.ckpt_dir,
                         executor=args.executor)
    if losses:
        first, last = losses[0][1], losses[-1][1]
        print(f"[train] loss {first:.3f} → {last:.3f} "
              f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
