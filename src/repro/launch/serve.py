"""Serving driver: continuous-batching decode with the static AOT runtime.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --requests 8 --batch 4 --prompt-len 32 --max-new 16 --reduced \
        --arrival-every 4

Reports the paper's metrics (TPOT mean/p50/p99, throughput) plus the
scheduler-side metrics the continuous engine adds (per-request TTFT, queue
delay, overlapped admissions) from real measured steps on this host (reduced
configs) — the measurement side of the Table 2 methodology;
benchmarks/table2_end_to_end.py compares these against the analytical model.

``--mode drain`` runs the legacy drain-then-refill baseline for A/B
comparison (late arrivals starve until the whole batch empties — DESIGN.md §7).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.registry import get_config
from repro.models.registry import build_model
from repro.models.sharding import ShardingCtx, operator_centric, sub_operator
from repro.runtime.serving import Request, ServingEngine


def make_requests(cfg, n_requests: int, prompt_len: int, max_new: int,
                  seed: int = 0, arrival_every: int = 0):
    """Synthetic workload; ``arrival_every`` > 0 staggers arrivals so request
    i becomes visible at decode step i*arrival_every (mid-serve admission)."""
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=max_new,
                    arrival_step=i * arrival_every)
            for i in range(n_requests)]


def serve(arch: str, n_requests: int, batch_slots: int, prompt_len: int,
          max_new: int, *, reduced: bool = True, seed: int = 0,
          executor: str = "sub_operator", mode: str = "auto",
          arrival_every: int = 0, block_size: int = 1,
          kv_bucket_chunk: int = 0, prefill_chunk: int = 0,
          backend: str = "colocated", a_shards: int = 1, overlap: int = 1,
          preemptible: bool = False, max_queue: int = 0,
          hot_window: int = 0, kv_cold_dtype: str = "int8",
          kv_cold_block: int = 16, kv_budget_bytes: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if hot_window:
        # tiered KV cache: hot ring at the resident dtype, cold prefix
        # quantized in fixed blocks (build-time statics — DESIGN.md §7)
        cfg = cfg.replace(hot_window=hot_window,
                          kv_cold_dtype=kv_cold_dtype,
                          kv_cold_block=kv_cold_block)
    if mode == "drain" and prefill_chunk:
        print("note: --prefill-chunk ignored (drain mode has no chunk lane)")
        prefill_chunk = 0
    api = build_model(cfg)
    ctx = ShardingCtx(None, sub_operator() if executor == "sub_operator"
                      else operator_centric())
    import jax
    params = api.init(jax.random.key(seed))
    reqs = make_requests(cfg, n_requests, prompt_len, max_new, seed,
                         arrival_every)
    eng = ServingEngine(api, ctx, batch_slots, prompt_len, mode=mode,
                        block_size=block_size,
                        kv_bucket_chunk=kv_bucket_chunk,
                        prefill_chunk=prefill_chunk, backend=backend,
                        a_shards=a_shards, overlap=overlap,
                        preemptible=preemptible, max_queue=max_queue,
                        kv_budget_bytes=kv_budget_bytes)
    stats = eng.run(params, reqs)
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "continuous", "drain"))
    ap.add_argument("--arrival-every", type=int, default=0,
                    help="stagger: request i arrives at step i*N (0 = all "
                         "at start)")
    ap.add_argument("--block-size", type=int, default=1,
                    help="decode micro-steps per host sync (macro-step "
                         "decode; 1 = per-token engine)")
    ap.add_argument("--kv-bucket-chunk", type=int, default=0,
                    help="KV bucket granularity for length-aware decode "
                         "(block mode; 0 = full extent)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill lane: admit prompts as fixed "
                         "(1,C) chunks, one per block boundary, with "
                         "length-true cursors (0 = monolithic admission)")
    ap.add_argument("--backend", default="colocated",
                    choices=("colocated", "wa"),
                    help="executor backend: colocated, or the weight-"
                         "attention disaggregated path (routing compiled "
                         "into every step program; DESIGN.md §3)")
    ap.add_argument("--a-shards", type=int, default=1,
                    help="split-KV flash decode width: shard each slot's "
                         "KV walk into N equal sequence shards recombined "
                         "by the partial-softmax LSE merge (token-exact; "
                         "the KV extent must divide by N; under --backend "
                         "wa on a mesh the shards ride the A-domain model "
                         "axis)")
    ap.add_argument("--overlap", type=int, default=1,
                    help="sub-operator micro-batch pipelining depth for "
                         "the W/A boundary (backend wa only; 1, 2 or 4 — "
                         "batch must divide evenly): W runs QKV/FFN for "
                         "one micro-batch while A attends another, "
                         "token-exact at every depth (DESIGN.md §3)")
    ap.add_argument("--preemptible", action="store_true",
                    help="compile the token-exact KV swap pair and allow "
                         "priority/pressure preemption at block boundaries "
                         "(DESIGN.md §7)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded-queue backpressure: shed lowest-priority "
                         "queued work beyond N as structured rejections "
                         "(0 = unbounded)")
    ap.add_argument("--hot-window", type=int, default=0,
                    help="tiered KV cache: keep the most recent N tokens "
                         "per slot at the cache-resident dtype and demote "
                         "older tokens to the quantized cold tier in "
                         "fixed blocks, inside the compiled programs "
                         "(0 = flat cache)")
    ap.add_argument("--kv-cold-dtype", default="int8",
                    choices=("bfloat16", "int8", "int4"),
                    help="cold-tier storage dtype (int4 packs two lanes "
                         "per byte with per-block scales)")
    ap.add_argument("--kv-cold-block", type=int, default=16,
                    help="demotion granularity: cold-boundary advances in "
                         "blocks of N tokens (build-time static)")
    ap.add_argument("--kv-budget-bytes", type=int, default=0,
                    help="tiered-KV arbiter byte budget: preempt victims "
                         "(with --preemptible) or hold admissions while "
                         "occupancy-priced live KV bytes exceed N "
                         "(0 = unbounded)")
    args = ap.parse_args(argv)
    stats = serve(args.arch, args.requests, args.batch, args.prompt_len,
                  args.max_new, mode=args.mode,
                  arrival_every=args.arrival_every,
                  block_size=args.block_size,
                  kv_bucket_chunk=args.kv_bucket_chunk,
                  prefill_chunk=args.prefill_chunk,
                  backend=args.backend, a_shards=args.a_shards,
                  overlap=args.overlap, preemptible=args.preemptible,
                  max_queue=args.max_queue, hot_window=args.hot_window,
                  kv_cold_dtype=args.kv_cold_dtype,
                  kv_cold_block=args.kv_cold_block,
                  kv_budget_bytes=args.kv_budget_bytes)
    per_req = stats.pop("per_request")
    rt = stats.pop("runtime")
    rejected = stats.pop("rejected")
    tiered = stats.pop("tiered", None)
    print("serve stats:", stats)
    if tiered:
        # host-side placement arbiter view (KVArbiter): tier occupancy,
        # in-program demotions counted off cursor watermarks, byte savings
        print(f"tiered kv:  hot_window={tiered['hot_window']} "
              f"cold={tiered['cold_dtype']}/block{tiered['cold_block']} "
              f"demotions={tiered['demotions']} "
              f"kv_bytes_per_slot={tiered['kv_bytes_per_slot']} "
              f"peak_kv_bytes={tiered['peak_kv_bytes']} "
              f"cold_bytes_saved={tiered['cold_bytes_saved']}")
        for s in tiered["per_slot"]:
            print(f"  slot {s['slot']}: {s['tokens']} tokens "
                  f"({s['hot_tokens']} hot / {s['cold_tokens']} cold, "
                  f"{s['kv_bytes']} B)")
        print(f"  arbiter: {tiered['recommendation']}")
    if "wa" in stats:
        # per-domain stall accounting of the W/A schedule (DESIGN.md §3):
        # overlap efficiency = busy ticks / total over both domains
        wa = stats["wa"]
        print(f"wa overlap: depth={wa['overlap']} "
              f"efficiency={wa['overlap_efficiency']:.3f} "
              f"(W busy {wa['w_busy_ticks']}/{wa['schedule_ticks']}, "
              f"A busy {wa['a_busy_ticks']}/{wa['schedule_ticks']} ticks); "
              f"per macro-step W-idle {wa['w_idle_ms_per_macro_step']:.2f} "
              f"ms / A-idle {wa['a_idle_ms_per_macro_step']:.2f} ms; "
              f"micro-batch occupancy {wa['micro_batch_occupancy']:.2f}")
    # pressure / robustness counters (DESIGN.md §7): every submitted
    # request is terminally accounted completed / rejected / deadline-missed
    print(f"pressure: preemptions={stats['preemptions']} "
          f"restores={stats['restores']} rejections={stats['rejections']} "
          f"deadline_misses={stats['deadline_misses']} "
          f"retries={stats['retries']} "
          f"watchdog_timeouts={stats['watchdog_timeouts']} "
          f"quarantined={stats['quarantined_slots']} "
          f"swap_time_ms={stats['swap_time_ms']:.2f}")
    for e in rejected:
        print(f"  shed rid={e['rid']:3d} [{e['status']}] "
              f"priority={e['priority']} reason={e['reason']}")
    print("per-request:")
    for m in per_req:
        print(f"  rid={m['rid']:3d} admit@{m['admit_step']:4d} "
              f"queue={m['queue_delay_ms']:8.1f}ms "
              f"ttft={m['ttft_ms']:8.1f}ms tpot={m['tpot_ms']:6.2f}ms "
              f"preempts={m['preemptions']}")
    print("runtime:", {k: {kk: round(vv, 3) if isinstance(vv, float) else vv
                           for kk, vv in v.items()} for k, v in rt.items()})


if __name__ == "__main__":
    main()
