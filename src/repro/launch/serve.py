"""Serving driver: batched decode with the static AOT runtime.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --requests 8 --batch 4 --prompt-len 32 --max-new 16 --reduced

Reports the paper's metrics (TPOT mean/p50/p99, throughput) from real
measured steps on this host (reduced configs) — the measurement side of the
Table 2 methodology; benchmarks/table2_end_to_end.py compares these against
the analytical model.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.registry import get_config
from repro.models.registry import build_model
from repro.models.sharding import ShardingCtx, operator_centric, sub_operator
from repro.runtime.serving import Request, ServingEngine


def serve(arch: str, n_requests: int, batch_slots: int, prompt_len: int,
          max_new: int, *, reduced: bool = True, seed: int = 0,
          executor: str = "sub_operator"):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)
    ctx = ShardingCtx(None, sub_operator() if executor == "sub_operator"
                      else operator_centric())
    rng = np.random.default_rng(seed)
    import jax
    params = api.init(jax.random.key(seed))
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(n_requests)]
    eng = ServingEngine(api, ctx, batch_slots, prompt_len)
    stats = eng.run(params, reqs)
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args(argv)
    stats = serve(args.arch, args.requests, args.batch, args.prompt_len,
                  args.max_new)
    print("serve stats:", stats)


if __name__ == "__main__":
    main()
