"""Compiled-HLO analysis: collective bytes, per-axis attribution, roofline
inputs.

collective_bytes is NOT in cost_analysis() — we parse the optimized HLO
(``compiled.as_text()``) and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, recovering the
operand size from the (always-printed) result type:

    op            operand_bytes (per participant)
    all-gather    result / group_size
    all-reduce    result
    reduce-scatter result × group_size
    all-to-all    result
    collective-permute result

replica_groups stride analysis attributes each collective to mesh axes so the
hierarchical-collective optimization (intra-pod vs cross-pod) is measurable:
for mesh (pod, data, model) flattened ids, a group over "model" is stride-1,
over "data" stride-16, over "pod" stride-256.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "bf16[16,512]{1,0}"  or tuple "(f32[8], f32[8])"
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\s*[,)]", re.S)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]"
                             r"(?:T\(([\d,]+)\))?")


@dataclass
class CollectiveOp:
    kind: str
    result_bytes: float
    operand_bytes: float
    group_size: int
    axes: Tuple[str, ...]
    line: str = ""


@dataclass
class CollectiveSummary:
    ops: List[CollectiveOp] = field(default_factory=list)

    @property
    def total_operand_bytes(self) -> float:
        return sum(o.operand_bytes for o in self.ops)

    def bytes_by_kind(self) -> Dict[str, float]:
        d: Dict[str, float] = defaultdict(float)
        for o in self.ops:
            d[o.kind] += o.operand_bytes
        return dict(d)

    def bytes_by_axes(self) -> Dict[str, float]:
        d: Dict[str, float] = defaultdict(float)
        for o in self.ops:
            d["+".join(o.axes) or "?"] += o.operand_bytes
        return dict(d)

    def count(self) -> int:
        return len(self.ops)


def _shape_bytes(type_str: str) -> float:
    """Sum bytes over (possibly tuple) HLO result type string."""
    total = 0.0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_info(line: str) -> Tuple[int, List[List[int]]]:
    """Return (group_size, example groups) from replica_groups."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        reshape = [int(x) for x in m.group(3).split(",")]
        transpose = ([int(x) for x in m.group(4).split(",")]
                     if m.group(4) else list(range(len(reshape))))
        ids = np.arange(int(np.prod(reshape))).reshape(reshape)
        ids = ids.transpose(transpose).reshape(n_groups, group_size)
        return group_size, [list(ids[0]), list(ids[min(1, n_groups - 1)])]
    m = _GROUPS_RE.search(line)
    if m:
        body = m.group(1)
        groups = []
        for g in re.findall(r"\{([\d,\s]+)\}", "{" + body + "}"):
            groups.append([int(x) for x in g.replace(" ", "").split(",") if x])
        if groups:
            return len(groups[0]), groups[:2]
    return 1, [[0]]


def _axes_of_group(group: List[int], mesh_shape: Tuple[int, ...],
                   axis_names: Tuple[str, ...]) -> Tuple[str, ...]:
    """Classify which mesh axes a replica group spans by id strides."""
    if len(group) <= 1:
        return ()
    n = len(mesh_shape)
    # per-axis stride in the flattened id space
    ax_stride = [int(np.prod(mesh_shape[i + 1:])) for i in range(n)]
    span = set()
    ids = np.array(sorted(group))
    # decompose each id into mesh coords; axes where coords vary are spanned
    coords = []
    for i in range(n):
        coords.append((ids // ax_stride[i]) % mesh_shape[i])
    for i in range(n):
        if len(np.unique(coords[i])) > 1:
            span.add(axis_names[i])
    return tuple(a for a in axis_names if a in span)


def parse_collectives(hlo_text: str, mesh_shape: Tuple[int, ...],
                      axis_names: Tuple[str, ...]) -> CollectiveSummary:
    summary = CollectiveSummary()
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("//") or " = " not in ls:
            continue
        head, rest = ls.split(" = ", 1)
        opm = re.match(r"(\([^)]*\)|\S+)\s+([\w-]+)", rest)
        if not opm:
            continue
        kind_raw = opm.group(2)
        kind = None
        for c in _COLLECTIVES:
            if kind_raw == c or kind_raw.startswith(c + "-start") or \
                    kind_raw == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        rb = _shape_bytes(opm.group(1) if opm.group(1).startswith("(")
                          else rest.split(" ", 1)[0])
        gsize, groups = _group_info(ls)
        axes = _axes_of_group(groups[0], mesh_shape, axis_names)
        if kind == "all-gather":
            ob = rb / max(gsize, 1)
        elif kind == "reduce-scatter":
            ob = rb * gsize
        else:
            ob = rb
        summary.ops.append(CollectiveOp(kind, rb, ob, gsize, axes, ls[:160]))
    return summary


# ---------------------------------------------------------------------------
# Static-verifier helpers (repro.analysis): donation + host-op audit
# ---------------------------------------------------------------------------

_ALIAS_ENTRY_RE = re.compile(r"\{\s*([\d,\s]*)\s*\}\s*:\s*\(\s*(\d+)")
_HOST_OPS = ("infeed", "outfeed", "send", "recv", "send-done", "recv-done")
_HOST_CALL_RE = re.compile(r"callback|py_func|host", re.I)
_CUSTOM_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')


def parse_input_output_alias(hlo_text: str) -> Dict[Tuple[int, ...], int]:
    """Donation map from the HloModule header:

        input_output_alias={ {0}: (12, {}, may-alias), {1}: (13, ...) }

    → {output_index_tuple: flat_parameter_number}. An empty dict means XLA
    kept NO buffer donation — every "donated" input is actually copied."""
    for line in hlo_text.splitlines():
        if "input_output_alias=" not in line:
            continue
        seg = line.split("input_output_alias=", 1)[1]
        # header is one line; entries are {out_idx}: (param, {param_idx}, kind)
        out: Dict[Tuple[int, ...], int] = {}
        for m in _ALIAS_ENTRY_RE.finditer(seg):
            idx = tuple(int(x) for x in
                        m.group(1).replace(" ", "").split(",") if x)
            out[idx] = int(m.group(2))
        return out
    return {}


def parse_host_ops(hlo_text: str) -> List[str]:
    """Ops that imply a host round-trip inside the compiled program:
    infeed/outfeed, send/recv, and python-callback custom-calls. A decode
    program containing any of these synchronizes with the host every
    dispatch — exactly the per-step sync the macro-step engine exists to
    remove."""
    hits: List[str] = []
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls or ls.startswith("//"):
            continue
        rest = ls.split(" = ", 1)[1]
        opm = re.match(r"(\([^)]*\)|\S+)\s+([\w-]+)", rest)
        if not opm:
            continue
        kind = opm.group(2)
        if kind in _HOST_OPS:
            hits.append(ls[:200])
        elif kind.startswith("custom-call"):
            tm = _CUSTOM_TARGET_RE.search(ls)
            if tm and _HOST_CALL_RE.search(tm.group(1)):
                hits.append(ls[:200])
    return hits


def ring_traffic_bytes(summary: CollectiveSummary) -> float:
    """Per-chip link traffic under ring algorithms (analysis supplement):
    AG: (g−1)/g × result; AR: 2(g−1)/g × operand; RS: (g−1)/g × operand;
    A2A: (g−1)/g × operand; permute: operand."""
    total = 0.0
    for o in summary.ops:
        g = max(o.group_size, 1)
        f = (g - 1) / g
        if o.kind == "all-gather":
            total += f * o.result_bytes
        elif o.kind == "all-reduce":
            total += 2 * f * o.operand_bytes
        elif o.kind == "reduce-scatter":
            total += f * o.operand_bytes / g * g  # (g-1)/g × input
        elif o.kind == "all-to-all":
            total += f * o.operand_bytes
        else:
            total += o.operand_bytes
    return total
