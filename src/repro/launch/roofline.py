"""Roofline-term computation from compiled dry-run artifacts.

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective operand bytes / (chips × link_bw)

Hardware constants (TPU v5e): 197 TFLOP/s bf16 (394 TOP/s int8), 819 GB/s
HBM, ~50 GB/s/link ICI. MODEL_FLOPS = 6·N·D (dense; N_active for MoE) for
train; 2·N·D + attention-term for inference steps.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig

PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9           # per link; v5e: 4 links/chip usable on a 2D torus


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    executor: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    cross_pod_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float            # MODEL_FLOPS / HLO_FLOPs
    step_s: float                  # max of the three terms
    roofline_frac: float           # useful compute time / step bound
    notes: str = ""

    def to_dict(self) -> Dict:
        return asdict(self)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Canonical useful FLOPs for this cell's step (whole step, all chips)."""
    from repro.models.registry import count_params
    n_active = count_params(cfg, active_only=True)
    B, S = shape.global_batch, shape.seq_len
    if shape.mode == "train":
        tokens = B * S
        base = 6.0 * n_active * tokens
        base += 3.0 * 2.0 * _attn_flops(cfg, S, causal=True) * B
        return base
    if shape.mode == "prefill":
        tokens = B * S
        return 2.0 * n_active * tokens + 2.0 * _attn_flops(cfg, S, True) * B
    # decode: one token against ctx S
    return (2.0 * n_active + _decode_attn_flops(cfg, S)) * B


def _attn_flops(cfg: ModelConfig, S: int, causal: bool) -> float:
    """QK^T + PV flops for a full sequence, per batch element."""
    if cfg.family == "ssm":
        nh = cfg.ssm.n_heads(cfg.d_model)
        return 4.0 * S * nh * cfg.ssm.head_dim * cfg.ssm.d_state
    total = 0.0
    for k in cfg.block_kinds():
        if k == "attn":
            span = S / 2 if causal else S
        elif k == "local":
            span = min(S, cfg.rglru.window) / (2 if causal else 1) \
                if S <= cfg.rglru.window else cfg.rglru.window
        else:
            total += 4.0 * S * (cfg.rglru.lru_width or cfg.d_model)
            continue
        total += 4.0 * S * span * cfg.n_heads * cfg.head_dim
    if cfg.family == "audio":
        F = cfg.encoder.n_frames
        total += cfg.encoder.n_layers * 4.0 * F * F * cfg.n_heads * cfg.head_dim
        total += cfg.n_layers * 4.0 * S * F * cfg.n_heads * cfg.head_dim
    return total


def _decode_attn_flops(cfg: ModelConfig, S: int) -> float:
    if cfg.family == "ssm":
        nh = cfg.ssm.n_heads(cfg.d_model)
        return cfg.n_layers * 4.0 * nh * cfg.ssm.head_dim * cfg.ssm.d_state
    total = 0.0
    for k in cfg.block_kinds():
        if k == "attn":
            span = S
        elif k == "local":
            span = min(S, cfg.rglru.window)
        else:
            total += 4.0 * (cfg.rglru.lru_width or cfg.d_model)
            continue
        total += 4.0 * span * cfg.n_heads * cfg.head_dim
    if cfg.family == "audio":
        total += cfg.n_layers * 4.0 * cfg.encoder.n_frames * \
            cfg.n_heads * cfg.head_dim
    return total


def compute_terms(cfg: ModelConfig, shape: ShapeConfig, *, mesh_name: str,
                  executor: str, chips: int, hlo_flops: float,
                  hlo_bytes: float, collective_bytes: float,
                  cross_pod_bytes: float = 0.0,
                  int8_compute: bool = False, notes: str = "") -> RooflineTerms:
    peak = PEAK_FLOPS_INT8 if int8_compute else PEAK_FLOPS_BF16
    c = hlo_flops / (chips * peak)
    m = hlo_bytes / (chips * HBM_BW)
    # collective term: assignment formula — operand bytes over chip link bw
    col = collective_bytes / (chips * ICI_BW)
    terms = {"compute": c, "memory": m, "collective": col}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    step = max(c, m, col)
    useful_time = mf / (chips * peak)
    return RooflineTerms(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, executor=executor,
        chips=chips, hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
        collective_bytes=collective_bytes, cross_pod_bytes=cross_pod_bytes,
        compute_s=c, memory_s=m, collective_s=col, dominant=dominant,
        model_flops=mf, useful_ratio=mf / max(hlo_flops, 1.0),
        step_s=step, roofline_frac=useful_time / max(step, 1e-30),
        notes=notes)
