import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile EVERY (arch × shape × mesh) cell and
record memory_analysis / cost_analysis / collective schedule.

The two lines above MUST run before any other import (jax locks the device
count on first init); they are intentionally placed before the module
docstring's siblings. Do NOT replicate this flag elsewhere — tests and
benches must see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
        --shape decode_32k --mesh single --executor sub_operator
    PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs.registry import ASSIGNED, get_config
from repro.configs.shapes import ALL_SHAPES, SHAPES, applicable
from repro.core.execution import make_step
from repro.launch.hlo_analysis import parse_collectives, ring_traffic_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import compute_terms

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


# ---------------------------------------------------------------------------
# Cost probes.
#
# XLA's cost_analysis() counts while-loop bodies ONCE (trip counts are not
# multiplied) and reports PER-DEVICE numbers (verified empirically — see
# EXPERIMENTS.md §Dry-run methodology). The full-config compile is therefore
# used for memory_analysis + the per-layer collective schedule, while exact
# FLOPs/bytes come from two depth-reduced FULLY-UNROLLED probe compiles
# (REPRO_UNROLL_SCANS=1) and linear extrapolation — exact for uniform stacks:
#     cost(L) = a + b·L  ⇒  cost_real = c_lo + (c_hi−c_lo)·(u_real−u_lo)/(u_hi−u_lo)
# ---------------------------------------------------------------------------

def probe_configs(cfg, mult: int = 1):
    """→ (cfg_lo, cfg_hi, u_lo, u_hi, u_real): layer-unit probe pair.
    ``mult``: minimum layer multiple (= n_stages under PP)."""
    import dataclasses
    if mult > 1:
        return (cfg.replace(n_layers=mult), cfg.replace(n_layers=2 * mult),
                mult, 2 * mult, cfg.n_layers)
    if cfg.family == "hybrid":
        pat = len(cfg.rglru.block_pattern)          # 3
        tail = cfg.n_layers % pat                   # 2 for 38
        lo = cfg.replace(n_layers=pat + tail)
        hi = cfg.replace(n_layers=2 * pat + tail)
        return lo, hi, 1, 2, (cfg.n_layers - tail) // pat
    if cfg.family == "audio":
        enc = cfg.encoder
        lo = cfg.replace(n_layers=1,
                         encoder=dataclasses.replace(enc, n_layers=1))
        hi = cfg.replace(n_layers=2,
                         encoder=dataclasses.replace(enc, n_layers=2))
        # units move enc+dec together; exact because both stacks are 24L
        return lo, hi, 1, 2, cfg.n_layers
    return cfg.replace(n_layers=1), cfg.replace(n_layers=2), 1, 2, cfg.n_layers


def _probe_cost(cfg, shape, multi_pod, executor, pod_strategy):
    """Compile the two unrolled probes; return extrapolated (flops, bytes,
    collective operand bytes, ring bytes, by_axes)."""
    mult = 2 if (pod_strategy == "pp" and multi_pod) else 1
    lo_cfg, hi_cfg, u_lo, u_hi, u_real = probe_configs(cfg, mult)
    os.environ["REPRO_UNROLL_SCANS"] = "1"
    try:
        vals = []
        for c in (lo_cfg, hi_cfg):
            mesh = make_production_mesh(multi_pod=multi_pod)
            with mesh:
                bundle = make_step(c, SHAPES[shape.name], mesh,
                                   executor=executor,
                                   pod_strategy=pod_strategy)
                lowered = bundle.lower()
                compiled = lowered.compile()
                from repro.core.compat import cost_analysis
                cost = cost_analysis(compiled)
                coll = parse_collectives(compiled.as_text(),
                                         mesh.devices.shape, mesh.axis_names)
            vals.append({
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": coll.total_operand_bytes,
                "ring": ring_traffic_bytes(coll),
                "by_axes": coll.bytes_by_axes(),
            })
    finally:
        os.environ.pop("REPRO_UNROLL_SCANS", None)

    def extrap(lo, hi):
        return lo + (hi - lo) * (u_real - u_lo) / (u_hi - u_lo)

    by_axes = {}
    for k in set(vals[0]["by_axes"]) | set(vals[1]["by_axes"]):
        by_axes[k] = extrap(vals[0]["by_axes"].get(k, 0.0),
                            vals[1]["by_axes"].get(k, 0.0))
    return {
        "flops": extrap(vals[0]["flops"], vals[1]["flops"]),
        "bytes": extrap(vals[0]["bytes"], vals[1]["bytes"]),
        "coll": extrap(vals[0]["coll"], vals[1]["coll"]),
        "ring": extrap(vals[0]["ring"], vals[1]["ring"]),
        "by_axes": by_axes,
        "probe_units": [u_lo, u_hi, u_real],
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             executor: str = "sub_operator", pod_strategy: str = "dp",
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "executor": executor, "pod_strategy": pod_strategy}
    ok, why = applicable(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=why)
        return rec

    t0 = time.monotonic()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh:
            bundle = make_step(cfg, shape, mesh, executor=executor,
                               pod_strategy=pod_strategy)
            lowered = bundle.lower()
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
        chips = int(np.prod(mesh.devices.shape))
        coll = parse_collectives(hlo, mesh.devices.shape, mesh.axis_names)

        # exact trip-scaled cost from unrolled probes (per-device numbers)
        probe = _probe_cost(cfg, shape, multi_pod, executor, pod_strategy)
        flops = probe["flops"] * chips        # per-device → whole-step totals
        byts = probe["bytes"] * chips
        coll_bytes = probe["coll"] * chips
        xpod = sum(v for k, v in probe["by_axes"].items() if "pod" in k) * chips
        terms = compute_terms(
            cfg, shape, mesh_name=mesh_name, executor=executor, chips=chips,
            hlo_flops=flops, hlo_bytes=byts,
            collective_bytes=coll_bytes, cross_pod_bytes=xpod)
        rec.update(
            status="ok",
            compile_s=round(time.monotonic() - t0, 1),
            chips=chips,
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_per_device_gb": round(
                    (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)
                    / 1e9, 3),
            },
            cost={"flops": flops, "bytes": byts,
                  "probe_units": probe["probe_units"]},
            collectives={
                "schedule_ops": coll.count(),          # per-body schedule
                "schedule_by_kind": coll.bytes_by_kind(),
                "operand_bytes": coll_bytes,           # trip-scaled, all chips
                "ring_traffic_bytes": probe["ring"] * chips,
                "by_axes": {k: v * chips for k, v in probe["by_axes"].items()},
            },
            roofline=terms.to_dict(),
        )
        if verbose:
            print(f"[ok {rec['compile_s']:>6}s] {arch} × {shape_name} × "
                  f"{mesh_name} × {executor}/{pod_strategy}: "
                  f"flops={flops:.3e} bytes={byts:.3e} "
                  f"coll={coll.total_operand_bytes:.3e} "
                  f"dom={terms.dominant} frac={terms.roofline_frac:.3f}")
            print(f"    memory_analysis: {rec['memory']}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:],
                   compile_s=round(time.monotonic() - t0, 1))
        if verbose:
            print(f"[ERR {rec['compile_s']:>5}s] {arch} × {shape_name} × "
                  f"{mesh_name}: {rec['error']}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--executor", default="sub_operator")
    ap.add_argument("--pod-strategy", default="dp", choices=["dp", "pp"])
    ap.add_argument("--all", action="store_true",
                    help="sweep all assigned archs × shapes")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args(argv)

    archs = list(ASSIGNED) if (args.all or args.arch is None) else [args.arch]
    shapes = [s.name for s in ALL_SHAPES] if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp,
                               executor=args.executor,
                               pod_strategy=args.pod_strategy)
                records.append(rec)
                if args.out:
                    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skip" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skip (documented), {n_err} error")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
