"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (required: tests/benches see 1 device; only dryrun.py sets
the 512-device XLA flag).

Mesh logic:
  single-pod: (16, 16)        = ("data", "model")   — 256 chips (one v5e pod)
  multi-pod:  (2, 16, 16)     = ("pod", "data", "model") — 512 chips

"model" is the high-bandwidth TP axis (paper: cores within a socket);
"data" the batch/KV-capacity axis (paper: attention domains); "pod" the
cross-pod pipeline/replica axis (paper: rack nodes, embeddings-only traffic).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under launch/dryrun.py (sets "
            "--xla_force_host_platform_device_count=512)")
    return Mesh(np.array(devices[:n]).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (uses however many host devices exist)."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.array(devices[:n]).reshape(shape), axes)
