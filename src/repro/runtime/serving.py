"""Continuous-batching decode serving engine under STATIC shapes.

The paper's prototype serves a fixed decode batch and defers continuous
batching to future work (§7.2). This engine closes that gap without leaving
the cache-resident/static-shape regime the paper's runtime depends on:

- the decode batch is a fixed set of SLOTS (static shapes → AOT compile once),
- a queued request is admitted into any free slot *mid-serve* — no drain, no
  retrace,
- every row carries its own cursor (``positions``) and an ``active`` mask is
  threaded through decode (``ModelAPI.decode_slotted``) so retired slots
  neither write KV nor pollute the argmax,
- **macro-step decode** (``block_size`` = T > 1): decode runs as
  ``ModelAPI.decode_block`` — T greedy micro-steps inside ONE AOT-compiled
  ``lax.scan``, with per-slot on-device halting. The host syncs ONCE per T
  tokens and admission waits for block boundaries — the step-axis analogue
  of the paper's sub-operator dependency relaxation (§5),
- **chunked-prefill lane** (``prefill_chunk`` = C > 0): admission prefill is
  no longer one monolithic full-width program that stalls the whole decode
  batch. Each block boundary runs AT MOST ONE fixed-(1,C) chunk
  (``ModelAPI.prefill_chunk``) for the admitting slot, writing KV at the
  slot's offset, then the decode block for live slots — in-flight TPOT pays
  one chunk per boundary instead of a full-prompt stall. Prompt lengths are
  TRUE lengths end to end: the cursor starts at the real length (short
  prompts land in small KV buckets from step 0) and arbitrary lengths are
  covered by the chunk loop — nothing is ever silently truncated,
- **length-aware KV walking**: in block mode each macro-step runs the block
  program compiled for the smallest KV *bucket* (chunk multiple) covering
  every live cursor + T (``kv_bucket_chunk``),
- all step programs are AOT-compiled through ``StaticRuntime`` — ``stats()``
  must show compiles == 1 per program with only ``calls`` growing across
  admissions (the §4.3 pinned-pool invariant).

The engine is split into a host-side **SlotScheduler** (slot occupancy,
arrival pump, cursors/halt operands, chunk-lane bookkeeping — decisions
only) and a device-side **ExecutorBackend** (the compiled step programs and
the slot caches — execution only); ``ServingEngine`` is the boundary loop
that connects them. The backend is PLUGGABLE (``backend=``): the colocated
backend runs the single-domain programs, the WA backend
(``backend="wa"``) runs the same feature set — macro-step blocks, KV
buckets, chunked prefill, slot admission — through the weight–attention
disaggregated layer loop of ``core/wa.py`` with the W→A→W routing inside
the compiled programs (sharding-constrained, ``device_put``-free). The
scheduler is backend-agnostic: no scheduling decision moves. The previous
drain-then-refill loop is kept as ``mode="drain"`` — the baseline the
continuous scheduler is measured against, and the fallback for model
families without slotted support.

Per-request accounting: queue delay (enqueue→admit), TTFT (enqueue→first
token, spanning chunk boundaries under chunked admission), TPOT, and max
inter-token gap (the decode-stall a prefill inflicts on in-flight requests).
Engine-level: decode-token throughput over decode wall-time only — prefill
AND chunk-prefill wall-time are excluded from both sides — host syncs per
decode token, and per-macro-step token counts.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.wa import WADisaggregated, routing_bytes
from repro.kv.cache import KVCache
from repro.models.attention import bucket_for, kv_buckets
from repro.models.common import dtype_of
from repro.models.param_specs import cache_specs
from repro.models.registry import DECODE_SLACK, ModelAPI
from repro.models.sharding import ShardingCtx
from repro.runtime.static_runtime import StaticRuntime


def _pin_cache_tree(caches, ctx: ShardingCtx):
    """Constrain every cache leaf to its planned layout (``cache_specs``).

    Cache-only programs (slot write, slot reset) contain no matmuls and no
    annotations of their own, so GSPMD sees nothing to anchor on and pins
    the whole program — including the DONATED cache buffer — to a single
    device, forcing a full-cache reshard every time dispatch alternates
    with the model-step programs. Pinning entry and exit keeps every
    program in a cell on one agreed cache placement."""
    if ctx.mesh is None or ctx.mesh.empty:
        return caches
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(ctx.mesh, s)),
        caches, cache_specs(caches, ctx))


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (L,) int32 — TRUE length, no padding
    max_new_tokens: int
    arrival_step: int = 0               # decode step at which it reaches the queue
    eos_id: int = -1                    # stop id (< 0 → budget-only halting)
    generated: List[int] = field(default_factory=list)
    t_enqueue: float = 0.0
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    admit_step: int = -1                # decode step at which it got a slot
    t_last_emit: float = 0.0            # last token-emission sync (gap stats)
    max_gap: float = 0.0                # max inter-token gap (decode stall)

    @property
    def done(self) -> bool:
        if self.eos_id >= 0 and self.generated\
                and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens

    def note_emit(self, now: float):
        """Token(s) for this request became host-visible at ``now``; the max
        gap between consecutive emissions is the decode-stall metric (a
        monolithic prefill of another request shows up here)."""
        if self.t_last_emit > 0.0:
            self.max_gap = max(self.max_gap, now - self.t_last_emit)
        self.t_last_emit = now

    def metrics(self) -> Dict[str, Any]:
        n = len(self.generated)
        return {
            "rid": self.rid,
            "tokens": n,
            "prompt_tokens": int(len(self.prompt)),
            "arrival_step": self.arrival_step,
            "admit_step": self.admit_step,
            "queue_delay_ms": max(0.0, self.t_admitted - self.t_enqueue) * 1e3,
            "ttft_ms": max(0.0, self.t_first_token - self.t_enqueue) * 1e3,
            "tpot_ms": ((self.t_done - self.t_first_token) / (n - 1) * 1e3
                        if n > 1 else 0.0),
            "max_gap_ms": self.max_gap * 1e3,
        }


def pad_row(prompt: np.ndarray, width: int) -> np.ndarray:
    """Zero-pad a prompt (or prompt slice) up to a static width. PAD ONLY:
    callers must have rejected anything longer (the silent-truncation fix
    deleted every truncating path)."""
    assert len(prompt) <= width, (len(prompt), width)
    row = np.zeros((width,), np.int32)
    row[:len(prompt)] = prompt
    return row


# ---------------------------------------------------------------------------
# SlotScheduler — the HOST half of the scheduler/executor split
# ---------------------------------------------------------------------------

class SlotScheduler:
    """Slot occupancy, arrival pump, per-slot cursors/halt operands and the
    chunked-prefill lane bookkeeping. Pure host state: it decides WHAT runs
    at each block boundary and never touches a device array — the
    ExecutorBackend owns every compiled call, and because no decision
    lives there, every backend serves through this ONE scheduler
    (DESIGN.md §7)."""

    FREE, PREFILL, DECODE = "free", "prefill", "decode"

    def __init__(self, n_slots: int, requests: List[Request],
                 queue: List[Request]):
        self.n = n_slots
        self.pending = sorted(requests, key=lambda r: r.arrival_step)
        self.queue = queue                       # engine-owned (submit target)
        self.req: List[Optional[Request]] = [None] * n_slots
        self.phase = [self.FREE] * n_slots
        self.filled = [0] * n_slots              # prompt tokens written so far
        self.prefill_fifo: List[int] = []        # slots awaiting chunk work
        self.positions = np.zeros((n_slots,), np.int32)
        self.last_tok = np.zeros((n_slots,), np.int32)
        self.remaining = np.zeros((n_slots,), np.int32)
        self.eos = np.full((n_slots,), -1, np.int32)

    # -- queue / occupancy ------------------------------------------------
    def work_remaining(self) -> bool:
        return bool(self.pending or self.queue
                    or any(p != self.FREE for p in self.phase))

    def pump(self, step: int):
        """Arrival simulation: requests whose arrival_step has come move to
        the queue (already validated by run()). Stamped here UNLESS the
        request was submit()ted before run() — its enqueue time is the
        submit, and queue_delay/TTFT must keep counting from there."""
        while self.pending and self.pending[0].arrival_step <= step:
            r = self.pending.pop(0)
            if not r.t_enqueue:
                r.t_enqueue = time.monotonic()
            self.queue.append(r)

    def occupied(self) -> bool:
        return any(p != self.FREE for p in self.phase)

    def decode_active(self) -> np.ndarray:
        return np.array([p == self.DECODE for p in self.phase])

    # -- chunk lane -------------------------------------------------------
    def assign_free(self, step: int) -> List[Request]:
        """Move queued requests into free slots (PREFILL phase); their
        chunks run one per boundary from the admission FIFO."""
        admitted = []
        now = time.monotonic()
        for i in range(self.n):
            if self.phase[i] == self.FREE and self.queue:
                r = self.queue.pop(0)
                r.t_admitted = now
                r.admit_step = step
                self.req[i] = r
                self.phase[i] = self.PREFILL
                self.filled[i] = 0
                self.prefill_fifo.append(i)
                admitted.append(r)
        return admitted

    def next_chunk(self, chunk: int, kv_extent: Optional[int]
                   ) -> Optional[Tuple[int, Request, int, int]]:
        """Head of the prefill FIFO → (slot, request, start, n_valid) for
        the next fixed-shape chunk, or None when no slot is prefilling.

        The fixed (1,C) window must FIT the cache: ``dynamic_update_slice``
        clamps an out-of-bounds start instead of erroring, which would land
        the final chunk's K/V at the wrong positions. When
        ``start + C > kv_extent`` the window shifts LEFT over
        already-written positions — recomputing a prefix position's K/V is
        bit-identical (same tokens, same attended prefix), so the overlap
        is a no-op and the window still ends at the prompt's true length."""
        if not self.prefill_fifo:
            return None
        i = self.prefill_fifo[0]
        r = self.req[i]
        start = self.filled[i]
        if kv_extent is not None and start + chunk > kv_extent:
            start = kv_extent - chunk
        return i, r, start, min(chunk, len(r.prompt) - start)

    def chunk_done(self, slot: int, start: int, n_valid: int) -> bool:
        """Advance the slot's prompt cursor; True when the prompt is fully
        written (the chunk that just ran was the final one)."""
        self.filled[slot] = start + n_valid
        if self.filled[slot] >= len(self.req[slot].prompt):
            self.prefill_fifo.pop(0)
            return True
        return False

    # -- phase transitions ------------------------------------------------
    def start_decode(self, slot: int, cursor: int, first_tok: int):
        r = self.req[slot]
        self.phase[slot] = self.DECODE
        self.positions[slot] = cursor
        self.last_tok[slot] = first_tok
        self.remaining[slot] = r.max_new_tokens - 1
        self.eos[slot] = r.eos_id

    def retire(self, slot: int):
        self.req[slot] = None
        self.phase[slot] = self.FREE
        if slot in self.prefill_fifo:
            self.prefill_fifo.remove(slot)


# ---------------------------------------------------------------------------
# ExecutorBackend — the DEVICE half of the scheduler/executor split
# ---------------------------------------------------------------------------

class ExecutorBackend:
    """Owns the slot caches and every AOT-compiled step program (compiled
    once through ``StaticRuntime`` — the §4.3 zero-retracing invariant).
    ``ServingEngine(backend=...)`` picks the implementation; the
    ``SlotScheduler`` is backend-agnostic and the boundary loop only ever
    calls this contract:

      fresh()                       fresh slot caches for a run (programs
                                    persist — compiles == 1 across runs)
      admit_full(params,row,slot)   monolithic admission → first-token array
      run_chunk(params,row,slot,start,valid)   one fixed-(1,C) prefill chunk
      decode_step(params,tok,pos,act)          one slotted step (T == 1)
      decode_block(params,bucket,…)  one T-micro-step block (per-bucket
                                     program; ``buckets`` fixed at build)
      reset(slot) / has_reset        debug slot zeroing
      drain_prefill / drain_decode   drain-mode batch programs (colocated
                                     backend only)

    Each backend × mode compiles exactly the programs it dispatches:

      colocated  chunked admission     serve_prefill_chunk
      colocated  monolithic admission  serve_prefill1 + serve_admit
      colocated  T == 1                serve_decode
      colocated  T > 1                 serve_decode_block[_s{N}] per bucket
      colocated  drain                 serve_prefill_batch + serve_decode_drain
      wa         chunked admission     serve_wa_prefill_chunk
      wa         monolithic admission  serve_wa_admit (full-width chunk)
      wa         T == 1                serve_wa_decode
      wa         T > 1                 serve_wa_decode_block[_s{N}] per bucket
      either     debug_reset_slots     serve_reset

    The scheduler never sees a jax array; the executor never makes a
    scheduling decision."""

    name = "colocated"

    def __init__(self, api: ModelAPI, ctx: ShardingCtx, rt: StaticRuntime,
                 params, caches_aval, *, mode: str, slots: int,
                 prompt_len: int, max_new_cap: int, block_size: int,
                 kv_bucket_chunk: int, prefill_chunk: int,
                 debug_reset_slots: bool, a_shards: int = 1):
        self.api, self.ctx, self.rt = api, ctx, rt
        self.slots, self.prompt_len = slots, prompt_len
        self.max_new_cap = max_new_cap
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        self.a_shards = a_shards
        self.caches = None
        self.buckets: Tuple[int, ...] = ()
        self._decode_blocks: Dict[int, Callable] = {}
        self._reset = None
        if mode == "continuous":
            self._build_continuous(params, caches_aval, kv_bucket_chunk,
                                   prefill_chunk, debug_reset_slots)
        else:
            self._build_drain(params)

    # -- shared build pieces ----------------------------------------------
    def _bucket_set(self, caches_aval, kv_bucket_chunk) -> Tuple[int, ...]:
        """Static KV bucket set for the block programs. Bucketing applies
        only to prefix-ordered KV caches; recurrent states (and ring
        buffers) get the single full program."""
        bucketable = isinstance(caches_aval, KVCache)\
            and not caches_aval.window
        s_max = caches_aval.k.shape[3] if bucketable else 0
        # a_shards > 1 → every bucket must split into equal shard blocks
        # (kv_buckets rounds the chunk up; the engine validated s_max)
        return kv_buckets(s_max, kv_bucket_chunk, self.a_shards)\
            if bucketable and kv_bucket_chunk > 0 else (0,)

    @property
    def cache_ctx(self) -> ShardingCtx:
        """Sharding ctx that owns the slot caches (A domain under WA)."""
        return self.ctx

    def _build_reset(self, caches_aval, debug_reset_slots):
        if debug_reset_slots and self.api.reset_slot is not None:
            scalar = jnp.zeros((), jnp.int32)
            cctx = self.cache_ctx
            self._reset = self.rt.compile_step(
                "serve_reset",
                lambda c, slot: _pin_cache_tree(
                    self.api.reset_slot(_pin_cache_tree(c, cctx), slot),
                    cctx),
                (caches_aval, scalar), donate_argnums=(0,))

    @staticmethod
    def _postprocess(logits, positions, active):
        # active-slot mask: retired slots emit a fixed token id 0 and
        # never advance — finished requests cannot pollute the stream
        nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        return jnp.where(active, nxt, 0),\
            positions + active.astype(jnp.int32)

    def _build_decode_programs(self, params, caches_aval, kv_bucket_chunk,
                               prefix, slotted_fn, block_fn):
        """Compile the decode half shared by every backend: one
        ``{prefix}decode_block[_s{N}]`` per KV bucket for T > 1, else the
        single ``{prefix}decode`` step program. Backends differ only in the
        step callables and the program-name prefix — the halting operands,
        donation and postprocess wiring cannot diverge between them.

        slotted_fn(params, caches, tokens, positions, active)
            → (caches, logits)
        block_fn(params, caches, tok, pos, act, rem, eos, kv_bucket)
            → the ``make_decode_block`` 7-tuple
        """
        B, T = self.slots, self.block_size
        pos0 = jnp.zeros((B,), jnp.int32)
        act0 = jnp.zeros((B,), bool)
        tok0 = jnp.zeros((B,), jnp.int32)
        if T > 1:
            # -- macro-step block programs, one per KV bucket --------------
            self.buckets = self._bucket_set(caches_aval, kv_bucket_chunk)
            rem0 = jnp.zeros((B,), jnp.int32)
            eos0 = jnp.full((B,), -1, jnp.int32)
            for sb in self.buckets:
                name = f"{prefix}decode_block" if len(self.buckets) == 1\
                    else f"{prefix}decode_block_s{sb}"

                def block_step(p, caches, tok, pos, act, rem, eos, _sb=sb):
                    return block_fn(p, caches, tok, pos, act, rem, eos, _sb)

                self._decode_blocks[sb] = self.rt.compile_step(
                    name, block_step,
                    (params, caches_aval, tok0, pos0, act0, rem0, eos0),
                    donate_argnums=(1,))
            return

        def decode_fn(p, caches, tokens, positions, active):
            caches, logits = slotted_fn(p, caches, tokens, positions, active)
            return (caches,) + self._postprocess(logits, positions, active)

        self._decode = self.rt.compile_step(
            f"{prefix}decode", decode_fn,
            (params, caches_aval, tok0, pos0, act0),
            donate_argnums=(1,))

    def _build_continuous(self, params, caches_aval, kv_bucket_chunk,
                          prefill_chunk, debug_reset_slots):
        raise NotImplementedError

    def _build_drain(self, params):
        raise NotImplementedError(
            f"the {self.name} backend has no drain mode")

    # -- execution --------------------------------------------------------
    @property
    def has_reset(self) -> bool:
        return self._reset is not None

    def fresh(self):
        """Fresh slot caches for a new run (AOT programs persist)."""
        self.caches = self.api.init_caches(self.slots,
                                           self.prompt_len + self.max_new_cap)

    def admit_full(self, params, row: np.ndarray, slot: int):
        """Monolithic admission of a full-width padded prompt row. Returns
        the device array holding the first token."""
        raise NotImplementedError

    def run_chunk(self, params, row: np.ndarray, slot: int, start: int,
                  valid: int):
        """One fixed-(1,C) prefill chunk at the slot's offset. Returns the
        device array holding the chunk's last-valid-position argmax (the
        first token when this was the prompt's final chunk)."""
        self.caches, tok = self._chunk(
            params, self.caches, jnp.asarray(row[None]),
            jnp.asarray(slot, jnp.int32), jnp.asarray(start, jnp.int32),
            jnp.asarray(valid, jnp.int32))
        return tok

    def decode_step(self, params, last_tok, positions, active):
        self.caches, nxt, new_pos = self._decode(
            params, self.caches, jnp.asarray(last_tok),
            jnp.asarray(positions), jnp.asarray(active))
        return nxt, new_pos

    def decode_block(self, params, bucket, last_tok, positions, active,
                     remaining, eos):
        self.caches, toks, emitted, last_d, pos_d, act_d, rem_d =\
            self._decode_blocks[bucket](
                params, self.caches, jnp.asarray(last_tok),
                jnp.asarray(positions), jnp.asarray(active),
                jnp.asarray(remaining), jnp.asarray(eos))
        return toks, emitted, last_d, pos_d, act_d, rem_d

    def reset(self, slot: int):
        self.caches = self._reset(self.caches, jnp.asarray(slot, jnp.int32))

    def drain_prefill(self, params, toks: np.ndarray):
        raise NotImplementedError

    def drain_decode(self, params, caches, last):
        raise NotImplementedError


class ColocatedBackend(ExecutorBackend):
    """Single-domain executor: weights and KV share every device; the step
    programs are the family's own ``ModelAPI`` slotted extensions."""

    name = "colocated"

    # -- program construction --------------------------------------------
    def _build_continuous(self, params, caches_aval, kv_bucket_chunk,
                          prefill_chunk, debug_reset_slots):
        api, ctx = self.api, self.ctx
        B, P, T = self.slots, self.prompt_len, self.block_size
        scalar = jnp.zeros((), jnp.int32)

        if prefill_chunk:
            def chunk_fn(p, caches, toks, slot, start, valid):
                caches, logits = api.prefill_chunk(p, caches, toks, slot,
                                                   start, valid, ctx)
                return caches, jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

            toks_c = jnp.zeros((1, prefill_chunk), jnp.int32)
            self._chunk = self.rt.compile_step(
                "serve_prefill_chunk", chunk_fn,
                (params, caches_aval, toks_c, scalar, scalar, scalar),
                donate_argnums=(1,))
        else:
            def prefill1_fn(p, toks):
                caches, logits = api.prefill(p, {"tokens": toks}, ctx)
                return caches, jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

            def admit_fn(caches, single, slot):
                caches = _pin_cache_tree(caches, ctx)
                return _pin_cache_tree(api.write_slot(caches, single, slot),
                                       ctx)

            toks1 = jnp.zeros((1, P), jnp.int32)
            single_aval, _ = jax.eval_shape(prefill1_fn, params, toks1)
            self._prefill1 = self.rt.compile_step(
                "serve_prefill1", prefill1_fn, (params, toks1))
            self._admit = self.rt.compile_step(
                "serve_admit", admit_fn, (caches_aval, single_aval, scalar),
                donate_argnums=(0,))

        self._build_reset(caches_aval, debug_reset_slots)
        # split-KV decode (a_shards > 1) is forwarded only when on:
        # attention-free families' decode_slotted has no kv_shards kwarg
        sh = {"kv_shards": self.a_shards} if self.a_shards > 1 else {}
        self._build_decode_programs(
            params, caches_aval, kv_bucket_chunk, "serve_",
            lambda p, c, t, pos, act: api.decode_slotted(p, c, t, pos, act,
                                                         ctx, **sh),
            lambda p, c, t, pos, act, rem, eos, sb: api.decode_block(
                p, c, t, pos, act, rem, eos, ctx, block_size=T,
                kv_bucket=sb, **sh))

    def _build_drain(self, params):
        api, ctx = self.api, self.ctx

        def prefill_fn(p, toks):
            caches, logits = api.prefill(p, {"tokens": toks}, ctx)
            return caches, jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

        def decode_fn(p, caches, tokens):
            caches, logits = api.decode(p, caches, tokens, ctx)
            return caches, jnp.argmax(logits[:, 0], -1).astype(jnp.int32)

        toks0 = jnp.zeros((self.slots, self.prompt_len), jnp.int32)
        caches_aval, tok_aval = jax.eval_shape(prefill_fn, params, toks0)
        self._prefill_b = self.rt.compile_step(
            "serve_prefill_batch", prefill_fn, (params, toks0))
        self._decode_b = self.rt.compile_step(
            "serve_decode_drain", decode_fn, (params, caches_aval, tok_aval),
            donate_argnums=(1,))

    # -- execution --------------------------------------------------------
    def admit_full(self, params, row: np.ndarray, slot: int):
        """Monolithic admission: batch-1 full-width prefill + slot write."""
        single, first = self._prefill1(params, jnp.asarray(row[None]))
        self.caches = self._admit(self.caches, single,
                                  jnp.asarray(slot, jnp.int32))
        return first

    def drain_prefill(self, params, toks: np.ndarray):
        caches, first = self._prefill_b(params, jnp.asarray(toks))
        return caches, first

    def drain_decode(self, params, caches, last):
        return self._decode_b(params, caches, last)


class WABackend(ExecutorBackend):
    """Weight–attention disaggregated executor (DESIGN.md §3): every step
    program runs ``core/wa.py``'s routed layer loop — QKV/FFN under the
    W-domain rules, KV writes / prefix reads / bucket slices / halt-mask
    advances under the A-domain rules, with the W→A→W hops as sharding
    constraints INSIDE the compiled program (``jax.device_put``-free).
    Per-slot cursors and KV buckets are A-side state; the scheduler's
    decisions arrive only as traced operands, so every program compiles
    exactly once across a staggered serve.

    Admission is ALWAYS the WA chunk program: the chunked lane runs the
    fixed (1,C) window; monolithic admission is the degenerate single
    full-width chunk (C = prompt_len, valid = prompt_len — padding
    attended, cursor at the padded width, exactly the colocated monolithic
    semantics).

    ``routed_bytes`` meters the W↔A hops (``core/wa.py::routing_bytes``):
    every dispatched micro-step routes the whole (B, d_model) batch twice
    per layer, every prefill chunk its (C, d_model) window — the measured
    form of the paper's "only embeddings move"."""

    name = "wa"

    @property
    def cache_ctx(self) -> ShardingCtx:
        return self.wa.a_ctx

    def _build_continuous(self, params, caches_aval, kv_bucket_chunk,
                          prefill_chunk, debug_reset_slots):
        api, ctx = self.api, self.ctx
        B, P, T = self.slots, self.prompt_len, self.block_size
        self.wa = WADisaggregated(api.config, ctx.mesh, routing="sharding",
                                  a_shards=self.a_shards)
        self._el = jnp.dtype(dtype_of(api.config)).itemsize
        self.routed_bytes = 0
        scalar = jnp.zeros((), jnp.int32)

        def chunk_fn(p, caches, toks, slot, start, valid):
            caches, logits = self.wa.prefill_chunk(p, caches, toks, slot,
                                                   start, valid)
            return caches, jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

        toks_c = jnp.zeros((1, prefill_chunk or P), jnp.int32)
        self._chunk = self.rt.compile_step(
            "serve_wa_prefill_chunk" if prefill_chunk else "serve_wa_admit",
            chunk_fn, (params, caches_aval, toks_c, scalar, scalar, scalar),
            donate_argnums=(1,))

        self._build_reset(caches_aval, debug_reset_slots)
        self._build_decode_programs(
            params, caches_aval, kv_bucket_chunk, "serve_wa_",
            lambda p, c, t, pos, act: self.wa.decode_step_slotted(
                p, c, t, pos, act),
            lambda p, c, t, pos, act, rem, eos, sb: self.wa.decode_block(
                p, c, t, pos, act, rem, eos, None, block_size=T,
                kv_bucket=sb))

    # -- W↔A traffic model -------------------------------------------------
    def expected_routing(self, name: str) -> Tuple[int, int]:
        """Analytic routing model for ONE dispatch of program ``name``:
        returns ``(rows, trips)`` meaning the dispatch routes
        ``trips × routing_bytes(cfg, rows, el)`` W↔A bytes (``trips`` =
        micro-steps inside the program; a T-block scans T micro-steps).
        Single source of truth shared by the runtime meter (``_meter``) and
        the static verifier's routing cross-check
        (``repro.analysis.routing_check``) — the meter and the compiled
        programs cannot drift apart without the gate failing."""
        if name == "serve_wa_admit":
            return self.prompt_len, 1
        if name == "serve_wa_prefill_chunk":
            return self.prefill_chunk, 1
        if name == "serve_wa_decode":
            return self.slots, 1
        if name.startswith("serve_wa_decode_block"):
            return self.slots, self.block_size
        raise KeyError(f"no routing model for WA program {name!r}")

    def _meter(self, name: str):
        rows, trips = self.expected_routing(name)
        self.routed_bytes += trips * routing_bytes(self.api.config, rows,
                                                   self._el)

    # -- execution (adds the W↔A traffic meter) ---------------------------
    def fresh(self):
        super().fresh()
        self.routed_bytes = 0

    def admit_full(self, params, row: np.ndarray, slot: int):
        """Monolithic WA admission: ONE full-width chunk (start 0, the
        padded width valid) — KV lands directly in the slot, no separate
        write-slot copy (the cache never leaves the A domain)."""
        self._meter("serve_wa_admit")
        self.caches, tok = self._chunk(
            params, self.caches, jnp.asarray(row[None]),
            jnp.asarray(slot, jnp.int32), jnp.asarray(0, jnp.int32),
            jnp.asarray(self.prompt_len, jnp.int32))
        return tok

    def run_chunk(self, params, row, slot, start, valid):
        self._meter("serve_wa_prefill_chunk")
        return super().run_chunk(params, row, slot, start, valid)

    def decode_step(self, params, last_tok, positions, active):
        self._meter("serve_wa_decode")
        return super().decode_step(params, last_tok, positions, active)

    def decode_block(self, params, bucket, last_tok, positions, active,
                     remaining, eos):
        self._meter("serve_wa_decode_block")
        return super().decode_block(params, bucket, last_tok, positions,
                                    active, remaining, eos)

    def routing_stats(self, decode_tokens: int) -> Dict[str, Any]:
        """The measured 'only embeddings move' numbers for ``run()`` stats:
        the per-token claim (2 hops × L × d_model for one row) plus the
        metered total across every dispatched program this run."""
        return {
            "routing_bytes_per_token": routing_bytes(self.api.config, 1,
                                                     self._el),
            "routing_total_bytes": int(self.routed_bytes),
            "routing_bytes_per_decode_token":
                float(self.routed_bytes / max(decode_tokens, 1)),
        }


BACKENDS: Dict[str, type] = {"colocated": ColocatedBackend, "wa": WABackend}


# ---------------------------------------------------------------------------
# ServingEngine — the boundary loop connecting scheduler and executor
# ---------------------------------------------------------------------------

class ServingEngine:
    """Greedy decoding over fixed batch slots with per-slot admission.

    mode="continuous": slot-level scheduler (requires the ModelAPI slotted
    extensions); mode="drain": legacy drain-then-refill baseline;
    mode="auto": continuous when the family supports it.

    ``block_size`` (T): decode micro-steps per host round-trip. T == 1 is the
    per-step engine (one ``serve_decode`` program, one host sync per token);
    T > 1 runs ``ModelAPI.decode_block`` with on-device halt masks — one host
    sync per T tokens, admission at block boundaries only.

    ``prefill_chunk`` (C, continuous mode, families with
    ``ModelAPI.prefill_chunk``): admission runs as fixed-(1,C) prompt chunks,
    AT MOST ONE per block boundary, interleaved with the decode block — the
    chunked-prefill lane. Prompts carry TRUE lengths end to end: the decode
    cursor starts at the real prompt length and any length that fits the KV
    extent (prompt + max_new_tokens ≤ prompt_len + max_new_cap) is admitted
    chunk by chunk. 0 → monolithic admission (one full-width prefill program;
    prompts longer than ``prompt_len`` raise ``ValueError`` at submit —
    nothing is ever silently truncated).

    ``kv_bucket_chunk`` (block mode, KV-cache families): > 0 compiles one
    decode-block program per KV bucket (chunk multiples up to the cache
    extent) and picks the smallest covering bucket per macro-step on the
    host. 0 disables bucketing (single full-extent block program).

    ``debug_reset_slots``: zero a slot's cache state when its request
    retires (``ModelAPI.reset_slot``, one more AOT program). Never required
    for correctness — masked attention cannot read past a cursor — but keeps
    cache dumps clean and slot-state invariants checkable.

    ``backend``: the executor implementation. ``"colocated"`` (default)
    runs the family's own slotted programs; ``"wa"`` runs the SAME feature
    set — macro-step blocks, KV buckets, chunked prefill, slot admission —
    through the weight–attention disaggregated layer loop (``core/wa.py``,
    DESIGN.md §3): QKV/FFN under the W-domain rules, all slot state (KV
    writes, prefix reads, bucket slices) under the A-domain rules, with the
    per-layer W→A→W routing compiled INTO each step program. The scheduler
    is backend-agnostic; ``stats()["wa"]`` reports the measured W↔A routing
    bytes. Requires ``ModelAPI.wa_servable`` (prefix-ordered KV-cache
    transformers) and the continuous scheduler.

    ``a_shards``: split-KV flash decode width (KV-cache families,
    continuous mode). > 1 splits every slot's KV walk into that many equal
    contiguous sequence shards; each shard computes partial softmax
    statistics (running max, normalizer, un-normalized accumulator) and the
    shards recombine through the LSE merge (``kernels/flash_decode/
    combine.py``) — token-exact vs the sequential walk. Under
    ``backend="wa"`` on a mesh the shard axis is the A-domain model axis
    (``seq_sharded_kv``), so attention latency scales with A-width; on the
    colocated backend (and any single-device run) the same math runs
    unsharded. The KV extent (prompt_len + max_new_cap) must divide by
    ``a_shards``; bucket sets are rounded so every bucket splits evenly.
    Program names do not change — the shard count is a build-time static
    baked into the same programs, so compiles == 1 still holds per bucket.

    An engine instance may be ``run()`` repeatedly: per-run accumulators
    (timings, sync counts, queues) reset and the slot caches are allocated
    fresh each run, while the AOT-compiled programs persist (compiles == 1
    across every run of the engine's lifetime).
    """

    def __init__(self, api: ModelAPI, ctx: ShardingCtx, batch_slots: int,
                 prompt_len: int, runtime: Optional[StaticRuntime] = None,
                 greedy: bool = True, mode: str = "auto",
                 max_new_cap: int = DECODE_SLACK,
                 block_size: int = 1, kv_bucket_chunk: int = 0,
                 prefill_chunk: int = 0,
                 debug_reset_slots: bool = False,
                 backend: str = "colocated", a_shards: int = 1):
        if mode not in ("auto", "continuous", "drain"):
            raise ValueError(mode)
        if a_shards < 1:
            raise ValueError(f"a_shards must be >= 1, got {a_shards}")
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; choose from "
                             f"{sorted(BACKENDS)}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if prefill_chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0, got {prefill_chunk}")
        if backend == "wa":
            # the WA backend carries its own decode/admission programs
            # (core/wa.py) — it needs the continuous scheduler and a family
            # whose KV the W/A split can decouple (DESIGN.md §6)
            if mode == "drain":
                raise ValueError("the WA backend serves through the "
                                 "continuous scheduler; drain mode is "
                                 "colocated-only")
            if not api.wa_servable:
                raise ValueError(
                    f"{api.config.family} family has no WA-disaggregated "
                    "serving support (DESIGN.md §6)")
            resolved_mode = "continuous"
        else:
            # continuous mode needs a decode half (api.decode_block for
            # T > 1, api.decode_slotted for T == 1) AND an admission half
            # (api.prefill_chunk for the chunked lane, api.write_slot for
            # monolithic admission)
            decode_ok = (api.decode_block is not None if block_size > 1 else
                         api.decode_slotted is not None)
            if mode == "auto" and prefill_chunk > 0\
                    and api.prefill_chunk is None:
                # fall back to monolithic admission — LOUDLY: a benchmark
                # config that asked for the chunk lane must not quietly
                # measure the monolithic one
                warnings.warn(
                    f"prefill_chunk={prefill_chunk} requested but the "
                    f"{api.config.family} family has no prefill_chunk "
                    "support; falling back to monolithic admission (the "
                    "chunked-prefill lane is OFF for this engine)",
                    UserWarning, stacklevel=2)
                prefill_chunk = 0
            admit_ok = (api.prefill_chunk is not None if prefill_chunk > 0
                        else api.write_slot is not None)
            slotted_ok = admit_ok and decode_ok
            if mode == "continuous" and not slotted_ok:
                raise ValueError(
                    f"{api.config.family} family has no "
                    f"{'chunked-prefill' if prefill_chunk > 0 else 'slotted'} "
                    "serving support")
            if mode == "drain" and prefill_chunk > 0:
                raise ValueError("chunked prefill requires the continuous "
                                 "scheduler (drain prefills the whole batch)")
            resolved_mode = ("continuous" if slotted_ok else "drain")\
                if mode == "auto" else mode
        self.api = api
        self.ctx = ctx
        self.slots = batch_slots
        self.prompt_len = prompt_len
        self.max_new_cap = min(max_new_cap, DECODE_SLACK)
        self.mode = resolved_mode
        self.backend = backend
        if self.mode == "drain":
            prefill_chunk = 0                    # auto fallback: no lane
        self.block_size = block_size
        self.kv_bucket_chunk = kv_bucket_chunk
        self.prefill_chunk = prefill_chunk
        self.a_shards = a_shards
        self.debug_reset_slots = debug_reset_slots
        self.rt = runtime or StaticRuntime()
        self.queue: List[Request] = []
        self._params = None
        self._ex: Optional[ExecutorBackend] = None
        # the ONE derivation of the slot-cache aval: the executor compiles
        # against it and the KV-extent admission bound reads off it
        # (None extent → no length axis to bound, e.g. recurrent state)
        self._caches_aval = jax.eval_shape(
            lambda: api.init_caches(batch_slots,
                                    prompt_len + self.max_new_cap))
        self._kv_extent = self._caches_aval.k.shape[3]\
            if isinstance(self._caches_aval, KVCache)\
            and not self._caches_aval.window else None
        if self.a_shards > 1:
            # split-KV flash decode shards the *prefix-ordered* KV walk of
            # one slot along the sequence axis; families without such a
            # cache (recurrent state, ring windows) have nothing to shard
            if self.mode == "drain":
                raise ValueError("split-KV decode (a_shards > 1) runs "
                                 "through the slotted decode programs; "
                                 "drain mode has none")
            if self._kv_extent is None:
                raise ValueError(
                    f"a_shards={self.a_shards} requires a prefix-ordered "
                    "(non-windowed) KV-cache family; the "
                    f"{api.config.family} family has no KV sequence axis "
                    "to shard")
            if self._kv_extent % self.a_shards:
                raise ValueError(
                    f"KV extent {self._kv_extent} (prompt_len + "
                    "max_new_cap) not divisible by a_shards="
                    f"{self.a_shards}; every shard must own an equal "
                    "contiguous block")
        if self.prefill_chunk and isinstance(self._caches_aval, KVCache)\
                and self._caches_aval.window:
            raise ValueError("chunked prefill requires a non-windowed KV "
                             "cache (ring order has no per-position write "
                             "offset)")
        if self.prefill_chunk and self._kv_extent is not None\
                and self.prefill_chunk > self._kv_extent:
            raise ValueError(
                f"prefill_chunk={self.prefill_chunk} exceeds the KV extent "
                f"{self._kv_extent}; the fixed (1,C) window must fit the "
                "cache")
        self._reset_per_run()

    # ------------------------------------------------------------------
    def _reset_per_run(self):
        """Per-run accumulators. An engine reused across ``run()`` calls
        must not leak timing samples or sync counts from a previous run
        (stats would blend workloads), and the executor's caches from a
        finished run must never seed the next one (stale KV in freed
        slots)."""
        self.tpot_samples: List[float] = []
        self.host_syncs = 0
        self._decode_tokens = 0
        self._decode_time = 0.0
        self._prefill_time = 0.0
        self._prefill_chunks = 0
        self._block_tokens: List[int] = []
        self._macro_steps = 0
        self.queue = []

    def _host_sync(self, *arrays):
        """THE counted device→host round-trip of the decode loop — the
        coordination cost the macro-step engine amortizes (1 sync per
        ``block_size`` tokens). Tests assert on ``self.host_syncs``."""
        self.host_syncs += 1
        out = tuple(np.asarray(a) for a in arrays)
        return out if len(out) > 1 else out[0]

    def load(self, params):
        self._params = params

    def _validate_request(self, r: Request):
        """Admission-time length contract — the silent-truncation fix: a
        prompt the engine cannot represent is REJECTED here, never cut."""
        L = len(r.prompt)
        if L == 0:
            raise ValueError(f"request {r.rid}: empty prompt")
        if r.max_new_tokens < 1:
            raise ValueError(
                f"request {r.rid}: max_new_tokens={r.max_new_tokens} "
                "must be >= 1 (every admission produces a first token)")
        if r.max_new_tokens > self.max_new_cap:
            raise ValueError(
                f"request {r.rid}: max_new_tokens={r.max_new_tokens} "
                f"exceeds cache slack {self.max_new_cap}")
        if self.mode == "drain" or not self.prefill_chunk:
            if L > self.prompt_len:
                raise ValueError(
                    f"request {r.rid}: prompt length {L} exceeds the static "
                    f"prompt width {self.prompt_len} and would be silently "
                    "truncated; raise prompt_len or enable the "
                    "chunked-prefill lane (prefill_chunk > 0)")
        elif self._kv_extent is not None\
                and L + r.max_new_tokens > self._kv_extent:
            raise ValueError(
                f"request {r.rid}: prompt length {L} + "
                f"max_new_tokens={r.max_new_tokens} exceeds the KV extent "
                f"{self._kv_extent}")

    def submit(self, req: Request):
        self._validate_request(req)
        req.t_enqueue = time.monotonic()
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _prepare(self, params):
        if self._ex is None:
            self._ex = BACKENDS[self.backend](
                self.api, self.ctx, self.rt, params, self._caches_aval,
                mode=self.mode,
                slots=self.slots, prompt_len=self.prompt_len,
                max_new_cap=self.max_new_cap, block_size=self.block_size,
                kv_bucket_chunk=self.kv_bucket_chunk,
                prefill_chunk=self.prefill_chunk,
                debug_reset_slots=self.debug_reset_slots,
                a_shards=self.a_shards)

    def run(self, params, requests: List[Request],
            max_steps: int = 10_000) -> Dict[str, Any]:
        """Serve all requests to completion; returns latency stats.
        Requests enqueued via ``submit()`` before this call are served too
        (never silently dropped). Reusable: each call starts from fresh
        caches and fresh accumulators (AOT programs persist — zero
        recompilation across runs)."""
        self.load(params)
        pre = list(self.queue)
        seen = {id(r) for r in pre}
        requests = pre + [r for r in requests if id(r) not in seen]
        for r in requests:
            self._validate_request(r)
        self._prepare(params)
        self._reset_per_run()
        if self.mode == "continuous":
            return self._run_continuous(params, requests, max_steps)
        return self._run_drain(params, requests, max_steps)

    # ------------------------------------------------------------------
    # continuous scheduler: ONE boundary loop for T == 1 and T > 1,
    # monolithic and chunked admission
    # ------------------------------------------------------------------

    def _run_continuous(self, params, requests, max_steps):
        T = self.block_size
        ex = self._ex
        ex.fresh()
        sched = SlotScheduler(self.slots, requests, self.queue)
        done: List[Request] = []
        steps = admissions = overlapped = 0
        s_max = self.prompt_len + self.max_new_cap
        while sched.work_remaining():
            if steps >= max_steps:
                break
            sched.pump(steps)
            # "overlapped" = admitted while the batch was already live at
            # the start of this boundary (cold-start fills don't count)
            batch_live = sched.occupied()
            if self.prefill_chunk:
                while True:
                    new = sched.assign_free(steps)
                    admissions += len(new)
                    overlapped += len(new) if batch_live else 0
                    done.extend(self._advance_chunk_lane(params, sched))
                    # the one-chunk-per-boundary throttle exists to bound
                    # the stall inflicted on LIVE decoders; with none live
                    # there is nothing to protect — keep chunking so a
                    # cold start does not serialize admission
                    if sched.decode_active().any() or not sched.prefill_fifo:
                        break
            else:
                n_adm, n_ovl, fin = self._admit_monolithic(
                    params, sched, steps, batch_live)
                admissions += n_adm
                overlapped += n_ovl
                done.extend(fin)
            active = sched.decode_active()
            if not active.any():
                steps += 1                       # idle/prefill-only boundary
                continue
            done.extend(self._decode_round(params, sched, active, s_max))
            steps += T
        self._caches = ex.caches
        return self._stats(done, steps, admissions, overlapped)

    # -- admission: monolithic lane ------------------------------------
    def _admit_monolithic(self, params, sched: SlotScheduler, steps: int,
                          batch_live: bool):
        """Fill EVERY free slot from the queue with a full-width batch-1
        prefill + slot write (the pre-chunking admission path, kept as the
        measured baseline). Prompts are zero-padded up to ``prompt_len`` —
        never truncated (submit rejects longer) — and the cursor starts at
        the padded width (the padding IS attended; the chunked lane is the
        length-true path)."""
        ex = self._ex
        admissions = overlapped = 0
        finished: List[Request] = []
        for i in range(self.slots):
            # retry the SAME slot while admissions complete at their first
            # token (max_new_tokens == 1 / instant EOS) — a one-token
            # request must not idle the slot until the next boundary
            while sched.phase[i] == sched.FREE and self.queue:
                r = self.queue.pop(0)
                if batch_live:
                    overlapped += 1
                r.t_admitted = time.monotonic()
                r.admit_step = steps
                sched.req[i] = r
                t0 = time.monotonic()
                first = ex.admit_full(params, pad_row(r.prompt,
                                                      self.prompt_len), i)
                first.block_until_ready()
                now = time.monotonic()
                self._prefill_time += now - t0
                r.t_first_token = now
                r.note_emit(now)
                r.generated.append(int(np.asarray(first)[0]))
                admissions += 1
                if r.done:
                    r.t_done = now
                    finished.append(r)
                    sched.req[i] = None
                    # the admit DID write its prompt KV — zero it like any
                    # other retirement so dumps stay clean
                    if ex.has_reset:
                        ex.reset(i)
                    continue
                sched.start_decode(i, self.prompt_len, r.generated[-1])
        return admissions, overlapped, finished

    # -- admission: chunked-prefill lane -------------------------------
    def _advance_chunk_lane(self, params, sched: SlotScheduler):
        """Run AT MOST ONE fixed-shape prefill chunk this boundary (the
        admitting slot at the head of the FIFO). In-flight decoders stall
        for one chunk, not one prompt; the final chunk's logits are the
        request's first token and flip the slot to the decode phase with
        its cursor at the TRUE prompt length."""
        job = sched.next_chunk(self.prefill_chunk, self._kv_extent)
        if job is None:
            return []
        slot, r, start, n_valid = job
        row = pad_row(r.prompt[start:start + n_valid], self.prefill_chunk)
        t0 = time.monotonic()
        tok = self._ex.run_chunk(params, row, slot, start, n_valid)
        first = np.asarray(tok)                   # blocks: chunk wall-time
        now = time.monotonic()
        self._prefill_time += now - t0
        self._prefill_chunks += 1
        finished: List[Request] = []
        if sched.chunk_done(slot, start, n_valid):
            r.t_first_token = now
            r.note_emit(now)
            r.generated.append(int(first[0]))
            if r.done:
                r.t_done = now
                finished.append(r)
                sched.retire(slot)
                if self._ex.has_reset:
                    self._ex.reset(slot)
            else:
                sched.start_decode(slot, len(r.prompt), r.generated[-1])
        return finished

    # -- decode round ---------------------------------------------------
    def _decode_round(self, params, sched: SlotScheduler, active, s_max):
        """One decode dispatch + ONE counted host sync: a single slotted
        step (T == 1) or a T-micro-step block with on-device halting."""
        T = self.block_size
        ex = self._ex
        finished: List[Request] = []
        if T == 1:
            t0 = time.monotonic()
            nxt, new_pos = ex.decode_step(params, sched.last_tok,
                                          sched.positions, active)
            nxt, new_pos = self._host_sync(nxt, new_pos)
            dt = time.monotonic() - t0
            self.tpot_samples.append(dt)
            self._decode_time += dt
            n_tok = int(active.sum())
            sched.positions = new_pos.copy()
            sched.last_tok = nxt.copy()
            now = time.monotonic()
            for i, r in enumerate(sched.req):
                if r is None or sched.phase[i] != sched.DECODE:
                    continue
                r.generated.append(int(nxt[i]))
                r.note_emit(now)
                if r.done:
                    r.t_done = now
                    finished.append(r)
                    sched.retire(i)              # freed → next boundary
                    if ex.has_reset:
                        ex.reset(i)
        else:
            # length-aware bucket: smallest compiled extent covering every
            # live cursor for the whole block (short prompts start low)
            if len(ex.buckets) > 1:
                needed = int(sched.positions[active].max()) + T
                sb = bucket_for(min(needed, s_max), ex.buckets)
            else:
                sb = ex.buckets[0]
            t0 = time.monotonic()
            out = ex.decode_block(params, sb, sched.last_tok,
                                  sched.positions, active,
                                  sched.remaining, sched.eos)
            toks, emitted, last_d, pos_d, act_np, rem_d =\
                self._host_sync(*out)
            dt = time.monotonic() - t0
            self.tpot_samples.append(dt / T)
            self._decode_time += dt
            sched.last_tok = last_d.copy()
            sched.positions = pos_d.copy()
            sched.remaining = rem_d.copy()
            n_tok = int(emitted.sum())
            now = time.monotonic()
            for i, r in enumerate(sched.req):
                if r is None or sched.phase[i] != sched.DECODE:
                    continue
                emitted_any = False
                for t in range(T):
                    if emitted[t, i]:
                        r.generated.append(int(toks[t, i]))
                        emitted_any = True
                if emitted_any:
                    r.note_emit(now)
                if not act_np[i]:                # budget/EOS halt on device
                    r.t_done = now
                    finished.append(r)
                    sched.retire(i)              # freed → next boundary
                    if ex.has_reset:
                        ex.reset(i)
        self._decode_tokens += n_tok
        self._block_tokens.append(n_tok)
        self._macro_steps += 1
        return finished

    # ------------------------------------------------------------------
    def _run_drain(self, params, requests, max_steps):
        """Legacy baseline: prefill only when the WHOLE batch has drained —
        one long request starves every queued request (kept for comparison
        and for families without slotted support)."""
        ex = self._ex
        pending = sorted(requests, key=lambda r: r.arrival_step)
        active_req: List[Optional[Request]] = [None] * self.slots
        caches = None
        last = None
        done: List[Request] = []
        steps = admissions = 0
        while pending or self.queue or any(r is not None for r in active_req):
            if steps >= max_steps:
                break
            while pending and pending[0].arrival_step <= steps:
                r = pending.pop(0)            # validated by run()
                if not r.t_enqueue:           # keep a pre-run submit() stamp
                    r.t_enqueue = time.monotonic()
                self.queue.append(r)
            if caches is None:
                toks = np.zeros((self.slots, self.prompt_len), np.int32)
                for i in range(self.slots):
                    if active_req[i] is None and self.queue:
                        r = self.queue.pop(0)
                        r.t_admitted = time.monotonic()
                        r.admit_step = steps
                        active_req[i] = r
                        admissions += 1
                    if active_req[i] is not None:
                        toks[i] = pad_row(active_req[i].prompt,
                                          self.prompt_len)
                if not any(r is not None for r in active_req):
                    steps += 1                   # idle tick: await arrivals
                    continue
                t0 = time.monotonic()
                caches, first = ex.drain_prefill(params, toks)
                first.block_until_ready()
                now = time.monotonic()
                self._prefill_time += now - t0
                first = np.asarray(first)
                for i, r in enumerate(active_req):
                    if r is not None and not r.generated:
                        r.t_first_token = now
                        r.note_emit(now)
                        r.generated.append(int(first[i]))
                        if r.done:
                            r.t_done = now
                last = jnp.asarray(first.astype(np.int32))
            t0 = time.monotonic()
            caches, nxt = ex.drain_decode(params, caches, last)
            nxt_np = self._host_sync(nxt)
            dt = time.monotonic() - t0
            self.tpot_samples.append(dt)
            self._decode_time += dt
            self._macro_steps += 1
            last = nxt
            steps += 1
            now = time.monotonic()
            n_tok = 0
            for i, r in enumerate(active_req):
                if r is None or r.done:
                    continue
                r.generated.append(int(nxt_np[i]))
                r.note_emit(now)
                n_tok += 1
                if r.done:
                    r.t_done = now
            self._decode_tokens += n_tok
            self._block_tokens.append(n_tok)
            for i, r in enumerate(active_req):
                if r is not None and r.done:
                    done.append(r)
                    active_req[i] = None
            if all(r is None for r in active_req):
                caches = None                    # drained → allow re-prefill
        return self._stats(done, steps, admissions, 0)

    # ------------------------------------------------------------------
    def _stats(self, done, steps, admissions, overlapped) -> Dict[str, Any]:
        tp = np.array(self.tpot_samples[1:] or [0.0])
        per_req = [r.metrics() for r in sorted(done, key=lambda r: r.rid)]
        ttfts = np.array([m["ttft_ms"] for m in per_req] or [0.0])
        qd = np.array([m["queue_delay_ms"] for m in per_req] or [0.0])
        gaps = np.array([m["max_gap_ms"] for m in per_req] or [0.0])
        blk = np.array(self._block_tokens or [0.0])
        # decode-token throughput: decode-PRODUCED tokens over decode
        # wall-time — prefill AND chunk-prefill wall-time are excluded from
        # both sides (their first tokens are not in the numerator, their
        # stalls not in the denominator)
        n_dec = self._decode_tokens
        out = {
            "mode": self.mode,
            "backend": self.backend,
            "block_size": self.block_size,
            "a_shards": self.a_shards,
            "prefill_mode": ("chunked" if self.prefill_chunk
                             else "monolithic"),
            "prefill_chunk": self.prefill_chunk,
            "completed": len(done),
            "decode_steps": steps,
            "macro_steps": self._macro_steps,
            "admissions": admissions,
            "overlapped_admissions": overlapped,
            "tpot_mean_ms": float(tp.mean() * 1e3),
            "tpot_p50_ms": float(np.percentile(tp, 50) * 1e3) if len(tp) else 0.0,
            "tpot_p99_ms": float(np.percentile(tp, 99) * 1e3) if len(tp) else 0.0,
            "ttft_mean_ms": float(ttfts.mean()),
            "ttft_p99_ms": float(np.percentile(ttfts, 99)),
            "queue_delay_mean_ms": float(qd.mean()),
            "max_inter_token_gap_ms": float(gaps.max()),
            "decode_tokens": n_dec,
            "throughput_tok_s": float(n_dec / max(self._decode_time, 1e-9)),
            "prefill_time_ms": float(self._prefill_time * 1e3),
            "prefill_chunks": self._prefill_chunks,
            "host_syncs": self.host_syncs,
            "syncs_per_token": float(self.host_syncs / max(n_dec, 1)),
            "tokens_per_macro_step_mean": float(blk.mean()),
            "per_request": per_req,
            "runtime": self.rt.stats(),
        }
        if self.backend == "wa" and self._ex is not None:
            # measured W↔A traffic — the paper's "only embeddings move"
            # claim as a number in every run's output
            out["wa"] = self._ex.routing_stats(n_dec)
        return out
