"""Continuous-batching decode serving engine under STATIC shapes.

The paper's prototype serves a fixed decode batch and defers continuous
batching to future work (§7.2). This engine closes that gap without leaving
the cache-resident/static-shape regime the paper's runtime depends on:

- the decode batch is a fixed set of SLOTS (static shapes → AOT compile once),
- a queued request is admitted into any free slot *mid-serve*: a batch-1
  prefill runs, its cache is written into the slot (``ModelAPI.write_slot``),
  and the slot's cursor restarts — no drain, no retrace,
- every row carries its own cursor (``positions``) and an ``active`` mask is
  threaded through decode (``ModelAPI.decode_slotted``) so retired slots
  neither write KV nor pollute the argmax,
- all three step programs (prefill-1, admit, decode) are AOT-compiled through
  ``StaticRuntime`` — ``stats()`` must show compiles == 1 per step with only
  ``calls`` growing across admissions (the §4.3 pinned-pool invariant).

The previous drain-then-refill loop is kept as ``mode="drain"`` — it is the
baseline the continuous scheduler is measured against (late-arrival TTFT) and
the fallback for model families without slotted support (DESIGN.md §7).

Per-request accounting: queue delay (enqueue→admit), TTFT (enqueue→first
token), TPOT (steady-state inter-token time) — the serving-side metrics of
the paper's Table 2 methodology.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import DECODE_SLACK, ModelAPI
from repro.models.sharding import ShardingCtx
from repro.runtime.static_runtime import StaticRuntime


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int
    arrival_step: int = 0               # decode step at which it reaches the queue
    generated: List[int] = field(default_factory=list)
    t_enqueue: float = 0.0
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    admit_step: int = -1                # decode step at which it got a slot

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    def metrics(self) -> Dict[str, Any]:
        n = len(self.generated)
        return {
            "rid": self.rid,
            "tokens": n,
            "arrival_step": self.arrival_step,
            "admit_step": self.admit_step,
            "queue_delay_ms": max(0.0, self.t_admitted - self.t_enqueue) * 1e3,
            "ttft_ms": max(0.0, self.t_first_token - self.t_enqueue) * 1e3,
            "tpot_ms": ((self.t_done - self.t_first_token) / (n - 1) * 1e3
                        if n > 1 else 0.0),
        }


class ServingEngine:
    """Greedy decoding over fixed batch slots with per-slot admission.

    mode="continuous": slot-level scheduler (requires the ModelAPI slotted
    extensions); mode="drain": legacy drain-then-refill baseline;
    mode="auto": continuous when the family supports it.

    ``raw_decode`` (optional): an eager decode-step callable
    ``(params, caches, tokens, positions, active) -> (caches, logits)`` used
    INSTEAD of the AOT-compiled slotted decode — the hook through which the
    WA-disaggregated backend (two submeshes, python-orchestrated routing)
    plugs into the same admission scheduler.
    """

    def __init__(self, api: ModelAPI, ctx: ShardingCtx, batch_slots: int,
                 prompt_len: int, runtime: Optional[StaticRuntime] = None,
                 greedy: bool = True, mode: str = "auto",
                 max_new_cap: int = DECODE_SLACK,
                 raw_decode: Optional[Callable] = None):
        if mode not in ("auto", "continuous", "drain"):
            raise ValueError(mode)
        # continuous mode always needs write_slot (admission); the decode
        # half comes from either api.decode_slotted or a raw_decode override
        slotted_ok = api.write_slot is not None and (
            api.decode_slotted is not None or raw_decode is not None)
        if mode == "continuous" and not slotted_ok:
            raise ValueError(
                f"{api.config.family} family has no slotted decode support")
        self.api = api
        self.ctx = ctx
        self.slots = batch_slots
        self.prompt_len = prompt_len
        self.max_new_cap = min(max_new_cap, DECODE_SLACK)
        self.mode = ("continuous" if slotted_ok else "drain") \
            if mode == "auto" else mode
        self.rt = runtime or StaticRuntime()
        self.queue: List[Request] = []
        self.tpot_samples: List[float] = []
        self._params = None
        self._raw_decode = raw_decode
        self._prepared = False

    # ------------------------------------------------------------------
    def load(self, params):
        self._params = params

    def submit(self, req: Request):
        req.t_enqueue = time.monotonic()
        self.queue.append(req)

    # ------------------------------------------------------------------
    # AOT step programs — compiled ONCE at first run; admission/decode are
    # cached-executable calls from then on (zero retracing, §4.3 analogue).
    # ------------------------------------------------------------------
    def _prepare_continuous(self, params):
        api, ctx = self.api, self.ctx
        B, P = self.slots, self.prompt_len

        def prefill1_fn(p, toks):
            caches, logits = api.prefill(p, {"tokens": toks}, ctx)
            return caches, jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

        def admit_fn(caches, single, slot):
            return api.write_slot(caches, single, slot)

        def postprocess(logits, positions, active):
            # active-slot mask: retired slots emit a fixed token id 0 and
            # never advance — finished requests cannot pollute the stream
            nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
            return jnp.where(active, nxt, 0), \
                positions + active.astype(jnp.int32)

        def decode_fn(p, caches, tokens, positions, active):
            caches, logits = api.decode_slotted(p, caches, tokens, positions,
                                                active, ctx)
            return (caches,) + postprocess(logits, positions, active)

        self._caches = api.init_caches(B, P + self.max_new_cap)
        toks1 = jnp.zeros((1, P), jnp.int32)
        single_aval, _ = jax.eval_shape(prefill1_fn, params, toks1)
        pos0 = jnp.zeros((B,), jnp.int32)
        act0 = jnp.zeros((B,), bool)
        tok0 = jnp.zeros((B,), jnp.int32)
        self._prefill1 = self.rt.compile_step(
            "serve_prefill1", prefill1_fn, (params, toks1))
        self._admit = self.rt.compile_step(
            "serve_admit", admit_fn,
            (self._caches, single_aval, jnp.zeros((), jnp.int32)),
            donate_argnums=(0,))
        if self._raw_decode is None:
            self._decode = self.rt.compile_step(
                "serve_decode", decode_fn,
                (params, self._caches, tok0, pos0, act0),
                donate_argnums=(1,))
        else:
            raw = self._raw_decode

            def decode_eager(p, caches, tokens, positions, active):
                caches, logits = raw(p, caches, tokens, positions, active)
                return (caches,) + postprocess(logits, positions, active)
            self._decode = decode_eager

    def _prepare_drain(self, params):
        api, ctx = self.api, self.ctx

        def prefill_fn(p, toks):
            caches, logits = api.prefill(p, {"tokens": toks}, ctx)
            return caches, jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

        def decode_fn(p, caches, tokens):
            caches, logits = api.decode(p, caches, tokens, ctx)
            return caches, jnp.argmax(logits[:, 0], -1).astype(jnp.int32)

        toks0 = jnp.zeros((self.slots, self.prompt_len), jnp.int32)
        caches_aval, tok_aval = jax.eval_shape(prefill_fn, params, toks0)
        self._prefill_b = self.rt.compile_step(
            "serve_prefill_batch", prefill_fn, (params, toks0))
        self._decode_b = self.rt.compile_step(
            "serve_decode_drain", decode_fn, (params, caches_aval, tok_aval),
            donate_argnums=(1,))

    def _prepare(self, params):
        if self._prepared:
            return
        if self.mode == "continuous":
            self._prepare_continuous(params)
        else:
            self._prepare_drain(params)
        self._prepared = True

    # ------------------------------------------------------------------
    def run(self, params, requests: List[Request],
            max_steps: int = 10_000) -> Dict[str, Any]:
        """Serve all requests to completion; returns latency stats."""
        self.load(params)
        for r in requests:
            if r.max_new_tokens > self.max_new_cap:
                raise ValueError(
                    f"request {r.rid}: max_new_tokens={r.max_new_tokens} "
                    f"exceeds cache slack {self.max_new_cap}")
        self._prepare(params)
        if self.mode == "continuous":
            return self._run_continuous(params, requests, max_steps)
        return self._run_drain(params, requests, max_steps)

    def _pad_prompt(self, r: Request) -> np.ndarray:
        """(prompt_len,) — prompt truncated/zero-padded to the static width."""
        row = np.zeros((self.prompt_len,), np.int32)
        row[:len(r.prompt)] = r.prompt[:self.prompt_len]
        return row

    # ------------------------------------------------------------------
    def _run_continuous(self, params, requests, max_steps):
        pending = sorted(requests, key=lambda r: r.arrival_step)
        active_req: List[Optional[Request]] = [None] * self.slots
        positions = np.zeros((self.slots,), np.int32)
        last_tok = np.zeros((self.slots,), np.int32)
        caches = self._caches
        done: List[Request] = []
        steps = admissions = overlapped = 0
        while pending or self.queue or any(r is not None for r in active_req):
            if steps >= max_steps:
                break
            while pending and pending[0].arrival_step <= steps:
                self.submit(pending.pop(0))
            # -- admission: fill EVERY free slot from the queue, no drain --
            # "overlapped" = admitted while the batch was already live at the
            # start of this round (cold-start fills at step 0 don't count)
            batch_live = any(a is not None for a in active_req)
            for i in range(self.slots):
                if active_req[i] is not None or not self.queue:
                    continue
                r = self.queue.pop(0)
                if batch_live:
                    overlapped += 1
                r.t_admitted = time.monotonic()
                r.admit_step = steps
                single, first = self._prefill1(
                    params, jnp.asarray(self._pad_prompt(r)[None]))
                caches = self._admit(caches, single,
                                     jnp.asarray(i, jnp.int32))
                first.block_until_ready()
                r.t_first_token = time.monotonic()
                r.generated.append(int(np.asarray(first)[0]))
                admissions += 1
                if r.done:                       # max_new_tokens == 1
                    r.t_done = r.t_first_token
                    done.append(r)
                    continue
                active_req[i] = r
                positions[i] = self.prompt_len
                last_tok[i] = r.generated[-1]
            active = np.array([a is not None for a in active_req])
            if not active.any():
                steps += 1                       # idle tick: await arrivals
                continue
            # -- one fused decode step over all slots ----------------------
            t0 = time.monotonic()
            caches, nxt, new_pos = self._decode(
                params, caches, jnp.asarray(last_tok),
                jnp.asarray(positions), jnp.asarray(active))
            nxt = np.asarray(nxt)
            self.tpot_samples.append(time.monotonic() - t0)
            positions = np.asarray(new_pos).copy()
            last_tok = nxt.copy()
            steps += 1
            now = time.monotonic()
            for i, r in enumerate(active_req):
                if r is None:
                    continue
                r.generated.append(int(nxt[i]))
                if r.done:
                    r.t_done = now
                    done.append(r)
                    active_req[i] = None         # freed → admitted next step
        self._caches = caches
        return self._stats(done, steps, admissions, overlapped)

    # ------------------------------------------------------------------
    def _run_drain(self, params, requests, max_steps):
        """Legacy baseline: prefill only when the WHOLE batch has drained —
        one long request starves every queued request (kept for comparison
        and for families without slotted support)."""
        pending = sorted(requests, key=lambda r: r.arrival_step)
        active_req: List[Optional[Request]] = [None] * self.slots
        caches = None
        last = None
        done: List[Request] = []
        steps = admissions = 0
        while pending or self.queue or any(r is not None for r in active_req):
            if steps >= max_steps:
                break
            while pending and pending[0].arrival_step <= steps:
                self.submit(pending.pop(0))
            if caches is None:
                toks = np.zeros((self.slots, self.prompt_len), np.int32)
                for i in range(self.slots):
                    if active_req[i] is None and self.queue:
                        r = self.queue.pop(0)
                        r.t_admitted = time.monotonic()
                        r.admit_step = steps
                        active_req[i] = r
                        admissions += 1
                    if active_req[i] is not None:
                        toks[i] = self._pad_prompt(active_req[i])
                if not any(r is not None for r in active_req):
                    steps += 1                   # idle tick: await arrivals
                    continue
                caches, first = self._prefill_b(params, jnp.asarray(toks))
                first.block_until_ready()
                now = time.monotonic()
                first = np.asarray(first)
                for i, r in enumerate(active_req):
                    if r is not None and not r.generated:
                        r.t_first_token = now
                        r.generated.append(int(first[i]))
                        if r.done:
                            r.t_done = now
                last = jnp.asarray(first.astype(np.int32))
            t0 = time.monotonic()
            caches, nxt = self._decode_b(params, caches, last)
            nxt_np = np.asarray(nxt)
            self.tpot_samples.append(time.monotonic() - t0)
            last = nxt
            steps += 1
            now = time.monotonic()
            for i, r in enumerate(active_req):
                if r is None or r.done:
                    continue
                r.generated.append(int(nxt_np[i]))
                if r.done:
                    r.t_done = now
            for i, r in enumerate(active_req):
                if r is not None and r.done:
                    done.append(r)
                    active_req[i] = None
            if all(r is None for r in active_req):
                caches = None                    # drained → allow re-prefill
        return self._stats(done, steps, admissions, 0)

    # ------------------------------------------------------------------
    def _stats(self, done, steps, admissions, overlapped) -> Dict[str, Any]:
        tp = np.array(self.tpot_samples[1:] or [0.0])
        per_req = [r.metrics() for r in sorted(done, key=lambda r: r.rid)]
        ttfts = np.array([m["ttft_ms"] for m in per_req] or [0.0])
        qd = np.array([m["queue_delay_ms"] for m in per_req] or [0.0])
        return {
            "mode": self.mode,
            "completed": len(done),
            "decode_steps": steps,
            "admissions": admissions,
            "overlapped_admissions": overlapped,
            "tpot_mean_ms": float(tp.mean() * 1e3),
            "tpot_p50_ms": float(np.percentile(tp, 50) * 1e3) if len(tp) else 0.0,
            "tpot_p99_ms": float(np.percentile(tp, 99) * 1e3) if len(tp) else 0.0,
            "ttft_mean_ms": float(ttfts.mean()),
            "ttft_p99_ms": float(np.percentile(ttfts, 99)),
            "queue_delay_mean_ms": float(qd.mean()),
            "throughput_tok_s": float(
                sum(len(r.generated) for r in done)
                / max(sum(self.tpot_samples), 1e-9)),
            "per_request": per_req,
            "runtime": self.rt.stats(),
        }
