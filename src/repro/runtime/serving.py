"""Continuous-batching decode serving engine under STATIC shapes.

The paper's prototype serves a fixed decode batch and defers continuous
batching to future work (§7.2). This engine closes that gap without leaving
the cache-resident/static-shape regime the paper's runtime depends on:

- the decode batch is a fixed set of SLOTS (static shapes → AOT compile once),
- a queued request is admitted into any free slot *mid-serve* — no drain, no
  retrace,
- every row carries its own cursor (``positions``) and an ``active`` mask is
  threaded through decode (``ModelAPI.decode_slotted``) so retired slots
  neither write KV nor pollute the argmax,
- **macro-step decode** (``block_size`` = T > 1): decode runs as
  ``ModelAPI.decode_block`` — T greedy micro-steps inside ONE AOT-compiled
  ``lax.scan``, with per-slot on-device halting. The host syncs ONCE per T
  tokens and admission waits for block boundaries — the step-axis analogue
  of the paper's sub-operator dependency relaxation (§5),
- **chunked-prefill lane** (``prefill_chunk`` = C > 0): admission prefill is
  no longer one monolithic full-width program that stalls the whole decode
  batch. Each block boundary runs AT MOST ONE fixed-(1,C) chunk
  (``ModelAPI.prefill_chunk``) for the admitting slot, writing KV at the
  slot's offset, then the decode block for live slots — in-flight TPOT pays
  one chunk per boundary instead of a full-prompt stall. Prompt lengths are
  TRUE lengths end to end: the cursor starts at the real length (short
  prompts land in small KV buckets from step 0) and arbitrary lengths are
  covered by the chunk loop — nothing is ever silently truncated,
- **length-aware KV walking**: in block mode each macro-step runs the block
  program compiled for the smallest KV *bucket* (chunk multiple) covering
  every live cursor + T (``kv_bucket_chunk``),
- all step programs are AOT-compiled through ``StaticRuntime`` — ``stats()``
  must show compiles == 1 per program with only ``calls`` growing across
  admissions (the §4.3 pinned-pool invariant).

The engine is split into a host-side **SlotScheduler** (slot occupancy,
arrival pump, cursors/halt operands, chunk-lane bookkeeping — decisions
only) and a device-side **ExecutorBackend** (the compiled step programs and
the slot caches — execution only); ``ServingEngine`` is the boundary loop
that connects them. The backend is PLUGGABLE (``backend=``): the colocated
backend runs the single-domain programs, the WA backend
(``backend="wa"``) runs the same feature set — macro-step blocks, KV
buckets, chunked prefill, slot admission — through the weight–attention
disaggregated layer loop of ``core/wa.py`` with the W→A→W routing inside
the compiled programs (sharding-constrained, ``device_put``-free). The
scheduler is backend-agnostic: no scheduling decision moves. The previous
drain-then-refill loop is kept as ``mode="drain"`` — the baseline the
continuous scheduler is measured against, and the fallback for model
families without slotted support.

Per-request accounting: queue delay (enqueue→admit), TTFT (enqueue→first
token, spanning chunk boundaries under chunked admission), TPOT, and max
inter-token gap (the decode-stall a prefill inflicts on in-flight requests).
Engine-level: decode-token throughput over decode wall-time only — prefill
AND chunk-prefill wall-time are excluded from both sides — host syncs per
decode token, and per-macro-step token counts.

**Serving under pressure** (DESIGN.md §7, failure model): requests carry a
``priority`` lane and TTFT/TPOT deadline fields; admission drains the queue
in priority order, a bounded queue (``max_queue``) sheds lowest-priority
work as STRUCTURED rejections, and expired-TTFT queued requests are shed as
deadline misses. With ``preemptible=True`` the engine may, at a block
boundary (the only preemption point), swap a victim slot's true-length KV
out to a host-side buffer (``serve_[wa_]swap_out`` — stored bytes verbatim,
int8 scales included) and later restore it via the masked full-width write
(``serve_[wa_]swap_in``); cursors already carry true lengths, so a restored
sequence is byte-identical to an uninterrupted one and the swap pair joins
the compile-once program set. Every program dispatch runs through a
hardened wrapper: bounded retry-with-backoff on ``DispatchError`` (raised
BEFORE the compiled call touches donated operands — retry-safe), a watchdog
counter for dispatches exceeding ``watchdog_s``, and a poisoned-slot
quarantine path that demotes a persistently failing request to a structured
rejection instead of a hung engine. Every request ends terminally accounted:
completed, rejected, or deadline_missed.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import wa_schedule_occupancy
from repro.core.wa import WADisaggregated, micro_batch_slices, routing_bytes
from repro.kv.cache import (KVCache, cold_boundary, export_slot_kv,
                            import_slot_kv)
from repro.models.attention import bucket_for, kv_buckets
from repro.models.common import dtype_of
from repro.models.param_specs import cache_specs
from repro.models.registry import DECODE_SLACK, ModelAPI
from repro.models.sharding import ShardingCtx
from repro.runtime.static_runtime import DispatchError, StaticRuntime


class RequestRejected(ValueError):
    """Enqueue-time rejection of an unrepresentable request. Carries the
    request id, the offending length and the per-mode limit as FIELDS (not
    just prose) so a fleet log line is actionable: which request, which
    length, which knob to raise."""

    def __init__(self, rid: int, reason: str, *, length=None, limit=None,
                 limit_name: str = ""):
        self.rid, self.reason = rid, reason
        self.length, self.limit, self.limit_name = length, limit, limit_name
        super().__init__(f"request {rid}: {reason}")


class DispatchFailure(RuntimeError):
    """A program dispatch kept raising ``DispatchError`` past the bounded
    retry budget. The boundary loop demotes this to a structured rejection
    of the responsible request (+ slot quarantine where the slot's cache
    bytes are suspect) — never a hung engine."""

    def __init__(self, name: str, attempts: int, cause: Exception):
        self.name, self.attempts, self.cause = name, attempts, cause
        super().__init__(f"dispatch of {name!r} failed after {attempts} "
                         f"attempt(s): {cause}")


@dataclass
class SwapState:
    """Host-side image of a preempted slot: the full-extent STORED bytes
    (``export_slot_kv`` tuple — int8 values + scales verbatim, dense K/V
    verbatim) plus the cursor triple that makes restore token-exact. The
    true KV length travels here, not in the buffer — exactly the chunk
    lane's cursors-are-validity contract."""
    saved: Tuple                     # (k, v, k_scale, v_scale) host arrays
    kv_len: int                      # TRUE length: positions cursor at swap
    last_tok: int                    # last emitted token (its KV not yet written)
    remaining: int                   # decode budget left


def _pin_cache_tree(caches, ctx: ShardingCtx):
    """Constrain every cache leaf to its planned layout (``cache_specs``).

    Cache-only programs (slot write, slot reset) contain no matmuls and no
    annotations of their own, so GSPMD sees nothing to anchor on and pins
    the whole program — including the DONATED cache buffer — to a single
    device, forcing a full-cache reshard every time dispatch alternates
    with the model-step programs. Pinning entry and exit keeps every
    program in a cell on one agreed cache placement."""
    if ctx.mesh is None or ctx.mesh.empty:
        return caches
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(ctx.mesh, s)),
        caches, cache_specs(caches, ctx))


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (L,) int32 — TRUE length, no padding
    max_new_tokens: int
    arrival_step: int = 0               # decode step at which it reaches the queue
    eos_id: int = -1                    # stop id (< 0 → budget-only halting)
    generated: List[int] = field(default_factory=list)
    t_enqueue: float = 0.0
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    admit_step: int = -1                # decode step at which it got a slot
    t_last_emit: float = 0.0            # last token-emission sync (gap stats)
    max_gap: float = 0.0                # max inter-token gap (decode stall)
    priority: int = 0                   # higher wins admission AND survives
                                        # preemption/shedding longer
    ttft_deadline_ms: float = 0.0       # 0 → none; queued past it → shed
    tpot_deadline_ms: float = 0.0       # SLO target (recorded, never sheds)
    status: str = "pending"             # pending/queued/active → terminal:
                                        # completed|rejected|deadline_missed
    reject_reason: Optional[str] = None
    preemptions: int = 0                # times swapped out of a slot
    swap: Optional[SwapState] = None    # host KV image while preempted
    kv_base: int = 0                    # cursor base at start_decode (true
                                        # prompt length; padded width when
                                        # admitted monolithically)

    @property
    def done(self) -> bool:
        if self.eos_id >= 0 and self.generated\
                and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens

    def note_emit(self, now: float):
        """Token(s) for this request became host-visible at ``now``; the max
        gap between consecutive emissions is the decode-stall metric (a
        monolithic prefill of another request shows up here)."""
        if self.t_last_emit > 0.0:
            self.max_gap = max(self.max_gap, now - self.t_last_emit)
        self.t_last_emit = now

    def metrics(self) -> Dict[str, Any]:
        n = len(self.generated)
        ttft = max(0.0, self.t_first_token - self.t_enqueue) * 1e3
        tpot = ((self.t_done - self.t_first_token) / (n - 1) * 1e3
                if n > 1 else 0.0)
        return {
            "rid": self.rid,
            "tokens": n,
            "prompt_tokens": int(len(self.prompt)),
            "arrival_step": self.arrival_step,
            "admit_step": self.admit_step,
            "queue_delay_ms": max(0.0, self.t_admitted - self.t_enqueue) * 1e3,
            "ttft_ms": ttft,
            "tpot_ms": tpot,
            "max_gap_ms": self.max_gap * 1e3,
            "priority": self.priority,
            "status": self.status,
            "preemptions": self.preemptions,
            # deadline attainment (completed requests; goodput-under-
            # deadline in the pressure benchmark sums these)
            "ttft_deadline_met": bool(self.ttft_deadline_ms <= 0
                                      or ttft <= self.ttft_deadline_ms),
            "tpot_deadline_met": bool(self.tpot_deadline_ms <= 0
                                      or tpot <= self.tpot_deadline_ms),
        }


def pad_row(prompt: np.ndarray, width: int) -> np.ndarray:
    """Zero-pad a prompt (or prompt slice) up to a static width. PAD ONLY:
    callers must have rejected anything longer (the silent-truncation fix
    deleted every truncating path)."""
    assert len(prompt) <= width, (len(prompt), width)
    row = np.zeros((width,), np.int32)
    row[:len(prompt)] = prompt
    return row


# ---------------------------------------------------------------------------
# SlotScheduler — the HOST half of the scheduler/executor split
# ---------------------------------------------------------------------------

class SlotScheduler:
    """Slot occupancy, arrival pump, per-slot cursors/halt operands and the
    chunked-prefill lane bookkeeping. Pure host state: it decides WHAT runs
    at each block boundary and never touches a device array — the
    ExecutorBackend owns every compiled call, and because no decision
    lives there, every backend serves through this ONE scheduler
    (DESIGN.md §7)."""

    FREE, PREFILL, DECODE = "free", "prefill", "decode"

    def __init__(self, n_slots: int, requests: List[Request],
                 queue: List[Request]):
        self.n = n_slots
        self.pending = sorted(requests, key=lambda r: r.arrival_step)
        self.queue = queue                       # engine-owned (submit target)
        self.req: List[Optional[Request]] = [None] * n_slots
        self.phase = [self.FREE] * n_slots
        self.filled = [0] * n_slots              # prompt tokens written so far
        self.prefill_fifo: List[int] = []        # slots awaiting chunk work
        self.positions = np.zeros((n_slots,), np.int32)
        self.last_tok = np.zeros((n_slots,), np.int32)
        self.remaining = np.zeros((n_slots,), np.int32)
        self.eos = np.full((n_slots,), -1, np.int32)
        self.quarantined: set = set()            # poisoned slots, never reused

    # -- queue / occupancy ------------------------------------------------
    def work_remaining(self) -> bool:
        return bool(self.pending or self.queue
                    or any(p != self.FREE for p in self.phase))

    def pump(self, step: int):
        """Arrival simulation: requests whose arrival_step has come move to
        the queue (already validated by run()). Stamped here UNLESS the
        request was submit()ted before run() — its enqueue time is the
        submit, and queue_delay/TTFT must keep counting from there."""
        while self.pending and self.pending[0].arrival_step <= step:
            r = self.pending.pop(0)
            if not r.t_enqueue:
                r.t_enqueue = time.monotonic()
            r.status = "queued"
            self.queue.append(r)

    def occupied(self) -> bool:
        return any(p != self.FREE for p in self.phase)

    def decode_active(self) -> np.ndarray:
        return np.array([p == self.DECODE for p in self.phase])

    def micro_batch_view(self, depth: int, active=None):
        """Per-micro-batch (slot indices, active-mask rows) under overlap
        depth ``depth`` — routed through ``core.wa.micro_batch_slices``,
        the SAME helper the pipelined layer loop slices its rows with, so
        the scheduler's occupancy view and the backend's micro-batch split
        share one source of truth and cannot drift."""
        act = self.decode_active() if active is None else np.asarray(active)
        return [(list(range(sl.start, sl.stop)), act[sl])
                for sl in micro_batch_slices(self.n, depth)]

    # -- priority queue / quarantine --------------------------------------
    def usable_free(self) -> Optional[int]:
        """Lowest-index FREE slot that is not quarantined, or None."""
        for i in range(self.n):
            if self.phase[i] == self.FREE and i not in self.quarantined:
                return i
        return None

    def usable_capacity(self) -> int:
        return self.n - len(self.quarantined)

    def pop_queue(self) -> Optional[Request]:
        """Highest-priority queued request; FIFO within a priority class.
        A preempted request keeps its ORIGINAL enqueue stamp, so it
        re-admits ahead of later same-priority arrivals (its wait already
        counted once)."""
        if not self.queue:
            return None
        j = min(range(len(self.queue)),
                key=lambda j: (-self.queue[j].priority,
                               self.queue[j].t_enqueue, self.queue[j].rid))
        return self.queue.pop(j)

    def top_priority(self) -> Optional[int]:
        return max((r.priority for r in self.queue), default=None)

    def decode_slots(self) -> List[int]:
        return [i for i in range(self.n) if self.phase[i] == self.DECODE]

    # -- chunk lane -------------------------------------------------------
    def begin_prefill(self, slot: int, r: Request, step: int):
        """Admit a fresh request into a free slot (PREFILL phase); its
        chunks run one per boundary from the admission FIFO."""
        r.t_admitted = time.monotonic()
        r.admit_step = step
        r.status = "active"
        self.req[slot] = r
        self.phase[slot] = self.PREFILL
        self.filled[slot] = 0
        self.prefill_fifo.append(slot)

    def next_chunk(self, chunk: int, kv_extent: Optional[int]
                   ) -> Optional[Tuple[int, Request, int, int]]:
        """Head of the prefill FIFO → (slot, request, start, n_valid) for
        the next fixed-shape chunk, or None when no slot is prefilling.

        The fixed (1,C) window must FIT the cache: ``dynamic_update_slice``
        clamps an out-of-bounds start instead of erroring, which would land
        the final chunk's K/V at the wrong positions. When
        ``start + C > kv_extent`` the window shifts LEFT over
        already-written positions — recomputing a prefix position's K/V is
        bit-identical (same tokens, same attended prefix), so the overlap
        is a no-op and the window still ends at the prompt's true length."""
        if not self.prefill_fifo:
            return None
        i = self.prefill_fifo[0]
        r = self.req[i]
        start = self.filled[i]
        if kv_extent is not None and start + chunk > kv_extent:
            start = kv_extent - chunk
        return i, r, start, min(chunk, len(r.prompt) - start)

    def chunk_done(self, slot: int, start: int, n_valid: int) -> bool:
        """Advance the slot's prompt cursor; True when the prompt is fully
        written (the chunk that just ran was the final one)."""
        self.filled[slot] = start + n_valid
        if self.filled[slot] >= len(self.req[slot].prompt):
            self.prefill_fifo.pop(0)
            return True
        return False

    # -- phase transitions ------------------------------------------------
    def start_decode(self, slot: int, cursor: int, first_tok: int):
        r = self.req[slot]
        r.kv_base = cursor
        self.phase[slot] = self.DECODE
        self.positions[slot] = cursor
        self.last_tok[slot] = first_tok
        self.remaining[slot] = r.max_new_tokens - 1
        self.eos[slot] = r.eos_id

    def preempt(self, slot: int) -> Request:
        """Release a DECODE slot whose KV the caller has already swapped
        out; the request goes back to the queue carrying its SwapState."""
        assert self.phase[slot] == self.DECODE, (slot, self.phase[slot])
        r = self.req[slot]
        self.req[slot] = None
        self.phase[slot] = self.FREE
        r.status = "queued"
        self.queue.append(r)
        return r

    def resume_decode(self, slot: int, r: Request, state: SwapState):
        """Re-enter DECODE directly from a restored swap image: cursors
        resume exactly where the preemption cut them — the prefill phase is
        skipped, the next decode step appends ``last_tok``'s KV at
        ``kv_len`` just as an uninterrupted serve would have."""
        r.status = "active"
        self.req[slot] = r
        self.phase[slot] = self.DECODE
        self.positions[slot] = state.kv_len
        self.last_tok[slot] = state.last_tok
        self.remaining[slot] = state.remaining
        self.eos[slot] = r.eos_id

    def retire(self, slot: int):
        self.req[slot] = None
        self.phase[slot] = self.FREE
        if slot in self.prefill_fifo:
            self.prefill_fifo.remove(slot)

    # -- invariants --------------------------------------------------------
    def invariant_violations(self) -> List[str]:
        """Occupancy/cursor consistency at a block boundary (the chaos
        harness runs this every boundary via ``strict_invariants``):
        FREE ⟺ no request, quarantined ⇒ FREE, no rid in two slots, the
        prefill FIFO holds exactly PREFILL slots, and every DECODE slot's
        cursor triple matches its request's emission count."""
        bad: List[str] = []
        seen: Dict[int, int] = {}
        for i in range(self.n):
            r, ph = self.req[i], self.phase[i]
            if ph == self.FREE and r is not None:
                bad.append(f"slot {i}: FREE but holds rid {r.rid}")
            if ph != self.FREE and r is None:
                bad.append(f"slot {i}: {ph} with no request")
            if ph != self.FREE and i in self.quarantined:
                bad.append(f"slot {i}: quarantined but {ph}")
            if r is not None:
                if r.rid in seen:
                    bad.append(f"rid {r.rid} in slots {seen[r.rid]} and {i}")
                seen[r.rid] = i
            if ph == self.DECODE:
                want_pos = r.kv_base + len(r.generated) - 1
                if int(self.positions[i]) != want_pos:
                    bad.append(
                        f"slot {i} rid {r.rid}: cursor {self.positions[i]} "
                        f"!= kv_base {r.kv_base} + emitted "
                        f"{len(r.generated)} - 1")
                if int(self.remaining[i]) != r.max_new_tokens\
                        - len(r.generated):
                    bad.append(
                        f"slot {i} rid {r.rid}: remaining "
                        f"{self.remaining[i]} != budget "
                        f"{r.max_new_tokens} - emitted {len(r.generated)}")
                if int(self.remaining[i]) < 0:
                    bad.append(f"slot {i} rid {r.rid}: negative remaining")
        if len(set(self.prefill_fifo)) != len(self.prefill_fifo):
            bad.append(f"duplicate slots in prefill FIFO {self.prefill_fifo}")
        for i in self.prefill_fifo:
            if self.phase[i] != self.PREFILL:
                bad.append(f"slot {i} in prefill FIFO but {self.phase[i]}")
        return bad


# ---------------------------------------------------------------------------
# ExecutorBackend — the DEVICE half of the scheduler/executor split
# ---------------------------------------------------------------------------

class ExecutorBackend:
    """Owns the slot caches and every AOT-compiled step program (compiled
    once through ``StaticRuntime`` — the §4.3 zero-retracing invariant).
    ``ServingEngine(backend=...)`` picks the implementation; the
    ``SlotScheduler`` is backend-agnostic and the boundary loop only ever
    calls this contract:

      fresh()                       fresh slot caches for a run (programs
                                    persist — compiles == 1 across runs)
      admit_full(params,row,slot)   monolithic admission → first-token array
      run_chunk(params,row,slot,start,valid)   one fixed-(1,C) prefill chunk
      decode_step(params,tok,pos,act)          one slotted step (T == 1)
      decode_block(params,bucket,…)  one T-micro-step block (per-bucket
                                     program; ``buckets`` fixed at build)
      reset(slot) / has_reset        debug slot zeroing
      drain_prefill / drain_decode   drain-mode batch programs (colocated
                                     backend only)

    Each backend × mode compiles exactly the programs it dispatches:

      colocated  chunked admission     serve_prefill_chunk
      colocated  monolithic admission  serve_prefill1 + serve_admit
      colocated  T == 1                serve_decode
      colocated  T > 1                 serve_decode_block[_s{N}] per bucket
      colocated  drain                 serve_prefill_batch + serve_decode_drain
      wa         chunked admission     serve_wa_prefill_chunk
      wa         monolithic admission  serve_wa_admit (full-width chunk)
      wa         T == 1                serve_wa_decode
      wa         T > 1                 serve_wa_decode_block[_s{N}] per bucket
      either     debug_reset_slots     serve_reset
      either     preemptible           serve_[wa_]swap_out + serve_[wa_]swap_in

    The scheduler never sees a jax array; the executor never makes a
    scheduling decision."""

    name = "colocated"
    program_prefix = "serve_"

    def __init__(self, api: ModelAPI, ctx: ShardingCtx, rt: StaticRuntime,
                 params, caches_aval, *, mode: str, slots: int,
                 prompt_len: int, max_new_cap: int, block_size: int,
                 kv_bucket_chunk: int, prefill_chunk: int,
                 debug_reset_slots: bool, a_shards: int = 1,
                 overlap: int = 1, preemptible: bool = False):
        self.api, self.ctx, self.rt = api, ctx, rt
        self.slots, self.prompt_len = slots, prompt_len
        self.max_new_cap = max_new_cap
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        self.a_shards = a_shards
        # sub-operator overlap depth (micro-batch software pipelining of
        # the W/A boundary — WA backend only; the engine validated it)
        self.overlap = overlap
        self.preemptible = preemptible
        self.caches = None
        self.buckets: Tuple[int, ...] = ()
        self._decode_blocks: Dict[int, Callable] = {}
        self._reset = None
        self._swap_out_p = self._swap_in_p = None
        if mode == "continuous":
            self._build_continuous(params, caches_aval, kv_bucket_chunk,
                                   prefill_chunk, debug_reset_slots)
            if preemptible:
                self._build_swap(caches_aval)
        else:
            self._build_drain(params)

    # -- shared build pieces ----------------------------------------------
    def _bucket_set(self, caches_aval, kv_bucket_chunk) -> Tuple[int, ...]:
        """Static KV bucket set for the block programs. Bucketing applies
        only to prefix-ordered KV caches; recurrent states (and ring
        buffers) get the single full program."""
        bucketable = isinstance(caches_aval, KVCache)\
            and not caches_aval.window
        s_max = caches_aval.k.shape[3] if bucketable else 0
        # a_shards > 1 → every bucket must split into equal shard blocks
        # (kv_buckets rounds the chunk up; the engine validated s_max)
        return kv_buckets(s_max, kv_bucket_chunk, self.a_shards)\
            if bucketable and kv_bucket_chunk > 0 else (0,)

    @property
    def cache_ctx(self) -> ShardingCtx:
        """Sharding ctx that owns the slot caches (A domain under WA)."""
        return self.ctx

    def _build_reset(self, caches_aval, debug_reset_slots):
        if debug_reset_slots and self.api.reset_slot is not None:
            scalar = jnp.zeros((), jnp.int32)
            cctx = self.cache_ctx
            self._reset = self.rt.compile_step(
                "serve_reset",
                lambda c, slot: _pin_cache_tree(
                    self.api.reset_slot(_pin_cache_tree(c, cctx), slot),
                    cctx),
                (caches_aval, scalar), donate_argnums=(0,))

    # -- preemption swap pair ---------------------------------------------
    def _swap_export_fn(self, caches, slot):
        """Traced body of ``{prefix}swap_out`` (backends may override to
        route through their own cache-domain pins)."""
        return export_slot_kv(_pin_cache_tree(caches, self.cache_ctx), slot)

    def _swap_import_fn(self, caches, saved, slot, valid_len):
        """Traced body of ``{prefix}swap_in`` — masked true-length restore
        (the chunk lane's keep-past-valid write at full width)."""
        cctx = self.cache_ctx
        caches = import_slot_kv(_pin_cache_tree(caches, cctx), saved, slot,
                                valid_len)
        return _pin_cache_tree(caches, cctx)

    def _build_swap(self, caches_aval):
        """Compile the token-exact preemption pair (engine validated the
        family: prefix-ordered non-windowed KV cache). ``swap_out`` is
        READ-ONLY — no donation, it returns only the slot slices, so a
        failed/retried dispatch can never corrupt the resident cache;
        ``swap_in`` donates the caches like every steady-state program.
        Slot index and true length are traced scalars — one compiled pair
        serves every slot at every length (compiles == 1)."""
        scalar = jnp.zeros((), jnp.int32)
        saved_aval = jax.eval_shape(self._swap_export_fn, caches_aval,
                                    scalar)
        self._swap_out_p = self.rt.compile_step(
            f"{self.program_prefix}swap_out", self._swap_export_fn,
            (caches_aval, scalar))
        self._swap_in_p = self.rt.compile_step(
            f"{self.program_prefix}swap_in", self._swap_import_fn,
            (caches_aval, saved_aval, scalar, scalar), donate_argnums=(0,))

    @staticmethod
    def _postprocess(logits, positions, active):
        # active-slot mask: retired slots emit a fixed token id 0 and
        # never advance — finished requests cannot pollute the stream
        nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        return jnp.where(active, nxt, 0),\
            positions + active.astype(jnp.int32)

    def _build_decode_programs(self, params, caches_aval, kv_bucket_chunk,
                               prefix, slotted_fn, block_fn):
        """Compile the decode half shared by every backend: one
        ``{prefix}decode_block[_s{N}]`` per KV bucket for T > 1, else the
        single ``{prefix}decode`` step program. Backends differ only in the
        step callables and the program-name prefix — the halting operands,
        donation and postprocess wiring cannot diverge between them.

        slotted_fn(params, caches, tokens, positions, active)
            → (caches, logits)
        block_fn(params, caches, tok, pos, act, rem, eos, kv_bucket)
            → the ``make_decode_block`` 7-tuple
        """
        B, T = self.slots, self.block_size
        pos0 = jnp.zeros((B,), jnp.int32)
        act0 = jnp.zeros((B,), bool)
        tok0 = jnp.zeros((B,), jnp.int32)
        # overlap depth is a build-time static baked into the SAME program
        # names (depth 1 compiles today's exact program set); record it as
        # program metadata so stats()/logs can say which variant serves
        meta = {"overlap": self.overlap} if self.overlap > 1 else None
        if T > 1:
            # -- macro-step block programs, one per KV bucket --------------
            self.buckets = self._bucket_set(caches_aval, kv_bucket_chunk)
            rem0 = jnp.zeros((B,), jnp.int32)
            eos0 = jnp.full((B,), -1, jnp.int32)
            for sb in self.buckets:
                name = f"{prefix}decode_block" if len(self.buckets) == 1\
                    else f"{prefix}decode_block_s{sb}"

                def block_step(p, caches, tok, pos, act, rem, eos, _sb=sb):
                    return block_fn(p, caches, tok, pos, act, rem, eos, _sb)

                self._decode_blocks[sb] = self.rt.compile_step(
                    name, block_step,
                    (params, caches_aval, tok0, pos0, act0, rem0, eos0),
                    donate_argnums=(1,), meta=meta)
            return

        def decode_fn(p, caches, tokens, positions, active):
            caches, logits = slotted_fn(p, caches, tokens, positions, active)
            return (caches,) + self._postprocess(logits, positions, active)

        self._decode = self.rt.compile_step(
            f"{prefix}decode", decode_fn,
            (params, caches_aval, tok0, pos0, act0),
            donate_argnums=(1,), meta=meta)

    def _build_continuous(self, params, caches_aval, kv_bucket_chunk,
                          prefill_chunk, debug_reset_slots):
        raise NotImplementedError

    def _build_drain(self, params):
        raise NotImplementedError(
            f"the {self.name} backend has no drain mode")

    # -- execution --------------------------------------------------------
    @property
    def has_reset(self) -> bool:
        return self._reset is not None

    def fresh(self):
        """Fresh slot caches for a new run (AOT programs persist)."""
        self.caches = self.api.init_caches(self.slots,
                                           self.prompt_len + self.max_new_cap)

    def admit_full(self, params, row: np.ndarray, slot: int):
        """Monolithic admission of a full-width padded prompt row. Returns
        the device array holding the first token."""
        raise NotImplementedError

    def run_chunk(self, params, row: np.ndarray, slot: int, start: int,
                  valid: int):
        """One fixed-(1,C) prefill chunk at the slot's offset. Returns the
        device array holding the chunk's last-valid-position argmax (the
        first token when this was the prompt's final chunk)."""
        self.caches, tok = self._chunk(
            params, self.caches, jnp.asarray(row[None]),
            jnp.asarray(slot, jnp.int32), jnp.asarray(start, jnp.int32),
            jnp.asarray(valid, jnp.int32))
        return tok

    def decode_step(self, params, last_tok, positions, active):
        self.caches, nxt, new_pos = self._decode(
            params, self.caches, jnp.asarray(last_tok),
            jnp.asarray(positions), jnp.asarray(active))
        return nxt, new_pos

    def decode_block(self, params, bucket, last_tok, positions, active,
                     remaining, eos):
        self.caches, toks, emitted, last_d, pos_d, act_d, rem_d =\
            self._decode_blocks[bucket](
                params, self.caches, jnp.asarray(last_tok),
                jnp.asarray(positions), jnp.asarray(active),
                jnp.asarray(remaining), jnp.asarray(eos))
        return toks, emitted, last_d, pos_d, act_d, rem_d

    def reset(self, slot: int):
        self.caches = self._reset(self.caches, jnp.asarray(slot, jnp.int32))

    def swap_out(self, slot: int):
        """Export one slot's stored KV (device tuple; caller hosts it).
        Read-only: the resident caches are NOT donated or modified."""
        return self._swap_out_p(self.caches, jnp.asarray(slot, jnp.int32))

    def swap_in(self, saved, slot: int, valid_len: int):
        """Masked true-length restore of an exported slot image."""
        self.caches = self._swap_in_p(
            self.caches, saved, jnp.asarray(slot, jnp.int32),
            jnp.asarray(valid_len, jnp.int32))

    def drain_prefill(self, params, toks: np.ndarray):
        raise NotImplementedError

    def drain_decode(self, params, caches, last):
        raise NotImplementedError


class ColocatedBackend(ExecutorBackend):
    """Single-domain executor: weights and KV share every device; the step
    programs are the family's own ``ModelAPI`` slotted extensions."""

    name = "colocated"

    # -- program construction --------------------------------------------
    def _build_continuous(self, params, caches_aval, kv_bucket_chunk,
                          prefill_chunk, debug_reset_slots):
        api, ctx = self.api, self.ctx
        B, P, T = self.slots, self.prompt_len, self.block_size
        scalar = jnp.zeros((), jnp.int32)
        self._prefill1 = None

        # tiered caches admit through the chunk program even monolithically:
        # write_prefill has no cold-staging path (the chunk program quantizes
        # the cold prefix and rings the hot tail inside ONE compiled body),
        # so monolithic admission compiles the degenerate full-width chunk —
        # the WA backend's serve_wa_admit shape, same semantics (padding
        # attended, cursor at the padded width)
        tiered = isinstance(caches_aval, KVCache) and caches_aval.is_tiered
        if prefill_chunk or tiered:
            def chunk_fn(p, caches, toks, slot, start, valid):
                caches, logits = api.prefill_chunk(p, caches, toks, slot,
                                                   start, valid, ctx)
                return caches, jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

            toks_c = jnp.zeros((1, prefill_chunk or P), jnp.int32)
            self._chunk = self.rt.compile_step(
                "serve_prefill_chunk" if prefill_chunk else "serve_admit",
                chunk_fn,
                (params, caches_aval, toks_c, scalar, scalar, scalar),
                donate_argnums=(1,))
        else:
            def prefill1_fn(p, toks):
                caches, logits = api.prefill(p, {"tokens": toks}, ctx)
                return caches, jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

            def admit_fn(caches, single, slot):
                caches = _pin_cache_tree(caches, ctx)
                return _pin_cache_tree(api.write_slot(caches, single, slot),
                                       ctx)

            toks1 = jnp.zeros((1, P), jnp.int32)
            single_aval, _ = jax.eval_shape(prefill1_fn, params, toks1)
            self._prefill1 = self.rt.compile_step(
                "serve_prefill1", prefill1_fn, (params, toks1))
            self._admit = self.rt.compile_step(
                "serve_admit", admit_fn, (caches_aval, single_aval, scalar),
                donate_argnums=(0,))

        self._build_reset(caches_aval, debug_reset_slots)
        # split-KV decode (a_shards > 1) is forwarded only when on:
        # attention-free families' decode_slotted has no kv_shards kwarg
        sh = {"kv_shards": self.a_shards} if self.a_shards > 1 else {}
        self._build_decode_programs(
            params, caches_aval, kv_bucket_chunk, "serve_",
            lambda p, c, t, pos, act: api.decode_slotted(p, c, t, pos, act,
                                                         ctx, **sh),
            lambda p, c, t, pos, act, rem, eos, sb: api.decode_block(
                p, c, t, pos, act, rem, eos, ctx, block_size=T,
                kv_bucket=sb, **sh))

    def _build_drain(self, params):
        api, ctx = self.api, self.ctx

        def prefill_fn(p, toks):
            caches, logits = api.prefill(p, {"tokens": toks}, ctx)
            return caches, jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

        def decode_fn(p, caches, tokens):
            caches, logits = api.decode(p, caches, tokens, ctx)
            return caches, jnp.argmax(logits[:, 0], -1).astype(jnp.int32)

        toks0 = jnp.zeros((self.slots, self.prompt_len), jnp.int32)
        caches_aval, tok_aval = jax.eval_shape(prefill_fn, params, toks0)
        self._prefill_b = self.rt.compile_step(
            "serve_prefill_batch", prefill_fn, (params, toks0))
        self._decode_b = self.rt.compile_step(
            "serve_decode_drain", decode_fn, (params, caches_aval, tok_aval),
            donate_argnums=(1,))

    # -- execution --------------------------------------------------------
    def admit_full(self, params, row: np.ndarray, slot: int):
        """Monolithic admission: batch-1 full-width prefill + slot write
        (flat caches), or — for tiered caches — ONE full-width chunk that
        lands both tiers directly in the slot (no separate write-slot copy:
        the cold quantization and hot ring write live inside the chunk
        program)."""
        if self._prefill1 is None:
            self.caches, tok = self._chunk(
                params, self.caches, jnp.asarray(row[None]),
                jnp.asarray(slot, jnp.int32), jnp.asarray(0, jnp.int32),
                jnp.asarray(self.prompt_len, jnp.int32))
            return tok
        single, first = self._prefill1(params, jnp.asarray(row[None]))
        self.caches = self._admit(self.caches, single,
                                  jnp.asarray(slot, jnp.int32))
        return first

    def drain_prefill(self, params, toks: np.ndarray):
        caches, first = self._prefill_b(params, jnp.asarray(toks))
        return caches, first

    def drain_decode(self, params, caches, last):
        return self._decode_b(params, caches, last)


class WABackend(ExecutorBackend):
    """Weight–attention disaggregated executor (DESIGN.md §3): every step
    program runs ``core/wa.py``'s routed layer loop — QKV/FFN under the
    W-domain rules, KV writes / prefix reads / bucket slices / halt-mask
    advances under the A-domain rules, with the W→A→W hops as sharding
    constraints INSIDE the compiled program (``jax.device_put``-free).
    Per-slot cursors and KV buckets are A-side state; the scheduler's
    decisions arrive only as traced operands, so every program compiles
    exactly once across a staggered serve.

    Admission is ALWAYS the WA chunk program: the chunked lane runs the
    fixed (1,C) window; monolithic admission is the degenerate single
    full-width chunk (C = prompt_len, valid = prompt_len — padding
    attended, cursor at the padded width, exactly the colocated monolithic
    semantics).

    ``routed_bytes`` meters the W↔A hops (``core/wa.py::routing_bytes``):
    every dispatched micro-step routes the whole (B, d_model) batch twice
    per layer, every prefill chunk its (C, d_model) window — the measured
    form of the paper's "only embeddings move"."""

    name = "wa"
    program_prefix = "serve_wa_"

    @property
    def cache_ctx(self) -> ShardingCtx:
        return self.wa.a_ctx

    # the swap pair runs on the A domain through core/wa.py's own cache
    # pins (split-KV stays a read-time view — the exported bytes are
    # shard-agnostic); zero W↔A hops, so expected_routing has no entry
    def _swap_export_fn(self, caches, slot):
        return self.wa.swap_out_slot(caches, slot)

    def _swap_import_fn(self, caches, saved, slot, valid_len):
        return self.wa.swap_in_slot(caches, saved, slot, valid_len)

    def _build_continuous(self, params, caches_aval, kv_bucket_chunk,
                          prefill_chunk, debug_reset_slots):
        api, ctx = self.api, self.ctx
        B, P, T = self.slots, self.prompt_len, self.block_size
        self.wa = WADisaggregated(api.config, ctx.mesh, routing="sharding",
                                  a_shards=self.a_shards,
                                  overlap=self.overlap)
        self._el = jnp.dtype(dtype_of(api.config)).itemsize
        self.routed_bytes = 0
        scalar = jnp.zeros((), jnp.int32)

        def chunk_fn(p, caches, toks, slot, start, valid):
            caches, logits = self.wa.prefill_chunk(p, caches, toks, slot,
                                                   start, valid)
            return caches, jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

        toks_c = jnp.zeros((1, prefill_chunk or P), jnp.int32)
        self._chunk = self.rt.compile_step(
            "serve_wa_prefill_chunk" if prefill_chunk else "serve_wa_admit",
            chunk_fn, (params, caches_aval, toks_c, scalar, scalar, scalar),
            donate_argnums=(1,))

        self._build_reset(caches_aval, debug_reset_slots)
        self._build_decode_programs(
            params, caches_aval, kv_bucket_chunk, "serve_wa_",
            lambda p, c, t, pos, act: self.wa.decode_step_slotted(
                p, c, t, pos, act),
            lambda p, c, t, pos, act, rem, eos, sb: self.wa.decode_block(
                p, c, t, pos, act, rem, eos, None, block_size=T,
                kv_bucket=sb))

    # -- W↔A traffic model -------------------------------------------------
    def expected_routing(self, name: str) -> Tuple[int, int]:
        """Analytic routing model for ONE dispatch of program ``name``:
        returns ``(rows, trips)`` meaning the dispatch routes
        ``trips × routing_bytes(cfg, rows, el)`` W↔A bytes (``trips`` =
        micro-steps inside the program; a T-block scans T micro-steps).
        Single source of truth shared by the runtime meter (``_meter``) and
        the static verifier's routing cross-check
        (``repro.analysis.routing_check``) — the meter and the compiled
        programs cannot drift apart without the gate failing."""
        if name == "serve_wa_admit":
            return self.prompt_len, 1
        if name == "serve_wa_prefill_chunk":
            return self.prefill_chunk, 1
        if name == "serve_wa_decode":
            return self.slots, 1
        if name.startswith("serve_wa_decode_block"):
            return self.slots, self.block_size
        raise KeyError(f"no routing model for WA program {name!r}")

    def _meter(self, name: str):
        rows, trips = self.expected_routing(name)
        self.routed_bytes += trips * routing_bytes(self.api.config, rows,
                                                   self._el)

    # -- execution (adds the W↔A traffic meter) ---------------------------
    def fresh(self):
        super().fresh()
        self.routed_bytes = 0

    def admit_full(self, params, row: np.ndarray, slot: int):
        """Monolithic WA admission: ONE full-width chunk (start 0, the
        padded width valid) — KV lands directly in the slot, no separate
        write-slot copy (the cache never leaves the A domain)."""
        self.caches, tok = self._chunk(
            params, self.caches, jnp.asarray(row[None]),
            jnp.asarray(slot, jnp.int32), jnp.asarray(0, jnp.int32),
            jnp.asarray(self.prompt_len, jnp.int32))
        # metered AFTER the dispatch ran: a failed/retried dispatch never
        # reached the device, so it must not inflate the routed-bytes claim
        self._meter("serve_wa_admit")
        return tok

    def run_chunk(self, params, row, slot, start, valid):
        out = super().run_chunk(params, row, slot, start, valid)
        self._meter("serve_wa_prefill_chunk")
        return out

    def decode_step(self, params, last_tok, positions, active):
        out = super().decode_step(params, last_tok, positions, active)
        self._meter("serve_wa_decode")
        return out

    def decode_block(self, params, bucket, last_tok, positions, active,
                     remaining, eos):
        out = super().decode_block(params, bucket, last_tok, positions,
                                   active, remaining, eos)
        self._meter("serve_wa_decode_block")
        return out

    def routing_stats(self, decode_tokens: int) -> Dict[str, Any]:
        """The measured 'only embeddings move' numbers for ``run()`` stats:
        the per-token claim (2 hops × L × d_model for one row) plus the
        metered total across every dispatched program this run. Both are
        overlap-invariant: depth D routes D× as many hops each carrying
        B/D rows."""
        return {
            "routing_bytes_per_token": routing_bytes(self.api.config, 1,
                                                     self._el),
            "routing_total_bytes": int(self.routed_bytes),
            "routing_bytes_per_decode_token":
                float(self.routed_bytes / max(decode_tokens, 1)),
        }

    def overlap_stats(self, decode_time_s: float, macro_steps: int,
                      mb_live: int, mb_total: int) -> Dict[str, Any]:
        """Per-domain stall accounting for the sub-operator overlap
        schedule (DESIGN.md §3). The skewed schedule is STATIC, so each
        domain's idle ticks are exact schedule arithmetic
        (``core.pipeline.wa_schedule_occupancy``) — the measured decode
        wall-time per macro-step splits by those fractions into W-idle vs
        A-idle time, and ``overlap_efficiency`` is busy ticks over total
        (both domains): ~0.5 sequential, → 1 as depth grows.
        ``micro_batch_occupancy`` is the scheduler-view fraction of
        dispatched micro-batches that carried a live slot (a fully-idle
        micro-batch still executes — static programs dispatch all rows)."""
        occ = wa_schedule_occupancy(self.api.config.n_layers, self.overlap)
        step_ms = decode_time_s * 1e3 / max(macro_steps, 1)
        return {
            "overlap": self.overlap,
            "overlap_efficiency": occ["overlap_efficiency"],
            "schedule_ticks": occ["total_ticks"],
            "w_busy_ticks": occ["w_busy_ticks"],
            "a_busy_ticks": occ["a_busy_ticks"],
            "w_idle_ms_per_macro_step": step_ms * occ["w_idle_frac"],
            "a_idle_ms_per_macro_step": step_ms * occ["a_idle_frac"],
            "micro_batch_occupancy": float(mb_live / max(mb_total, 1)),
        }


BACKENDS: Dict[str, type] = {"colocated": ColocatedBackend, "wa": WABackend}


# ---------------------------------------------------------------------------
# KVArbiter — host-side placement arbiter for the tiered KV cache
# ---------------------------------------------------------------------------

class KVArbiter:
    """Host-side placement arbiter for the tiered KV cache (DESIGN.md §7).

    Demotion itself happens INSIDE the compiled programs — the read-side
    cold boundary advances with each slot's cursor, so no host round-trip
    ever moves a token between tiers. What remains for the host is pure
    accounting and policy, and that is this class: it observes per-slot
    cursors at the block boundaries the engine already syncs at (zero extra
    device traffic), derives tier occupancy from the same
    ``cold_boundary()`` arithmetic the programs compiled in, counts
    demotions from cursor watermarks, tracks live/peak KV bytes against an
    optional byte budget (the pressure loop preempts victims while over
    it), and recommends a placement policy from the observed access
    pattern (the LLaMCAT-style arbiter of the paper's §6 discussion).

    The byte model reads off the cache aval: a hot token costs the
    cache-resident dtype across every layer/head; a cold token costs the
    packed cold store (int4 packs two lanes per byte) plus its per-row
    f32 scales. ``cold_bytes_saved`` is live occupancy priced at the hot
    rate minus the cold rate — the bytes the LLC does NOT hold because the
    cold prefix is quantized."""

    def __init__(self, caches_aval: KVCache, budget_bytes: int = 0):
        if not caches_aval.is_tiered:
            raise ValueError("KVArbiter requires a tiered cache aval")
        self.hot_window = int(caches_aval.hot_window)
        self.cold_block = int(caches_aval.cold_block)
        self.cold_dtype = str(caches_aval.cold_dtype)
        self.budget = int(budget_bytes)
        L, B, n_kv, S, hd_c = caches_aval.k.shape
        H = caches_aval.hot_k.shape[3]
        hd = caches_aval.hot_k.shape[4]
        hot_el = jnp.dtype(caches_aval.hot_k.dtype).itemsize
        cold_el = jnp.dtype(caches_aval.k.dtype).itemsize
        scale_b = 0 if caches_aval.k_scale is None else\
            jnp.dtype(caches_aval.k_scale.dtype).itemsize
        # per-token rates, K + V, across all layers and KV heads
        self.hot_bytes_per_token = 2 * L * n_kv * hd * hot_el
        self.cold_bytes_per_token = 2 * L * n_kv * (hd_c * cold_el + scale_b)
        # allocated footprint of ONE slot (what fresh() reserves for it):
        # full-extent cold store + scales + the hot ring
        self.kv_bytes_per_slot = (S * self.cold_bytes_per_token
                                  + H * self.hot_bytes_per_token)
        self.n_slots = B
        self.reset()

    def reset(self):
        """Per-run accounting reset (mirrors the engine's accumulators)."""
        self._cursor: Dict[int, int] = {}
        self._watermark: Dict[int, int] = {}    # last-seen cold boundary
        self.demotions = 0                      # cold blocks crossed, total
        self.peak_bytes = 0
        self.peak_saved = 0
        self._last_rec = "no live slots observed"

    # -- bookkeeping (called at host-sync boundaries only) ---------------
    def _boundary(self, cursor: int) -> int:
        return int(cold_boundary(np.int32(cursor), self.hot_window,
                                 self.cold_block))

    def observe(self, slot: int, cursor: int):
        """One slot's cursor at a block boundary. Cold-boundary advances
        since the last observation count as demotions (one per crossed
        ``cold_block``)."""
        cursor = int(cursor)
        nb = self._boundary(cursor)
        prev = self._watermark.get(slot, 0)
        if nb > prev:
            self.demotions += (nb - prev) // self.cold_block
        self._watermark[slot] = nb
        self._cursor[slot] = cursor
        self.peak_bytes = max(self.peak_bytes, self.live_bytes())
        self.peak_saved = max(self.peak_saved, self.cold_bytes_saved())
        self._last_rec = self._recommend_live()

    def seed(self, slot: int, cursor: int):
        """Swap-in restore: the slot resumes at ``cursor`` with its cold
        prefix already staged and already COUNTED pre-preemption — seed the
        watermark so the restore recounts nothing."""
        cursor = int(cursor)
        self._watermark[slot] = self._boundary(cursor)
        self._cursor[slot] = cursor

    def release(self, slot: int):
        """Slot freed (retire / preempt / quarantine): its occupancy and
        watermark leave the live view; cumulative counters stay."""
        self._cursor.pop(slot, None)
        self._watermark.pop(slot, None)

    # -- occupancy / budget ----------------------------------------------
    def slot_occupancy(self, slot: int) -> Dict[str, int]:
        c = self._cursor.get(slot, 0)
        cold = self._boundary(c)
        hot = c - cold
        return {"slot": slot, "tokens": c, "hot_tokens": hot,
                "cold_tokens": cold,
                "kv_bytes": hot * self.hot_bytes_per_token
                + cold * self.cold_bytes_per_token}

    def live_bytes(self) -> int:
        """Occupancy-priced KV bytes across every live slot (hot tokens at
        the resident rate, cold tokens at the quantized rate)."""
        total = 0
        for c in self._cursor.values():
            cold = self._boundary(c)
            total += (c - cold) * self.hot_bytes_per_token\
                + cold * self.cold_bytes_per_token
        return total

    def cold_bytes_saved(self) -> int:
        saved_rate = self.hot_bytes_per_token - self.cold_bytes_per_token
        return sum(self._boundary(c) for c in self._cursor.values())\
            * saved_rate

    def over_budget(self) -> bool:
        return bool(self.budget) and self.live_bytes() > self.budget

    # -- policy -----------------------------------------------------------
    def recommend(self) -> str:
        """Placement recommendation from the observed pattern: deepen the
        quantized tier while the cold fraction dominates, surface the hot
        window when the working set already fits it. After a drained run
        (no live slots) the last live-boundary verdict stands."""
        return self._recommend_live() if self._cursor else self._last_rec

    def _recommend_live(self) -> str:
        cursors = list(self._cursor.values())
        if not cursors:
            return "no live slots observed"
        total = sum(cursors)
        cold = sum(self._boundary(c) for c in cursors)
        if cold == 0:
            return (f"working set fits hot_window={self.hot_window}; cold "
                    "tier idle — a smaller hot_window frees resident bytes")
        frac = cold / max(total, 1)
        if frac > 0.75 and self.cold_dtype == "int8":
            return ("cold tier dominates (>75% of tokens); int4 cold "
                    "storage would halve its footprint")
        if frac > 0.5 and self.cold_dtype == "bfloat16":
            return ("cold tier holds most tokens at full width; quantize "
                    "it (kv_cold_dtype=int8 or int4)")
        return "placement balanced for the observed access pattern"

    def stats(self) -> Dict[str, Any]:
        return {
            "hot_window": self.hot_window,
            "cold_block": self.cold_block,
            "cold_dtype": self.cold_dtype,
            "hot_bytes_per_token": self.hot_bytes_per_token,
            "cold_bytes_per_token": self.cold_bytes_per_token,
            "kv_bytes_per_slot": self.kv_bytes_per_slot,
            "kv_budget_bytes": self.budget,
            "demotions": self.demotions,
            "live_kv_bytes": self.live_bytes(),
            "peak_kv_bytes": self.peak_bytes,
            "cold_bytes_saved": max(self.peak_saved,
                                    self.cold_bytes_saved()),
            "per_slot": [self.slot_occupancy(s)
                         for s in sorted(self._cursor)],
            "recommendation": self.recommend(),
        }


# ---------------------------------------------------------------------------
# ServingEngine — the boundary loop connecting scheduler and executor
# ---------------------------------------------------------------------------

class ServingEngine:
    """Greedy decoding over fixed batch slots with per-slot admission.

    mode="continuous": slot-level scheduler (requires the ModelAPI slotted
    extensions); mode="drain": legacy drain-then-refill baseline;
    mode="auto": continuous when the family supports it.

    ``block_size`` (T): decode micro-steps per host round-trip. T == 1 is the
    per-step engine (one ``serve_decode`` program, one host sync per token);
    T > 1 runs ``ModelAPI.decode_block`` with on-device halt masks — one host
    sync per T tokens, admission at block boundaries only.

    ``prefill_chunk`` (C, continuous mode, families with
    ``ModelAPI.prefill_chunk``): admission runs as fixed-(1,C) prompt chunks,
    AT MOST ONE per block boundary, interleaved with the decode block — the
    chunked-prefill lane. Prompts carry TRUE lengths end to end: the decode
    cursor starts at the real prompt length and any length that fits the KV
    extent (prompt + max_new_tokens ≤ prompt_len + max_new_cap) is admitted
    chunk by chunk. 0 → monolithic admission (one full-width prefill program;
    prompts longer than ``prompt_len`` raise ``ValueError`` at submit —
    nothing is ever silently truncated).

    ``kv_bucket_chunk`` (block mode, KV-cache families): > 0 compiles one
    decode-block program per KV bucket (chunk multiples up to the cache
    extent) and picks the smallest covering bucket per macro-step on the
    host. 0 disables bucketing (single full-extent block program).

    ``debug_reset_slots``: zero a slot's cache state when its request
    retires (``ModelAPI.reset_slot``, one more AOT program). Never required
    for correctness — masked attention cannot read past a cursor — but keeps
    cache dumps clean and slot-state invariants checkable.

    ``backend``: the executor implementation. ``"colocated"`` (default)
    runs the family's own slotted programs; ``"wa"`` runs the SAME feature
    set — macro-step blocks, KV buckets, chunked prefill, slot admission —
    through the weight–attention disaggregated layer loop (``core/wa.py``,
    DESIGN.md §3): QKV/FFN under the W-domain rules, all slot state (KV
    writes, prefix reads, bucket slices) under the A-domain rules, with the
    per-layer W→A→W routing compiled INTO each step program. The scheduler
    is backend-agnostic; ``stats()["wa"]`` reports the measured W↔A routing
    bytes. Requires ``ModelAPI.wa_servable`` (prefix-ordered KV-cache
    transformers) and the continuous scheduler.

    ``a_shards``: split-KV flash decode width (KV-cache families,
    continuous mode). > 1 splits every slot's KV walk into that many equal
    contiguous sequence shards; each shard computes partial softmax
    statistics (running max, normalizer, un-normalized accumulator) and the
    shards recombine through the LSE merge (``kernels/flash_decode/
    combine.py``) — token-exact vs the sequential walk. Under
    ``backend="wa"`` on a mesh the shard axis is the A-domain model axis
    (``seq_sharded_kv``), so attention latency scales with A-width; on the
    colocated backend (and any single-device run) the same math runs
    unsharded. The KV extent (prompt_len + max_new_cap) must divide by
    ``a_shards``; bucket sets are rounded so every bucket splits evenly.
    Program names do not change — the shard count is a build-time static
    baked into the same programs, so compiles == 1 still holds per bucket.

    ``overlap``: sub-operator micro-batch pipelining of the W/A boundary
    (WA backend only, DESIGN.md §3). > 1 splits each decode dispatch's
    batch into that many equal micro-batches and software-pipelines them
    through the routed layer loop with skewed layer indices
    (``core/wa.py::_layer_loop_pipelined``): W runs QKV/FFN for one
    micro-batch while A attends another — true sub-operator dependencies
    instead of a per-layer W→A→W barrier. Token-exact at every depth,
    program names unchanged (the depth is a build-time static; depth 1
    compiles today's exact sequential programs), composes with macro-step
    blocks, KV buckets, split-KV ``a_shards``, chunked prefill and the
    preemption swap pair (the swap programs are cache-only — no layer
    loop, nothing to pipeline). ``batch_slots`` must divide by
    ``overlap``. ``stats()['wa']`` reports the schedule's per-domain
    stall accounting (W-idle / A-idle per macro-step, overlap
    efficiency).

    ``preemptible``: compile the token-exact swap pair
    (``serve_[wa_]swap_out`` / ``serve_[wa_]swap_in``) and allow the
    boundary loop to preempt a decoding slot — swap its true-length KV to
    a host-side buffer, free the slot for higher-priority work (or under
    injected KV pressure), and restore later with cursors intact. Requires
    the continuous scheduler and a prefix-ordered non-windowed KV-cache
    family. Restored sequences are byte-identical to uninterrupted ones.

    ``max_queue``: bounded-queue backpressure. > 0 sheds the
    lowest-priority (then most recently enqueued) queued request as a
    structured rejection whenever the queue exceeds the bound — overload
    degrades to explicit rejections, not unbounded queueing.

    ``max_retries`` / ``retry_backoff_s`` / ``watchdog_s``: dispatch
    hardening. Every program dispatch retries up to ``max_retries`` times
    on ``DispatchError`` (with exponential backoff when ``retry_backoff_s``
    > 0); a dispatch exceeding ``watchdog_s`` wall-clock bumps the watchdog
    counter. A dispatch that exhausts its budget demotes the responsible
    request to a structured rejection and quarantines the slot whose cache
    bytes are suspect (``stats()['quarantined_slots']``).

    ``strict_invariants``: run the scheduler's occupancy/cursor invariant
    check at every block boundary (the chaos harness turns this on);
    violations raise ``AssertionError`` immediately.

    ``fault_injector``: deterministic chaos hook
    (``repro.runtime.faults.FaultInjector`` or compatible). Its
    ``on_dispatch(name)`` is installed as the ``StaticRuntime`` dispatch
    interceptor for the run (slow/failed dispatches); its
    ``slots_held(step)`` models artificial KV pressure — that many slots
    are withheld at each boundary, preempting victims when preemptible.

    An engine instance may be ``run()`` repeatedly: per-run accumulators
    (timings, sync counts, queues) reset and the slot caches are allocated
    fresh each run, while the AOT-compiled programs persist (compiles == 1
    across every run of the engine's lifetime).
    """

    def __init__(self, api: ModelAPI, ctx: ShardingCtx, batch_slots: int,
                 prompt_len: int, runtime: Optional[StaticRuntime] = None,
                 greedy: bool = True, mode: str = "auto",
                 max_new_cap: int = DECODE_SLACK,
                 block_size: int = 1, kv_bucket_chunk: int = 0,
                 prefill_chunk: int = 0,
                 debug_reset_slots: bool = False,
                 backend: str = "colocated", a_shards: int = 1,
                 overlap: int = 1,
                 preemptible: bool = False, max_queue: int = 0,
                 max_retries: int = 2, retry_backoff_s: float = 0.0,
                 watchdog_s: float = 0.0,
                 strict_invariants: bool = False,
                 fault_injector: Optional[Any] = None,
                 kv_budget_bytes: int = 0):
        if mode not in ("auto", "continuous", "drain"):
            raise ValueError(mode)
        if a_shards < 1:
            raise ValueError(f"a_shards must be >= 1, got {a_shards}")
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; choose from "
                             f"{sorted(BACKENDS)}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if prefill_chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0, got {prefill_chunk}")
        if overlap < 1:
            raise ValueError(f"overlap must be >= 1, got {overlap}")
        if overlap > 1:
            # sub-operator pipelining splits the decode batch into overlap
            # micro-batches and skews them across the W/A boundary — it
            # needs that boundary (the WA backend) and equal micro-batches
            if backend != "wa":
                raise ValueError(
                    f"overlap={overlap} pipelines the W/A boundary; the "
                    f"{backend} backend has no W↔A hops to overlap "
                    "(use backend='wa', DESIGN.md §3)")
            if batch_slots % overlap:
                raise ValueError(
                    f"batch_slots={batch_slots} does not divide into "
                    f"overlap={overlap} equal micro-batches")
        if backend == "wa":
            # the WA backend carries its own decode/admission programs
            # (core/wa.py) — it needs the continuous scheduler and a family
            # whose KV the W/A split can decouple (DESIGN.md §6)
            if mode == "drain":
                raise ValueError("the WA backend serves through the "
                                 "continuous scheduler; drain mode is "
                                 "colocated-only")
            if not api.wa_servable:
                raise ValueError(
                    f"{api.config.family} family has no WA-disaggregated "
                    "serving support (DESIGN.md §6)")
            resolved_mode = "continuous"
        else:
            # continuous mode needs a decode half (api.decode_block for
            # T > 1, api.decode_slotted for T == 1) AND an admission half
            # (api.prefill_chunk for the chunked lane, api.write_slot for
            # monolithic admission)
            decode_ok = (api.decode_block is not None if block_size > 1 else
                         api.decode_slotted is not None)
            if mode == "auto" and prefill_chunk > 0\
                    and api.prefill_chunk is None:
                # fall back to monolithic admission — LOUDLY: a benchmark
                # config that asked for the chunk lane must not quietly
                # measure the monolithic one
                warnings.warn(
                    f"prefill_chunk={prefill_chunk} requested but the "
                    f"{api.config.family} family has no prefill_chunk "
                    "support; falling back to monolithic admission (the "
                    "chunked-prefill lane is OFF for this engine)",
                    UserWarning, stacklevel=2)
                prefill_chunk = 0
            admit_ok = (api.prefill_chunk is not None if prefill_chunk > 0
                        else api.write_slot is not None)
            slotted_ok = admit_ok and decode_ok
            if mode == "continuous" and not slotted_ok:
                raise ValueError(
                    f"{api.config.family} family has no "
                    f"{'chunked-prefill' if prefill_chunk > 0 else 'slotted'} "
                    "serving support")
            if mode == "drain" and prefill_chunk > 0:
                raise ValueError("chunked prefill requires the continuous "
                                 "scheduler (drain prefills the whole batch)")
            resolved_mode = ("continuous" if slotted_ok else "drain")\
                if mode == "auto" else mode
        self.api = api
        self.ctx = ctx
        self.slots = batch_slots
        self.prompt_len = prompt_len
        self.max_new_cap = min(max_new_cap, DECODE_SLACK)
        self.mode = resolved_mode
        self.backend = backend
        if self.mode == "drain":
            prefill_chunk = 0                    # auto fallback: no lane
        self.block_size = block_size
        self.kv_bucket_chunk = kv_bucket_chunk
        self.prefill_chunk = prefill_chunk
        self.a_shards = a_shards
        self.overlap = overlap
        self.debug_reset_slots = debug_reset_slots
        self.preemptible = preemptible
        self.max_queue = max_queue
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.watchdog_s = watchdog_s
        self.strict_invariants = strict_invariants
        self.fault_injector = fault_injector
        self.rt = runtime or StaticRuntime()
        self.queue: List[Request] = []
        self._params = None
        self._ex: Optional[ExecutorBackend] = None
        # the ONE derivation of the slot-cache aval: the executor compiles
        # against it and the KV-extent admission bound reads off it
        # (None extent → no length axis to bound, e.g. recurrent state)
        self._caches_aval = jax.eval_shape(
            lambda: api.init_caches(batch_slots,
                                    prompt_len + self.max_new_cap))
        self._kv_extent = self._caches_aval.k.shape[3]\
            if isinstance(self._caches_aval, KVCache)\
            and not self._caches_aval.window else None
        self._tiered = isinstance(self._caches_aval, KVCache)\
            and self._caches_aval.is_tiered
        if self._tiered:
            # the tiered cache stages its cold prefix inside the chunk
            # program — only the continuous scheduler has one, and only
            # families exposing prefill_chunk can compile it (monolithic
            # tiered admission is the degenerate full-width chunk)
            if self.mode != "continuous":
                raise ValueError(
                    "tiered KV caches (hot_window > 0) serve through the "
                    "continuous scheduler; drain mode has no chunk program "
                    "to stage the cold tier")
            if api.prefill_chunk is None:
                raise ValueError(
                    f"{api.config.family} family has no prefill_chunk "
                    "support; tiered admission stages the cold tier "
                    "through the chunk program")
        if kv_budget_bytes < 0:
            raise ValueError(
                f"kv_budget_bytes must be >= 0, got {kv_budget_bytes}")
        if kv_budget_bytes and not self._tiered:
            raise ValueError(
                "kv_budget_bytes is the tiered-KV arbiter's pressure knob "
                "(hot_window > 0); flat caches have no arbiter to enforce "
                "it")
        self.kv_budget_bytes = kv_budget_bytes
        self._arbiter = KVArbiter(self._caches_aval, kv_budget_bytes)\
            if self._tiered else None
        if self.a_shards > 1:
            # split-KV flash decode shards the *prefix-ordered* KV walk of
            # one slot along the sequence axis; families without such a
            # cache (recurrent state, ring windows) have nothing to shard
            if self.mode == "drain":
                raise ValueError("split-KV decode (a_shards > 1) runs "
                                 "through the slotted decode programs; "
                                 "drain mode has none")
            if self._kv_extent is None:
                raise ValueError(
                    f"a_shards={self.a_shards} requires a prefix-ordered "
                    "(non-windowed) KV-cache family; the "
                    f"{api.config.family} family has no KV sequence axis "
                    "to shard")
            if self._kv_extent % self.a_shards:
                raise ValueError(
                    f"KV extent {self._kv_extent} (prompt_len + "
                    "max_new_cap) not divisible by a_shards="
                    f"{self.a_shards}; every shard must own an equal "
                    "contiguous block")
        if self.prefill_chunk and isinstance(self._caches_aval, KVCache)\
                and self._caches_aval.window:
            raise ValueError("chunked prefill requires a non-windowed KV "
                             "cache (ring order has no per-position write "
                             "offset)")
        if self.prefill_chunk and self._kv_extent is not None\
                and self.prefill_chunk > self._kv_extent:
            raise ValueError(
                f"prefill_chunk={self.prefill_chunk} exceeds the KV extent "
                f"{self._kv_extent}; the fixed (1,C) window must fit the "
                "cache")
        if self.preemptible:
            # swap-out/restore slices one slot of a prefix-ordered KV
            # cache at its true length — recurrent states and ring windows
            # have no such slice, drain mode has no slot scheduler
            if self.mode != "continuous":
                raise ValueError("preemptible serving requires the "
                                 "continuous scheduler (drain has no slots "
                                 "to swap)")
            if self._kv_extent is None:
                raise ValueError(
                    f"preemptible=True requires a prefix-ordered "
                    "(non-windowed) KV-cache family; the "
                    f"{api.config.family} family has no slot KV extent to "
                    "swap out")
        self._reset_per_run()

    # ------------------------------------------------------------------
    def _reset_per_run(self):
        """Per-run accumulators. An engine reused across ``run()`` calls
        must not leak timing samples or sync counts from a previous run
        (stats would blend workloads), and the executor's caches from a
        finished run must never seed the next one (stale KV in freed
        slots)."""
        self.tpot_samples: List[float] = []
        self.host_syncs = 0
        self._decode_tokens = 0
        self._decode_time = 0.0
        self._prefill_time = 0.0
        self._prefill_chunks = 0
        self._block_tokens: List[int] = []
        self._macro_steps = 0
        # micro-batch occupancy under overlap > 1 (scheduler view)
        self._micro_batches_live = 0
        self._micro_batches_total = 0
        self.queue = []
        # pressure/robustness accounting (DESIGN.md §7 failure model)
        self._rejected: List[Request] = []
        self._deadline_missed: List[Request] = []
        self._preemptions = 0
        self._restores = 0
        self._retries = 0
        self._watchdog_timeouts = 0
        self._swap_time = 0.0
        self._quarantined: set = set()
        # emission log: (rid, token_index) in host-visible order — the
        # chaos invariant checker proves no token was duplicated, lost or
        # reordered from this alone
        self._emit_log: List[Tuple[int, int]] = []
        self._cursor_watermark: Dict[int, int] = {}
        self._slot_cap = self.slots
        if self._arbiter is not None:
            self._arbiter.reset()

    def _emit_token(self, r: Request, tok: int):
        r.generated.append(int(tok))
        self._emit_log.append((r.rid, len(r.generated) - 1))

    def _finish(self, r: Request, now: float):
        r.status = "completed"
        r.t_done = now

    def _reject(self, r: Request, reason: str):
        r.status = "rejected"
        r.reject_reason = reason
        r.t_done = time.monotonic()
        r.swap = None                    # drop any held KV image
        self._rejected.append(r)

    def _miss_deadline(self, r: Request, reason: str):
        r.status = "deadline_missed"
        r.reject_reason = reason
        r.t_done = time.monotonic()
        r.swap = None
        self._deadline_missed.append(r)

    # -- hardened dispatch ---------------------------------------------
    def _dispatch(self, name: str, fn, *args):
        """Bounded retry-with-backoff around one program dispatch.
        ``DispatchError`` is raised by the interceptor layer BEFORE the
        compiled call touches its operands (donated buffers still valid),
        so the dispatch retries verbatim; exhausting the budget raises
        ``DispatchFailure`` for the boundary loop to demote to a structured
        rejection. Any other exception is a real bug and propagates. A
        dispatch exceeding ``watchdog_s`` wall-clock bumps the watchdog
        counter (the work DID run — JAX cannot cancel an in-flight
        dispatch — so the watchdog detects and records stalls rather than
        aborting them)."""
        attempt = 0
        while True:
            t0 = time.monotonic()
            try:
                out = fn(*args)
            except DispatchError as e:
                if attempt >= self.max_retries:
                    raise DispatchFailure(name, attempt + 1, e) from e
                attempt += 1
                self._retries += 1
                if self.retry_backoff_s:
                    time.sleep(self.retry_backoff_s * (2 ** (attempt - 1)))
                continue
            if self.watchdog_s and time.monotonic() - t0 > self.watchdog_s:
                self._watchdog_timeouts += 1
            return out

    def _quarantine_slot(self, sched: SlotScheduler, slot: int):
        sched.quarantined.add(slot)
        self._quarantined.add(slot)

    def _host_sync(self, *arrays):
        """THE counted device→host round-trip of the decode loop — the
        coordination cost the macro-step engine amortizes (1 sync per
        ``block_size`` tokens). Tests assert on ``self.host_syncs``."""
        self.host_syncs += 1
        out = tuple(np.asarray(a) for a in arrays)
        return out if len(out) > 1 else out[0]

    def load(self, params):
        self._params = params

    def _validate_request(self, r: Request):
        """Admission-time length contract — the silent-truncation fix: a
        prompt the engine cannot represent is REJECTED here, never cut.
        Raises ``RequestRejected`` (a ``ValueError``) carrying the request
        id, the offending length and the per-mode limit as fields, so a
        fleet log can say WHICH knob the request overflowed."""
        L = len(r.prompt)
        if L == 0:
            raise RequestRejected(r.rid, "empty prompt", length=0,
                                  limit=1, limit_name="min prompt length")
        if r.max_new_tokens < 1:
            raise RequestRejected(
                r.rid,
                f"max_new_tokens={r.max_new_tokens} must be >= 1 (every "
                "admission produces a first token)",
                length=r.max_new_tokens, limit=1,
                limit_name="min max_new_tokens")
        if r.max_new_tokens > self.max_new_cap:
            raise RequestRejected(
                r.rid,
                f"max_new_tokens={r.max_new_tokens} exceeds cache slack "
                f"{self.max_new_cap} (raise max_new_cap)",
                length=r.max_new_tokens, limit=self.max_new_cap,
                limit_name="max_new_cap")
        if self.mode == "drain" or not self.prefill_chunk:
            if L > self.prompt_len:
                raise RequestRejected(
                    r.rid,
                    f"prompt length {L} exceeds the static prompt width "
                    f"{self.prompt_len} (monolithic admission) and would "
                    "be silently truncated; raise prompt_len or enable the "
                    "chunked-prefill lane (prefill_chunk > 0)",
                    length=L, limit=self.prompt_len,
                    limit_name="prompt_len")
        elif self._kv_extent is not None\
                and L + r.max_new_tokens > self._kv_extent:
            raise RequestRejected(
                r.rid,
                f"prompt length {L} + max_new_tokens={r.max_new_tokens} "
                f"= {L + r.max_new_tokens} exceeds the KV extent "
                f"{self._kv_extent} (chunked admission; raise prompt_len "
                "or max_new_cap)",
                length=L + r.max_new_tokens, limit=self._kv_extent,
                limit_name="kv_extent")

    def submit(self, req: Request):
        self._validate_request(req)
        req.t_enqueue = time.monotonic()
        req.status = "queued"
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _prepare(self, params):
        if self._ex is None:
            self._ex = BACKENDS[self.backend](
                self.api, self.ctx, self.rt, params, self._caches_aval,
                mode=self.mode,
                slots=self.slots, prompt_len=self.prompt_len,
                max_new_cap=self.max_new_cap, block_size=self.block_size,
                kv_bucket_chunk=self.kv_bucket_chunk,
                prefill_chunk=self.prefill_chunk,
                debug_reset_slots=self.debug_reset_slots,
                a_shards=self.a_shards, overlap=self.overlap,
                preemptible=self.preemptible)

    def run(self, params, requests: List[Request],
            max_steps: int = 10_000) -> Dict[str, Any]:
        """Serve all requests to completion; returns latency stats.
        Requests enqueued via ``submit()`` before this call are served too
        (never silently dropped). Reusable: each call starts from fresh
        caches and fresh accumulators (AOT programs persist — zero
        recompilation across runs)."""
        self.load(params)
        pre = list(self.queue)
        seen = {id(r) for r in pre}
        requests = pre + [r for r in requests if id(r) not in seen]
        for r in requests:
            self._validate_request(r)
        self._prepare(params)
        self._reset_per_run()
        # fault-injection hook: installed (or cleared) per run so a clean
        # reference run on the same engine sees zero injected faults
        self.rt.set_interceptor(
            getattr(self.fault_injector, "on_dispatch", None)
            if self.fault_injector is not None else None)
        if self.mode == "continuous":
            return self._run_continuous(params, requests, max_steps)
        return self._run_drain(params, requests, max_steps)

    # ------------------------------------------------------------------
    # continuous scheduler: ONE boundary loop for T == 1 and T > 1,
    # monolithic and chunked admission
    # ------------------------------------------------------------------

    def _run_continuous(self, params, requests, max_steps):
        T = self.block_size
        ex = self._ex
        ex.fresh()
        sched = SlotScheduler(self.slots, requests, self.queue)
        self._sched = sched
        done: List[Request] = []
        steps = admissions = overlapped = 0
        s_max = self.prompt_len + self.max_new_cap
        while sched.work_remaining():
            if steps >= max_steps:
                break
            sched.pump(steps)
            if sched.usable_capacity() == 0:
                # every slot quarantined: nothing can ever be admitted
                # again — demote ALL remaining work to structured
                # rejections instead of spinning to max_steps
                for r in sched.pending + sched.queue:
                    self._reject(r, "no usable slots (all quarantined)")
                sched.pending.clear()
                sched.queue.clear()
                break
            self._shed_deadlines(sched)
            self._bound_queue(sched)
            self._apply_pressure(sched, steps)
            self._apply_kv_budget(sched)
            self._priority_preempt(sched)
            # "overlapped" = admitted while the batch was already live at
            # the start of this boundary (cold-start fills don't count)
            batch_live = sched.occupied()
            if self.prefill_chunk:
                while True:
                    n_adm, n_ovl, fin = self._admission_phase(
                        params, sched, steps, batch_live)
                    admissions += n_adm
                    overlapped += n_ovl
                    done.extend(fin)
                    done.extend(self._advance_chunk_lane(params, sched))
                    # the one-chunk-per-boundary throttle exists to bound
                    # the stall inflicted on LIVE decoders; with none live
                    # there is nothing to protect — keep chunking so a
                    # cold start does not serialize admission
                    if sched.decode_active().any() or not sched.prefill_fifo:
                        break
            else:
                n_adm, n_ovl, fin = self._admission_phase(
                    params, sched, steps, batch_live)
                admissions += n_adm
                overlapped += n_ovl
                done.extend(fin)
            self._observe_tiers(sched)
            if self.strict_invariants:
                self._assert_invariants(sched)
            active = sched.decode_active()
            if not active.any():
                steps += 1                       # idle/prefill-only boundary
                continue
            done.extend(self._decode_round(params, sched, active, s_max))
            self._observe_tiers(sched)
            steps += T
        self._caches = ex.caches
        return self._stats(done, steps, admissions, overlapped)

    # -- pressure / SLO policies ---------------------------------------
    def _shed_deadlines(self, sched: SlotScheduler):
        """A queued request whose TTFT deadline already expired can only
        miss — shed it NOW as deadline_missed (terminal, structured)
        instead of wasting a slot on it. Preempted requests already
        produced their first token and are never TTFT-shed."""
        now = time.monotonic()
        for r in list(sched.queue):
            if r.ttft_deadline_ms > 0 and not r.generated\
                    and (now - r.t_enqueue) * 1e3 > r.ttft_deadline_ms:
                sched.queue.remove(r)
                self._miss_deadline(
                    r, f"ttft_deadline_ms={r.ttft_deadline_ms:g} expired "
                       "in queue")

    def _bound_queue(self, sched: SlotScheduler):
        """Bounded-queue backpressure: shed the lowest-priority (then most
        recently enqueued) request while the queue exceeds ``max_queue``.
        Preempted requests (holding swapped-out KV and emitted tokens) are
        shed only when nothing else is left."""
        if not self.max_queue:
            return
        while len(sched.queue) > self.max_queue:
            pool = [r for r in sched.queue if r.swap is None]\
                or list(sched.queue)
            v = min(pool, key=lambda r: (r.priority, -r.t_enqueue, -r.rid))
            sched.queue.remove(v)
            self._reject(v, f"queue_full (max_queue={self.max_queue})")

    def _pick_victim(self, sched: SlotScheduler) -> Optional[int]:
        """Lowest-priority decoding slot; most recently admitted within a
        priority class (least sunk work — its wait already counted and it
        re-admits first among equals)."""
        victims = sched.decode_slots()
        if not victims:
            return None
        return min(victims, key=lambda i: (sched.req[i].priority,
                                           -sched.req[i].t_admitted))

    def _apply_pressure(self, sched: SlotScheduler, steps: int):
        """Artificial KV pressure from the fault injector: ``slots_held``
        slots are withheld this boundary — preempt decoding victims until
        the occupancy fits the reduced capacity, and hold admissions to the
        same cap (``_slot_cap``) so the boundary doesn't immediately
        restore what it just swapped out."""
        self._slot_cap = self.slots
        inj = self.fault_injector
        if inj is None or not self.preemptible:
            return
        held_fn = getattr(inj, "slots_held", None)
        if held_fn is None:
            return
        cap = max(0, self.slots - int(held_fn(steps)))
        self._slot_cap = cap
        for _ in range(self.slots):
            busy = sum(1 for p in sched.phase if p != sched.FREE)
            if busy <= cap:
                break
            v = self._pick_victim(sched)
            if v is None or not self._preempt_slot(sched, v):
                break

    def _apply_kv_budget(self, sched: SlotScheduler):
        """Real (not injected) KV pressure: while the arbiter's
        occupancy-priced live bytes exceed ``kv_budget_bytes``, preempt the
        usual lowest-priority victim; if preemption cannot get under the
        budget (or the engine is not preemptible), hold admissions this
        boundary instead — over-budget occupancy must never grow."""
        arb = self._arbiter
        if arb is None or not arb.budget:
            return
        self._observe_tiers(sched)
        while self.preemptible and arb.over_budget():
            v = self._pick_victim(sched)
            if v is None or not self._preempt_slot(sched, v):
                break
        if arb.over_budget():
            busy = sum(1 for p in sched.phase if p != sched.FREE)
            self._slot_cap = min(self._slot_cap, busy)

    def _observe_tiers(self, sched: SlotScheduler):
        """Sync the arbiter's per-slot cursor view at a host boundary: live
        decoders report their cursor (demotions count off the cold-boundary
        watermark), freed slots leave the live view. Pure host arithmetic —
        no device traffic."""
        arb = self._arbiter
        if arb is None:
            return
        for i in range(sched.n):
            if sched.phase[i] == sched.DECODE:
                arb.observe(i, int(sched.positions[i]))
            elif sched.phase[i] == sched.FREE:
                arb.release(i)

    def _priority_preempt(self, sched: SlotScheduler):
        """Priority lane: while the queue's best request outranks the
        lowest-priority decoding slot and no usable slot is free, swap the
        victim out (a block boundary is the ONLY preemption point — KV is
        consistent there, mid-block it is not host-visible)."""
        if not self.preemptible:
            return
        for _ in range(self.slots):
            if not sched.queue or sched.usable_free() is not None:
                break
            head = sched.top_priority()
            v = self._pick_victim(sched)
            if v is None or sched.req[v].priority >= head:
                break
            if not self._preempt_slot(sched, v):
                break

    def _preempt_slot(self, sched: SlotScheduler, slot: int) -> bool:
        """Token-exact swap-out of one decoding slot: export the stored
        bytes (read-only program — a failed dispatch leaves the victim
        decoding), host the image + cursor triple on the request, free the
        slot and requeue. False if the swap-out dispatch failed."""
        ex = self._ex
        r = sched.req[slot]
        t0 = time.monotonic()
        try:
            saved = self._dispatch(ex.program_prefix + "swap_out",
                                   ex.swap_out, slot)
        except DispatchFailure:
            return False                 # victim keeps its slot
        saved = tuple(None if a is None else np.asarray(a) for a in saved)
        self._swap_time += time.monotonic() - t0
        r.swap = SwapState(saved=saved,
                           kv_len=int(sched.positions[slot]),
                           last_tok=int(sched.last_tok[slot]),
                           remaining=int(sched.remaining[slot]))
        r.preemptions += 1
        self._preemptions += 1
        sched.preempt(slot)
        if self._arbiter is not None:
            self._arbiter.release(slot)
        return True

    def _restore(self, params, sched: SlotScheduler, slot: int,
                 r: Request) -> bool:
        """Swap a preempted request back in: masked true-length write of
        its host image, then resume decode with the saved cursor triple —
        byte-identical to never having been preempted."""
        ex = self._ex
        st = r.swap
        t0 = time.monotonic()
        try:
            self._dispatch(ex.program_prefix + "swap_in", ex.swap_in,
                           st.saved, slot, st.kv_len)
        except DispatchFailure as e:
            # the restore never touched the device (DispatchError fires
            # pre-call): the slot stays clean and FREE; the request is
            # demoted to a structured rejection
            self._reject(r, f"dispatch_failed:{e.name}")
            return False
        self._swap_time += time.monotonic() - t0
        r.swap = None
        sched.resume_decode(slot, r, st)
        if self._arbiter is not None:
            # the restored prefix's demotions were counted pre-preemption —
            # seed the watermark so nothing is recounted
            self._arbiter.seed(slot, st.kv_len)
        self._restores += 1
        return True

    # -- admission ------------------------------------------------------
    def _admission_phase(self, params, sched: SlotScheduler, steps: int,
                         batch_live: bool):
        """Drain the queue into usable free slots in priority order. A
        preempted request re-enters DECODE directly through the swap-in
        program (no prefill — its KV and cursors are the saved ones); a
        fresh request enters the chunk lane (PREFILL) or admits
        monolithically. Returns (fresh admissions, overlapped, finished)."""
        admissions = overlapped = 0
        finished: List[Request] = []
        while True:
            busy = sum(1 for p in sched.phase if p != sched.FREE)
            if busy >= self._slot_cap:
                break                    # injected KV pressure holds slots
            slot = sched.usable_free()
            if slot is None:
                break
            r = sched.pop_queue()
            if r is None:
                break
            if r.swap is not None:
                self._restore(params, sched, slot, r)
                continue
            admissions += 1
            if batch_live:
                overlapped += 1
            if self.prefill_chunk:
                sched.begin_prefill(slot, r, steps)
            else:
                finished.extend(self._admit_one_monolithic(
                    params, sched, slot, r, steps))
        return admissions, overlapped, finished

    def _admit_one_monolithic(self, params, sched: SlotScheduler, slot: int,
                              r: Request, steps: int) -> List[Request]:
        """Full-width batch-1 prefill + slot write (the pre-chunking
        admission path, kept as the measured baseline). Prompts are
        zero-padded up to ``prompt_len`` — never truncated (submit rejects
        longer) — and the cursor starts at the padded width (the padding IS
        attended; the chunked lane is the length-true path). A one-token
        request (instant EOS / budget 1) finishes AT admission and frees
        the slot for the caller's loop to reuse this same boundary."""
        ex = self._ex
        r.t_admitted = time.monotonic()
        r.admit_step = steps
        r.status = "active"
        sched.req[slot] = r
        t0 = time.monotonic()
        try:
            first = self._dispatch(
                ex.program_prefix + "admit", ex.admit_full, params,
                pad_row(r.prompt, self.prompt_len), slot)
        except DispatchFailure as e:
            self._demote_admission(sched, slot, r, e)
            return []
        first.block_until_ready()
        now = time.monotonic()
        self._prefill_time += now - t0
        r.t_first_token = now
        r.note_emit(now)
        self._emit_token(r, np.asarray(first)[0])
        if r.done:
            self._finish(r, now)
            sched.req[slot] = None
            # the admit DID write its prompt KV — zero it like any other
            # retirement so dumps stay clean
            self._safe_reset(sched, slot)
            return [r]
        sched.start_decode(slot, self.prompt_len, r.generated[-1])
        return []

    def _demote_admission(self, sched: SlotScheduler, slot: int, r: Request,
                          exc: DispatchFailure):
        """An admission dispatch exhausted its retries: the slot's cache
        bytes are suspect (the prompt may be partially written), so the
        request demotes to a structured rejection and the slot is
        quarantined — one poisoned request costs one slot, not the
        engine."""
        self._reject(r, f"dispatch_failed:{exc.name}")
        sched.req[slot] = None
        sched.phase[slot] = sched.FREE
        if slot in sched.prefill_fifo:
            sched.prefill_fifo.remove(slot)
        self._quarantine_slot(sched, slot)

    def _safe_reset(self, sched: SlotScheduler, slot: int):
        """Debug slot zeroing, hardened: a reset that keeps failing
        quarantines the slot (its bytes are unknown) instead of killing
        the serve."""
        if not self._ex.has_reset:
            return
        try:
            self._dispatch("serve_reset", self._ex.reset, slot)
        except DispatchFailure:
            self._quarantine_slot(sched, slot)

    def _assert_invariants(self, sched: SlotScheduler):
        bad = sched.invariant_violations()
        for i in range(sched.n):
            r = sched.req[i]
            if r is None or sched.phase[i] != sched.DECODE:
                continue
            wm = self._cursor_watermark.get(r.rid, -1)
            pos = int(sched.positions[i])
            if pos < wm:
                bad.append(f"rid {r.rid}: cursor moved backwards "
                           f"{wm} -> {pos}")
            self._cursor_watermark[r.rid] = pos
        if bad:
            raise AssertionError("scheduler invariant violation(s): "
                                 + "; ".join(bad))

    # -- admission: chunked-prefill lane -------------------------------
    def _advance_chunk_lane(self, params, sched: SlotScheduler):
        """Run AT MOST ONE fixed-shape prefill chunk this boundary (the
        admitting slot at the head of the FIFO). In-flight decoders stall
        for one chunk, not one prompt; the final chunk's logits are the
        request's first token and flip the slot to the decode phase with
        its cursor at the TRUE prompt length."""
        ex = self._ex
        job = sched.next_chunk(self.prefill_chunk, self._kv_extent)
        if job is None:
            return []
        slot, r, start, n_valid = job
        row = pad_row(r.prompt[start:start + n_valid], self.prefill_chunk)
        t0 = time.monotonic()
        try:
            tok = self._dispatch(ex.program_prefix + "prefill_chunk",
                                 ex.run_chunk, params, row, slot, start,
                                 n_valid)
        except DispatchFailure as e:
            # the slot may hold a partially-written prompt — demote the
            # request, quarantine the slot (drops it from the FIFO too)
            self._demote_admission(sched, slot, r, e)
            return []
        first = np.asarray(tok)                   # blocks: chunk wall-time
        now = time.monotonic()
        self._prefill_time += now - t0
        self._prefill_chunks += 1
        finished: List[Request] = []
        if sched.chunk_done(slot, start, n_valid):
            r.t_first_token = now
            r.note_emit(now)
            self._emit_token(r, first[0])
            if r.done:
                self._finish(r, now)
                finished.append(r)
                sched.retire(slot)
                self._safe_reset(sched, slot)
            else:
                sched.start_decode(slot, len(r.prompt), r.generated[-1])
        return finished

    # -- decode round ---------------------------------------------------
    def _demote_decode(self, sched: SlotScheduler, finished: List[Request],
                       exc: DispatchFailure) -> np.ndarray:
        """A decode dispatch exhausted its retries. The fault is the
        DISPATCH, not an identifiable request — demote the lowest-priority
        decoding victim (least lost work among the suspects), quarantine
        its slot, and hand back the shrunken active mask so the caller can
        retry the round for the survivors. Survivor KV is intact: the
        failed dispatch never touched its (donated) operands."""
        v = self._pick_victim(sched)
        if v is not None:
            self._reject(sched.req[v], f"dispatch_failed:{exc.name}")
            sched.retire(v)
            self._quarantine_slot(sched, v)
        return sched.decode_active()

    def _decode_round(self, params, sched: SlotScheduler, active, s_max):
        """One decode dispatch + ONE counted host sync: a single slotted
        step (T == 1) or a T-micro-step block with on-device halting. A
        dispatch that exhausts its retry budget sheds one victim and
        retries for the survivors — a poisoned round degrades to one
        structured rejection, never a hung engine."""
        T = self.block_size
        ex = self._ex
        finished: List[Request] = []
        if ex.overlap > 1:
            # scheduler-view micro-batch occupancy (single source of truth
            # with the layer loop's row split: micro_batch_slices) — a
            # fully-idle micro-batch still dispatches, so this measures
            # how much of the pipelined work carried live slots
            for _slots, act in sched.micro_batch_view(ex.overlap, active):
                self._micro_batches_total += 1
                self._micro_batches_live += bool(act.any())
        if T == 1:
            while True:
                t0 = time.monotonic()
                try:
                    nxt, new_pos = self._dispatch(
                        ex.program_prefix + "decode", ex.decode_step,
                        params, sched.last_tok, sched.positions, active)
                except DispatchFailure as e:
                    active = self._demote_decode(sched, finished, e)
                    if not active.any():
                        return finished
                    continue
                break
            nxt, new_pos = self._host_sync(nxt, new_pos)
            dt = time.monotonic() - t0
            self.tpot_samples.append(dt)
            self._decode_time += dt
            n_tok = int(active.sum())
            sched.positions = new_pos.copy()
            sched.last_tok = nxt.copy()
            now = time.monotonic()
            for i, r in enumerate(sched.req):
                if r is None or sched.phase[i] != sched.DECODE:
                    continue
                self._emit_token(r, nxt[i])
                # host-side budget mirror (the device manages it only in
                # block mode) — keeps SwapState and the invariant checker
                # uniform across T
                sched.remaining[i] -= 1
                r.note_emit(now)
                if r.done:
                    self._finish(r, now)
                    finished.append(r)
                    sched.retire(i)              # freed → next boundary
                    self._safe_reset(sched, i)
        else:
            while True:
                # length-aware bucket: smallest compiled extent covering
                # every live cursor for the whole block (short prompts
                # start low); recomputed if a shed victim shrank the mask
                if len(ex.buckets) > 1:
                    needed = int(sched.positions[active].max()) + T
                    sb = bucket_for(min(needed, s_max), ex.buckets)
                else:
                    sb = ex.buckets[0]
                t0 = time.monotonic()
                try:
                    out = self._dispatch(
                        ex.program_prefix + "decode_block", ex.decode_block,
                        params, sb, sched.last_tok, sched.positions, active,
                        sched.remaining, sched.eos)
                except DispatchFailure as e:
                    active = self._demote_decode(sched, finished, e)
                    if not active.any():
                        return finished
                    continue
                break
            toks, emitted, last_d, pos_d, act_np, rem_d =\
                self._host_sync(*out)
            dt = time.monotonic() - t0
            self.tpot_samples.append(dt / T)
            self._decode_time += dt
            sched.last_tok = last_d.copy()
            sched.positions = pos_d.copy()
            sched.remaining = rem_d.copy()
            n_tok = int(emitted.sum())
            now = time.monotonic()
            for i, r in enumerate(sched.req):
                if r is None or sched.phase[i] != sched.DECODE:
                    continue
                emitted_any = False
                for t in range(T):
                    if emitted[t, i]:
                        self._emit_token(r, toks[t, i])
                        emitted_any = True
                if emitted_any:
                    r.note_emit(now)
                if not act_np[i]:                # budget/EOS halt on device
                    self._finish(r, now)
                    finished.append(r)
                    sched.retire(i)              # freed → next boundary
                    self._safe_reset(sched, i)
        self._decode_tokens += n_tok
        self._block_tokens.append(n_tok)
        self._macro_steps += 1
        return finished

    # ------------------------------------------------------------------
    def _run_drain(self, params, requests, max_steps):
        """Legacy baseline: prefill only when the WHOLE batch has drained —
        one long request starves every queued request (kept for comparison
        and for families without slotted support)."""
        ex = self._ex
        pending = sorted(requests, key=lambda r: r.arrival_step)
        active_req: List[Optional[Request]] = [None] * self.slots
        caches = None
        last = None
        done: List[Request] = []
        steps = admissions = 0
        while pending or self.queue or any(r is not None for r in active_req):
            if steps >= max_steps:
                break
            while pending and pending[0].arrival_step <= steps:
                r = pending.pop(0)            # validated by run()
                if not r.t_enqueue:           # keep a pre-run submit() stamp
                    r.t_enqueue = time.monotonic()
                self.queue.append(r)
            if caches is None:
                toks = np.zeros((self.slots, self.prompt_len), np.int32)
                for i in range(self.slots):
                    if active_req[i] is None and self.queue:
                        r = self.queue.pop(0)
                        r.t_admitted = time.monotonic()
                        r.admit_step = steps
                        active_req[i] = r
                        admissions += 1
                    if active_req[i] is not None:
                        toks[i] = pad_row(active_req[i].prompt,
                                          self.prompt_len)
                if not any(r is not None for r in active_req):
                    steps += 1                   # idle tick: await arrivals
                    continue
                t0 = time.monotonic()
                caches, first = ex.drain_prefill(params, toks)
                first.block_until_ready()
                now = time.monotonic()
                self._prefill_time += now - t0
                first = np.asarray(first)
                for i, r in enumerate(active_req):
                    if r is not None and not r.generated:
                        r.t_first_token = now
                        r.note_emit(now)
                        self._emit_token(r, first[i])
                        if r.done:
                            self._finish(r, now)
                last = jnp.asarray(first.astype(np.int32))
            t0 = time.monotonic()
            caches, nxt = ex.drain_decode(params, caches, last)
            nxt_np = self._host_sync(nxt)
            dt = time.monotonic() - t0
            self.tpot_samples.append(dt)
            self._decode_time += dt
            self._macro_steps += 1
            last = nxt
            steps += 1
            now = time.monotonic()
            n_tok = 0
            for i, r in enumerate(active_req):
                if r is None or r.done:
                    continue
                self._emit_token(r, nxt_np[i])
                r.note_emit(now)
                n_tok += 1
                if r.done:
                    self._finish(r, now)
            self._decode_tokens += n_tok
            self._block_tokens.append(n_tok)
            for i, r in enumerate(active_req):
                if r is not None and r.done:
                    done.append(r)
                    active_req[i] = None
            if all(r is None for r in active_req):
                caches = None                    # drained → allow re-prefill
        return self._stats(done, steps, admissions, 0)

    # ------------------------------------------------------------------
    def _stats(self, done, steps, admissions, overlapped) -> Dict[str, Any]:
        tp = np.array(self.tpot_samples[1:] or [0.0])
        per_req = [r.metrics() for r in sorted(done, key=lambda r: r.rid)]
        ttfts = np.array([m["ttft_ms"] for m in per_req] or [0.0])
        qd = np.array([m["queue_delay_ms"] for m in per_req] or [0.0])
        gaps = np.array([m["max_gap_ms"] for m in per_req] or [0.0])
        blk = np.array(self._block_tokens or [0.0])
        # decode-token throughput: decode-PRODUCED tokens over decode
        # wall-time — prefill AND chunk-prefill wall-time are excluded from
        # both sides (their first tokens are not in the numerator, their
        # stalls not in the denominator)
        n_dec = self._decode_tokens
        out = {
            "mode": self.mode,
            "backend": self.backend,
            "block_size": self.block_size,
            "a_shards": self.a_shards,
            "prefill_mode": ("chunked" if self.prefill_chunk
                             else "monolithic"),
            "prefill_chunk": self.prefill_chunk,
            "completed": len(done),
            "decode_steps": steps,
            "macro_steps": self._macro_steps,
            "admissions": admissions,
            "overlapped_admissions": overlapped,
            "tpot_mean_ms": float(tp.mean() * 1e3),
            "tpot_p50_ms": float(np.percentile(tp, 50) * 1e3) if len(tp) else 0.0,
            "tpot_p99_ms": float(np.percentile(tp, 99) * 1e3) if len(tp) else 0.0,
            "ttft_mean_ms": float(ttfts.mean()),
            "ttft_p99_ms": float(np.percentile(ttfts, 99)),
            "queue_delay_mean_ms": float(qd.mean()),
            "max_inter_token_gap_ms": float(gaps.max()),
            "decode_tokens": n_dec,
            "throughput_tok_s": float(n_dec / max(self._decode_time, 1e-9)),
            "prefill_time_ms": float(self._prefill_time * 1e3),
            "prefill_chunks": self._prefill_chunks,
            "host_syncs": self.host_syncs,
            "syncs_per_token": float(self.host_syncs / max(n_dec, 1)),
            "tokens_per_macro_step_mean": float(blk.mean()),
            "per_request": per_req,
            "runtime": self.rt.stats(),
            # pressure / robustness counters (DESIGN.md §7 failure model):
            # every submitted request is terminally accounted in exactly
            # one of completed / rejected / deadline_missed
            "preemptions": self._preemptions,
            "restores": self._restores,
            "rejections": len(self._rejected),
            "deadline_misses": len(self._deadline_missed),
            "retries": self._retries,
            "watchdog_timeouts": self._watchdog_timeouts,
            "quarantined_slots": sorted(self._quarantined),
            "swap_time_ms": float(self._swap_time * 1e3),
            "rejected": [
                {"rid": r.rid, "status": r.status, "priority": r.priority,
                 "reason": r.reject_reason}
                for r in sorted(self._rejected + self._deadline_missed,
                                key=lambda r: r.rid)],
        }
        if self._arbiter is not None:
            # tiered-KV occupancy and placement policy: tier splits,
            # demotions counted off cursor watermarks, live/peak bytes and
            # the byte-budget verdict — stats() is the arbiter's output
            out["tiered"] = self._arbiter.stats()
        if self.backend == "wa" and self._ex is not None:
            # measured W↔A traffic — the paper's "only embeddings move"
            # claim as a number in every run's output — plus the
            # per-domain stall accounting of the overlap schedule
            out["wa"] = self._ex.routing_stats(n_dec)
            out["wa"].update(self._ex.overlap_stats(
                self._decode_time, self._macro_steps,
                self._micro_batches_live, self._micro_batches_total))
        return out
