"""Continuous-batching decode serving engine under STATIC shapes.

The paper's prototype serves a fixed decode batch and defers continuous
batching to future work (§7.2). This engine closes that gap without leaving
the cache-resident/static-shape regime the paper's runtime depends on:

- the decode batch is a fixed set of SLOTS (static shapes → AOT compile once),
- a queued request is admitted into any free slot *mid-serve*: a batch-1
  prefill runs, its cache is written into the slot (``ModelAPI.write_slot``),
  and the slot's cursor restarts — no drain, no retrace,
- every row carries its own cursor (``positions``) and an ``active`` mask is
  threaded through decode (``ModelAPI.decode_slotted``) so retired slots
  neither write KV nor pollute the argmax,
- **macro-step decode** (``block_size`` = T > 1): decode runs as
  ``ModelAPI.decode_block`` — T greedy micro-steps inside ONE AOT-compiled
  ``lax.scan``, with per-slot on-device halting (token budget + optional EOS
  id as ``(B,)`` operands). The host syncs ONCE per T tokens instead of once
  per token and admission waits for block boundaries — the step-axis analogue
  of the paper's sub-operator dependency relaxation (§5): synchronize where
  the dependency is (block edges), not at every operator/token boundary,
- **length-aware KV walking**: in block mode each macro-step runs the block
  program compiled for the smallest KV *bucket* (chunk multiple) covering
  every live cursor + T — freshly admitted requests stop paying for the
  padded ``prompt_len + slack`` extent (``kv_bucket_chunk``; bucket set
  fixed at prepare time, one compiled program per bucket),
- all step programs (prefill-1, admit, per-bucket decode blocks) are
  AOT-compiled through ``StaticRuntime`` — ``stats()`` must show
  compiles == 1 per program with only ``calls`` growing across admissions
  (the §4.3 pinned-pool invariant).

The previous drain-then-refill loop is kept as ``mode="drain"`` — it is the
baseline the continuous scheduler is measured against (late-arrival TTFT) and
the fallback for model families without slotted support (DESIGN.md §7).

Per-request accounting: queue delay (enqueue→admit), TTFT (enqueue→first
token), TPOT (steady-state inter-token time) — the serving-side metrics of
the paper's Table 2 methodology. Engine-level: decode-token throughput
(decode-produced tokens over decode wall-time only — prefill first-tokens
are excluded from BOTH sides), host syncs per decode token (the macro-step
headline metric) and per-macro-step token counts.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kv.cache import KVCache
from repro.models.attention import bucket_for, kv_buckets
from repro.models.registry import DECODE_SLACK, ModelAPI
from repro.models.sharding import ShardingCtx
from repro.runtime.static_runtime import StaticRuntime


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int
    arrival_step: int = 0               # decode step at which it reaches the queue
    eos_id: int = -1                    # stop id (< 0 → budget-only halting)
    generated: List[int] = field(default_factory=list)
    t_enqueue: float = 0.0
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    admit_step: int = -1                # decode step at which it got a slot

    @property
    def done(self) -> bool:
        if self.eos_id >= 0 and self.generated \
                and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens

    def metrics(self) -> Dict[str, Any]:
        n = len(self.generated)
        return {
            "rid": self.rid,
            "tokens": n,
            "arrival_step": self.arrival_step,
            "admit_step": self.admit_step,
            "queue_delay_ms": max(0.0, self.t_admitted - self.t_enqueue) * 1e3,
            "ttft_ms": max(0.0, self.t_first_token - self.t_enqueue) * 1e3,
            "tpot_ms": ((self.t_done - self.t_first_token) / (n - 1) * 1e3
                        if n > 1 else 0.0),
        }


class ServingEngine:
    """Greedy decoding over fixed batch slots with per-slot admission.

    mode="continuous": slot-level scheduler (requires the ModelAPI slotted
    extensions); mode="drain": legacy drain-then-refill baseline;
    mode="auto": continuous when the family supports it.

    ``block_size`` (T): decode micro-steps per host round-trip. T == 1 is the
    per-step engine (one ``serve_decode`` program, one host sync per token);
    T > 1 runs ``ModelAPI.decode_block`` with on-device halt masks — one host
    sync per T tokens, admission at block boundaries only.

    ``kv_bucket_chunk`` (block mode, KV-cache families): > 0 compiles one
    decode-block program per KV bucket (chunk multiples up to the cache
    extent) and picks the smallest covering bucket per macro-step on the
    host. 0 disables bucketing (single full-extent block program).

    ``debug_reset_slots``: zero a slot's cache state when its request
    retires (``ModelAPI.reset_slot``, one more AOT program). Never required
    for correctness — masked attention cannot read past a cursor — but keeps
    cache dumps clean and slot-state invariants checkable.

    ``raw_decode`` (optional, T == 1 only): an eager decode-step callable
    ``(params, caches, tokens, positions, active) -> (caches, logits)`` used
    INSTEAD of the AOT-compiled slotted decode — the hook through which the
    WA-disaggregated backend (two submeshes, python-orchestrated routing)
    plugs into the same admission scheduler.

    An engine instance may be ``run()`` repeatedly: per-run accumulators
    (timings, sync counts, queues) reset and the slot caches are allocated
    fresh each run, while the AOT-compiled programs persist (compiles == 1
    across every run of the engine's lifetime).
    """

    def __init__(self, api: ModelAPI, ctx: ShardingCtx, batch_slots: int,
                 prompt_len: int, runtime: Optional[StaticRuntime] = None,
                 greedy: bool = True, mode: str = "auto",
                 max_new_cap: int = DECODE_SLACK,
                 raw_decode: Optional[Callable] = None,
                 block_size: int = 1, kv_bucket_chunk: int = 0,
                 debug_reset_slots: bool = False):
        if mode not in ("auto", "continuous", "drain"):
            raise ValueError(mode)
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if block_size > 1 and raw_decode is not None:
            raise ValueError("raw_decode is a per-step hook; macro-step "
                             "decode (block_size > 1) requires the AOT "
                             "decode_block path")
        # continuous mode always needs write_slot (admission); the decode
        # half comes from api.decode_block (T > 1), api.decode_slotted or a
        # raw_decode override (T == 1)
        decode_ok = (api.decode_block is not None if block_size > 1 else
                     api.decode_slotted is not None or raw_decode is not None)
        slotted_ok = api.write_slot is not None and decode_ok
        if mode == "continuous" and not slotted_ok:
            raise ValueError(
                f"{api.config.family} family has no slotted decode support")
        self.api = api
        self.ctx = ctx
        self.slots = batch_slots
        self.prompt_len = prompt_len
        self.max_new_cap = min(max_new_cap, DECODE_SLACK)
        self.mode = ("continuous" if slotted_ok else "drain") \
            if mode == "auto" else mode
        self.block_size = block_size
        self.kv_bucket_chunk = kv_bucket_chunk
        self.debug_reset_slots = debug_reset_slots
        self.rt = runtime or StaticRuntime()
        self.queue: List[Request] = []
        self._params = None
        self._raw_decode = raw_decode
        self._prepared = False
        self._buckets: Tuple[int, ...] = ()
        self._reset = None
        self._reset_per_run()

    # ------------------------------------------------------------------
    def _reset_per_run(self):
        """Per-run accumulators. An engine reused across ``run()`` calls
        must not leak timing samples or sync counts from a previous run
        (stats would blend workloads), and ``self._caches`` from a finished
        run must never seed the next one (stale KV in freed slots)."""
        self.tpot_samples: List[float] = []
        self.host_syncs = 0
        self._decode_tokens = 0
        self._decode_time = 0.0
        self._block_tokens: List[int] = []
        self._macro_steps = 0
        self.queue = []

    def _host_sync(self, *arrays):
        """THE counted device→host round-trip of the decode loop — the
        coordination cost the macro-step engine amortizes (1 sync per
        ``block_size`` tokens). Tests assert on ``self.host_syncs``."""
        self.host_syncs += 1
        out = tuple(np.asarray(a) for a in arrays)
        return out if len(out) > 1 else out[0]

    def load(self, params):
        self._params = params

    def submit(self, req: Request):
        req.t_enqueue = time.monotonic()
        self.queue.append(req)

    # ------------------------------------------------------------------
    # AOT step programs — compiled ONCE at first run; admission/decode are
    # cached-executable calls from then on (zero retracing, §4.3 analogue).
    # ------------------------------------------------------------------
    def _fresh_caches(self):
        return self.api.init_caches(self.slots,
                                    self.prompt_len + self.max_new_cap)

    def _prepare_continuous(self, params):
        api, ctx = self.api, self.ctx
        B, P, T = self.slots, self.prompt_len, self.block_size

        def prefill1_fn(p, toks):
            caches, logits = api.prefill(p, {"tokens": toks}, ctx)
            return caches, jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

        def admit_fn(caches, single, slot):
            return api.write_slot(caches, single, slot)

        def postprocess(logits, positions, active):
            # active-slot mask: retired slots emit a fixed token id 0 and
            # never advance — finished requests cannot pollute the stream
            nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
            return jnp.where(active, nxt, 0), \
                positions + active.astype(jnp.int32)

        caches_aval = jax.eval_shape(self._fresh_caches)
        toks1 = jnp.zeros((1, P), jnp.int32)
        single_aval, _ = jax.eval_shape(prefill1_fn, params, toks1)
        pos0 = jnp.zeros((B,), jnp.int32)
        act0 = jnp.zeros((B,), bool)
        tok0 = jnp.zeros((B,), jnp.int32)
        self._prefill1 = self.rt.compile_step(
            "serve_prefill1", prefill1_fn, (params, toks1))
        self._admit = self.rt.compile_step(
            "serve_admit", admit_fn,
            (caches_aval, single_aval, jnp.zeros((), jnp.int32)),
            donate_argnums=(0,))
        if self.debug_reset_slots and api.reset_slot is not None:
            self._reset = self.rt.compile_step(
                "serve_reset", lambda c, slot: api.reset_slot(c, slot),
                (caches_aval, jnp.zeros((), jnp.int32)), donate_argnums=(0,))
        if T > 1:
            # -- macro-step block programs, one per KV bucket --------------
            # Bucketing applies only to prefix-ordered KV caches; recurrent
            # states (and ring buffers) get the single full program.
            bucketable = isinstance(caches_aval, KVCache) \
                and not caches_aval.window
            s_max = caches_aval.k.shape[3] if bucketable else 0
            self._buckets = kv_buckets(s_max, self.kv_bucket_chunk) \
                if bucketable and self.kv_bucket_chunk > 0 else (0,)
            rem0 = jnp.zeros((B,), jnp.int32)
            eos0 = jnp.full((B,), -1, jnp.int32)
            self._decode_blocks: Dict[int, Callable] = {}
            for sb in self._buckets:
                name = "serve_decode_block" if len(self._buckets) == 1 \
                    else f"serve_decode_block_s{sb}"

                def block_fn(p, caches, tok, pos, act, rem, eos, _sb=sb):
                    return api.decode_block(p, caches, tok, pos, act, rem,
                                            eos, ctx, block_size=T,
                                            kv_bucket=_sb)

                self._decode_blocks[sb] = self.rt.compile_step(
                    name, block_fn,
                    (params, caches_aval, tok0, pos0, act0, rem0, eos0),
                    donate_argnums=(1,))
            return

        def decode_fn(p, caches, tokens, positions, active):
            caches, logits = api.decode_slotted(p, caches, tokens, positions,
                                                active, ctx)
            return (caches,) + postprocess(logits, positions, active)

        if self._raw_decode is None:
            self._decode = self.rt.compile_step(
                "serve_decode", decode_fn,
                (params, caches_aval, tok0, pos0, act0),
                donate_argnums=(1,))
        else:
            raw = self._raw_decode

            def decode_eager(p, caches, tokens, positions, active):
                caches, logits = raw(p, caches, tokens, positions, active)
                return (caches,) + postprocess(logits, positions, active)
            self._decode = decode_eager

    def _prepare_drain(self, params):
        api, ctx = self.api, self.ctx

        def prefill_fn(p, toks):
            caches, logits = api.prefill(p, {"tokens": toks}, ctx)
            return caches, jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

        def decode_fn(p, caches, tokens):
            caches, logits = api.decode(p, caches, tokens, ctx)
            return caches, jnp.argmax(logits[:, 0], -1).astype(jnp.int32)

        toks0 = jnp.zeros((self.slots, self.prompt_len), jnp.int32)
        caches_aval, tok_aval = jax.eval_shape(prefill_fn, params, toks0)
        self._prefill_b = self.rt.compile_step(
            "serve_prefill_batch", prefill_fn, (params, toks0))
        self._decode_b = self.rt.compile_step(
            "serve_decode_drain", decode_fn, (params, caches_aval, tok_aval),
            donate_argnums=(1,))

    def _prepare(self, params):
        if self._prepared:
            return
        if self.mode == "continuous":
            self._prepare_continuous(params)
        else:
            self._prepare_drain(params)
        self._prepared = True

    # ------------------------------------------------------------------
    def run(self, params, requests: List[Request],
            max_steps: int = 10_000) -> Dict[str, Any]:
        """Serve all requests to completion; returns latency stats.
        Reusable: each call starts from fresh caches and fresh accumulators
        (AOT programs persist — zero recompilation across runs)."""
        self.load(params)
        for r in requests:
            if r.max_new_tokens > self.max_new_cap:
                raise ValueError(
                    f"request {r.rid}: max_new_tokens={r.max_new_tokens} "
                    f"exceeds cache slack {self.max_new_cap}")
        self._prepare(params)
        self._reset_per_run()
        if self.mode == "continuous":
            return self._run_continuous(params, requests, max_steps)
        return self._run_drain(params, requests, max_steps)

    def _pad_prompt(self, r: Request) -> np.ndarray:
        """(prompt_len,) — prompt truncated/zero-padded to the static width."""
        row = np.zeros((self.prompt_len,), np.int32)
        row[:len(r.prompt)] = r.prompt[:self.prompt_len]
        return row

    # ------------------------------------------------------------------
    def _admit_requests(self, params, caches, active_req, steps, batch_live):
        """Fill EVERY free slot from the queue (no drain). Returns
        (caches, admissions, overlapped, finished, admitted) —
        ``finished`` are requests done at their first (prefill) token,
        ``admitted`` the (slot, request) pairs now occupying a slot (the
        caller initializes its cursor/halt arrays from these)."""
        admissions = overlapped = 0
        finished: List[Request] = []
        admitted: List[Tuple[int, Request]] = []
        for i in range(self.slots):
            # retry the SAME slot while admissions complete at their first
            # token (max_new_tokens == 1 / instant EOS) — a one-token
            # request must not idle the slot until the next boundary
            while active_req[i] is None and self.queue:
                r = self.queue.pop(0)
                if batch_live:
                    overlapped += 1
                r.t_admitted = time.monotonic()
                r.admit_step = steps
                single, first = self._prefill1(
                    params, jnp.asarray(self._pad_prompt(r)[None]))
                caches = self._admit(caches, single,
                                     jnp.asarray(i, jnp.int32))
                first.block_until_ready()
                r.t_first_token = time.monotonic()
                r.generated.append(int(np.asarray(first)[0]))
                admissions += 1
                if r.done:
                    r.t_done = r.t_first_token
                    finished.append(r)
                    # the admit DID write its prompt KV — zero it like any
                    # other retirement so dumps stay clean
                    if self._reset is not None:
                        caches = self._reset(caches,
                                             jnp.asarray(i, jnp.int32))
                    continue
                active_req[i] = r
                admitted.append((i, r))
        return caches, admissions, overlapped, finished, admitted

    def _run_continuous(self, params, requests, max_steps):
        if self.block_size > 1:
            return self._run_continuous_block(params, requests, max_steps)
        pending = sorted(requests, key=lambda r: r.arrival_step)
        active_req: List[Optional[Request]] = [None] * self.slots
        positions = np.zeros((self.slots,), np.int32)
        last_tok = np.zeros((self.slots,), np.int32)
        caches = self._fresh_caches()
        done: List[Request] = []
        steps = admissions = overlapped = 0
        while pending or self.queue or any(r is not None for r in active_req):
            if steps >= max_steps:
                break
            while pending and pending[0].arrival_step <= steps:
                self.submit(pending.pop(0))
            # -- admission: fill EVERY free slot from the queue, no drain --
            # "overlapped" = admitted while the batch was already live at the
            # start of this round (cold-start fills at step 0 don't count)
            batch_live = any(a is not None for a in active_req)
            caches, n_adm, n_ovl, finished, new_slots = self._admit_requests(
                params, caches, active_req, steps, batch_live)
            admissions += n_adm
            overlapped += n_ovl
            done.extend(finished)
            for i, r in new_slots:
                positions[i] = self.prompt_len
                last_tok[i] = r.generated[-1]
            active = np.array([a is not None for a in active_req])
            if not active.any():
                steps += 1                       # idle tick: await arrivals
                continue
            # -- one fused decode step over all slots ----------------------
            t0 = time.monotonic()
            caches, nxt, new_pos = self._decode(
                params, caches, jnp.asarray(last_tok),
                jnp.asarray(positions), jnp.asarray(active))
            nxt, new_pos = self._host_sync(nxt, new_pos)
            dt = time.monotonic() - t0
            self.tpot_samples.append(dt)
            self._decode_time += dt
            n_tok = int(active.sum())
            self._decode_tokens += n_tok
            self._block_tokens.append(n_tok)
            self._macro_steps += 1
            positions = new_pos.copy()
            last_tok = nxt.copy()
            steps += 1
            now = time.monotonic()
            for i, r in enumerate(active_req):
                if r is None:
                    continue
                r.generated.append(int(nxt[i]))
                if r.done:
                    r.t_done = now
                    done.append(r)
                    active_req[i] = None         # freed → admitted next step
                    if self._reset is not None:
                        caches = self._reset(caches,
                                             jnp.asarray(i, jnp.int32))
        self._caches = caches
        return self._stats(done, steps, admissions, overlapped)

    # ------------------------------------------------------------------
    def _run_continuous_block(self, params, requests, max_steps):
        """Macro-step scheduler: T decode micro-steps per device call, one
        host sync + one admission round per block boundary. Per-slot halt
        state (budget ``remaining``, ``eos`` ids) rides along as (B,)
        operands so the device loop never needs the host to retire a slot.

        Deliberately a twin of the T == 1 loop in ``_run_continuous``
        (shared admission via ``_admit_requests``; the scheduler shell —
        arrival pump, idle tick, retirement+reset — is kept in both).
        A fix to the shell logic must land in BOTH loops; the token-equality
        tests in test_macro_step.py catch divergence."""
        T = self.block_size
        pending = sorted(requests, key=lambda r: r.arrival_step)
        active_req: List[Optional[Request]] = [None] * self.slots
        positions = np.zeros((self.slots,), np.int32)
        last_tok = np.zeros((self.slots,), np.int32)
        remaining = np.zeros((self.slots,), np.int32)
        eos = np.full((self.slots,), -1, np.int32)
        caches = self._fresh_caches()
        s_max = self.prompt_len + self.max_new_cap
        done: List[Request] = []
        steps = admissions = overlapped = 0
        while pending or self.queue or any(r is not None for r in active_req):
            if steps >= max_steps:
                break
            while pending and pending[0].arrival_step <= steps:
                self.submit(pending.pop(0))
            # -- admission at the block boundary ---------------------------
            batch_live = any(a is not None for a in active_req)
            caches, n_adm, n_ovl, finished, new_slots = self._admit_requests(
                params, caches, active_req, steps, batch_live)
            admissions += n_adm
            overlapped += n_ovl
            done.extend(finished)
            for i, r in new_slots:
                positions[i] = self.prompt_len
                last_tok[i] = r.generated[-1]
                remaining[i] = r.max_new_tokens - 1
                eos[i] = r.eos_id
            active = np.array([a is not None for a in active_req])
            if not active.any():
                steps += 1                       # idle tick: await arrivals
                continue
            # -- length-aware bucket: smallest compiled extent covering
            #    every live cursor for the whole block -----------------------
            if len(self._buckets) > 1:
                needed = int(positions[active].max()) + T
                sb = bucket_for(min(needed, s_max), self._buckets)
            else:
                sb = self._buckets[0]
            # -- ONE device call = T micro-steps; ONE host sync ------------
            t0 = time.monotonic()
            caches, toks, emitted, last_d, pos_d, act_d, rem_d = \
                self._decode_blocks[sb](
                    params, caches, jnp.asarray(last_tok),
                    jnp.asarray(positions), jnp.asarray(active),
                    jnp.asarray(remaining), jnp.asarray(eos))
            toks, emitted, last_d, pos_d, act_np, rem_d = \
                self._host_sync(toks, emitted, last_d, pos_d, act_d, rem_d)
            last_tok, positions, remaining = \
                last_d.copy(), pos_d.copy(), rem_d.copy()
            dt = time.monotonic() - t0
            self.tpot_samples.append(dt / T)
            self._decode_time += dt
            n_tok = int(emitted.sum())
            self._decode_tokens += n_tok
            self._block_tokens.append(n_tok)
            self._macro_steps += 1
            steps += T
            now = time.monotonic()
            for i, r in enumerate(active_req):
                if r is None:
                    continue
                for t in range(T):
                    if emitted[t, i]:
                        r.generated.append(int(toks[t, i]))
                if not act_np[i]:                # budget/EOS halt on device
                    r.t_done = now
                    done.append(r)
                    active_req[i] = None         # freed → next boundary
                    if self._reset is not None:
                        caches = self._reset(caches,
                                             jnp.asarray(i, jnp.int32))
        self._caches = caches
        return self._stats(done, steps, admissions, overlapped)

    # ------------------------------------------------------------------
    def _run_drain(self, params, requests, max_steps):
        """Legacy baseline: prefill only when the WHOLE batch has drained —
        one long request starves every queued request (kept for comparison
        and for families without slotted support)."""
        pending = sorted(requests, key=lambda r: r.arrival_step)
        active_req: List[Optional[Request]] = [None] * self.slots
        caches = None
        last = None
        done: List[Request] = []
        steps = admissions = 0
        while pending or self.queue or any(r is not None for r in active_req):
            if steps >= max_steps:
                break
            while pending and pending[0].arrival_step <= steps:
                self.submit(pending.pop(0))
            if caches is None:
                toks = np.zeros((self.slots, self.prompt_len), np.int32)
                for i in range(self.slots):
                    if active_req[i] is None and self.queue:
                        r = self.queue.pop(0)
                        r.t_admitted = time.monotonic()
                        r.admit_step = steps
                        active_req[i] = r
                        admissions += 1
                    if active_req[i] is not None:
                        toks[i] = self._pad_prompt(active_req[i])
                if not any(r is not None for r in active_req):
                    steps += 1                   # idle tick: await arrivals
                    continue
                caches, first = self._prefill_b(params, jnp.asarray(toks))
                first.block_until_ready()
                now = time.monotonic()
                first = np.asarray(first)
                for i, r in enumerate(active_req):
                    if r is not None and not r.generated:
                        r.t_first_token = now
                        r.generated.append(int(first[i]))
                        if r.done:
                            r.t_done = now
                last = jnp.asarray(first.astype(np.int32))
            t0 = time.monotonic()
            caches, nxt = self._decode_b(params, caches, last)
            nxt_np = self._host_sync(nxt)
            dt = time.monotonic() - t0
            self.tpot_samples.append(dt)
            self._decode_time += dt
            self._macro_steps += 1
            last = nxt
            steps += 1
            now = time.monotonic()
            n_tok = 0
            for i, r in enumerate(active_req):
                if r is None or r.done:
                    continue
                r.generated.append(int(nxt_np[i]))
                n_tok += 1
                if r.done:
                    r.t_done = now
            self._decode_tokens += n_tok
            self._block_tokens.append(n_tok)
            for i, r in enumerate(active_req):
                if r is not None and r.done:
                    done.append(r)
                    active_req[i] = None
            if all(r is None for r in active_req):
                caches = None                    # drained → allow re-prefill
        return self._stats(done, steps, admissions, 0)

    # ------------------------------------------------------------------
    def _stats(self, done, steps, admissions, overlapped) -> Dict[str, Any]:
        tp = np.array(self.tpot_samples[1:] or [0.0])
        per_req = [r.metrics() for r in sorted(done, key=lambda r: r.rid)]
        ttfts = np.array([m["ttft_ms"] for m in per_req] or [0.0])
        qd = np.array([m["queue_delay_ms"] for m in per_req] or [0.0])
        blk = np.array(self._block_tokens or [0.0])
        # decode-token throughput: decode-PRODUCED tokens over decode
        # wall-time — the prefill-produced first token is excluded from the
        # numerator because its cost is not in the denominator
        n_dec = self._decode_tokens
        return {
            "mode": self.mode,
            "block_size": self.block_size,
            "completed": len(done),
            "decode_steps": steps,
            "macro_steps": self._macro_steps,
            "admissions": admissions,
            "overlapped_admissions": overlapped,
            "tpot_mean_ms": float(tp.mean() * 1e3),
            "tpot_p50_ms": float(np.percentile(tp, 50) * 1e3) if len(tp) else 0.0,
            "tpot_p99_ms": float(np.percentile(tp, 99) * 1e3) if len(tp) else 0.0,
            "ttft_mean_ms": float(ttfts.mean()),
            "ttft_p99_ms": float(np.percentile(ttfts, 99)),
            "queue_delay_mean_ms": float(qd.mean()),
            "decode_tokens": n_dec,
            "throughput_tok_s": float(n_dec / max(self._decode_time, 1e-9)),
            "host_syncs": self.host_syncs,
            "syncs_per_token": float(self.host_syncs / max(n_dec, 1)),
            "tokens_per_macro_step_mean": float(blk.mean()),
            "per_request": per_req,
            "runtime": self.rt.stats(),
        }
