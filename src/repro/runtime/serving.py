"""Batched decode serving engine.

Decode-centric per the paper ("decoding ... is the long-running steady state
and dominates execution time"). Static batch slots (static shapes — the AOT
runtime requirement); finished requests are swapped out between steps, giving
continuous-batching-lite without dynamic shapes (the paper defers full
continuous batching to future work, §7.2 — we implement the slot-swap form
that preserves socket/chip-local hot state).

Tracks TPOT (time-per-output-token) and per-phase latency, the paper's
headline metrics (Table 2).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelAPI
from repro.models.sharding import ShardingCtx
from repro.runtime.static_runtime import StaticRuntime


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class ServingEngine:
    """Greedy decoding over fixed batch slots."""

    def __init__(self, api: ModelAPI, ctx: ShardingCtx, batch_slots: int,
                 prompt_len: int, runtime: Optional[StaticRuntime] = None,
                 greedy: bool = True):
        self.api = api
        self.ctx = ctx
        self.slots = batch_slots
        self.prompt_len = prompt_len
        self.rt = runtime or StaticRuntime()
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.tpot_samples: List[float] = []
        self._params = None
        self._caches = None
        self._last_tokens = None
        # static-runtime dispatch: trace once, call forever (§4.3 analogue)
        self._prefill_jit = jax.jit(
            lambda p, b: self.api.prefill(p, b, self.ctx))
        self._decode_jit = jax.jit(
            lambda p, c, t: self.api.decode(p, c, t, self.ctx),
            donate_argnums=(1,))

    # ------------------------------------------------------------------
    def load(self, params):
        self._params = params

    def submit(self, req: Request):
        req.t_enqueue = time.monotonic()
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _prefill_batch(self):
        """Fill every empty slot, then prefill the whole batch at once."""
        newly = []
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                self.active[i] = self.queue.pop(0)
                newly.append(i)
        if not any(self.active):
            return False
        toks = np.zeros((self.slots, self.prompt_len), np.int32)
        for i, r in enumerate(self.active):
            if r is not None:
                toks[i, :len(r.prompt)] = r.prompt[:self.prompt_len]
        batch = {"tokens": jnp.asarray(toks)}
        self._caches, logits = self._prefill_jit(self._params, batch)
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        self._record_tokens(nxt)
        self._last_tokens = nxt.astype(jnp.int32)
        return True

    def _record_tokens(self, nxt):
        now = time.monotonic()
        arr = np.asarray(nxt)
        for i, r in enumerate(self.active):
            if r is None or r.done:
                continue
            if not r.generated:
                r.t_first_token = now
            r.generated.append(int(arr[i]))
            if r.done:
                r.t_done = now

    # ------------------------------------------------------------------
    def run(self, params, requests: List[Request],
            max_steps: int = 10_000) -> Dict[str, Any]:
        """Serve all requests to completion; returns latency stats."""
        self.load(params)
        for r in requests:
            self.submit(r)
        done: List[Request] = []
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) \
                and steps < max_steps:
            if self._caches is None:
                if not self._prefill_batch():
                    break
            t0 = time.monotonic()
            self._caches, logits = self._decode_jit(
                self._params, self._caches, self._last_tokens)
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            nxt.block_until_ready()
            self.tpot_samples.append(time.monotonic() - t0)
            self._record_tokens(nxt)
            self._last_tokens = nxt
            steps += 1
            # retire finished requests; refill slots → next loop prefills
            for i, r in enumerate(self.active):
                if r is not None and r.done:
                    done.append(r)
                    self.active[i] = None
            if all(r is None for r in self.active):
                self._caches = None      # batch drained → allow re-prefill
        tp = np.array(self.tpot_samples[1:] or [0.0])
        return {
            "completed": len(done),
            "decode_steps": steps,
            "tpot_mean_ms": float(tp.mean() * 1e3),
            "tpot_p50_ms": float(np.percentile(tp, 50) * 1e3) if len(tp) else 0.0,
            "tpot_p99_ms": float(np.percentile(tp, 99) * 1e3) if len(tp) else 0.0,
            "throughput_tok_s": float(
                sum(len(r.generated) for r in done)
                / max(sum(self.tpot_samples), 1e-9)),
        }
