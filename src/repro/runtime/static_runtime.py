"""Static AOT runtime — the TPU analogue of the paper's pinned thread pool
(§4.3).

The paper replaces OpenMP's dynamic scheduling with threads pinned once at
init, deterministic shard→core maps, and state-transition execution loops.
The JAX analogue of each piece:

  pinned threads / fixed shard→core map  → shardings fixed at compile time,
                                            AOT ``.lower().compile()``
  no per-task queue or dynamic dispatch  → compiled executable cached by
                                            (step-name, shape signature);
                                            dispatch = one cached call, ZERO
                                            retracing on the critical path
  cache warmup / first-touch placement   → explicit warmup() that materializes
                                            params/caches with their final
                                            shardings before serving starts

Fig 10's "thread pool vs OpenMP" ablation maps to: cached AOT dispatch vs
re-tracing dispatch — benchmarks/fig10_runtime.py measures both on CPU.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax


class DispatchError(RuntimeError):
    """A program dispatch failed before the compiled call ran (transient
    driver hiccup, injected fault). Raised by dispatch interceptors BEFORE
    ``Compiled.__call__`` touches its operands, so donated buffers are
    still valid and the caller may retry the dispatch verbatim. The serving
    engine's retry/quarantine path (DESIGN.md §7) catches exactly this
    type — anything else is a real bug and propagates."""


@dataclass
class CompiledStep:
    name: str
    compiled: Any                    # jax.stages.Compiled
    lowered: Any                     # jax.stages.Lowered (kept for analysis)
    compile_s: float
    calls: int = 0
    # retained for static analysis (repro.analysis): the traced callable and
    # its abstract signature let the verifier re-derive the jaxpr of the
    # EXACT program that serves — no shadow re-implementation to drift
    fn: Optional[Callable] = None
    abstract_args: Optional[Tuple] = None
    donate_argnums: Tuple[int, ...] = ()
    static_argnums: Tuple[int, ...] = ()
    # build-time statics baked into this program that do NOT show in the
    # name or signature (e.g. the WA backend's sub-operator overlap depth)
    # — surfaced through StaticRuntime.stats() so a serve log can say
    # WHICH variant of a program it dispatched
    meta: Optional[Dict[str, Any]] = None
    # dispatch interceptor (fault injection / tracing). Runs BEFORE the
    # compiled call: raising DispatchError here models a dispatch that
    # never reached the device — donated operands stay valid, the dispatch
    # is retryable. Installed fleet-wide via StaticRuntime.set_interceptor.
    interceptor: Optional[Callable[[str], None]] = None

    def __call__(self, *args):
        if self.interceptor is not None:
            self.interceptor(self.name)
        self.calls += 1
        return self.compiled(*args)

    def cost_analysis(self):
        from repro.core.compat import cost_analysis
        return cost_analysis(self.compiled)

    def memory_analysis(self):
        return self.compiled.memory_analysis()

    def jaxpr(self):
        """ClosedJaxpr of the step as traced at compile time (for the
        static verifier's jaxpr-level passes)."""
        if self.fn is None or self.abstract_args is None:
            raise ValueError(f"step {self.name!r} kept no trace inputs")
        if self.static_argnums:
            raise ValueError(f"step {self.name!r} has static argnums; "
                             "jaxpr() supports fully-traced steps only")
        return jax.make_jaxpr(self.fn)(*self.abstract_args)


class StaticRuntime:
    """AOT compile cache keyed on (name, mesh, abstract arg signature)."""

    def __init__(self, mesh=None):
        self.mesh = mesh
        self._cache: Dict[Tuple, CompiledStep] = {}
        self._interceptor: Optional[Callable[[str], None]] = None

    def set_interceptor(self, fn: Optional[Callable[[str], None]]):
        """Install (or clear, with None) a dispatch interceptor on every
        compiled step — existing and future. The hook runs at the top of
        each dispatch with the program name; raising ``DispatchError``
        models a failed dispatch (operands untouched, retry-safe), sleeping
        models a stalled one. This is the single injection point the chaos
        harness (``repro.runtime.faults``) uses."""
        self._interceptor = fn
        for step in self._cache.values():
            step.interceptor = fn

    # ------------------------------------------------------------------
    @staticmethod
    def _sig(args) -> Tuple:
        # weak_type participates in the signature: a weakly-typed scalar
        # (e.g. a bare python int leaking into an operand slot) traces to a
        # DIFFERENT program than the committed-dtype one and silently
        # recompiles on the serving path.  The compile-once auditor
        # (repro.analysis.compile_once) flags any weak-typed leaf.
        leaves = jax.tree_util.tree_leaves(args)
        return tuple((getattr(x, "shape", None), str(getattr(x, "dtype", "")),
                      bool(getattr(x, "weak_type", False)))
                     for x in leaves)

    def compile_step(self, name: str, fn: Callable, abstract_args: Tuple,
                     in_shardings=None, out_shardings=None,
                     donate_argnums: Tuple[int, ...] = (),
                     static_argnums: Tuple[int, ...] = (),
                     meta: Optional[Dict[str, Any]] = None) -> CompiledStep:
        key = (name, id(self.mesh), self._sig(abstract_args))
        if key in self._cache:
            return self._cache[key]
        t0 = time.monotonic()
        jitted = jax.jit(fn,
                         in_shardings=in_shardings,
                         out_shardings=out_shardings,
                         donate_argnums=donate_argnums,
                         static_argnums=static_argnums)
        lowered = jitted.lower(*abstract_args)
        compiled = lowered.compile()
        step = CompiledStep(name, compiled, lowered,
                            compile_s=time.monotonic() - t0,
                            fn=fn, abstract_args=abstract_args,
                            donate_argnums=tuple(donate_argnums),
                            static_argnums=tuple(static_argnums),
                            meta=dict(meta) if meta else None,
                            interceptor=self._interceptor)
        self._cache[key] = step
        return step

    def get(self, name: str, abstract_args) -> Optional[CompiledStep]:
        return self._cache.get((name, id(self.mesh), self._sig(abstract_args)))

    # ------------------------------------------------------------------
    def warmup(self, step: CompiledStep, *args):
        """First-touch analogue: run once so buffers land with their final
        shardings/layouts before the latency-critical loop starts."""
        out = step(*args)
        jax.block_until_ready(out)
        return out

    def stats(self) -> Dict[str, Dict]:
        """Per-step-name compile/call accounting. ``compiles`` counts distinct
        (mesh, signature) variants — a steady-state serving loop must show
        compiles == 1 per step with only ``calls`` growing (zero retracing
        across admissions; the §4.3 pinned-pool invariant)."""
        out: Dict[str, Dict] = {}
        for (name, *_), s in self._cache.items():
            rec = out.setdefault(name,
                                 {"compiles": 0, "compile_s": 0.0, "calls": 0})
            rec["compiles"] += 1
            rec["compile_s"] += s.compile_s
            rec["calls"] += s.calls
            if s.meta:
                rec.update(s.meta)
        return out
