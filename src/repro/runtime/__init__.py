from repro.runtime.static_runtime import StaticRuntime, CompiledStep  # noqa: F401
from repro.runtime.serving import ServingEngine, Request  # noqa: F401
from repro.runtime.elastic import ElasticController, NodeFailure  # noqa: F401
