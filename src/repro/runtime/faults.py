"""Deterministic fault-injection harness for the serving engine
(DESIGN.md §7, failure model).

The paper's runtime argument is that cache-resident serving is only as
good as its worst boundary: a single stalled dispatch or an overload burst
must degrade to explicit, accounted outcomes — never a hung engine or a
corrupted token stream. This module makes that claim TESTABLE:

- ``FaultPlan``: a frozen, seeded description of one chaos schedule —
  dispatch failure/slowdown rates, an artificial-KV-pressure square wave,
  and a bursty heavy-tailed arrival workload. Same seed → same plan →
  same injected faults, so every red run replays exactly.
- ``FaultInjector``: the live hook. ``on_dispatch(name)`` installs as the
  ``StaticRuntime`` dispatch interceptor (raising ``DispatchError`` BEFORE
  the compiled call touches donated operands — retry-safe by
  construction); ``slots_held(step)`` models KV pressure the boundary
  loop answers with preemption.
- ``check_invariants``: the post-run auditor — terminal accounting
  (every request completed / rejected / deadline_missed), occupancy
  consistency, emission-log contiguity (no duplicated, lost or reordered
  token), preemption/restore conservation, and token-byte equality of
  every COMPLETED request against a clean reference run.
- ``run_chaos``: clean run → chaos run → audit, on one engine (the AOT
  programs compile once and serve both).

CLI smoke (the ``make test-chaos`` job drives the pytest suite instead)::

    PYTHONPATH=src python -m repro.runtime.faults --seeds 5
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import numpy as np

from repro.runtime.serving import Request, ServingEngine
from repro.runtime.static_runtime import DispatchError

TERMINAL = ("completed", "rejected", "deadline_missed")


# ---------------------------------------------------------------------------
# FaultPlan — the seeded schedule
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultPlan:
    """One deterministic chaos schedule. Frozen: a plan is a VALUE — the
    injector and the workload generator derive everything from it and the
    seed, nothing mutates mid-run."""
    seed: int
    # dispatch faults (drawn per dispatch from the seeded stream)
    fail_rate: float = 0.0          # P(raise DispatchError)
    slow_rate: float = 0.0          # P(sleep slow_s before dispatching)
    slow_s: float = 0.0
    # artificial KV pressure: a square wave over boundary steps —
    # ``pressure_slots`` slots withheld for the duty fraction of each
    # period. Duty < 1 guarantees pressure always lifts (no livelock).
    pressure_slots: int = 0
    pressure_period: int = 0        # 0 → no pressure
    pressure_duty: float = 0.5
    # bursty arrival workload (heavy-tailed lengths)
    n_requests: int = 8
    burst_size: int = 3             # arrivals per burst
    burst_gap: int = 12             # boundary steps between bursts
    max_new_lo: int = 2
    max_new_hi: int = 16            # heavy tail: few long, many short
    deadline_frac: float = 0.0      # fraction of requests carrying a TTFT
    ttft_deadline_ms: float = 0.0   # deadline (tight → shed under slowness)

    @staticmethod
    def generate(seed: int, *, max_fail_rate: float = 0.12,
                 max_slow_rate: float = 0.1, max_pressure: int = 2,
                 n_requests: int = 8) -> "FaultPlan":
        """Randomize a plan FROM the seed (two seeds, two schedules) while
        keeping every knob inside the always-terminates envelope: bounded
        fail rate (retries + quarantine absorb it), pressure duty < 1."""
        rng = np.random.default_rng(seed)
        return FaultPlan(
            seed=seed,
            fail_rate=float(rng.uniform(0, max_fail_rate)),
            slow_rate=float(rng.uniform(0, max_slow_rate)),
            slow_s=float(rng.uniform(0, 0.002)),
            pressure_slots=int(rng.integers(0, max_pressure + 1)),
            pressure_period=int(rng.integers(8, 40)),
            pressure_duty=float(rng.uniform(0.25, 0.75)),
            n_requests=n_requests,
            burst_size=int(rng.integers(2, 5)),
            burst_gap=int(rng.integers(6, 24)),
            max_new_lo=2,
            max_new_hi=int(rng.integers(8, 20)),
            deadline_frac=float(rng.uniform(0, 0.5)),
            ttft_deadline_ms=float(rng.uniform(50, 500)),
        )

    def requests(self, vocab_size: int, prompt_lo: int, prompt_hi: int
                 ) -> List[Request]:
        """Seeded bursty open-loop workload: arrivals land in bursts of
        ``burst_size`` every ``burst_gap`` boundary steps; prompt and
        output lengths are heavy-tailed (mostly short, a fat tail of
        long) — the overload shape a production engine must degrade
        under, not the uniform trickle it is tuned on."""
        rng = np.random.default_rng(self.seed + 1)       # independent stream
        out: List[Request] = []
        for i in range(self.n_requests):
            burst, lane = divmod(i, self.burst_size)
            # Pareto-ish tail for lengths, clamped to the engine bounds
            plen = int(np.clip(prompt_lo + rng.pareto(2.0) * prompt_lo,
                               prompt_lo, prompt_hi))
            mnew = int(np.clip(self.max_new_lo + rng.pareto(1.5) * 2,
                               self.max_new_lo, self.max_new_hi))
            has_dl = rng.uniform() < self.deadline_frac
            out.append(Request(
                rid=i,
                prompt=rng.integers(0, vocab_size, plen, dtype=np.int32),
                max_new_tokens=mnew,
                arrival_step=burst * self.burst_gap,
                priority=int(rng.integers(0, 3)),
                ttft_deadline_ms=self.ttft_deadline_ms if has_dl else 0.0))
        return out


# ---------------------------------------------------------------------------
# FaultInjector — the live hook
# ---------------------------------------------------------------------------

class FaultInjector:
    """Consumes the plan's seeded random stream one draw per dispatch, so
    the injected fault sequence is a pure function of (plan, dispatch
    order) — and dispatch order is deterministic for a fixed engine
    config. Passed to ``ServingEngine(fault_injector=...)``."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._rng = np.random.default_rng(plan.seed + 2)
        self.injected_failures = 0
        self.injected_slowdowns = 0
        self.dispatches = 0

    # -- StaticRuntime dispatch interceptor -----------------------------
    def on_dispatch(self, name: str):
        self.dispatches += 1
        u = float(self._rng.uniform())
        if u < self.plan.fail_rate:
            self.injected_failures += 1
            raise DispatchError(
                f"injected dispatch failure #{self.injected_failures} "
                f"for {name!r} (seed {self.plan.seed})")
        if u < self.plan.fail_rate + self.plan.slow_rate and self.plan.slow_s:
            self.injected_slowdowns += 1
            time.sleep(self.plan.slow_s)

    # -- artificial KV pressure -----------------------------------------
    def slots_held(self, step: int) -> int:
        p = self.plan
        if not p.pressure_period or not p.pressure_slots:
            return 0
        phase = (step % p.pressure_period) / p.pressure_period
        return p.pressure_slots if phase < p.pressure_duty else 0

    def counters(self) -> Dict[str, int]:
        return {"dispatches": self.dispatches,
                "injected_failures": self.injected_failures,
                "injected_slowdowns": self.injected_slowdowns}


# ---------------------------------------------------------------------------
# Invariant checker
# ---------------------------------------------------------------------------

def check_invariants(engine: ServingEngine, stats: Dict[str, Any],
                     requests: List[Request],
                     reference: Optional[Dict[int, List[int]]] = None
                     ) -> List[str]:
    """Audit one finished ``run()``. Returns violation strings (empty =
    green). ``reference`` maps rid → token list from a CLEAN run of the
    same workload on the same engine config; every request the chaos run
    COMPLETED must match it byte for byte (preemption/restore and victim
    shedding may change WHO finishes, never WHAT a finisher says)."""
    bad: List[str] = []

    # 1. terminal accounting: exactly one outcome per request
    terminal_rids = set()
    for r in requests:
        if r.status not in TERMINAL:
            bad.append(f"rid {r.rid}: non-terminal status {r.status!r}")
        if r.rid in terminal_rids:
            bad.append(f"rid {r.rid}: duplicated in request list")
        terminal_rids.add(r.rid)
        if r.swap is not None:
            bad.append(f"rid {r.rid}: terminal but still holds a swap "
                       "image")
    completed = {r.rid for r in requests if r.status == "completed"}
    stats_rids = {m["rid"] for m in stats["per_request"]}
    if completed != stats_rids:
        bad.append(f"completed set mismatch: requests say "
                   f"{sorted(completed)}, stats say {sorted(stats_rids)}")
    shed = {e["rid"] for e in stats.get("rejected", [])}
    want_shed = {r.rid for r in requests
                 if r.status in ("rejected", "deadline_missed")}
    if shed != want_shed:
        bad.append(f"shed set mismatch: requests say {sorted(want_shed)}, "
                   f"stats say {sorted(shed)}")

    # 2. emission log: per rid the token indices must be exactly
    #    0,1,2,...,n-1 IN ORDER — one line proves no token was
    #    duplicated, lost or reordered on its way to the host
    per_rid: Dict[int, List[int]] = {}
    for rid, idx in engine._emit_log:
        per_rid.setdefault(rid, []).append(idx)
    for r in requests:
        got = per_rid.get(r.rid, [])
        want = list(range(len(r.generated)))
        if got != want:
            bad.append(f"rid {r.rid}: emission log {got[:8]}... != "
                       f"contiguous 0..{len(r.generated) - 1}")

    # 3. occupancy at end of run: the scheduler must have drained (or the
    #    run hit max_steps — surfaced as non-terminal statuses above)
    sched = getattr(engine, "_sched", None)
    if sched is not None:
        bad.extend(sched.invariant_violations())
        for i in range(sched.n):
            if sched.phase[i] != sched.FREE and sched.req[i] is not None\
                    and sched.req[i].status in TERMINAL:
                bad.append(f"slot {i}: occupied by terminal rid "
                           f"{sched.req[i].rid}")

    # 4. conservation: restores never exceed preemptions; the difference
    #    is exactly the preempted-then-shed population
    if stats["restores"] > stats["preemptions"]:
        bad.append(f"restores {stats['restores']} > preemptions "
                   f"{stats['preemptions']}")

    # 5. token-byte equality of completed requests vs the clean run
    if reference is not None:
        for r in requests:
            if r.status != "completed":
                continue
            if reference.get(r.rid) != r.generated:
                bad.append(
                    f"rid {r.rid}: completed tokens diverge from the "
                    f"clean run ({r.generated[:6]}... vs "
                    f"{reference.get(r.rid, [])[:6]}...)")
    return bad


# ---------------------------------------------------------------------------
# run_chaos — clean run, chaos run, audit
# ---------------------------------------------------------------------------

def clone_requests(requests: List[Request]) -> List[Request]:
    """Fresh Request objects for a run (``run()`` mutates its requests):
    only the WORKLOAD fields carry over — status, stamps, generated
    tokens and swap images all restart from their defaults."""
    return [Request(rid=r.rid, prompt=r.prompt.copy(),
                    max_new_tokens=r.max_new_tokens,
                    arrival_step=r.arrival_step, eos_id=r.eos_id,
                    priority=r.priority,
                    ttft_deadline_ms=r.ttft_deadline_ms,
                    tpot_deadline_ms=r.tpot_deadline_ms)
            for r in requests]


def run_chaos(engine: ServingEngine, params, plan: FaultPlan,
              requests: List[Request], max_steps: int = 20_000
              ) -> Dict[str, Any]:
    """One seeded chaos schedule end to end on ``engine``:

    1. CLEAN reference run (injector cleared) → rid → tokens map,
    2. chaos run with ``FaultInjector(plan)`` installed,
    3. ``check_invariants`` over the chaos run against the reference.

    The same engine serves both (programs compile once); the injector is
    cleared afterwards so the engine is reusable. Returns a report dict —
    ``report["violations"] == []`` is the green condition."""
    clean = clone_requests(requests)
    engine.fault_injector = None
    clean_stats = engine.run(params, clean, max_steps=max_steps)
    reference = {r.rid: list(r.generated) for r in clean}
    if clean_stats["completed"] != len(clean):
        raise ValueError(
            f"clean run incomplete ({clean_stats['completed']}/"
            f"{len(clean)}): the workload must fit the engine before "
            "chaos means anything")

    inj = FaultInjector(plan)
    chaos = clone_requests(requests)
    engine.fault_injector = inj
    try:
        stats = engine.run(params, chaos, max_steps=max_steps)
    finally:
        engine.fault_injector = None
        engine.rt.set_interceptor(None)
    violations = check_invariants(engine, stats, chaos, reference)
    return {
        "seed": plan.seed,
        "violations": violations,
        "injected": inj.counters(),
        "completed": stats["completed"],
        "rejections": stats["rejections"],
        "deadline_misses": stats["deadline_misses"],
        "preemptions": stats["preemptions"],
        "restores": stats["restores"],
        "retries": stats["retries"],
        "quarantined_slots": stats["quarantined_slots"],
    }


# ---------------------------------------------------------------------------
# CLI smoke
# ---------------------------------------------------------------------------

def _main(argv=None):
    import argparse

    import jax

    from repro.configs.registry import ASSIGNED
    from repro.models import NULL_CTX, build_model

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--seeds", type=int, default=5)
    ap.add_argument("--seed0", type=int, default=0)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--block-size", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = ASSIGNED["qwen2-0.5b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    prompt_len = 8
    eng = ServingEngine(api, NULL_CTX, args.slots, prompt_len,
                        mode="continuous", block_size=args.block_size,
                        prefill_chunk=4, preemptible=True, max_queue=16,
                        max_retries=2, strict_invariants=True)
    red = 0
    for seed in range(args.seed0, args.seed0 + args.seeds):
        plan = FaultPlan.generate(seed)
        reqs = plan.requests(cfg.vocab_size, prompt_lo=4,
                             prompt_hi=prompt_len + 8)
        rep = run_chaos(eng, params, plan, reqs)
        status = "green" if not rep["violations"] else "RED"
        red += bool(rep["violations"])
        print(f"seed {seed:3d} {status:5s} completed={rep['completed']} "
              f"rej={rep['rejections']} miss={rep['deadline_misses']} "
              f"preempt={rep['preemptions']} restore={rep['restores']} "
              f"inj={rep['injected']['injected_failures']}")
        for v in rep["violations"]:
            print(f"         - {v}")
    print(f"{args.seeds - red}/{args.seeds} schedules green")
    return 1 if red else 0


if __name__ == "__main__":
    raise SystemExit(_main())
