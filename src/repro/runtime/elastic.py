"""Elastic scaling / fault tolerance / straggler mitigation.

At 1000+ nodes, single-chip MTBF makes failures routine. The controller
implements the standard recovery loop for TPU-style SPMD jobs:

  detect (health probe / timeout) → exclude failed domain → re-mesh to the
  largest valid (data′, model) grid → re-compile from the AOT cache →
  restore latest checkpoint (re-sharded on load) → resume (deterministic
  data pipeline replays from the restored step).

The data axis shrinks (DP is elastic); the model axis is preserved because
TP-sharded weights assume that divisor (same policy as production serving
stacks). Straggler mitigation is a step-deadline policy: per-step durations
feed an EWMA; a step exceeding ``k×`` the EWMA marks the slow domain
suspect — after ``patience`` consecutive marks the domain is treated as
failed and excluded (grey-failure handling, i.e. stragglers ARE failures in
steady-state decode, where the pipeline rate is the slowest stage — the
paper's T = 1/l).

On this CPU host, failures are injected (``inject_failure``) and the device
set is simulated; the control flow (re-mesh, restore, resume) is the real
code path and is unit-tested.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple



class NodeFailure(RuntimeError):
    def __init__(self, domain: int, reason: str = "health-probe"):
        super().__init__(f"domain {domain} failed ({reason})")
        self.domain = domain
        self.reason = reason


@dataclass
class ElasticController:
    n_data: int                       # current data-axis size
    n_model: int                      # fixed model-axis size
    n_pod: int = 1
    ewma_alpha: float = 0.2
    straggler_factor: float = 3.0
    patience: int = 3
    min_data: int = 1
    failed_domains: List[int] = field(default_factory=list)
    _ewma: Optional[float] = None
    _suspect: Dict[int, int] = field(default_factory=dict)
    events: List[str] = field(default_factory=list)

    # ------------------------------------------------------------------
    @property
    def healthy_data(self) -> int:
        return self.n_data - len(self.failed_domains)

    def mesh_shape(self) -> Tuple[int, ...]:
        """Largest valid mesh after failures: data axis rounded down to a
        power-of-two-friendly divisor of the batch."""
        d = self.healthy_data
        # keep data a divisor of the original (batch divisibility)
        while d > self.min_data and self.n_data % d != 0:
            d -= 1
        d = max(d, self.min_data)
        if self.n_pod > 1:
            return (self.n_pod, d, self.n_model)
        return (d, self.n_model)

    # ------------------------------------------------------------------
    def inject_failure(self, domain: int, reason: str = "injected"):
        if domain not in self.failed_domains:
            self.failed_domains.append(domain)
            self.events.append(f"FAIL domain={domain} reason={reason}")

    def observe_step(self, duration_s: float,
                     slow_domain: Optional[int] = None) -> Optional[int]:
        """Feed one step duration; returns a domain to evict, or None."""
        if self._ewma is None:
            self._ewma = duration_s
            return None
        if duration_s > self.straggler_factor * self._ewma \
                and slow_domain is not None:
            self._suspect[slow_domain] = self._suspect.get(slow_domain, 0) + 1
            self.events.append(
                f"STRAGGLER domain={slow_domain} x{duration_s / self._ewma:.1f} "
                f"strike={self._suspect[slow_domain]}")
            if self._suspect[slow_domain] >= self.patience:
                self.inject_failure(slow_domain, "straggler")
                del self._suspect[slow_domain]
                return slow_domain
        else:
            self._ewma = (1 - self.ewma_alpha) * self._ewma \
                + self.ewma_alpha * duration_s
        return None

    # ------------------------------------------------------------------
    def recover(self, make_mesh: Callable[[Tuple[int, ...]], object],
                recompile: Callable[[object], object],
                restore: Callable[[object], Tuple[int, object]]):
        """Run the recovery loop; returns (mesh, step, state, compiled)."""
        shape = self.mesh_shape()
        self.events.append(f"REMESH shape={shape}")
        mesh = make_mesh(shape)
        compiled = recompile(mesh)
        step, state = restore(mesh)
        self.events.append(f"RESUME step={step}")
        return mesh, step, state, compiled
