"""Pure-JAX AdamW with global-norm clipping and cosine schedule.

Moments stored f32 regardless of param dtype (mixed-precision training
standard). State is a plain pytree → shards with the params and checkpoints
through repro.checkpoint.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    mu: Any                  # f32 pytree, like params
    nu: Any                  # f32 pytree, like params


def adamw_init(params) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def cosine_lr(step: jax.Array, base_lr: float, warmup: int,
              total: int, min_frac: float = 0.1) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(s < warmup, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0
                 ) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / c1) / (jnp.sqrt(v / c2) + eps)
        new_p = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, mu, nu), {"grad_norm": gnorm}
