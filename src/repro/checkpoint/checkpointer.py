"""Fault-tolerant checkpointing (no orbax dependency).

Design goals (the large-scale-runnability requirements):
- **atomic**: write to ``step_XXXX.tmp`` dir, fsync, rename — a crash mid-save
  never corrupts the latest good checkpoint;
- **resumable**: ``latest_step()`` scans for the newest complete checkpoint;
  the training driver restores params/opt state/data step and continues;
- **sharded-aware**: arrays are pulled host-side per-leaf (on a real multi-host
  pod each host would write its addressable shards; the layout here is the
  single-process form of that protocol, with the leaf manifest making the
  format host-count independent);
- **self-describing**: a JSON manifest stores the pytree structure, shapes and
  dtypes so restoration validates compatibility before loading (and an elastic
  re-mesh can re-shard on load).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"
_DONE = "DONE"


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_leaves_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "name",
                       getattr(p, "idx", p)))) for p in path)
        out[key] = leaf
    return out


def save_pytree(tree, directory: str, step: int) -> str:
    """Atomic save: <dir>/step_<step>/ with npz shards + manifest + DONE."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _leaf_paths(tree)
    manifest = {}
    for i, (key, leaf) in enumerate(sorted(leaves.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        logical = jnp.dtype(leaf.dtype).name if hasattr(leaf, "dtype") \
            else str(arr.dtype)
        if logical == "bfloat16":          # np.save can't round-trip bf16
            np.save(os.path.join(tmp, fname), arr.view(np.uint16))
        else:
            np.save(os.path.join(tmp, fname), arr)
        manifest[key] = {"file": fname, "shape": list(arr.shape),
                         "dtype": logical}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    with open(os.path.join(tmp, _DONE), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, _DONE)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_pytree(template, directory: str, step: int,
                   shardings=None):
    """Restore into ``template``'s structure; optional pytree of shardings
    re-shards on load (elastic re-mesh path)."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)["leaves"]
    leaves = _leaf_paths(template)
    shard_leaves = _leaf_paths(shardings) if shardings is not None else {}
    restored = {}
    for key, leaf in leaves.items():
        meta = manifest.get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(d, meta["file"]))
        if meta["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        want = tuple(getattr(leaf, "shape", ()) or ())
        if want and tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs template {want}")
        sh = shard_leaves.get(key)
        restored[key] = (jax.device_put(arr, sh) if sh is not None
                         else jnp.asarray(arr))

    # rebuild in template order
    flat, treedef = jax.tree_util.tree_flatten(template)
    paths = jax.tree_util.tree_leaves_with_path(template)
    ordered = []
    for path, _ in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "name",
                       getattr(p, "idx", p)))) for p in path)
        ordered.append(restored[key])
    return jax.tree_util.tree_unflatten(treedef, ordered)


class Checkpointer:
    """Keep-last-k policy + convenience save/restore of train state."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, step: int, **trees) -> str:
        path = save_pytree(trees, self.directory, step)
        self._gc()
        return path

    def restore(self, template_trees: Dict[str, Any], step: Optional[int] = None,
                shardings=None):
        step = step if step is not None else latest_step(self.directory)
        if step is None:
            return None, None
        tree = restore_pytree(template_trees, self.directory, step, shardings)
        return step, tree

    def _gc(self):
        steps = sorted(s for s in (
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
