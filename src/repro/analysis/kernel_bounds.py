"""Kernel bounds checker (pass 5).

Two invariants at the bottom of the stack:

  K1  flash-decode grid coverage + live kv_limit: the Pallas grid must
      tile the FULL KV extent of every operand (an under-covering grid
      silently drops tail KV — attention quietly forgets the newest
      positions), and the traced ``kv_limit`` operand must actually be
      READ by the kernel body (a dead limit means the tile early-out — the
      whole point of the traced operand — is gone). Checked by evaluating
      each BlockSpec index map over every grid point and unioning the
      covered index ranges; no TPU needed, tracing is enough.

  K2  chunk-write slot isolation: the chunked-prefill lane writes each
      (1, n_kv, C, hd) chunk with ``dynamic_update_slice`` at a TRACED
      slot offset. Its update extent along the slot axis must be 1 — an
      extent > 1 with a traced start could alias a neighbouring slot's
      live KV at runtime and no runtime check would ever fire (DUS clamps,
      it does not trap). Stack-level writes (extent == slots) are safe
      only at a LITERAL 0 offset.

The serving programs on CPU dispatch the jnp reference kernel, so K1 runs
against the kernel library directly at every (bucket, shard) shape the
cell's engine would serve — same shapes, same dtypes, no hardware.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import numpy as np
from jax import core as jax_core

from repro.analysis.findings import Report
from repro.analysis.jaxpr_walk import iter_eqns, literal_value
from repro.analysis.programs import Cell, ProgramRecord
from repro.kv.cache import KVCache

PASS = "kernel_bounds"

_MAX_GRID_POINTS = 65536


# ---------------------------------------------------------------------------
# K1: pallas grid coverage + kv_limit liveness
# ---------------------------------------------------------------------------

def _eval_index_map(bm, idx: Tuple[int, ...]) -> Optional[Tuple[int, ...]]:
    im = getattr(bm, "index_map_jaxpr", None)
    if im is None:
        return None
    try:
        out = jax_core.eval_jaxpr(im.jaxpr, im.consts,
                                  *[np.int32(i) for i in idx])
        return tuple(int(x) for x in out)
    except Exception:
        return None


def check_pallas_sites(jaxpr, program: str, report: Report,
                       expect_limit: bool = False) -> int:
    """Audit every pallas_call in ``jaxpr``; returns how many were seen."""
    seen = 0
    for site in iter_eqns(jaxpr):
        eqn = site.eqn
        if eqn.primitive.name != "pallas_call":
            continue
        seen += 1
        gm = eqn.params.get("grid_mapping")
        if gm is None:
            report.warning(PASS, program, "pallas_call",
                           "no grid_mapping param — cannot audit bounds")
            continue
        grid = tuple(int(g) for g in gm.grid)
        npts = int(np.prod(grid, dtype=np.int64)) if grid else 1
        if npts > _MAX_GRID_POINTS:
            report.warning(PASS, program, "pallas_call",
                           f"grid {grid} too large to enumerate "
                           f"({npts} points) — coverage unchecked")
            continue
        n_in = getattr(gm, "num_inputs", None)
        mappings = list(gm.block_mappings)
        in_avals = [v.aval for v in eqn.invars]
        if n_in is None:
            n_in = min(len(mappings), len(in_avals))
        pts = [()] if not grid else list(np.ndindex(*grid))
        for op_i in range(min(n_in, len(mappings), len(in_avals))):
            _check_coverage(program, report, op_i, in_avals[op_i],
                            mappings[op_i], pts)
        if expect_limit:
            _check_limit_live(program, report, eqn, in_avals[:n_in])
    return seen


def _check_coverage(program: str, report: Report, op_i: int, aval,
                    bm, pts: List[Tuple[int, ...]]):
    bshape = tuple(1 if b is None else int(b)
                   for b in getattr(bm, "block_shape", ()))
    if len(bshape) != len(aval.shape) or not pts:
        return
    starts = set()
    for p in pts:
        s = _eval_index_map(bm, p)
        if s is None:
            return                      # exotic index map — skip, don't lie
        starts.add(s)
    for d, (extent, blk) in enumerate(zip(aval.shape, bshape)):
        covered = set()
        for s in starts:
            lo = s[d] * blk
            covered.update(range(lo, min(lo + blk, extent)))
        if len(covered) != extent:
            missing = sorted(set(range(extent)) - covered)
            report.error(
                PASS, program,
                f"pallas operand {op_i} ({aval.shape}:{aval.dtype}) dim {d}",
                f"grid tiles cover only {len(covered)}/{extent} positions "
                f"(first missing: {missing[:4]}) — the kernel silently "
                "drops the uncovered KV tail; grid/block_s do not tile "
                "the extent")


def _check_limit_live(program: str, report: Report, eqn, in_avals):
    """The (1,1) int32 kv_limit operand must be consumed by the kernel."""
    lim_idx = [i for i, a in enumerate(in_avals)
               if tuple(a.shape) == (1, 1) and a.dtype == np.int32]
    if not lim_idx:
        report.error(
            PASS, program, "kv_limit",
            "flash-decode pallas_call has NO (1,1) int32 kv_limit "
            "operand — tile early-out is impossible and every dispatch "
            "walks the full padded extent")
        return
    kjaxpr = eqn.params.get("jaxpr")
    if kjaxpr is None:
        return
    kj = kjaxpr.jaxpr if isinstance(kjaxpr, jax_core.ClosedJaxpr) else kjaxpr
    for i in lim_idx:
        if i >= len(kj.invars):
            continue
        ref = kj.invars[i]
        used = any(ref in site.eqn.invars for site in iter_eqns(kj))
        if not used:
            report.error(
                PASS, program, f"kv_limit (operand {i})",
                "kv_limit ref is never read inside the kernel body — the "
                "early-out is dead code and padded tiles all execute")


# ---------------------------------------------------------------------------
# K1 driver: trace the kernel library at the cell's serving shapes
# ---------------------------------------------------------------------------

def _flash_shapes(cell: Cell) -> List[Tuple[str, int]]:
    """(label, kv extent) pairs the cell's engine would hand the kernel:
    each KV bucket, and each per-shard extent under split-KV."""
    backend = cell.backend
    caches = cell.caches_aval
    if not isinstance(caches, KVCache):
        return []
    S_full = caches.k.shape[3]
    out = []
    buckets = [b for b in (backend.buckets or ()) if b > 0] or [S_full]
    for b in buckets:
        sh = cell.spec.a_shards
        if sh > 1:
            out.append((f"bucket {b} / {sh} shards", b // sh))
        else:
            out.append((f"bucket {b}", b))
    return out


def check_kernel_library(cell: Cell, report: Report):
    from repro.kernels.flash_decode.flash_decode import flash_decode_pallas
    caches = cell.caches_aval
    if not isinstance(caches, KVCache):
        report.info(PASS, "<kernel>", cell.spec.label,
                    "attention-free family: no flash-decode kernel")
        return
    _L, B, n_kv, _S, hd = caches.k.shape
    Hq = cell.cfg.n_heads
    quant = caches.k_scale is not None
    kv_dtype = caches.k.dtype
    if caches.is_tiered:
        # the tiered read dequantizes the cold prefix and merges it with
        # the hot ring BEFORE attention — the kernel sees the compute-dtype
        # image at the full head_dim (int4's packed hd/2 and the cold
        # scales never reach it)
        hd = caches.hot_k.shape[4]
        kv_dtype = caches.hot_k.dtype
        quant = False
    for label, S in _flash_shapes(cell):
        for bs in {S, max(S // 2, 1)}:
            if S % bs:
                continue

            def trace(q, k, v, ks, vs, mask, lim, _bs=bs):
                return flash_decode_pallas(q, k, v, ks, vs, mask,
                                           block_s=_bs, kv_limit=lim)

            q = jax.ShapeDtypeStruct((B, Hq, hd), np.float32)
            kv = jax.ShapeDtypeStruct((B, n_kv, S, hd), kv_dtype)
            sc = jax.ShapeDtypeStruct((B, n_kv, S, 1), np.float32)\
                if quant else None
            mask = jax.ShapeDtypeStruct((B, S), np.bool_)
            lim = jax.ShapeDtypeStruct((1, 1), np.int32)
            try:
                jaxpr = jax.make_jaxpr(trace)(q, kv, kv, sc, sc, mask, lim)
            except Exception as e:
                report.error(PASS, f"flash_decode[{label}]", f"block_s={bs}",
                             "kernel fails to trace at serving shape "
                             f"(B={B}, n_kv={n_kv}, S={S}, hd={hd}): {e}")
                continue
            n = check_pallas_sites(jaxpr, f"flash_decode[{label}]", report,
                                   expect_limit=True)
            if n == 0:
                report.error(PASS, f"flash_decode[{label}]", "pallas_call",
                             "no pallas_call traced — the kernel path "
                             "silently fell back")


# ---------------------------------------------------------------------------
# K2: chunk-write slot isolation
# ---------------------------------------------------------------------------

def check_chunk_writes(cell: Cell, rec: ProgramRecord, report: Report):
    caches = cell.caches_aval
    if not isinstance(caches, KVCache):
        return
    # tiered colocated monolithic admission compiles a chunk BODY under the
    # "serve_admit" name (kind "admit") — its traced-offset DUS writes get
    # the same slot-isolation audit as the chunked lane
    tiered_admit = rec.kind == "admit" and caches.is_tiered
    if rec.kind != "chunk" and not tiered_admit:
        return
    try:
        jaxpr = rec.step.jaxpr()
    except (ValueError, TypeError) as e:
        report.warning(PASS, rec.name, "jaxpr",
                       f"could not retrace for chunk-write audit: {e}")
        return
    leaves = [leaf for leaf in jax.tree_util.tree_leaves(caches)
              if getattr(leaf, "ndim", 0) == 5]
    slice_shapes = {leaf.shape[1:] for leaf in leaves}   # (B, n_kv, S, *)
    stack_shapes = {leaf.shape for leaf in leaves}       # (L, B, n_kv, S, *)
    B = cell.spec.slots
    n_checked = 0
    for site in iter_eqns(jaxpr):
        eqn = site.eqn
        if eqn.primitive.name != "dynamic_update_slice":
            continue
        dst, upd, *starts = eqn.invars
        dshape = tuple(dst.aval.shape)
        if dshape in slice_shapes:                       # per-layer write
            slot_dim = 0
        elif dshape in stack_shapes:                     # whole-stack write
            slot_dim = 1
        else:
            continue
        n_checked += 1
        extent = upd.aval.shape[slot_dim]
        start = literal_value(starts[slot_dim])
        if extent == 1:
            continue
        if extent == dshape[slot_dim] and start == 0:
            continue                                     # full-width literal
        report.error(
            PASS, rec.name,
            f"dynamic_update_slice dst {dshape} slot dim {slot_dim}",
            f"chunk write updates {extent} slots at "
            f"{'a TRACED offset' if start is None else f'offset {start}'} "
            "— a masked chunk/shard write may alias a neighbouring "
            f"slot's live KV (slot-extent must be 1, got {extent} of "
            f"{B} slots)")
    if n_checked == 0:
        report.warning(PASS, rec.name, "dynamic_update_slice",
                       "no cache-shaped DUS writes found in the chunk "
                       "program — the slot-isolation audit matched nothing "
                       "(cache write idiom changed?)")


def check_kernel_bounds(cell: Cell, report: Report):
    # serving programs (CPU programs carry no pallas_call; audit anyway —
    # on TPU builds the same pass sees the real kernels in-program)
    for rec in cell.records:
        try:
            jaxpr = rec.step.jaxpr()
        except (ValueError, TypeError):
            continue
        check_pallas_sites(jaxpr, rec.name, report)
        check_chunk_writes(cell, rec, report)
    check_kernel_library(cell, report)
