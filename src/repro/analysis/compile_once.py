"""Compile-once auditor (pass 2).

Invariant (§4.3 pinned-pool): every serving program compiles EXACTLY once
per (mesh, signature) — dispatch is a cached call with zero retracing. Two
signatures under one ``serve_*`` name mean some operand's shape/dtype
drifts between dispatches and the runtime silently recompiles on the
latency-critical path. Weak-typed leaves are the classic cause: a bare
python scalar reaching an operand slot traces to a weak dtype, and the
first committed array at the same slot retraces the program.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Tuple

from repro.analysis.findings import Report
from repro.analysis.programs import Cell
from repro.runtime.static_runtime import StaticRuntime

PASS = "compile_once"


def _sig_diff(a: Tuple, b: Tuple) -> str:
    if len(a) != len(b):
        return f"leaf count {len(a)} vs {len(b)}"
    for i, (la, lb) in enumerate(zip(a, b)):
        if la != lb:
            return f"leaf {i}: {la} vs {lb}"
    return "identical signatures under distinct keys"


def audit_runtime(rt: StaticRuntime, report: Report,
                  expect_serve_prefix: bool = True):
    by_name: Dict[str, List[Tuple[Tuple, object]]] = defaultdict(list)
    for (name, _mesh_id, sig), step in rt._cache.items():
        by_name[name].append((sig, step))

    for name, entries in sorted(by_name.items()):
        if expect_serve_prefix and not name.startswith("serve"):
            report.warning(
                PASS, name, "program name",
                "non-serve_* program registered in the serving runtime — "
                "the zero-retracing audit only covers named serving steps")
        if len(entries) > 1:
            (sig0, _), (sig1, _) = entries[0], entries[1]
            report.error(
                PASS, name, f"{len(entries)} signatures",
                f"program compiled under {len(entries)} distinct operand "
                "signatures — every dispatch whose operands alternate "
                "between them retraces on the critical path "
                f"({_sig_diff(sig0, sig1)})")
        for sig, step in entries:
            for i, leaf in enumerate(sig):
                shape, dtype, weak = leaf
                if weak:
                    report.error(
                        PASS, name, f"operand leaf {i}",
                        f"weak-typed {dtype}{list(shape or ())} in the "
                        "compile signature — a bare python scalar reached "
                        "this slot; the first committed array here "
                        "retraces the program (wrap with jnp.asarray / "
                        "an explicit dtype)")


def check_compile_once(cell: Cell, report: Report):
    audit_runtime(cell.rt, report)
    # cross-check the registry against what the engine exposes: every
    # dispatched program handle must be IN the audited cache (a handle
    # compiled outside StaticRuntime would dodge the zero-retrace stats)
    names = {name for (name, *_rest) in cell.rt._cache}
    for rec in cell.records:
        if rec.name not in names:
            report.error(PASS, rec.name, "registry",
                         "program handle not present in the StaticRuntime "
                         "cache — compiled outside the audited path")
