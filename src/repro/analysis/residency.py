"""Residency checker (pass 1).

Invariant (paper §3.1): KV stays resident in the A domain — under the WA
backend the cache's sequence axis is sharded over the model axis
(``seq_sharded_kv``) and every serving program must consume AND produce the
cache in that layout; weights stay planned under the W-domain rules. The
failure this guards is exactly the PR 5 reshape bug: GSPMD cannot
back-propagate a shard-major annotation through a reshape, so one dropped
``with_sharding_constraint`` makes the compiled program accept a REPLICATED
cache — every device holds (and updates) the full KV, silently.

Checks, per serving program on a real (dry-run) mesh:

  R1  WA cells: each KV leaf's compiled input sharding is equivalent to
      the A-domain plan whenever that plan shards the sequence/shard axis
      → ERROR on mismatch (the bug class above).
  R2  cache coherence: every program in a cell agrees on each cache leaf's
      input sharding, and each donating program's OUTPUT cache sharding
      equals its input (donated buffers round-trip stably; a disagreement
      = one full cache reshard per dispatch) → ERROR.
  R3  weight placement: compiled weight shardings vs the W-domain plan
      (``param_specs``). The serving driver feeds uncommitted params, so
      GSPMD may legitimately pick replication for small leaves → WARNING
      by default, ERROR under strict_weights.
  R4  no cache-sized collectives: any collective moving ≥ one full
      per-layer KV slice per dispatch means KV crosses domains → ERROR.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.kv.cache import KVCache
from repro.launch.hlo_analysis import parse_collectives
from repro.models.param_specs import cache_specs, param_specs
from repro.analysis.findings import Report
from repro.analysis.programs import Cell, ProgramRecord

PASS = "residency"


_KV_FIELDS = ("k", "v", "k_scale", "v_scale", "hot_k", "hot_v", "length")


def _leaf_paths(tree) -> List[str]:
    # KVCache registers flat children (no keypaths) — keystr would print
    # "<flat index N>"; name its fields so diagnostics are actionable
    if isinstance(tree, KVCache):
        kids = (tree.k, tree.v, tree.k_scale, tree.v_scale,
                tree.hot_k, tree.hot_v, tree.length)
        return [f".{name}" for name, kid in zip(_KV_FIELDS, kids)
                if kid is not None]
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(path) for path, _leaf in flat]


def _shardings_for_arg(rec: ProgramRecord, role: str):
    """Flat list of compiled input shardings for the given role's arg."""
    idx = rec.arg_roles.get(role)
    if idx is None:
        return None
    per_arg = rec.step.compiled.input_shardings[0]
    return jax.tree_util.tree_leaves(per_arg[idx])


def _output_cache_shardings(rec: ProgramRecord, caches_aval):
    """Compiled output shardings of the caches subtree, or None when the
    program's output does not lead with a caches-shaped tree."""
    out_sh = rec.step.compiled.output_shardings
    c_struct = jax.tree_util.tree_structure(caches_aval)
    if jax.tree_util.tree_structure(out_sh) == c_struct:
        return jax.tree_util.tree_leaves(out_sh)
    if isinstance(out_sh, tuple) and out_sh and\
            jax.tree_util.tree_structure(out_sh[0]) == c_struct:
        return jax.tree_util.tree_leaves(out_sh[0])
    return None


def _equiv(a, b, ndim: int) -> bool:
    try:
        return a.is_equivalent_to(b, ndim)
    except (TypeError, ValueError):
        return False


def check_residency(cell: Cell, report: Report,
                    strict_weights: bool = False):
    if cell.mesh is None:
        report.info(PASS, "<cell>", cell.spec.label,
                    "no mesh: residency is vacuous on a single device")
        return
    caches_aval = cell.caches_aval
    cache_paths = _leaf_paths(caches_aval)
    cache_leaves = jax.tree_util.tree_leaves(caches_aval)
    exp_specs = jax.tree_util.tree_leaves(
        cache_specs(caches_aval, cell.cache_ctx), is_leaf=lambda x:
        isinstance(x, jax.sharding.PartitionSpec))
    seen: Dict[str, tuple] = {}          # leaf path → (program, sharding)

    for rec in cell.records:
        # R4: cache-sized collectives
        _check_cache_collectives(cell, rec, caches_aval, report)
        # R3: weight placement
        _check_weights(cell, rec, report, strict_weights)
        got = _shardings_for_arg(rec, "caches")
        if got is None:
            continue
        # R1: A-domain plan adherence (the planned-sharded leaves)
        for path, leaf, spec, sh in zip(cache_paths, cache_leaves,
                                        exp_specs, got):
            planned = NamedSharding(cell.mesh, spec)
            plan_shards = any(p is not None for p in spec)
            if plan_shards and not _equiv(sh, planned, len(leaf.shape)):
                detail = ("compiled REPLICATED — every device holds the "
                          "full KV (the PR 5 reshape-dropped-annotation "
                          "failure)") if sh.is_fully_replicated else\
                    f"compiled {sh}"
                report.error(
                    PASS, rec.name, f"caches{path}",
                    f"KV leaf planned {spec} in the "
                    f"{cell.cache_ctx.rules.name} domain but {detail}; "
                    "re-pin the cache operand with ann(..., 'kv_seq', ...)")
            # R2a: cross-program coherence
            prev = seen.get(path)
            if prev is None:
                seen[path] = (rec.name, sh)
            elif not _equiv(sh, prev[1], len(leaf.shape)):
                report.error(
                    PASS, rec.name, f"caches{path}",
                    f"cache leaf sharding {sh} disagrees with "
                    f"{prev[0]}'s {prev[1]} — the donated cache buffer is "
                    "resharded every time dispatch alternates between "
                    "these programs")
        # R2b: donated output == input (round-trip stability)
        out_sh = _output_cache_shardings(rec, caches_aval)
        if out_sh is not None and rec.step.donate_argnums:
            for path, leaf, ish, osh in zip(cache_paths, cache_leaves,
                                            got, out_sh):
                if not _equiv(ish, osh, len(leaf.shape)):
                    report.error(
                        PASS, rec.name, f"caches{path}",
                        f"donated cache leaf enters as {ish} but is "
                        f"produced as {osh} — the donation aliases "
                        "mismatched layouts (reshard per dispatch)")


def _check_weights(cell: Cell, rec: ProgramRecord, report: Report,
                   strict: bool):
    got = _shardings_for_arg(rec, "params")
    if got is None:
        return
    paths = _leaf_paths(cell.params_aval)
    leaves = jax.tree_util.tree_leaves(cell.params_aval)
    specs = jax.tree_util.tree_leaves(
        param_specs(cell.params_aval, cell.w_ctx), is_leaf=lambda x:
        isinstance(x, jax.sharding.PartitionSpec))
    emit = report.error if strict else report.warning
    for path, leaf, spec, sh in zip(paths, leaves, specs, got):
        plan_shards = any(p is not None for p in spec)
        planned = NamedSharding(cell.mesh, spec)
        if plan_shards and not _equiv(sh, planned, len(leaf.shape)):
            emit(PASS, rec.name, f"params{path}",
                 f"weight planned {spec} under "
                 f"{cell.w_ctx.rules.name} but compiled "
                 f"{'replicated' if sh.is_fully_replicated else str(sh)} — "
                 "the leaf materializes outside its W-domain shard "
                 "(cache-residency budget assumes the plan)")


def _check_cache_collectives(cell: Cell, rec: ProgramRecord, caches_aval,
                             report: Report):
    if not isinstance(caches_aval, KVCache):
        return
    if rec.kind in ("swap_out", "swap_in"):
        # the preemption swap pair is the SANCTIONED cross-domain lane:
        # its whole purpose is moving one slot's KV to/from the host, and
        # with the slot axis sharded + a traced slot index GSPMD must
        # gather that axis. Off the steady-state path (rare, priced in
        # stats()['swap_time_ms']) — the R4 residency budget is about
        # per-token programs, not the swap lane
        report.info(PASS, rec.name, "swap lane",
                    "cache-sized collective allowed: slot export/restore "
                    "is the explicit host-swap path (DESIGN.md §7)")
        return
    k = caches_aval.k                     # (L, B, n_kv, S, hd)
    slice_bytes = int(np.prod(k.shape[1:], dtype=np.int64)) * k.dtype.itemsize
    # one per-layer K slice spans every store a read touches: the packed
    # cold bytes alone would undercut ordinary activation-sized collectives
    # (an int4 cold store can be SMALLER than one d_model hop) — price the
    # scales and the tiered hot ring into the threshold too
    for extra in (caches_aval.k_scale, caches_aval.hot_k):
        if extra is not None:
            slice_bytes += int(np.prod(extra.shape[1:], dtype=np.int64))\
                * extra.dtype.itemsize
    mesh_shape = tuple(cell.mesh.devices.shape)
    axes = tuple(cell.mesh.axis_names)
    summary = parse_collectives(rec.step.compiled.as_text(), mesh_shape, axes)
    for op in summary.ops:
        if op.operand_bytes >= slice_bytes:
            report.error(
                PASS, rec.name, op.kind,
                f"collective moves {int(op.operand_bytes)} B ≥ one full "
                f"per-layer KV slice ({slice_bytes} B) every dispatch — "
                "the cache is crossing domains instead of staying "
                f"A-resident ({op.line})")
