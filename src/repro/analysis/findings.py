"""Finding/Report containers shared by the verifier passes.

A Finding names the pass, the PROGRAM and the OPERAND it fired on — a
diagnostic that cannot be acted on (which program? which buffer?) is a
bug in the pass, not a style problem.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

ERROR = "error"
WARNING = "warning"
INFO = "info"


@dataclass(frozen=True)
class Finding:
    severity: str          # ERROR / WARNING / INFO
    pass_name: str         # residency / compile_once / host_sync / ...
    program: str           # serving program name (or "<runtime>")
    operand: str           # leaf path, eqn descriptor or param index
    message: str

    def format(self) -> str:
        return (f"[{self.severity.upper():7s}] {self.pass_name}: "
                f"{self.program} :: {self.operand}\n    {self.message}")


@dataclass
class Report:
    findings: List[Finding] = field(default_factory=list)

    def add(self, severity: str, pass_name: str, program: str,
            operand: str, message: str):
        self.findings.append(
            Finding(severity, pass_name, program, operand, message))

    def error(self, pass_name, program, operand, message):
        self.add(ERROR, pass_name, program, operand, message)

    def warning(self, pass_name, program, operand, message):
        self.add(WARNING, pass_name, program, operand, message)

    def info(self, pass_name, program, operand, message):
        self.add(INFO, pass_name, program, operand, message)

    def extend(self, other: "Report"):
        self.findings.extend(other.findings)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == WARNING]

    @property
    def ok(self) -> bool:
        return not self.errors

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.severity] = out.get(f.severity, 0) + 1
        return out

    def format(self, verbose: bool = False) -> str:
        shown = self.findings if verbose \
            else [f for f in self.findings if f.severity != INFO]
        lines = [f.format() for f in shown]
        c = self.counts()
        lines.append(f"-- {c.get(ERROR, 0)} error(s), "
                     f"{c.get(WARNING, 0)} warning(s), "
                     f"{c.get(INFO, 0)} info")
        return "\n".join(lines)
