"""Static-verifier CLI: ``python -m repro.analysis.verify``.

Builds the serving-program matrix on a dry-run host-device mesh
(``--xla_force_host_platform_device_count`` — no accelerator needed) and
runs every pass over every compiled program. Exit code 0 iff no ERROR
findings.

    python -m repro.analysis.verify                    # full matrix
    python -m repro.analysis.verify --preset ci        # the CI matrix
    python -m repro.analysis.verify --mesh 1,8 --strict-weights

NOTE: device forcing must happen before jax initializes — this module
imports jax (and everything that imports jax) only inside ``main``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _parse(argv):
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.verify",
        description="static invariant verifier for the AOT serving programs")
    p.add_argument("--preset", choices=("ci", "full"), default="full",
                   help="cell matrix: ci = both backends × {dense,int8} × "
                        "a_shards {1,4}; full adds monolithic admission, "
                        "a_shards=2 and T=1 (default)")
    p.add_argument("--mesh", default="2,4", metavar="DATA,MODEL",
                   help="dry-run mesh shape (default 2,4)")
    p.add_argument("--no-mesh", action="store_true",
                   help="single-device run (residency/routing vacuous; "
                        "fast syntax-level gate)")
    p.add_argument("--strict-weights", action="store_true",
                   help="weight-placement mismatches become errors")
    p.add_argument("--cell", action="append", default=None,
                   help="only cells whose label contains this substring "
                        "(repeatable)")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="also write findings as JSON")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="include INFO findings in the report")
    return p.parse_args(argv)


def _force_devices(n: int):
    if "jax" in sys.modules:
        import jax
        if len(jax.devices()) < n:
            raise RuntimeError(
                f"jax already initialized with {len(jax.devices())} "
                f"device(s) but the mesh needs {n}; run this CLI in a "
                "fresh process")
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] =\
            f"{flags} --xla_force_host_platform_device_count={n}".strip()


def verify_cell(cell, strict_weights: bool = False):
    """Run every pass over one built cell; returns the cell's Report."""
    from repro.analysis import (compile_once, host_sync, kernel_bounds,
                                residency, routing_check)
    from repro.analysis.findings import Report
    report = Report()
    residency.check_residency(cell, report, strict_weights=strict_weights)
    compile_once.check_compile_once(cell, report)
    host_sync.check_host_sync(cell, report)
    routing_check.check_routing(cell, report)
    kernel_bounds.check_kernel_bounds(cell, report)
    return report


def main(argv=None) -> int:
    args = _parse(argv if argv is not None else sys.argv[1:])
    mesh_shape = tuple(int(x) for x in args.mesh.split(","))
    if not args.no_mesh:
        _force_devices(int(mesh_shape[0] * mesh_shape[1]))

    from repro.analysis.findings import ERROR, Report
    from repro.analysis.programs import MATRICES, build_cell, make_mesh

    specs = MATRICES[args.preset]()
    if args.cell:
        specs = [s for s in specs
                 if any(sub in s.label for sub in args.cell)]
        if not specs:
            print(f"no cells match {args.cell}", file=sys.stderr)
            return 2
    mesh = None if args.no_mesh else make_mesh(*mesh_shape)

    total = Report()
    rows = []
    t_all = time.monotonic()
    for spec in specs:
        t0 = time.monotonic()
        print(f"==> {spec.describe()}", flush=True)
        cell = build_cell(spec, mesh)
        report = verify_cell(cell, strict_weights=args.strict_weights)
        dt = time.monotonic() - t0
        n_err = len(report.errors)
        n_warn = len(report.warnings)
        programs = [r.name for r in cell.records]
        print(f"    {len(programs)} programs, {n_err} error(s), "
              f"{n_warn} warning(s)  [{dt:.1f}s]", flush=True)
        if report.findings:
            for line in report.format(verbose=args.verbose).splitlines():
                print(f"    {line}")
        total.extend(report)
        rows.append({"cell": spec.label, "programs": programs,
                     "errors": n_err, "warnings": n_warn,
                     "seconds": round(dt, 2),
                     "findings": [f.__dict__ for f in report.findings]})

    dt_all = time.monotonic() - t_all
    c = total.counts()
    verdict = "PASS" if total.ok else "FAIL"
    print(f"\n{verdict}: {len(specs)} cell(s), "
          f"{c.get(ERROR, 0)} error(s), "
          f"{c.get('warning', 0)} warning(s) in {dt_all:.1f}s")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"verdict": verdict, "cells": rows}, fh, indent=2)
        print(f"wrote {args.json}")
    return 0 if total.ok else 1


if __name__ == "__main__":
    sys.exit(main())
