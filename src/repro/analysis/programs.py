"""Build serving cells for verification and classify their programs.

A *cell* is one (arch, backend, kv dtype, a_shards, admission mode) point:
the verifier builds the REAL ``ServingEngine`` for it — same constructors,
same ``StaticRuntime``, same program names as serving — against abstract
parameters (``jax.eval_shape`` of ``api.init``), so nothing runs and no
weights materialize; only compilation happens. Whatever the engine would
serve is exactly what gets linted; there is no shadow model to drift.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.registry import ASSIGNED
from repro.models import NULL_CTX, build_model
from repro.models.sharding import ShardingCtx, sub_operator
from repro.runtime.serving import ServingEngine
from repro.runtime.static_runtime import CompiledStep, StaticRuntime

# program-name suffix → kind; kinds drive per-pass policy (which programs
# must donate, which carry routed hops, which hold chunk writes)
_KINDS = (
    ("prefill_chunk", "chunk"),
    ("wa_admit", "chunk"),          # degenerate full-width chunk
    ("decode_block", "block"),
    ("decode_drain", "drain"),
    ("prefill_batch", "prefill"),
    ("prefill1", "prefill"),
    ("swap_out", "swap_out"),       # preemption export — READ-ONLY
    ("swap_in", "swap_in"),         # preemption restore — donates
    ("admit", "admit"),             # colocated write_slot copy
    ("decode", "decode"),
    ("reset", "reset"),
)

# kinds whose programs sit on the steady-state serving path and must donate
# their cache operand (a non-donated cache = one full KV copy per dispatch).
# swap_in restores INTO the resident cache and donates like the rest;
# swap_out is deliberately absent — it only READS the victim slot, so a
# failed/retried dispatch can never corrupt the cache (DESIGN.md §7)
DONATING_KINDS = ("chunk", "block", "decode", "admit", "reset", "drain",
                  "swap_in")


@dataclass(frozen=True)
class CellSpec:
    """One verification point. Defaults mirror the serving-test fixtures
    (qwen2-0.5b reduced, f32 activations) — small enough that a CI host
    compiles the full matrix, real enough to exercise every program."""
    label: str
    arch: str = "qwen2-0.5b"
    backend: str = "colocated"
    kv_dtype: Optional[str] = None          # None = dense, "int8" = quantized
    # tiered KV cache (0 → flat): hot ring window, cold-tier storage dtype
    # and demotion block — build-time statics baked into the program set
    hot_window: int = 0
    kv_cold_dtype: str = "int8"
    kv_cold_block: int = 16
    a_shards: int = 1
    overlap: int = 1                        # W/A micro-batch pipelining depth
    block_size: int = 4
    prefill_chunk: int = 4                  # 0 → monolithic admission
    slots: int = 2
    prompt_len: int = 8
    max_new_cap: int = 24
    kv_bucket_chunk: int = 16
    # every cell compiles the preemption swap pair by default — the
    # verifier lints the extended (robustness) program set, not a subset
    preemptible: bool = True

    def describe(self) -> str:
        kv = self.kv_dtype or "dense"
        if self.hot_window:
            kv += f"+tiered(hot{self.hot_window}/{self.kv_cold_dtype})"
        adm = f"chunk{self.prefill_chunk}" if self.prefill_chunk \
            else "monolithic"
        return (f"{self.label}: {self.arch} backend={self.backend} kv={kv} "
                f"a_shards={self.a_shards} overlap={self.overlap} "
                f"T={self.block_size} adm={adm}")


@dataclass
class ProgramRecord:
    name: str
    step: CompiledStep
    kind: str
    arg_roles: Dict[str, int]               # 'params'/'caches' → arg position

    def flat_leaf_range(self, role: str) -> Optional[Tuple[int, int]]:
        """[start, stop) of this role's leaves in the program's FLAT
        parameter numbering (the numbering HLO alias maps use)."""
        idx = self.arg_roles.get(role)
        if idx is None:
            return None
        start = sum(len(jax.tree_util.tree_leaves(a))
                    for a in self.step.abstract_args[:idx])
        n = len(jax.tree_util.tree_leaves(self.step.abstract_args[idx]))
        return start, start + n


@dataclass
class Cell:
    spec: CellSpec
    cfg: object
    api: object
    mesh: object                            # None for the no-mesh dry run
    engine: ServingEngine
    rt: StaticRuntime
    params_aval: object
    caches_aval: object
    records: List[ProgramRecord] = field(default_factory=list)

    @property
    def backend(self):
        return self.engine._ex

    @property
    def w_ctx(self) -> ShardingCtx:
        """Rules the weight leaves are planned under."""
        if self.spec.backend == "wa":
            return self.backend.wa.w_ctx
        return self.engine.ctx

    @property
    def cache_ctx(self) -> ShardingCtx:
        """Rules the KV-cache leaves are planned under (the A domain for
        the WA backend; the engine's own rules when colocated)."""
        return self.backend.cache_ctx


def classify(name: str) -> str:
    for suffix, kind in _KINDS:
        if suffix in name:
            return kind
    return "other"


def _arg_roles(step: CompiledStep, params_aval, caches_aval) \
        -> Dict[str, int]:
    """Locate the params / caches arguments by pytree structure. The first
    caches-shaped arg wins (serve_admit also takes a batch-1 caches-shaped
    ``single`` operand in position 1)."""
    roles: Dict[str, int] = {}
    p_struct = jax.tree_util.tree_structure(params_aval)
    c_struct = jax.tree_util.tree_structure(caches_aval)
    for i, a in enumerate(step.abstract_args or ()):
        s = jax.tree_util.tree_structure(a)
        if "params" not in roles and s == p_struct:
            roles["params"] = i
        elif "caches" not in roles and s == c_struct:
            roles["caches"] = i
    return roles


def build_cell(spec: CellSpec, mesh) -> Cell:
    cfg = ASSIGNED[spec.arch].reduced().replace(dtype="float32")
    if spec.kv_dtype:
        cfg = cfg.replace(kv_dtype=spec.kv_dtype)
    if spec.hot_window:
        cfg = cfg.replace(hot_window=spec.hot_window,
                          kv_cold_dtype=spec.kv_cold_dtype,
                          kv_cold_block=spec.kv_cold_block)
    api = build_model(cfg)
    params_aval = jax.eval_shape(lambda: api.init(jax.random.key(0)))
    ctx = ShardingCtx(mesh, sub_operator()) if mesh is not None else NULL_CTX
    rt = StaticRuntime(mesh)
    eng = ServingEngine(api, ctx, spec.slots, spec.prompt_len, runtime=rt,
                        mode="continuous", max_new_cap=spec.max_new_cap,
                        block_size=spec.block_size,
                        kv_bucket_chunk=spec.kv_bucket_chunk,
                        prefill_chunk=spec.prefill_chunk,
                        backend=spec.backend, a_shards=spec.a_shards,
                        overlap=spec.overlap,
                        preemptible=spec.preemptible)
    eng._prepare(params_aval)               # compiles; runs nothing
    caches_aval = eng._caches_aval
    cell = Cell(spec, cfg, api, mesh, eng, rt, params_aval, caches_aval)
    for (name, _mesh_id, _sig), step in sorted(rt._cache.items(),
                                               key=lambda kv: kv[0][0]):
        cell.records.append(ProgramRecord(
            name, step, classify(name),
            _arg_roles(step, params_aval, caches_aval)))
    return cell


def make_mesh(data: int, model: int):
    """(data, model) mesh over the visible devices — with
    ``--xla_force_host_platform_device_count`` these are host devices and
    the whole verification run needs no accelerator."""
    devs = np.array(jax.devices()[:data * model]).reshape(data, model)
    from jax.sharding import Mesh
    return Mesh(devs, ("data", "model"))


# ---------------------------------------------------------------------------
# Verification matrices
# ---------------------------------------------------------------------------

def ci_matrix() -> List[CellSpec]:
    """Both backends × {dense, int8} × a_shards {1, 4}, plus the
    sub-operator overlap cells (depth 2 and 4; slots=4 so the batch splits
    into equal micro-batches) — residency / compile-once / host-sync /
    routing gate the pipelined programs too (the CI job)."""
    out = []
    for backend in ("colocated", "wa"):
        for kv in (None, "int8"):
            for sh in (1, 4):
                kvs = kv or "dense"
                out.append(CellSpec(
                    label=f"{backend}-{kvs}-a{sh}",
                    backend=backend, kv_dtype=kv, a_shards=sh))
    out.append(CellSpec(label="wa-dense-a1-ov2", backend="wa",
                        overlap=2, slots=4))
    out.append(CellSpec(label="wa-int8-a4-ov4", backend="wa",
                        kv_dtype="int8", a_shards=4, overlap=4, slots=4))
    # tiered-KV cells: the colocated one admits MONOLITHICALLY so the
    # degenerate full-width serve_admit chunk program is linted (tier
    # residency, donation, slot-isolated DUS writes); the WA one runs the
    # packed-int4 cold store under split-KV sequence sharding
    out.append(CellSpec(label="colocated-int8cold-mono",
                        hot_window=4, kv_cold_dtype="int8", kv_cold_block=4,
                        prefill_chunk=0))
    out.append(CellSpec(label="wa-int4cold-a2", backend="wa",
                        hot_window=4, kv_cold_dtype="int4", kv_cold_block=4,
                        a_shards=2))
    return out


def full_matrix() -> List[CellSpec]:
    """The acceptance matrix: CI cells + monolithic admission, a_shards=2,
    the per-step (T=1) decode program and a T=1 overlap cell."""
    out = ci_matrix()
    for backend in ("colocated", "wa"):
        out.append(CellSpec(label=f"{backend}-dense-a1-mono",
                            backend=backend, prefill_chunk=0))
        out.append(CellSpec(label=f"{backend}-dense-a2",
                            backend=backend, a_shards=2))
    out.append(CellSpec(label="wa-dense-a1-T1", backend="wa", block_size=1))
    out.append(CellSpec(label="wa-dense-a1-T1-ov2", backend="wa",
                        block_size=1, overlap=2, slots=4))
    return out


MATRICES = {"ci": ci_matrix, "full": full_matrix}
