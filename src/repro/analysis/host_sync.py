"""Host-sync detector (pass 3).

Invariant (§4.3 / DESIGN.md §7): the steady-state serving loop syncs with
the host once per macro-step — nothing INSIDE a step program may force an
extra round-trip. Two ways a program smuggles one in:

  - host ops compiled into the program: python callbacks
    (pure/io/debug_callback), infeed/outfeed, send/recv. Each runs every
    dispatch (worse: every micro-step if inside the block scan).
  - a "donated" KV cache the compiler could not alias: the donation
    silently degrades to a full device copy of the cache per dispatch —
    and the alias map in the optimized HLO is the only place that truth
    appears.

The donation audit reads ``input_output_alias`` from the compiled HLO and
requires every cache leaf of every steady-state program (kinds in
``DONATING_KINDS``) to be aliased.
"""
from __future__ import annotations

import jax

from repro.analysis.findings import Report
from repro.analysis.jaxpr_walk import iter_eqns
from repro.analysis.programs import Cell, DONATING_KINDS, ProgramRecord
from repro.launch.hlo_analysis import parse_host_ops, parse_input_output_alias

PASS = "host_sync"

# jaxpr-level primitives that round-trip through the host
_CALLBACK_PRIMS = ("pure_callback", "io_callback", "debug_callback",
                   "infeed", "outfeed", "host_local_array_to_global_array")


def _check_callbacks(rec: ProgramRecord, report: Report):
    try:
        jaxpr = rec.step.jaxpr()
    except (ValueError, TypeError) as e:
        report.warning(PASS, rec.name, "jaxpr",
                       f"could not retrace for callback scan: {e}")
        return
    for site in iter_eqns(jaxpr):
        name = site.eqn.primitive.name
        if name in _CALLBACK_PRIMS:
            times = f"{site.trips}×" if site.trips > 1 else "once"
            report.error(
                PASS, rec.name, name,
                "host callback compiled into the step program (runs "
                f"{times} per dispatch) — every dispatch blocks on a "
                "device→host→device round-trip, defeating the macro-step "
                "sync amortization")


def _check_hlo_host_ops(rec: ProgramRecord, report: Report):
    for line in parse_host_ops(rec.step.compiled.as_text()):
        report.error(PASS, rec.name, "hlo host op",
                     f"host-facing op in optimized HLO: {line}")


def _check_donation(rec: ProgramRecord, cell: Cell, report: Report):
    rng = rec.flat_leaf_range("caches")
    if rng is None or rec.kind not in DONATING_KINDS:
        return
    if not rec.step.donate_argnums:
        report.error(
            PASS, rec.name, "caches",
            f"steady-state {rec.kind} program does not donate its cache "
            "operand — XLA must copy the full KV every dispatch (pass "
            "donate_argnums for the caches arg)")
        return
    alias = parse_input_output_alias(rec.step.compiled.as_text())
    aliased_params = set(alias.values())
    flat, _ = jax.tree_util.tree_flatten_with_path(cell.caches_aval)
    start, stop = rng
    for offset, (path, leaf) in enumerate(flat):
        pnum = start + offset
        if pnum not in aliased_params:
            report.error(
                PASS, rec.name,
                f"caches{jax.tree_util.keystr(path)} (param {pnum})",
                f"cache leaf {leaf.shape}:{leaf.dtype} marked donated but "
                "ABSENT from the compiled alias map — the donation "
                "degraded to a copy of this buffer every dispatch")


def check_host_sync(cell: Cell, report: Report):
    for rec in cell.records:
        _check_callbacks(rec, report)
        _check_hlo_host_ops(rec, report)
        _check_donation(rec, cell, report)
