"""Routing-bytes cross-check (pass 4).

Invariant ("only embeddings move", §3.1): each WA serving program routes
exactly ``2 × n_layers`` W↔A hops per micro-step — 3 W→A (q,k,v) and
1 A→W (attention output) per layer — and the analytic meter
``WABackend.expected_routing`` / ``core.wa.routing_bytes`` claims precisely
those bytes. This pass recomputes the hop traffic FROM THE PROGRAM: it
walks the jaxpr for the tagged hop markers (``wa_hop_to_a`` /
``wa_hop_to_w`` pjit eqns, scan-trip-weighted) and fails on any drift —
a dropped hop (a layer silently bypassing the A domain), an extra hop, or
a meter constant that no longer matches what the compiled program moves.

Sub-operator overlap (``overlap`` = D > 1) scales the hop COUNT of the
slotted decode programs, not the bytes: the pipelined layer loop routes
each micro-batch separately, so a decode micro-step carries ``D × 3L``
W→A and ``D × L`` A→W hops of ``rows / D`` rows each. Chunk/admission
programs are batch-1 and never pipeline (D = 1 for them regardless of the
knob).

The bytes identity: per micro-step the A→W hops carry
``L × rows × n_heads × head_dim × el`` bytes IN TOTAL across micro-batches
(depth-invariant) while the analytic meter claims
``2 × L × rows × d_model × el``, so

    2 × d_model × Σ(A→W hop bytes)  ==  (n_heads × head_dim) × analytic

holds exactly in integers for every current program at every overlap
depth — checked per program with no tolerance.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.findings import Report
from repro.analysis.jaxpr_walk import named_pjit_sites
from repro.analysis.programs import Cell
from repro.core.wa import WA_HOP_TO_A, WA_HOP_TO_W, routing_bytes

PASS = "routing_check"


def _hop_stats(jaxpr):
    """{tag: (weighted_count, weighted_bytes, dtypes)} over tagged hops."""
    stats = {WA_HOP_TO_A: [0, 0, set()], WA_HOP_TO_W: [0, 0, set()]}
    for tag, site in named_pjit_sites(jaxpr, stats):
        aval = site.eqn.invars[0].aval
        nbytes = int(np.prod(aval.shape, dtype=np.int64))\
            * aval.dtype.itemsize
        stats[tag][0] += site.trips
        stats[tag][1] += site.trips * nbytes
        stats[tag][2].add(str(aval.dtype))
        if site.unbounded:
            return None
    return {k: (c, b, d) for k, (c, b, d) in stats.items()}


def check_routing(cell: Cell, report: Report):
    if cell.spec.backend != "wa":
        return
    backend = cell.backend
    cfg = cell.cfg
    mesh_on = cell.mesh is not None
    for rec in cell.records:
        if not rec.name.startswith("serve_wa_")\
                or rec.kind in ("reset", "swap_out", "swap_in"):
            # reset and the preemption swap pair are cache-only programs:
            # zero W↔A hops by construction, no routing model to check
            continue
        try:
            rows, trips = backend.expected_routing(rec.name)
        except KeyError as e:
            report.error(PASS, rec.name, "routing model", str(e))
            continue
        if not mesh_on:
            # mesh=None no-ops every constraint — nothing to cross-check
            report.info(PASS, rec.name, "hops",
                        "no mesh: hops are no-ops, cross-check skipped")
            continue
        try:
            jaxpr = rec.step.jaxpr()
        except (ValueError, TypeError) as e:
            report.error(PASS, rec.name, "jaxpr",
                         f"could not retrace for hop audit: {e}")
            continue
        stats = _hop_stats(jaxpr)
        if stats is None:
            report.error(PASS, rec.name, "while",
                         "hops inside an unbounded while loop — static "
                         "byte accounting impossible")
            continue
        to_a_n, _to_a_b, _ = stats[WA_HOP_TO_A]
        to_w_n, to_w_b, to_w_dt = stats[WA_HOP_TO_W]
        L = cfg.n_layers
        # overlap depth D multiplies the hop COUNT of the slotted decode
        # programs (one routed chain per micro-batch); chunk/admission
        # programs are batch-1 and stay sequential at any depth
        depth = backend.overlap if rec.kind in ("decode", "block") else 1
        if to_a_n != 3 * L * trips * depth or to_w_n != L * trips * depth:
            report.error(
                PASS, rec.name, "hop count",
                f"expected 3·L·T·D={3 * L * trips * depth} W→A and "
                f"L·T·D={L * trips * depth} A→W routed hops (L={L} "
                f"layers, T={trips} micro-steps, overlap D={depth}) "
                f"but the compiled program routes {to_a_n} W→A / {to_w_n} "
                "A→W — a W↔A boundary was dropped or duplicated in "
                "core/wa.py's layer loop")
            continue
        # the meter's bytes-per-element must match the traced activations
        el = backend._el
        traced_el = {np.dtype(d).itemsize for d in to_w_dt} or {el}
        if traced_el != {el}:
            report.error(
                PASS, rec.name, "element size",
                f"meter assumes {el} B/element but the routed activations "
                f"trace as {sorted(to_w_dt)} — stats()['wa'] under/over-"
                "counts every dispatch")
            continue
        analytic = trips * routing_bytes(cfg, rows, el)
        lhs = 2 * cfg.d_model * to_w_b
        rhs = cfg.n_heads * cfg.head_dim * analytic
        if lhs != rhs:
            report.error(
                PASS, rec.name, "hop bytes",
                f"analytic meter claims {analytic} routed B/dispatch "
                f"(rows={rows}, trips={trips}) but the compiled A→W hops "
                f"move {to_w_b} B — 2·d_model·hops = {lhs} != "
                f"heads·head_dim·analytic = {rhs}; the meter in "
                "runtime/serving.py drifted from the program")
        else:
            report.info(PASS, rec.name, "hops",
                        f"{to_a_n}+{to_w_n} hops, analytic "
                        f"{analytic} B/dispatch confirmed")
