"""Static program verifier (DESIGN.md §8).

The paper's result rests on three *static* properties of the compiled
serving programs — weights stay resident in the W domain, KV stays in the
A domain, and coordination is relaxed to true sub-operator dependencies.
Nothing at runtime checks them: a sharding annotation lost through a
reshape (the GSPMD back-propagation failure PR 5 hit in ``core/wa.py``)
silently turns a cache-resident program into a replicated one and shows up
only as a diffuse TPOT regression.

This package lints every AOT serving program at the jaxpr and optimized-HLO
level, on dry-run host-device meshes, so CI needs no hardware:

  residency      KV buffers keep their A-domain (kv_seq-sharded) layout and
                 never cross into W; weight placement vs the W-domain plan
  compile_once   every serve_* name compiles exactly once per signature;
                 weak-type/dtype drift that causes silent retraces
  host_sync      no callbacks/infeed/host round-trips inside step programs;
                 KV buffers are donated (alias map audited)
  routing_check  W↔A hop bytes recomputed from the program jaxpr must match
                 the analytic routing_bytes meter in runtime/serving.py
  kernel_bounds  flash-decode grids cover the KV extent, kv_limit is traced
                 and consumed; chunk-lane dynamic_update_slice writes cannot
                 alias across slots

CLI: ``python -m repro.analysis.verify`` (or ``make verify-static``).
"""
