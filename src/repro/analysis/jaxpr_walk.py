"""Recursive jaxpr traversal with trip-count multipliers.

Serving programs nest: pjit wrappers, the T-micro-step ``lax.scan`` of a
decode block, vmapped cache writes, cond branches. Every verifier pass
that counts or sizes eqns (routed hops, callbacks, DUS writes) must see
through that nesting AND weight body eqns by how often they run — a hop
inside a ``scan(length=T)`` moves T× the bytes of the same hop at top
level.

``while`` bodies have no static trip count; they are traversed with an
``unbounded`` flag so passes can refuse to reason about them rather than
under-count silently (no serving program uses while today).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterator, List, Optional, Tuple

from jax import core as jax_core


@dataclass(frozen=True)
class EqnSite:
    eqn: Any               # jax.core.JaxprEqn
    trips: int             # product of enclosing static scan lengths
    unbounded: bool        # inside a while body (trips is a lower bound)


def _subjaxprs(params) -> List[jax_core.Jaxpr]:
    """All jaxprs stashed in an eqn's params (closed or open, incl. inside
    tuples/lists — cond branches, custom_vjp pairs, pallas kernels)."""
    out: List[jax_core.Jaxpr] = []

    def visit(v):
        if isinstance(v, jax_core.ClosedJaxpr):
            out.append(v.jaxpr)
        elif isinstance(v, jax_core.Jaxpr):
            out.append(v)
        elif isinstance(v, (tuple, list)):
            for x in v:
                visit(x)

    for v in params.values():
        visit(v)
    return out


def iter_eqns(jaxpr, trips: int = 1, unbounded: bool = False) \
        -> Iterator[EqnSite]:
    """Yield every eqn in ``jaxpr`` and its subjaxprs as an EqnSite."""
    if isinstance(jaxpr, jax_core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield EqnSite(eqn, trips, unbounded)
        name = eqn.primitive.name
        sub_trips, sub_unbounded = trips, unbounded
        if name == "scan":
            sub_trips = trips * int(eqn.params.get("length", 1))
        elif name == "while":
            sub_unbounded = True
        for sub in _subjaxprs(eqn.params):
            yield from iter_eqns(sub, sub_trips, sub_unbounded)


def named_pjit_sites(jaxpr, names) -> List[Tuple[str, EqnSite]]:
    """(name, site) for every pjit eqn whose name is in ``names`` — the
    anchor used by routing_check to find the tagged W↔A hop markers."""
    names = set(names)
    out = []
    for site in iter_eqns(jaxpr):
        if site.eqn.primitive.name == "pjit" \
                and site.eqn.params.get("name") in names:
            out.append((site.eqn.params["name"], site))
    return out


def primitive_sites(jaxpr, prim_names) -> List[EqnSite]:
    prim_names = set(prim_names)
    return [s for s in iter_eqns(jaxpr)
            if s.eqn.primitive.name in prim_names]


def literal_value(v) -> Optional[int]:
    """Int value of a jaxpr literal operand, None if traced."""
    if isinstance(v, jax_core.Literal):
        try:
            return int(v.val)
        except (TypeError, ValueError):
            return None
    return None


def aval_bytes(aval) -> int:
    import numpy as np
    return int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize


__all__ = ["EqnSite", "iter_eqns", "named_pjit_sites", "primitive_sites",
           "literal_value", "aval_bytes"]
