"""Recurrent decode state for attention-free blocks (RG-LRU, Mamba-2 SSD).

Unlike KV caches these are O(1) in context length — the KV-pressure paradox
(paper §2.3) does not bind here, which is exactly why long_500k decode is
runnable for the ssm/hybrid archs (DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class RecurrentState(NamedTuple):
    h: jax.Array             # RG-LRU: (L,B,lru) f32 | SSD: (L,B,nh,hd,N) f32
    conv: jax.Array          # rolling conv window (L,B,W-1,C)


def init_rglru_state(n_layers: int, batch: int, lru_width: int,
                     conv_width: int) -> RecurrentState:
    return RecurrentState(
        h=jnp.zeros((n_layers, batch, lru_width), jnp.float32),
        conv=jnp.zeros((n_layers, batch, conv_width - 1, lru_width), jnp.float32),
    )


def init_ssd_state(n_layers: int, batch: int, n_heads: int, head_dim: int,
                   d_state: int, conv_width: int, conv_channels: int) -> RecurrentState:
    return RecurrentState(
        h=jnp.zeros((n_layers, batch, n_heads, head_dim, d_state), jnp.float32),
        conv=jnp.zeros((n_layers, batch, conv_width - 1, conv_channels), jnp.float32),
    )


def read_state(state: RecurrentState, layer: jax.Array):
    h = jax.lax.dynamic_index_in_dim(state.h, layer, 0, keepdims=False)
    c = jax.lax.dynamic_index_in_dim(state.conv, layer, 0, keepdims=False)
    return h, c


def write_state(state: RecurrentState, layer: jax.Array,
                h: Optional[jax.Array] = None,
                conv: Optional[jax.Array] = None) -> RecurrentState:
    new_h, new_c = state.h, state.conv
    if h is not None:
        new_h = jax.lax.dynamic_update_slice(
            state.h, h[None].astype(state.h.dtype), (layer,) + (0,) * h.ndim)
    if conv is not None:
        new_c = jax.lax.dynamic_update_slice(
            state.conv, conv[None].astype(state.conv.dtype), (layer,) + (0,) * conv.ndim)
    return RecurrentState(new_h, new_c)


# ---------------------------------------------------------------------------
# Per-slot (continuous-batching) support — attention-free states are O(1) in
# context, so admission is a single per-slot overwrite (DESIGN.md §7).
# ---------------------------------------------------------------------------

def write_slot_tree(dst, src, slot, batch_axis: int = 1):
    """Admission for recurrent-state pytrees: copy the batch-1 pytree ``src``
    into index ``slot`` along ``batch_axis`` of every leaf. Leaves with rank
    ≤ batch_axis (scalar cursors) take the elementwise max as an upper
    bound. ``slot`` may be traced — one compiled program serves all slots."""
    def put(d, s):
        if d is None:
            return None
        if d.ndim <= batch_axis:
            return jnp.maximum(d, s) if d.shape == s.shape else d
        start = (0,) * batch_axis + (slot,) + (0,) * (d.ndim - batch_axis - 1)
        return jax.lax.dynamic_update_slice(d, s.astype(d.dtype), start)

    return jax.tree.map(put, dst, src)


def reset_slot_tree(state, slot, batch_axis: int = 1):
    """Zero one batch slot of every leaf (retire a finished request)."""
    zeros = jax.tree.map(
        lambda a: jnp.zeros(a.shape[:batch_axis] + (1,)
                            + a.shape[batch_axis + 1:], a.dtype)
        if a.ndim > batch_axis else a, state)
    return write_slot_tree(state, zeros, slot, batch_axis)


def mask_slots(active: jax.Array, new_tree, old_tree, batch_axis: int = 1):
    """Active-slot masking for recurrent decode: every step rewrites the
    WHOLE state, so retired rows must be selected back to their old value
    (the KV path masks at the append instead). active: (B,) bool."""
    def sel(n, o):
        if n is None:
            return None
        if n.ndim <= batch_axis:
            return n
        shape = [1] * n.ndim
        shape[batch_axis] = n.shape[batch_axis]
        return jnp.where(active.reshape(shape), n, o)

    return jax.tree.map(sel, new_tree, old_tree)


def conv_step(conv_state: jax.Array, x_new: jax.Array, conv_w: jax.Array,
              conv_b: Optional[jax.Array] = None):
    """Causal depthwise conv, one step. conv_state: (B,W-1,C); x_new: (B,C);
    conv_w: (W,C). Returns (y (B,C), new_state)."""
    window = jnp.concatenate([conv_state, x_new[:, None, :].astype(conv_state.dtype)],
                             axis=1)                       # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", window, conv_w.astype(window.dtype))
    if conv_b is not None:
        y = y + conv_b
    return y.astype(x_new.dtype), window[:, 1:, :]


def causal_conv(x: jax.Array, conv_w: jax.Array,
                conv_b: Optional[jax.Array] = None) -> jax.Array:
    """Causal depthwise conv over a sequence. x: (B,S,C); conv_w: (W,C)."""
    W = conv_w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    # windowed dot: y[b,s,c] = sum_w pad[b,s+w,c] * conv_w[w,c]
    y = jnp.zeros_like(x, shape=x.shape)
    for w in range(W):                                     # W is 4 — unrolled
        y = y + pad[:, w:w + x.shape[1], :] * conv_w[w][None, None, :].astype(x.dtype)
    if conv_b is not None:
        y = y + conv_b.astype(x.dtype)
    return y
