from repro.kv.cache import KVCache, init_kv_cache, append_kv, window_slots  # noqa: F401
from repro.kv.state import RecurrentState, init_rglru_state, init_ssd_state  # noqa: F401
