"""KV-cache management.

Layout is CONTIGUOUS (L, B, n_kv, S_max, head_dim) — the paper (§7.1) explicitly
rejects paged layouts because address indirection lands on the decode critical
path; we follow that choice and isolate KV by *placement* instead (WA
separation / sequence sharding), not by virtual-memory tricks.

Supports:
- full-context caches (global attention),
- ring-buffer sliding-window caches (recurrentgemma local attention),
- INT8-quantized storage with per-(b, head, pos) scales (paper runs fully INT8).

The cache is a pytree; decode steps donate it (buffer reuse — no double
allocation of the GB-scale KV in steady state).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.int8 import dequantize_kv, quantize_kv


@jax.tree_util.register_pytree_node_class
class KVCache:
    """Pytree: (k, v, k_scale, v_scale, length) children; ``window`` static."""

    def __init__(self, k, v, k_scale, v_scale, length, window: int = 0):
        self.k = k                       # (L,B,n_kv,S,hd)  kv_dtype
        self.v = v
        self.k_scale = k_scale           # (L,B,n_kv,S,1) f32 — int8 only
        self.v_scale = v_scale
        self.length = length             # () int32 — tokens appended so far
        self.window = window             # 0 → full ctx; >0 → ring buffer

    def tree_flatten(self):
        return ((self.k, self.v, self.k_scale, self.v_scale, self.length),
                self.window)

    @classmethod
    def tree_unflatten(cls, window, children):
        return cls(*children, window=window)

    def _replace(self, **kw):
        d = dict(k=self.k, v=self.v, k_scale=self.k_scale,
                 v_scale=self.v_scale, length=self.length, window=self.window)
        d.update(kw)
        return KVCache(**d)

    @property
    def max_len(self) -> int:
        return self.k.shape[3]

    @property
    def is_quantized(self) -> bool:
        return self.k_scale is not None


def init_kv_cache(n_layers: int, batch: int, n_kv: int, max_len: int,
                  head_dim: int, dtype=jnp.bfloat16, quantized: bool = False,
                  window: int = 0) -> KVCache:
    size = min(window, max_len) if window else max_len
    store = jnp.int8 if quantized else dtype
    shape = (n_layers, batch, n_kv, size, head_dim)
    # k/v (and the scales) must be DISTINCT buffers: the serving engine
    # donates the whole cache pytree per step, and XLA rejects donating one
    # buffer twice
    def mk(s, dt):
        return jnp.zeros(s, dt)
    sshape = shape[:-1] + (1,)
    return KVCache(mk(shape, store), mk(shape, store),
                   mk(sshape, jnp.float32) if quantized else None,
                   mk(sshape, jnp.float32) if quantized else None,
                   jnp.zeros((), jnp.int32), window=window)


def _slot(cache: KVCache, pos: jax.Array) -> jax.Array:
    return jax.lax.rem(pos, cache.k.shape[3]) if cache.window else pos


def append_kv(cache: KVCache, layer: jax.Array, k_new: jax.Array,
              v_new: jax.Array) -> KVCache:
    """Append ONE position for one layer. k_new/v_new: (B, n_kv, hd).

    Used inside the per-layer scan: ``layer`` is the scan index. The write is
    a dynamic_update_slice — O(1), no relayout (contiguity preserved).
    """
    pos = cache.length
    slot = _slot(cache, pos)
    if cache.is_quantized:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k = jax.lax.dynamic_update_slice(
            cache.k, kq[None, :, :, None, :], (layer, 0, 0, slot, 0))
        v = jax.lax.dynamic_update_slice(
            cache.v, vq[None, :, :, None, :], (layer, 0, 0, slot, 0))
        k_scale = jax.lax.dynamic_update_slice(
            cache.k_scale, ks[None, :, :, None, :], (layer, 0, 0, slot, 0))
        v_scale = jax.lax.dynamic_update_slice(
            cache.v_scale, vs[None, :, :, None, :], (layer, 0, 0, slot, 0))
        return cache._replace(k=k, v=v, k_scale=k_scale, v_scale=v_scale)
    k = jax.lax.dynamic_update_slice(
        cache.k, k_new[None, :, :, None, :].astype(cache.k.dtype),
        (layer, 0, 0, slot, 0))
    v = jax.lax.dynamic_update_slice(
        cache.v, v_new[None, :, :, None, :].astype(cache.v.dtype),
        (layer, 0, 0, slot, 0))
    return cache._replace(k=k, v=v)


def bump_length(cache: KVCache) -> KVCache:
    """Advance the write cursor once per decode step (after all layers)."""
    return cache._replace(length=cache.length + 1)


def read_kv(cache: KVCache, layer: jax.Array, dtype=jnp.bfloat16):
    """Return (k, v) for a layer as compute dtype: (B, n_kv, S, hd)."""
    k = jax.lax.dynamic_index_in_dim(cache.k, layer, axis=0, keepdims=False)
    v = jax.lax.dynamic_index_in_dim(cache.v, layer, axis=0, keepdims=False)
    if cache.is_quantized:
        ks = jax.lax.dynamic_index_in_dim(cache.k_scale, layer, 0, keepdims=False)
        vs = jax.lax.dynamic_index_in_dim(cache.v_scale, layer, 0, keepdims=False)
        return dequantize_kv(k, ks, dtype), dequantize_kv(v, vs, dtype)
    return k.astype(dtype), v.astype(dtype)


# ---------------------------------------------------------------------------
# Per-layer slice API — used inside decode layer-scans so each layer touches
# ONLY its own (B,n_kv,S,hd) slice (the whole-cache carry would cost O(L)
# bytes per layer ⇒ O(L²) per step; slices flow as scan xs/ys instead and
# alias in place under donation).
# ---------------------------------------------------------------------------

def layer_append(k_l: jax.Array, v_l: jax.Array, k_scale_l, v_scale_l,
                 k_new: jax.Array, v_new: jax.Array, pos: jax.Array,
                 window: int):
    """k_l/v_l: (B,n_kv,S,hd); k_new/v_new: (B,n_kv,hd). Returns updated
    slices. Quantizes when scale slices are present."""
    size = k_l.shape[2]
    slot = jax.lax.rem(pos, size) if window else pos
    if k_scale_l is not None:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k_l = jax.lax.dynamic_update_slice(k_l, kq[:, :, None, :], (0, 0, slot, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, vq[:, :, None, :], (0, 0, slot, 0))
        k_scale_l = jax.lax.dynamic_update_slice(
            k_scale_l, ks[:, :, None, :], (0, 0, slot, 0))
        v_scale_l = jax.lax.dynamic_update_slice(
            v_scale_l, vs[:, :, None, :], (0, 0, slot, 0))
        return k_l, v_l, k_scale_l, v_scale_l
    k_l = jax.lax.dynamic_update_slice(
        k_l, k_new[:, :, None, :].astype(k_l.dtype), (0, 0, slot, 0))
    v_l = jax.lax.dynamic_update_slice(
        v_l, v_new[:, :, None, :].astype(v_l.dtype), (0, 0, slot, 0))
    return k_l, v_l, None, None


def layer_read(k_l, v_l, k_scale_l, v_scale_l, dtype=jnp.bfloat16):
    if k_scale_l is not None:
        return (dequantize_kv(k_l, k_scale_l, dtype),
                dequantize_kv(v_l, v_scale_l, dtype))
    return k_l.astype(dtype), v_l.astype(dtype)


def layer_read_bucket(k_l, v_l, k_scale_l, v_scale_l, bucket: int,
                      dtype=jnp.bfloat16):
    """``layer_read`` over only the first ``bucket`` positions (static slice
    of the STORED buffers, so int8 caches dequantize just the bucket — the
    length-aware decode path never upcasts KV it will not attend).
    ``bucket`` of 0 or >= S is the full-extent read."""
    S = k_l.shape[2]
    if bucket and bucket < S:
        def cut(a):
            return (None if a is None
                    else jax.lax.slice_in_dim(a, 0, bucket, axis=2))
        k_l, v_l = cut(k_l), cut(v_l)
        k_scale_l, v_scale_l = cut(k_scale_l), cut(v_scale_l)
    return layer_read(k_l, v_l, k_scale_l, v_scale_l, dtype)


# ---------------------------------------------------------------------------
# Split-KV shard-local layout (DESIGN.md §3) — one slot's contiguous
# (B,n_kv,S,hd) extent cut into n_shards equal sequence blocks for the
# A-domain split flash walk. Sharding is a READ-time view: the stored layout
# stays contiguous (no paging, §7.1), writes and cursors remain absolute.
# ---------------------------------------------------------------------------

def shard_extent(extent: int, n_shards: int) -> int:
    """Shard-local block length for a (bucketed) extent; validates that the
    extent cuts into ``n_shards`` equal contiguous blocks."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if extent % n_shards:
        raise ValueError(
            f"KV extent {extent} not divisible by n_shards={n_shards}")
    return extent // n_shards


def shard_kv_limits(kv_limit: jax.Array, n_shards: int,
                    block: int) -> jax.Array:
    """Per-shard live extents for a GLOBAL limit over a contiguous split:
    shard s owns absolute positions [s*block, (s+1)*block), so its local
    live extent is clamp(kv_limit - s*block, 0, block). Returns (n_shards,)
    int32 — traced, advancing cursors never recompile. A shard whose limit
    clamps to 0 is fully skippable (the flash kernel then reports the exact
    merge identity)."""
    lim = jnp.asarray(kv_limit, jnp.int32).reshape(())
    starts = jnp.arange(n_shards, dtype=jnp.int32) * block
    return jnp.clip(lim - starts, 0, block)


def layer_read_shards(k_l, v_l, k_scale_l, v_scale_l, bucket: int,
                      n_shards: int, dtype=jnp.bfloat16):
    """Shard-major bucketed read: ``layer_read_bucket``'s static prefix cut
    of the STORED buffers (int8 dequantizes just the bucket), then a
    contiguous reshape (B,n_kv,Se,hd) -> (B,n_kv,n_shards,Se/n_shards,hd).
    Identical prefix semantics to the sequential read — the two only differ
    in the shard axis the split flash walk reduces over."""
    k, v = layer_read_bucket(k_l, v_l, k_scale_l, v_scale_l, bucket, dtype)
    B, n_kv, Se, hd = k.shape
    Sb = shard_extent(Se, n_shards)
    return (k.reshape(B, n_kv, n_shards, Sb, hd),
            v.reshape(B, n_kv, n_shards, Sb, hd))


# ---------------------------------------------------------------------------
# Per-slot (continuous-batching) API — the serving engine admits a request
# into ONE batch slot while the other slots keep decoding (DESIGN.md §7).
# Shapes stay static: the slot index and per-row cursors are traced scalars /
# (B,) vectors, so every program below compiles exactly once.
# ---------------------------------------------------------------------------

def layer_append_slotted(k_l: jax.Array, v_l: jax.Array, k_scale_l, v_scale_l,
                         k_new: jax.Array, v_new: jax.Array,
                         positions: jax.Array, window: int,
                         active: Optional[jax.Array] = None):
    """Per-row append: row ``b`` writes ``k_new[b]`` at its OWN cursor
    ``positions[b]`` (vmapped dynamic_update_slice — rows may sit at
    different depths). k_l/v_l: (B,n_kv,S,hd); k_new/v_new: (B,n_kv,hd);
    positions: (B,) int32; active: (B,) bool — inactive rows keep their
    slice byte-identical (retired slots must not pollute the cache)."""
    size = k_l.shape[2]
    slots = jax.lax.rem(positions, size) if window else positions
    if active is None:
        active = jnp.ones(positions.shape, bool)

    def row(dst, new, slot, act):
        upd = jax.lax.dynamic_update_slice(
            dst, new[:, None, :].astype(dst.dtype), (0, slot, 0))
        return jnp.where(act, upd, dst)

    if k_scale_l is not None:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        return (jax.vmap(row)(k_l, kq, slots, active),
                jax.vmap(row)(v_l, vq, slots, active),
                jax.vmap(row)(k_scale_l, ks, slots, active),
                jax.vmap(row)(v_scale_l, vs, slots, active))
    return (jax.vmap(row)(k_l, k_new, slots, active),
            jax.vmap(row)(v_l, v_new, slots, active), None, None)


def layer_write_chunk(k_l: jax.Array, v_l: jax.Array, k_scale_l, v_scale_l,
                      k_new: jax.Array, v_new: jax.Array, slot,
                      start, valid_len):
    """Chunked-prefill write: ONE slot's (C,)-wide chunk lands at cache
    positions [start, start+C) of row ``slot``. k_l/v_l: (B,n_kv,S,hd);
    k_new/v_new: (n_kv,C,hd); slot/start/valid_len are traced scalars — one
    compiled program serves every chunk of every prompt. Chunk positions
    >= ``valid_len`` (last-chunk padding) keep their previous bytes, so the
    cache past a prompt's true length is never touched and per-row cursor
    masks stay the single source of validity. Quantizes per position when
    scale slices are present (int8 caches store the chunk pre-dequant)."""
    C = k_new.shape[1]
    keep = (jnp.arange(C, dtype=jnp.int32) < valid_len)[None, :, None]

    def put(dst, new):
        if dst is None:
            return None
        cur = jax.lax.dynamic_slice(
            dst, (slot, 0, start, 0), (1,) + new.shape)
        new = jnp.where(keep, new.astype(dst.dtype), cur[0])
        return jax.lax.dynamic_update_slice(dst, new[None],
                                            (slot, 0, start, 0))

    if k_scale_l is not None:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        return (put(k_l, kq), put(v_l, vq),
                put(k_scale_l, ks), put(v_scale_l, vs))
    return put(k_l, k_new), put(v_l, v_new), None, None


def layer_read_slot(k_l, v_l, k_scale_l, v_scale_l, slot,
                    dtype=jnp.bfloat16):
    """``layer_read`` over ONE batch row (traced ``slot``): returns the
    slot's (1,n_kv,S,hd) K/V in compute dtype — the chunk-prefill attention
    reads the prefix it just extended without touching other slots."""
    def take(a):
        if a is None:
            return None
        return jax.lax.dynamic_slice(
            a, (slot,) + (0,) * (a.ndim - 1), (1,) + a.shape[1:])

    return layer_read(take(k_l), take(v_l), take(k_scale_l),
                      take(v_scale_l), dtype)


def batch_valid_mask(size: int, window: int, positions: jax.Array) -> jax.Array:
    """(B,S) bool — per-row ``slot_valid_mask`` (decode order: append→attend);
    row b attends exactly the positions its own cursor has written."""
    return jax.vmap(lambda p: slot_valid_mask(size, window, p))(positions)


def write_slot_kv(dst: KVCache, src: KVCache, slot) -> KVCache:
    """Admission: copy the batch-1 cache ``src`` (a fresh prefill) into batch
    slot ``slot`` of ``dst``. ``slot`` may be traced — ONE compiled program
    serves every slot. Seq lengths may differ (registry prefill sizes its
    cache as prompt+slack): the first min(S_src, S_dst) positions are copied,
    which covers the prompt for non-windowed caches. The cursor ``length``
    is NOT per-slot here — slotted decode threads per-row positions
    explicitly — so it is kept as max() purely as an upper bound."""
    n = min(src.k.shape[3], dst.k.shape[3])

    def put(d, s):
        if d is None:
            return None
        s = jax.lax.slice_in_dim(s, 0, n, axis=3).astype(d.dtype)
        return jax.lax.dynamic_update_slice(d, s, (0, slot, 0, 0, 0))

    return dst._replace(k=put(dst.k, src.k), v=put(dst.v, src.v),
                        k_scale=put(dst.k_scale, src.k_scale),
                        v_scale=put(dst.v_scale, src.v_scale),
                        length=jnp.maximum(dst.length, src.length))


def export_slot_kv(cache: KVCache, slot):
    """Preemption swap-out: ONE batch slot's full-extent stored K/V stacks
    as a ``(k, v, k_scale, v_scale)`` tuple of (L,1,n_kv,S,hd) slices
    (scales (L,1,n_kv,S,1); ``None`` entries for dense caches). ``slot`` is
    a traced scalar — one compiled program swaps out every slot.

    The slices are the STORED bytes — int8 caches export the quantized
    values and their per-(b,head,pos) scales verbatim, never a dequantized
    image — so a later ``import_slot_kv`` of the same tuple is
    byte-identical, the contract token-exact preemption rests on
    (DESIGN.md §7). The host keeps the full static extent and carries the
    TRUE length separately (cursors are the source of validity, exactly as
    in the chunk lane)."""
    def take(a):
        if a is None:
            return None
        return jax.lax.dynamic_slice(
            a, (0, slot, 0, 0, 0), (a.shape[0], 1) + a.shape[2:])

    return (take(cache.k), take(cache.v),
            take(cache.k_scale), take(cache.v_scale))


def import_slot_kv(cache: KVCache, saved, slot, valid_len) -> KVCache:
    """Preemption restore: write an ``export_slot_kv`` tuple back into
    ``slot``, masked to the sequence's TRUE length — positions
    >= ``valid_len`` keep the bytes already in the cache, mirroring
    ``layer_write_chunk``'s keep-past-valid semantics (the restore is the
    chunk lane's masked write at full width). ``slot``/``valid_len`` are
    traced scalars; the saved bytes land verbatim (stored dtype, scales
    included), so restore ∘ export is byte-identical below the cursor."""
    k_s, v_s, ks_s, vs_s = saved
    S = cache.k.shape[3]
    keep = (jnp.arange(S, dtype=jnp.int32) < valid_len)\
        .reshape(1, 1, 1, S, 1)

    def put(dst, new):
        if dst is None:
            return None
        cur = jax.lax.dynamic_slice(
            dst, (0, slot, 0, 0, 0), new.shape)
        merged = jnp.where(keep, new.astype(dst.dtype), cur)
        return jax.lax.dynamic_update_slice(dst, merged, (0, slot, 0, 0, 0))

    return cache._replace(k=put(cache.k, k_s), v=put(cache.v, v_s),
                          k_scale=put(cache.k_scale, ks_s),
                          v_scale=put(cache.v_scale, vs_s),
                          length=jnp.maximum(cache.length,
                                             jnp.asarray(valid_len,
                                                         jnp.int32)))


def reset_slot(cache: KVCache, slot) -> KVCache:
    """Zero one batch slot's K/V (retire). Not required for correctness —
    masked attention never reads past a slot's cursor and admission
    overwrites the prompt region — but keeps retired garbage out of cache
    dumps and makes slot-state invariants checkable."""
    def zero(d):
        if d is None:
            return None
        z = jnp.zeros((d.shape[0], 1) + d.shape[2:], d.dtype)
        return jax.lax.dynamic_update_slice(d, z, (0, slot, 0, 0, 0))

    return cache._replace(k=zero(cache.k), v=zero(cache.v),
                          k_scale=zero(cache.k_scale),
                          v_scale=zero(cache.v_scale))


def slot_valid_mask(size: int, window: int, query_pos: jax.Array) -> jax.Array:
    """(S,) bool — standalone form of valid_mask (decode order: append→attend)."""
    count = query_pos + 1
    idx = jnp.arange(size, dtype=jnp.int32)
    if not window:
        return idx < count
    head = jax.lax.rem(count + size - 1 - idx, size)
    p = count - 1 - head
    ok = (p >= 0) & (p <= query_pos) & (p > query_pos - window)
    return ok


def window_slots(cache: KVCache, count: jax.Array) -> jax.Array:
    """Absolute position held in each slot given ``count`` stored tokens
    (−1 if empty). Ring slot s holds the largest p < count with p ≡ s (mod W).
    """
    size = cache.k.shape[3]
    idx = jnp.arange(size, dtype=jnp.int32)
    if not cache.window:
        return jnp.where(idx < count, idx, -1)
    head = jax.lax.rem(count + size - 1 - idx, size)  # distance back from cursor
    p = count - 1 - head
    return jnp.where(p >= 0, p, -1)


def valid_mask(cache: KVCache, query_pos: jax.Array) -> jax.Array:
    """(S,) bool — slots attendable by a query at ``query_pos``, ASSUMING the
    query's own KV has been appended (decode order: append → attend).
    Window semantics inclusive: positions in [query_pos−W+1, query_pos]."""
    slots = window_slots(cache, query_pos + 1)
    ok = (slots >= 0) & (slots <= query_pos)
    if cache.window:
        ok &= slots > (query_pos - cache.window)
    return ok
