"""KV-cache management.

Layout is CONTIGUOUS (L, B, n_kv, S_max, head_dim) — the paper (§7.1) explicitly
rejects paged layouts because address indirection lands on the decode critical
path; we follow that choice and isolate KV by *placement* instead (WA
separation / sequence sharding), not by virtual-memory tricks.

Supports:
- full-context caches (global attention),
- ring-buffer sliding-window caches (recurrentgemma local attention),
- INT8-quantized storage with per-(b, head, pos) scales (paper runs fully INT8),
- TIERED storage (DESIGN.md §7): a hot ring of the most recent
  ``hot_window`` tokens at the compute dtype plus a cold tier holding every
  position quantized at ``cold_dtype`` (bf16 passthrough, int8, or packed
  int4). The hot→cold boundary advances in ``cold_block`` steps inside the
  compiled programs — per-QUERY, from traced cursors — so chunked prefill,
  monolithic admission and macro-step decode all attend the identical
  hot/cold image for every (key, query) pair.

The cache is a pytree; decode steps donate it (buffer reuse — no double
allocation of the GB-scale KV in steady state).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.int4 import dequantize_kv_int4, quantize_kv_int4
from repro.quant.int8 import dequantize_kv, quantize_kv


@jax.tree_util.register_pytree_with_keys_class
class KVCache:
    """Pytree: (k, v, k_scale, v_scale, hot_k, hot_v, length) children;
    ``window`` / tier geometry (hot_window, cold_block, cold_dtype) static.
    Untiered caches carry ``hot_k = hot_v = None`` — k/v are then the one
    flat tier; tiered caches store the cold image in k/v (+scales for
    int8/int4) and the exact recents in the hot ring."""

    _FIELDS = ("k", "v", "k_scale", "v_scale", "hot_k", "hot_v", "length")

    def __init__(self, k, v, k_scale, v_scale, length, window: int = 0,
                 hot_k=None, hot_v=None, hot_window: int = 0,
                 cold_block: int = 0, cold_dtype: str = "bfloat16"):
        self.k = k                       # (L,B,n_kv,S,hd_c)  cold/flat tier
        self.v = v
        self.k_scale = k_scale           # (L,B,n_kv,S,1) f32 — int8/int4 only
        self.v_scale = v_scale
        self.hot_k = hot_k               # (L,B,n_kv,H,hd) compute dtype ring
        self.hot_v = hot_v               # H = hot_window + cold_block
        self.length = length             # () int32 — tokens appended so far
        self.window = window             # 0 → full ctx; >0 → ring buffer
        self.hot_window = hot_window     # 0 → flat (untiered)
        self.cold_block = cold_block     # demotion granularity (tokens)
        self.cold_dtype = cold_dtype     # bfloat16 | int8 | int4

    def tree_flatten_with_keys(self):
        kids = tuple((jax.tree_util.GetAttrKey(f), getattr(self, f))
                     for f in self._FIELDS)
        return kids, (self.window, self.hot_window, self.cold_block,
                      self.cold_dtype)

    def tree_flatten(self):
        return (tuple(getattr(self, f) for f in self._FIELDS),
                (self.window, self.hot_window, self.cold_block,
                 self.cold_dtype))

    @classmethod
    def tree_unflatten(cls, aux, children):
        k, v, k_scale, v_scale, hot_k, hot_v, length = children
        window, hot_window, cold_block, cold_dtype = aux
        return cls(k, v, k_scale, v_scale, length, window=window,
                   hot_k=hot_k, hot_v=hot_v, hot_window=hot_window,
                   cold_block=cold_block, cold_dtype=cold_dtype)

    def _replace(self, **kw):
        d = dict(k=self.k, v=self.v, k_scale=self.k_scale,
                 v_scale=self.v_scale, length=self.length, window=self.window,
                 hot_k=self.hot_k, hot_v=self.hot_v,
                 hot_window=self.hot_window, cold_block=self.cold_block,
                 cold_dtype=self.cold_dtype)
        d.update(kw)
        return KVCache(**d)

    @property
    def max_len(self) -> int:
        return self.k.shape[3]

    @property
    def is_quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def is_tiered(self) -> bool:
        return self.hot_k is not None


def hot_extent(hot_window: int, cold_block: int) -> int:
    """Hot-ring size: the live hot region spans [cold_boundary, cursor] whose
    length is at most hot_window + cold_block − 1 (the boundary advances in
    cold_block jumps), so a ring of hot_window + cold_block slots always
    holds every hot position distinctly."""
    return hot_window + cold_block


def cold_boundary(counts, hot_window: int, cold_block: int):
    """First position still HOT for a row holding ``counts`` tokens —
    positions < boundary resolve to the cold tier, positions >= boundary to
    the exact hot ring. The boundary only moves at cold_block multiples:
    floor((counts − hot_window) / cold_block) · cold_block, clamped at 0.
    Depends only on the row's token count, never on chunk/block geometry, so
    every serving lane computes the identical per-query image."""
    over = jnp.maximum(jnp.asarray(counts, jnp.int32) - hot_window, 0)
    return (over // cold_block) * cold_block


def cold_pack_dim(head_dim: int, cold_dtype: str) -> int:
    """Stored head_dim of the cold tier (int4 packs two nibbles per byte)."""
    if cold_dtype == "int4":
        if head_dim % 2:
            raise ValueError(f"int4 cold tier needs even head_dim, "
                             f"got {head_dim}")
        return head_dim // 2
    return head_dim


def quantize_cold(x, cold_dtype: str):
    """(values, scale) at the cold dtype; bf16 cold stores verbatim."""
    if cold_dtype == "int4":
        return quantize_kv_int4(x)
    if cold_dtype == "int8":
        return quantize_kv(x)
    return x, None


def cold_read(k_l, v_l, k_scale_l, v_scale_l, cold_dtype: str,
              dtype=jnp.bfloat16):
    """Dequantize a cold-tier slice to the compute dtype (format-aware
    ``layer_read``: int4 unpacks, int8 rescales, bf16 casts)."""
    if k_scale_l is None:
        return k_l.astype(dtype), v_l.astype(dtype)
    if cold_dtype == "int4":
        return (dequantize_kv_int4(k_l, k_scale_l, dtype),
                dequantize_kv_int4(v_l, v_scale_l, dtype))
    return (dequantize_kv(k_l, k_scale_l, dtype),
            dequantize_kv(v_l, v_scale_l, dtype))


def init_kv_cache(n_layers: int, batch: int, n_kv: int, max_len: int,
                  head_dim: int, dtype=jnp.bfloat16, quantized: bool = False,
                  window: int = 0, hot_window: int = 0, cold_block: int = 0,
                  cold_dtype: str = "bfloat16") -> KVCache:
    size = min(window, max_len) if window else max_len
    # k/v (and the scales) must be DISTINCT buffers: the serving engine
    # donates the whole cache pytree per step, and XLA rejects donating one
    # buffer twice
    def mk(s, dt):
        return jnp.zeros(s, dt)

    if hot_window:
        if quantized:
            raise ValueError("tiered KV (hot_window > 0) subsumes the flat "
                             "int8 cache; use kv_cold_dtype instead of "
                             "kv_dtype='int8'")
        if window:
            raise ValueError("tiered KV does not compose with sliding-window "
                             "(ring) caches")
        if cold_block < 1:
            raise ValueError(f"cold_block must be >= 1, got {cold_block}")
        if cold_dtype not in ("bfloat16", "int8", "int4"):
            raise ValueError(f"unknown kv_cold_dtype {cold_dtype!r}")
        cold_scaled = cold_dtype in ("int8", "int4")
        cshape = (n_layers, batch, n_kv, size,
                  cold_pack_dim(head_dim, cold_dtype))
        sshape = cshape[:-1] + (1,)
        hshape = (n_layers, batch, n_kv, hot_extent(hot_window, cold_block),
                  head_dim)
        return KVCache(mk(cshape, jnp.int8 if cold_scaled else dtype),
                       mk(cshape, jnp.int8 if cold_scaled else dtype),
                       mk(sshape, jnp.float32) if cold_scaled else None,
                       mk(sshape, jnp.float32) if cold_scaled else None,
                       jnp.zeros((), jnp.int32), window=0,
                       hot_k=mk(hshape, dtype), hot_v=mk(hshape, dtype),
                       hot_window=hot_window, cold_block=cold_block,
                       cold_dtype=cold_dtype)
    store = jnp.int8 if quantized else dtype
    shape = (n_layers, batch, n_kv, size, head_dim)
    sshape = shape[:-1] + (1,)
    return KVCache(mk(shape, store), mk(shape, store),
                   mk(sshape, jnp.float32) if quantized else None,
                   mk(sshape, jnp.float32) if quantized else None,
                   jnp.zeros((), jnp.int32), window=window)


def _slot(cache: KVCache, pos: jax.Array) -> jax.Array:
    return jax.lax.rem(pos, cache.k.shape[3]) if cache.window else pos


def append_kv(cache: KVCache, layer: jax.Array, k_new: jax.Array,
              v_new: jax.Array) -> KVCache:
    """Append ONE position for one layer. k_new/v_new: (B, n_kv, hd).

    Used inside the per-layer scan: ``layer`` is the scan index. The write is
    a dynamic_update_slice — O(1), no relayout (contiguity preserved).
    """
    pos = cache.length
    slot = _slot(cache, pos)
    if cache.is_quantized:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k = jax.lax.dynamic_update_slice(
            cache.k, kq[None, :, :, None, :], (layer, 0, 0, slot, 0))
        v = jax.lax.dynamic_update_slice(
            cache.v, vq[None, :, :, None, :], (layer, 0, 0, slot, 0))
        k_scale = jax.lax.dynamic_update_slice(
            cache.k_scale, ks[None, :, :, None, :], (layer, 0, 0, slot, 0))
        v_scale = jax.lax.dynamic_update_slice(
            cache.v_scale, vs[None, :, :, None, :], (layer, 0, 0, slot, 0))
        return cache._replace(k=k, v=v, k_scale=k_scale, v_scale=v_scale)
    k = jax.lax.dynamic_update_slice(
        cache.k, k_new[None, :, :, None, :].astype(cache.k.dtype),
        (layer, 0, 0, slot, 0))
    v = jax.lax.dynamic_update_slice(
        cache.v, v_new[None, :, :, None, :].astype(cache.v.dtype),
        (layer, 0, 0, slot, 0))
    return cache._replace(k=k, v=v)


def bump_length(cache: KVCache) -> KVCache:
    """Advance the write cursor once per decode step (after all layers)."""
    return cache._replace(length=cache.length + 1)


def read_kv(cache: KVCache, layer: jax.Array, dtype=jnp.bfloat16):
    """Return (k, v) for a layer as compute dtype: (B, n_kv, S, hd)."""
    k = jax.lax.dynamic_index_in_dim(cache.k, layer, axis=0, keepdims=False)
    v = jax.lax.dynamic_index_in_dim(cache.v, layer, axis=0, keepdims=False)
    if cache.is_quantized:
        ks = jax.lax.dynamic_index_in_dim(cache.k_scale, layer, 0, keepdims=False)
        vs = jax.lax.dynamic_index_in_dim(cache.v_scale, layer, 0, keepdims=False)
        return dequantize_kv(k, ks, dtype), dequantize_kv(v, vs, dtype)
    return k.astype(dtype), v.astype(dtype)


# ---------------------------------------------------------------------------
# Per-layer slice API — used inside decode layer-scans so each layer touches
# ONLY its own (B,n_kv,S,hd) slice (the whole-cache carry would cost O(L)
# bytes per layer ⇒ O(L²) per step; slices flow as scan xs/ys instead and
# alias in place under donation).
# ---------------------------------------------------------------------------

def layer_append(k_l: jax.Array, v_l: jax.Array, k_scale_l, v_scale_l,
                 k_new: jax.Array, v_new: jax.Array, pos: jax.Array,
                 window: int):
    """k_l/v_l: (B,n_kv,S,hd); k_new/v_new: (B,n_kv,hd). Returns updated
    slices. Quantizes when scale slices are present."""
    size = k_l.shape[2]
    slot = jax.lax.rem(pos, size) if window else pos
    if k_scale_l is not None:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k_l = jax.lax.dynamic_update_slice(k_l, kq[:, :, None, :], (0, 0, slot, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, vq[:, :, None, :], (0, 0, slot, 0))
        k_scale_l = jax.lax.dynamic_update_slice(
            k_scale_l, ks[:, :, None, :], (0, 0, slot, 0))
        v_scale_l = jax.lax.dynamic_update_slice(
            v_scale_l, vs[:, :, None, :], (0, 0, slot, 0))
        return k_l, v_l, k_scale_l, v_scale_l
    k_l = jax.lax.dynamic_update_slice(
        k_l, k_new[:, :, None, :].astype(k_l.dtype), (0, 0, slot, 0))
    v_l = jax.lax.dynamic_update_slice(
        v_l, v_new[:, :, None, :].astype(v_l.dtype), (0, 0, slot, 0))
    return k_l, v_l, None, None


def layer_read(k_l, v_l, k_scale_l, v_scale_l, dtype=jnp.bfloat16):
    if k_scale_l is not None:
        return (dequantize_kv(k_l, k_scale_l, dtype),
                dequantize_kv(v_l, v_scale_l, dtype))
    return k_l.astype(dtype), v_l.astype(dtype)


def layer_read_bucket(k_l, v_l, k_scale_l, v_scale_l, bucket: int,
                      dtype=jnp.bfloat16):
    """``layer_read`` over only the first ``bucket`` positions (static slice
    of the STORED buffers, so int8 caches dequantize just the bucket — the
    length-aware decode path never upcasts KV it will not attend).
    ``bucket`` of 0 or >= S is the full-extent read."""
    S = k_l.shape[2]
    if bucket and bucket < S:
        def cut(a):
            return (None if a is None
                    else jax.lax.slice_in_dim(a, 0, bucket, axis=2))
        k_l, v_l = cut(k_l), cut(v_l)
        k_scale_l, v_scale_l = cut(k_scale_l), cut(v_scale_l)
    return layer_read(k_l, v_l, k_scale_l, v_scale_l, dtype)


# ---------------------------------------------------------------------------
# Split-KV shard-local layout (DESIGN.md §3) — one slot's contiguous
# (B,n_kv,S,hd) extent cut into n_shards equal sequence blocks for the
# A-domain split flash walk. Sharding is a READ-time view: the stored layout
# stays contiguous (no paging, §7.1), writes and cursors remain absolute.
# ---------------------------------------------------------------------------

def shard_extent(extent: int, n_shards: int) -> int:
    """Shard-local block length for a (bucketed) extent; validates that the
    extent cuts into ``n_shards`` equal contiguous blocks."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if extent % n_shards:
        raise ValueError(
            f"KV extent {extent} not divisible by n_shards={n_shards}")
    return extent // n_shards


def shard_kv_limits(kv_limit: jax.Array, n_shards: int,
                    block: int) -> jax.Array:
    """Per-shard live extents for a GLOBAL limit over a contiguous split:
    shard s owns absolute positions [s*block, (s+1)*block), so its local
    live extent is clamp(kv_limit - s*block, 0, block). Returns (n_shards,)
    int32 — traced, advancing cursors never recompile. A shard whose limit
    clamps to 0 is fully skippable (the flash kernel then reports the exact
    merge identity)."""
    lim = jnp.asarray(kv_limit, jnp.int32).reshape(())
    starts = jnp.arange(n_shards, dtype=jnp.int32) * block
    return jnp.clip(lim - starts, 0, block)


def layer_read_shards(k_l, v_l, k_scale_l, v_scale_l, bucket: int,
                      n_shards: int, dtype=jnp.bfloat16):
    """Shard-major bucketed read: ``layer_read_bucket``'s static prefix cut
    of the STORED buffers (int8 dequantizes just the bucket), then a
    contiguous reshape (B,n_kv,Se,hd) -> (B,n_kv,n_shards,Se/n_shards,hd).
    Identical prefix semantics to the sequential read — the two only differ
    in the shard axis the split flash walk reduces over."""
    k, v = layer_read_bucket(k_l, v_l, k_scale_l, v_scale_l, bucket, dtype)
    B, n_kv, Se, hd = k.shape
    Sb = shard_extent(Se, n_shards)
    return (k.reshape(B, n_kv, n_shards, Sb, hd),
            v.reshape(B, n_kv, n_shards, Sb, hd))


# ---------------------------------------------------------------------------
# Per-slot (continuous-batching) API — the serving engine admits a request
# into ONE batch slot while the other slots keep decoding (DESIGN.md §7).
# Shapes stay static: the slot index and per-row cursors are traced scalars /
# (B,) vectors, so every program below compiles exactly once.
# ---------------------------------------------------------------------------

def layer_append_slotted(k_l: jax.Array, v_l: jax.Array, k_scale_l, v_scale_l,
                         k_new: jax.Array, v_new: jax.Array,
                         positions: jax.Array, window: int,
                         active: Optional[jax.Array] = None):
    """Per-row append: row ``b`` writes ``k_new[b]`` at its OWN cursor
    ``positions[b]`` (vmapped dynamic_update_slice — rows may sit at
    different depths). k_l/v_l: (B,n_kv,S,hd); k_new/v_new: (B,n_kv,hd);
    positions: (B,) int32; active: (B,) bool — inactive rows keep their
    slice byte-identical (retired slots must not pollute the cache)."""
    size = k_l.shape[2]
    slots = jax.lax.rem(positions, size) if window else positions
    if active is None:
        active = jnp.ones(positions.shape, bool)

    def row(dst, new, slot, act):
        upd = jax.lax.dynamic_update_slice(
            dst, new[:, None, :].astype(dst.dtype), (0, slot, 0))
        return jnp.where(act, upd, dst)

    if k_scale_l is not None:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        return (jax.vmap(row)(k_l, kq, slots, active),
                jax.vmap(row)(v_l, vq, slots, active),
                jax.vmap(row)(k_scale_l, ks, slots, active),
                jax.vmap(row)(v_scale_l, vs, slots, active))
    return (jax.vmap(row)(k_l, k_new, slots, active),
            jax.vmap(row)(v_l, v_new, slots, active), None, None)


def layer_write_chunk(k_l: jax.Array, v_l: jax.Array, k_scale_l, v_scale_l,
                      k_new: jax.Array, v_new: jax.Array, slot,
                      start, valid_len):
    """Chunked-prefill write: ONE slot's (C,)-wide chunk lands at cache
    positions [start, start+C) of row ``slot``. k_l/v_l: (B,n_kv,S,hd);
    k_new/v_new: (n_kv,C,hd); slot/start/valid_len are traced scalars — one
    compiled program serves every chunk of every prompt. Chunk positions
    >= ``valid_len`` (last-chunk padding) keep their previous bytes, so the
    cache past a prompt's true length is never touched and per-row cursor
    masks stay the single source of validity. Quantizes per position when
    scale slices are present (int8 caches store the chunk pre-dequant)."""
    C = k_new.shape[1]
    keep = (jnp.arange(C, dtype=jnp.int32) < valid_len)[None, :, None]

    def put(dst, new):
        if dst is None:
            return None
        cur = jax.lax.dynamic_slice(
            dst, (slot, 0, start, 0), (1,) + new.shape)
        new = jnp.where(keep, new.astype(dst.dtype), cur[0])
        return jax.lax.dynamic_update_slice(dst, new[None],
                                            (slot, 0, start, 0))

    if k_scale_l is not None:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        return (put(k_l, kq), put(v_l, vq),
                put(k_scale_l, ks), put(v_scale_l, vs))
    return put(k_l, k_new), put(v_l, v_new), None, None


def layer_read_slot(k_l, v_l, k_scale_l, v_scale_l, slot,
                    dtype=jnp.bfloat16):
    """``layer_read`` over ONE batch row (traced ``slot``): returns the
    slot's (1,n_kv,S,hd) K/V in compute dtype — the chunk-prefill attention
    reads the prefix it just extended without touching other slots."""
    def take(a):
        if a is None:
            return None
        return jax.lax.dynamic_slice(
            a, (slot,) + (0,) * (a.ndim - 1), (1,) + a.shape[1:])

    return layer_read(take(k_l), take(v_l), take(k_scale_l),
                      take(v_scale_l), dtype)


# ---------------------------------------------------------------------------
# Tiered (hot ring + quantized cold) per-layer API — DESIGN.md §7.
#
# Every position is STAGED into the cold tier at write time (quantization of
# a given bf16 vector is deterministic, so staging eagerly at append is
# byte-identical to lazily re-quantizing the aging block at the demotion
# boundary — with uniform per-step cost and no gather). The hot ring holds
# the exact values of the most recent positions; "demotion" is the read-side
# boundary ``cold_boundary(count)`` advancing by cold_block inside the
# compiled program. Both writes are slot-extent-1 dynamic_update_slices, the
# same isolation contract the kernel-bounds pass audits for flat caches.
# ---------------------------------------------------------------------------

def layer_append_tiered(k_l, v_l, k_scale_l, v_scale_l, hot_k_l, hot_v_l,
                        k_new, v_new, positions: jax.Array,
                        cold_dtype: str, active: Optional[jax.Array] = None):
    """Decode append for a tiered layer: stage the new position into the
    cold tier (quantized at ``cold_dtype``) AND write it exactly into the
    hot ring at slot position % H. k_l/v_l: (B,n_kv,S,hd_c); hot rings
    (B,n_kv,H,hd); k_new/v_new: (B,n_kv,hd); positions: (B,) int32."""
    H = hot_k_l.shape[2]
    ring = jax.lax.rem(positions, H)
    if active is None:
        active = jnp.ones(positions.shape, bool)

    def row(dst, new, slot, act):
        upd = jax.lax.dynamic_update_slice(
            dst, new[:, None, :].astype(dst.dtype), (0, slot, 0))
        return jnp.where(act, upd, dst)

    kq, ks = quantize_cold(k_new, cold_dtype)
    vq, vs = quantize_cold(v_new, cold_dtype)
    k_l = jax.vmap(row)(k_l, kq, positions, active)
    v_l = jax.vmap(row)(v_l, vq, positions, active)
    if k_scale_l is not None:
        k_scale_l = jax.vmap(row)(k_scale_l, ks, positions, active)
        v_scale_l = jax.vmap(row)(v_scale_l, vs, positions, active)
    hot_k_l = jax.vmap(row)(hot_k_l, k_new, ring, active)
    hot_v_l = jax.vmap(row)(hot_v_l, v_new, ring, active)
    return k_l, v_l, k_scale_l, v_scale_l, hot_k_l, hot_v_l


def layer_read_tiered(k_l, v_l, k_scale_l, v_scale_l, hot_k_l, hot_v_l,
                      counts: jax.Array, bucket: int, hot_window: int,
                      cold_block: int, cold_dtype: str, dtype=jnp.bfloat16):
    """Tiered bucketed read: (B,n_kv,Se,hd) image where position j of row b
    resolves to the exact hot-ring value when j >= cold_boundary(counts[b])
    and to the dequantized cold bytes otherwise. The bucket prefix is cut
    from the STORED buffers first — only the touched prefix of each tier is
    ever dequantized/tiled. ``counts``: (B,) tokens stored per row (cursors
    + 1, post-append)."""
    S = k_l.shape[2]
    Se = bucket if (bucket and bucket < S) else S

    def cut(a):
        if a is None or Se == S:
            return a
        return jax.lax.slice_in_dim(a, 0, Se, axis=2)
    kc, vc = cold_read(cut(k_l), cut(v_l), cut(k_scale_l), cut(v_scale_l),
                       cold_dtype, dtype)
    H = hot_k_l.shape[2]
    idx = jnp.arange(Se, dtype=jnp.int32)
    kh = jnp.take(hot_k_l, jax.lax.rem(idx, H), axis=2).astype(dtype)
    vh = jnp.take(hot_v_l, jax.lax.rem(idx, H), axis=2).astype(dtype)
    cb = cold_boundary(counts, hot_window, cold_block)          # (B,)
    hot = (idx[None, :] >= cb[:, None])[:, None, :, None]       # (B,1,Se,1)
    return jnp.where(hot, kh, kc), jnp.where(hot, vh, vc)


def layer_read_tiered_shards(k_l, v_l, k_scale_l, v_scale_l, hot_k_l,
                             hot_v_l, counts, bucket: int, n_shards: int,
                             hot_window: int, cold_block: int,
                             cold_dtype: str, dtype=jnp.bfloat16):
    """Shard-major tiered read: the tiered image select is positionwise, so
    the split-KV layout is the same contiguous reshape as
    ``layer_read_shards`` applied AFTER the hot/cold resolve — shard s owns
    absolute positions [s·Sb, (s+1)·Sb) of the concatenated image."""
    k, v = layer_read_tiered(k_l, v_l, k_scale_l, v_scale_l, hot_k_l,
                             hot_v_l, counts, bucket, hot_window, cold_block,
                             cold_dtype, dtype)
    B, n_kv, Se, hd = k.shape
    Sb = shard_extent(Se, n_shards)
    return (k.reshape(B, n_kv, n_shards, Sb, hd),
            v.reshape(B, n_kv, n_shards, Sb, hd))


def layer_write_chunk_tiered(k_l, v_l, k_scale_l, v_scale_l, hot_k_l,
                             hot_v_l, k_new, v_new, slot, start, valid_len,
                             cold_dtype: str):
    """Chunked-prefill write into BOTH tiers: the chunk's positions are
    staged into the cold container (quantized at the cold dtype, with
    ``layer_write_chunk``'s keep-past-valid masking) and the hot ring takes
    a residue write — ring slot s receives the LAST valid chunk position
    ≡ s (mod H); ring slots the chunk does not cover keep their bytes (they
    hold still-hot positions of earlier chunks). k_new/v_new: (n_kv,C,hd)."""
    C = k_new.shape[1]
    keep = (jnp.arange(C, dtype=jnp.int32) < valid_len)[None, :, None]

    def put(dst, new):
        if dst is None:
            return None
        cur = jax.lax.dynamic_slice(
            dst, (slot, 0, start, 0), (1,) + new.shape)
        new = jnp.where(keep, new.astype(dst.dtype), cur[0])
        return jax.lax.dynamic_update_slice(dst, new[None],
                                            (slot, 0, start, 0))

    kq, ks = quantize_cold(k_new, cold_dtype)
    vq, vs = quantize_cold(v_new, cold_dtype)
    k_l, v_l = put(k_l, kq), put(v_l, vq)
    k_scale_l, v_scale_l = put(k_scale_l, ks), put(v_scale_l, vs)

    H = hot_k_l.shape[2]
    s_idx = jnp.arange(H, dtype=jnp.int32)
    # r = (ring slot − start) mod H: chunk index of the FIRST position that
    # lands in ring slot s; the last valid one is r + H·⌊(valid−1−r)/H⌋
    r = jax.lax.rem(s_idx - jax.lax.rem(start, H) + H, H)
    i_star = jnp.clip(r + H * ((valid_len - 1 - r) // H), 0, C - 1)
    keep_h = (r < valid_len)[None, :, None]

    def put_hot(dst, new):
        g = jnp.take(new, i_star, axis=1)                   # (n_kv,H,hd)
        cur = jax.lax.dynamic_slice(dst, (slot, 0, 0, 0), (1,) + g.shape)
        g = jnp.where(keep_h, g.astype(dst.dtype), cur[0])
        return jax.lax.dynamic_update_slice(dst, g[None], (slot, 0, 0, 0))

    return (k_l, v_l, k_scale_l, v_scale_l,
            put_hot(hot_k_l, k_new), put_hot(hot_v_l, v_new))


def layer_read_slot_cold(k_l, v_l, k_scale_l, v_scale_l, slot,
                         cold_dtype: str, dtype=jnp.bfloat16):
    """``layer_read_slot`` for the COLD tier: one slot's (1,n_kv,S,hd)
    dequantized cold image, format-aware (int4 unpacks, int8 rescales,
    bf16 casts). The chunk program attends this against the per-query
    ``chunk_hot_image`` under the ``cold_boundary`` select."""
    def take(a):
        if a is None:
            return None
        return jax.lax.dynamic_slice(
            a, (slot,) + (0,) * (a.ndim - 1), (1,) + a.shape[1:])

    return cold_read(take(k_l), take(v_l), take(k_scale_l),
                     take(v_scale_l), cold_dtype, dtype)


def chunk_hot_image(hot_k_l, hot_v_l, k_new, v_new, slot, start, valid_len,
                    extent: int, dtype=jnp.bfloat16):
    """(1,n_kv,S,hd) exact-value image for the chunk program's per-query hot
    reads, built from the PRE-write ring: positions < start tile from the
    ring (the incoming chunk may overwrite exactly those ring slots), and
    positions in [start, start+valid) come from the incoming chunk itself.
    The pre-write ring holds every position >= cold_boundary(start) — a
    superset of every query's hot tail — because the hot region never
    exceeds H − 1 positions."""
    idx = jnp.arange(extent, dtype=jnp.int32)
    in_chunk = ((idx >= start) & (idx < start + valid_len))[None, None, :,
                                                            None]

    def one(h_l, new):
        H = h_l.shape[2]
        row = jax.lax.dynamic_slice(
            h_l, (slot, 0, 0, 0), (1,) + h_l.shape[1:])     # (1,n_kv,H,hd)
        tiled = jnp.take(row, jax.lax.rem(idx, H), axis=2).astype(dtype)
        placed = jax.lax.dynamic_update_slice(
            jnp.zeros_like(tiled), new[None].astype(dtype), (0, 0, start, 0))
        return jnp.where(in_chunk, placed, tiled)

    return one(hot_k_l, k_new), one(hot_v_l, v_new)


def batch_valid_mask(size: int, window: int, positions: jax.Array) -> jax.Array:
    """(B,S) bool — per-row ``slot_valid_mask`` (decode order: append→attend);
    row b attends exactly the positions its own cursor has written."""
    return jax.vmap(lambda p: slot_valid_mask(size, window, p))(positions)


def write_slot_kv(dst: KVCache, src: KVCache, slot) -> KVCache:
    """Admission: copy the batch-1 cache ``src`` (a fresh prefill) into batch
    slot ``slot`` of ``dst``. ``slot`` may be traced — ONE compiled program
    serves every slot. Seq lengths may differ (registry prefill sizes its
    cache as prompt+slack): the first min(S_src, S_dst) positions are copied,
    which covers the prompt for non-windowed caches. The cursor ``length``
    is NOT per-slot here — slotted decode threads per-row positions
    explicitly — so it is kept as max() purely as an upper bound."""
    n = min(src.k.shape[3], dst.k.shape[3])

    def put(d, s, m=None):
        if d is None:
            return None
        s = jax.lax.slice_in_dim(s, 0, m or n, axis=3).astype(d.dtype)
        return jax.lax.dynamic_update_slice(d, s, (0, slot, 0, 0, 0))

    nh = None if dst.hot_k is None \
        else min(src.hot_k.shape[3], dst.hot_k.shape[3])
    return dst._replace(k=put(dst.k, src.k), v=put(dst.v, src.v),
                        k_scale=put(dst.k_scale, src.k_scale),
                        v_scale=put(dst.v_scale, src.v_scale),
                        hot_k=put(dst.hot_k, src.hot_k, nh)
                        if dst.hot_k is not None else None,
                        hot_v=put(dst.hot_v, src.hot_v, nh)
                        if dst.hot_v is not None else None,
                        length=jnp.maximum(dst.length, src.length))


def export_slot_kv(cache: KVCache, slot):
    """Preemption swap-out: ONE batch slot's full-extent stored K/V stacks
    as a ``(k, v, k_scale, v_scale, hot_k, hot_v)`` tuple of (L,1,n_kv,S,hd)
    slices (scales (L,1,n_kv,S,1); hot rings (L,1,n_kv,H,hd); ``None``
    entries for dense/untiered caches). ``slot`` is a traced scalar — one
    compiled program swaps out every slot. Tiered victims export BOTH
    tiers: the quantized cold bytes + scales verbatim and the exact hot
    ring, so restore reproduces the tier state bit-for-bit.

    The slices are the STORED bytes — int8 caches export the quantized
    values and their per-(b,head,pos) scales verbatim, never a dequantized
    image — so a later ``import_slot_kv`` of the same tuple is
    byte-identical, the contract token-exact preemption rests on
    (DESIGN.md §7). The host keeps the full static extent and carries the
    TRUE length separately (cursors are the source of validity, exactly as
    in the chunk lane)."""
    def take(a):
        if a is None:
            return None
        return jax.lax.dynamic_slice(
            a, (0, slot, 0, 0, 0), (a.shape[0], 1) + a.shape[2:])

    return (take(cache.k), take(cache.v),
            take(cache.k_scale), take(cache.v_scale),
            take(cache.hot_k), take(cache.hot_v))


def import_slot_kv(cache: KVCache, saved, slot, valid_len) -> KVCache:
    """Preemption restore: write an ``export_slot_kv`` tuple back into
    ``slot``, masked to the sequence's TRUE length — positions
    >= ``valid_len`` keep the bytes already in the cache, mirroring
    ``layer_write_chunk``'s keep-past-valid semantics (the restore is the
    chunk lane's masked write at full width). ``slot``/``valid_len`` are
    traced scalars; the saved bytes land verbatim (stored dtype, scales
    included), so restore ∘ export is byte-identical below the cursor.
    The hot ring restores VERBATIM at full ring width: ring slots are only
    ever read for positions inside the restored row's hot region, and the
    export captured exactly the victim's pre-swap ring state."""
    k_s, v_s, ks_s, vs_s, hk_s, hv_s = saved
    S = cache.k.shape[3]
    keep = (jnp.arange(S, dtype=jnp.int32) < valid_len)\
        .reshape(1, 1, 1, S, 1)

    def put(dst, new, masked=True):
        if dst is None:
            return None
        cur = jax.lax.dynamic_slice(
            dst, (0, slot, 0, 0, 0), new.shape)
        merged = jnp.where(keep, new.astype(dst.dtype), cur) if masked \
            else new.astype(dst.dtype)
        return jax.lax.dynamic_update_slice(dst, merged, (0, slot, 0, 0, 0))

    return cache._replace(k=put(cache.k, k_s), v=put(cache.v, v_s),
                          k_scale=put(cache.k_scale, ks_s),
                          v_scale=put(cache.v_scale, vs_s),
                          hot_k=put(cache.hot_k, hk_s, masked=False)
                          if hk_s is not None else cache.hot_k,
                          hot_v=put(cache.hot_v, hv_s, masked=False)
                          if hv_s is not None else cache.hot_v,
                          length=jnp.maximum(cache.length,
                                             jnp.asarray(valid_len,
                                                         jnp.int32)))


def reset_slot(cache: KVCache, slot) -> KVCache:
    """Zero one batch slot's K/V (retire) — both tiers for tiered caches.
    Not required for correctness — masked attention never reads past a
    slot's cursor and admission overwrites the prompt region — but keeps
    retired garbage out of cache dumps and makes slot-state invariants
    checkable."""
    def zero(d):
        if d is None:
            return None
        z = jnp.zeros((d.shape[0], 1) + d.shape[2:], d.dtype)
        return jax.lax.dynamic_update_slice(d, z, (0, slot, 0, 0, 0))

    return cache._replace(k=zero(cache.k), v=zero(cache.v),
                          k_scale=zero(cache.k_scale),
                          v_scale=zero(cache.v_scale),
                          hot_k=zero(cache.hot_k), hot_v=zero(cache.hot_v))


def slot_valid_mask(size: int, window: int, query_pos: jax.Array) -> jax.Array:
    """(S,) bool — standalone form of valid_mask (decode order: append→attend)."""
    count = query_pos + 1
    idx = jnp.arange(size, dtype=jnp.int32)
    if not window:
        return idx < count
    head = jax.lax.rem(count + size - 1 - idx, size)
    p = count - 1 - head
    ok = (p >= 0) & (p <= query_pos) & (p > query_pos - window)
    return ok


def window_slots(cache: KVCache, count: jax.Array) -> jax.Array:
    """Absolute position held in each slot given ``count`` stored tokens
    (−1 if empty). Ring slot s holds the largest p < count with p ≡ s (mod W).
    """
    size = cache.k.shape[3]
    idx = jnp.arange(size, dtype=jnp.int32)
    if not cache.window:
        return jnp.where(idx < count, idx, -1)
    head = jax.lax.rem(count + size - 1 - idx, size)  # distance back from cursor
    p = count - 1 - head
    return jnp.where(p >= 0, p, -1)


def valid_mask(cache: KVCache, query_pos: jax.Array) -> jax.Array:
    """(S,) bool — slots attendable by a query at ``query_pos``, ASSUMING the
    query's own KV has been appended (decode order: append → attend).
    Window semantics inclusive: positions in [query_pos−W+1, query_pos]."""
    slots = window_slots(cache, query_pos + 1)
    ok = (slots >= 0) & (slots <= query_pos)
    if cache.window:
        ok &= slots > (query_pos - cache.window)
    return ok
