"""Uniform model API over all families + input_specs() for the dry-run.

``build_model(cfg)`` → ModelAPI with:
    init(key) -> params
    loss(params, batch, ctx) -> scalar
    prefill(params, batch, ctx) -> (caches, last_logits)
    decode(params, caches, tokens, ctx) -> (caches, logits)
    init_caches(batch, max_len) -> caches pytree
    input_specs(shape) -> dict of jax.ShapeDtypeStruct (weak-type-correct,
                          shardable, no device allocation)
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig

DECODE_SLACK = 128      # cache headroom beyond the shape's context length


class ModelAPI(NamedTuple):
    config: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    init_caches: Callable
    input_specs: Callable
    # -- continuous-batching extensions (None → family only serves in the
    #    drain-then-refill mode; see runtime/serving.py + DESIGN.md §7) -----
    # decode_slotted(params, caches, tokens, positions, active, ctx,
    #                kv_bucket=0[, kv_shards=1])
    #   → (caches, logits): per-slot cursors + active mask through decode;
    #   kv_bucket (static) caps the attended KV extent (length-aware walk);
    #   kv_shards (static, KV-cache families only) splits the walk into
    #   sequence shards combined by the partial-softmax LSE merge
    #   (split-KV flash decode — models/attention.py)
    decode_slotted: Optional[Callable] = None
    # write_slot(caches, single, slot) → caches: admit a batch-1 prefill
    #   into one batch slot (slot is traced — one program for all slots)
    write_slot: Optional[Callable] = None
    # reset_slot(caches, slot) → caches: zero a retired slot's state
    reset_slot: Optional[Callable] = None
    # decode_block(params, caches, tokens, positions, active, remaining,
    #              eos_ids, ctx, *, block_size, kv_bucket=0): T greedy
    #   micro-steps in ONE program with on-device per-slot halting — the
    #   macro-step decode path (DESIGN.md §7); see make_decode_block
    decode_block: Optional[Callable] = None
    # prefill_chunk(params, caches, tokens, slot, start, valid_len, ctx)
    #   → (caches, logits (1,1,V)): ONE fixed-(1,C) program that writes slot
    #   ``slot``'s prompt chunk [start, start+valid_len) at its per-slot
    #   offset and attends/advances over the prefix — the chunked-prefill
    #   lane (DESIGN.md §7). slot/start/valid_len traced: zero retracing
    #   across chunks, prompts and slots. None → monolithic admission only.
    prefill_chunk: Optional[Callable] = None
    # wa_servable: the family can serve through the WA-disaggregated backend
    #   (ServingEngine(backend="wa") → core/wa.py). True only for prefix-
    #   ordered KV-cache transformers: attention-free families have no KV to
    #   decouple (DESIGN.md §6), windowed ring buffers have no stable
    #   per-position offsets, and VLM prompts interleave vision embeds the
    #   token-only WA chunk walk cannot cover.
    wa_servable: bool = False


def make_decode_block(decode_slotted: Callable) -> Callable:
    """Lift a family's ``decode_slotted`` into a macro-step ``decode_block``:
    ``block_size`` greedy micro-steps inside one ``lax.scan`` — caches,
    cursors, halt masks and sampled tokens all advance ON DEVICE, so the
    host syncs once per block instead of once per token (the step-axis
    analogue of the paper's sub-operator dependency relaxation, §5).

    Per-slot halting: ``remaining[b]`` is row b's token budget and
    ``eos_ids[b]`` an optional stop id (< 0 disables). A row that exhausts
    its budget or emits its EOS flips its own ``active`` bit mid-block and
    idles (no KV writes, token id 0) without host intervention.

    Returns ``(caches, toks (T,B) int32, emitted (T,B) bool, last_tok,
    positions, active, remaining)`` — ``emitted[t, b]`` marks micro-steps
    that really generated a token, so the host can unpack the block without
    guessing which zeros are padding."""

    def decode_block(params, caches, tokens, positions, active, remaining,
                     eos_ids, ctx, *, block_size: int, kv_bucket: int = 0,
                     kv_shards: int = 1):
        # kv_shards is forwarded only when split (> 1): attention-free
        # families' decode_slotted has no such axis and no such kwarg
        extra = {"kv_shards": kv_shards} if kv_shards != 1 else {}

        def micro(carry, _):
            caches, tok, pos, act, rem = carry
            caches, logits = decode_slotted(params, caches, tok, pos, act,
                                            ctx, kv_bucket=kv_bucket, **extra)
            nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
            nxt = jnp.where(act, nxt, 0)
            emitted = act
            step = act.astype(jnp.int32)
            pos = pos + step
            rem = rem - step
            act = act & (rem > 0) & ((eos_ids < 0) | (nxt != eos_ids))
            return (caches, nxt, pos, act, rem), (nxt, emitted)

        (caches, tok, pos, act, rem), (toks, emitted) = jax.lax.scan(
            micro, (caches, tokens, positions, active, remaining),
            None, length=block_size)
        return caches, toks, emitted, tok, pos, act, rem

    return decode_block


# ---------------------------------------------------------------------------
# family builders
# ---------------------------------------------------------------------------

def _build_transformer(cfg: ModelConfig) -> ModelAPI:
    from repro.models import transformer as T

    is_vlm = cfg.family == "vlm"

    def loss(params, batch, ctx):
        return T.loss_fn(params, batch, cfg, ctx)

    def prefill(params, batch, ctx):
        cache = T.make_cache(cfg, batch["tokens"].shape[0],
                             batch["tokens"].shape[1]
                             + (cfg.n_vision_tokens if is_vlm else 0)
                             + DECODE_SLACK)
        return T.prefill(params, batch["tokens"], cfg, ctx, cache,
                         vision_embeds=batch.get("vision_embeds"))

    def decode(params, caches, tokens, ctx):
        return T.decode_step(params, caches, tokens, cfg, ctx)

    def init_caches(batch, max_len):
        return T.make_cache(cfg, batch, max_len)

    def decode_slotted(params, caches, tokens, positions, active, ctx,
                       kv_bucket: int = 0, kv_shards: int = 1):
        return T.decode_step_slotted(params, caches, tokens, positions,
                                     active, cfg, ctx, kv_bucket=kv_bucket,
                                     kv_shards=kv_shards)

    from repro.kv.cache import reset_slot, write_slot_kv

    def prefill_chunk(params, caches, tokens, slot, start, valid_len, ctx):
        return T.prefill_chunk(params, caches, tokens, slot, start,
                               valid_len, cfg, ctx)

    return ModelAPI(cfg, lambda k: T.init_params(k, cfg), loss, prefill,
                    decode, init_caches, _lm_input_specs(cfg),
                    decode_slotted=decode_slotted,
                    write_slot=write_slot_kv,
                    reset_slot=reset_slot,
                    decode_block=make_decode_block(decode_slotted),
                    # VLM prompts interleave vision embeds — the token-only
                    # chunk walk cannot cover them; monolithic admission only
                    prefill_chunk=None if is_vlm else prefill_chunk,
                    wa_servable=not is_vlm)


def _build_ssm(cfg: ModelConfig) -> ModelAPI:
    from repro.models import ssm as S
    from repro.kv.state import reset_slot_tree, write_slot_tree

    def decode_slotted(params, state, tokens, positions, active, ctx,
                       kv_bucket: int = 0):
        return S.decode_step_slotted(params, state, tokens, positions,
                                     active, cfg, ctx, kv_bucket=kv_bucket)

    def prefill_chunk(params, state, tokens, slot, start, valid_len, ctx):
        return S.prefill_chunk(params, state, tokens, slot, start,
                               valid_len, cfg, ctx)

    return ModelAPI(
        cfg,
        lambda k: S.init_params(k, cfg),
        lambda p, b, ctx: S.loss_fn(p, b, cfg, ctx),
        lambda p, b, ctx: S.prefill(p, b["tokens"], cfg, ctx),
        lambda p, c, t, ctx: S.decode_step(p, c, t, cfg, ctx),
        lambda batch, max_len: S.make_state(cfg, batch),
        _lm_input_specs(cfg),
        decode_slotted=decode_slotted,
        write_slot=write_slot_tree,
        reset_slot=reset_slot_tree,
        decode_block=make_decode_block(decode_slotted),
        prefill_chunk=prefill_chunk)


def _build_hybrid(cfg: ModelConfig) -> ModelAPI:
    from repro.models import rglru as R

    return ModelAPI(
        cfg,
        lambda k: R.init_params(k, cfg),
        lambda p, b, ctx: R.loss_fn(p, b, cfg, ctx),
        lambda p, b, ctx: R.prefill(p, b["tokens"], cfg, ctx),
        lambda p, c, t, ctx: R.decode_step(p, c, t, cfg, ctx),
        lambda batch, max_len: R.make_caches(cfg, batch, max_len),
        _lm_input_specs(cfg))


def _build_encdec(cfg: ModelConfig) -> ModelAPI:
    from repro.models import encdec as E

    def prefill(p, b, ctx):
        return E.prefill(p, b["tokens"], b["frames"], cfg, ctx)

    return ModelAPI(
        cfg,
        lambda k: E.init_params(k, cfg),
        lambda p, b, ctx: E.loss_fn(p, b, cfg, ctx),
        prefill,
        lambda p, c, t, ctx: E.decode_step(p, c, t, cfg, ctx),
        lambda batch, max_len: E.make_caches(cfg, batch, max_len),
        _lm_input_specs(cfg))


def build_model(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _build_transformer(cfg)
    if fam == "ssm":
        return _build_ssm(cfg)
    if fam == "hybrid":
        return _build_hybrid(cfg)
    if fam == "audio":
        return _build_encdec(cfg)
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def _lm_input_specs(cfg: ModelConfig):
    f32 = jnp.dtype(jnp.float32)
    i32 = jnp.dtype(jnp.int32)

    def specs(shape: ShapeConfig) -> Dict[str, Any]:
        B, S = shape.global_batch, shape.seq_len
        sd = jax.ShapeDtypeStruct
        if shape.mode == "decode":
            return {"tokens": sd((B,), i32)}
        out: Dict[str, Any] = {}
        if cfg.family == "audio":
            out["frames"] = sd((B, cfg.encoder.n_frames, cfg.d_model), f32)
        s_text = S
        if cfg.family == "vlm":
            out["vision_embeds"] = sd((B, cfg.n_vision_tokens, cfg.d_model), f32)
            s_text = S - cfg.n_vision_tokens
        out["tokens"] = sd((B, s_text), i32)
        if shape.mode == "train":
            out["labels"] = sd((B, s_text), i32)
        return out

    return specs


def decode_cache_len(shape: ShapeConfig) -> int:
    return shape.seq_len + DECODE_SLACK


# ---------------------------------------------------------------------------
# parameter counting (exact, via eval_shape — no allocation)
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    api = build_model(cfg)
    shapes = jax.eval_shape(api.init, jax.random.key(0))
    leaves = jax.tree_util.tree_leaves_with_path(shapes)
    total = 0
    for path, leaf in leaves:
        keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        n = int(np.prod(leaf.shape))
        if "scale" in keys and any(k in ("w", "table") for k in keys):
            continue                        # int8 quant scales aren't params
        if active_only and cfg.moe is not None and any(
                k in ("w_gate", "w_up", "w_down") for k in keys) and \
                "moe" in keys:
            n = n * cfg.moe.experts_per_token // cfg.moe.num_experts
        total += n
    return total
