"""Parameter → PartitionSpec assignment by leaf-path pattern matching.

Equivalent role to the paper's deterministic shard→core map (§4.3): the
placement of every weight shard is decided statically, once, before compile.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.models.sharding import ShardingCtx

# (match keys..., logical axes for the trailing dims of the leaf)
# Leading stacked dims ("layers"/superblock) are padded with None.
_RULES = [
    (("embed", "table"), ("vocab", "embed_w")),
    (("unembed", "table"), ("vocab", "embed_w")),
    (("pos_embed",), (None, "embed_w")),
    (("router", "w"), ("embed_w", None)),
    (("moe", "w_gate"), ("experts", "embed_w", "mlp_shard")),
    (("moe", "w_up"), ("experts", "embed_w", "mlp_shard")),
    (("moe", "w_down"), ("experts", "mlp_shard", "embed_w")),
    (("wq", "w"), ("embed_w", "heads")),
    (("wk", "w"), ("embed_w", "kv_heads")),
    (("wv", "w"), ("embed_w", "kv_heads")),
    (("wo", "w"), ("heads", "embed_w")),
    (("wq", "b"), ("heads",)),
    (("wk", "b"), ("kv_heads",)),
    (("wv", "b"), ("kv_heads",)),
    (("wo", "b"), ("embed",)),
    (("w_gate", "w"), ("embed_w", "mlp")),
    (("w_up", "w"), ("embed_w", "mlp")),
    (("w_down", "w"), ("mlp", "embed_w")),
    (("w_in", "w"), ("embed_w", "mlp")),
    (("w_out", "w"), ("mlp", "embed_w")),
    (("w_in", "b"), ("mlp",)),
    (("w_out", "b"), ("embed",)),
    # --- ssd ---
    (("z_proj", "w"), ("embed_w", "lru")),
    (("x_proj", "w"), ("embed_w", "lru")),
    (("bc_proj", "w"), ("embed_w", None)),
    (("dt_proj", "w"), ("embed_w", "ssm_heads")),
    (("dt_bias",), ("ssm_heads",)),
    (("A_log",), ("ssm_heads",)),
    (("D_skip",), ("ssm_heads",)),
    (("conv_x",), ("conv", "lru")),
    (("conv_bc",), ("conv", None)),
    (("out_proj", "w"), ("lru", "embed_w")),
    # --- rglru ---
    (("in_a", "w"), ("embed_w", "lru")),
    (("in_b", "w"), ("embed_w", "lru")),
    (("mix", "conv"), ("conv", "lru")),
    (("w_a",), ("heads", None, None)),
    (("w_x",), ("heads", None, None)),
    (("lam",), ("lru",)),
    (("out", "w"), ("lru", "embed_w")),
]

_STACK_KEYS = ("blocks", "super", "tail", "enc_blocks", "dec_blocks")


def _path_keys(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        k = getattr(p, "key", None)
        if k is None:
            k = getattr(p, "name", str(getattr(p, "idx", p)))
        out.append(str(k))
    return tuple(out)


def leaf_logical(path, leaf) -> Tuple:
    keys = _path_keys(path)
    n_stack = sum(1 for k in keys if k in _STACK_KEYS)
    logical = None
    for match, log in _RULES:
        # all match keys appear, in order, as a subsequence tail-anchored
        ki = 0
        for k in keys:
            if ki < len(match) and k == match[ki]:
                ki += 1
        if ki == len(match):
            logical = log
            break
    if logical is None:
        logical = (None,) * (leaf.ndim - n_stack)     # norms, scales → replicate
    pad = leaf.ndim - len(logical)
    return (None,) * pad + tuple(logical)


def param_specs(params, ctx: ShardingCtx):
    """pytree of PartitionSpec matching ``params``' structure."""
    def one(path, leaf):
        logical = leaf_logical(path, leaf)
        return ctx.spec(logical, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, params)


def cache_specs(caches, ctx: ShardingCtx):
    """KV caches / recurrent state sharding.

    KV leaves are (L,B,n_kv,S,hd)-shaped (5D [+scale 5D]); recurrent h is
    (L,B,...) — batch over data; heads/channels over model per the rules.
    """
    def one(path, leaf):
        keys = _path_keys(path)
        nd = leaf.ndim
        if leaf.ndim == 0:
            return P()
        if "conv" in keys and nd == 4:          # (L,B,W-1,C)
            return ctx.spec((None, "batch", None, "lru"), leaf.shape)
        if nd == 5 and "h" in keys:             # ssd state (L,B,nh,hd,N)
            return ctx.spec((None, "batch", "ssm_heads", None, None), leaf.shape)
        if nd == 3 and "h" in keys:             # rglru state (L,B,lru)
            return ctx.spec((None, "batch", "lru"), leaf.shape)
        if nd == 6:                             # quant scale (L,B,kv,S,1)+? n/a
            return P()
        if "hot_k" in keys or "hot_v" in keys:
            # tiered hot ring (L,B,n_kv,H,hd): dim 3 is the RING axis
            # (position mod H), not kv_seq — never sequence-shard it
            return ctx.spec((None, "batch", "kv_heads", None, None),
                            leaf.shape)
        if nd == 5:                             # KV (L,B,n_kv,S,hd) or scales
            return ctx.spec((None, "batch", "kv_heads", "kv_seq", None),
                            leaf.shape)
        return P()

    return jax.tree_util.tree_map_with_path(one, caches)
