"""Whisper-style encoder–decoder.

The conv/mel frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, n_frames, d_model). We implement the
transformer backbone: 24L non-causal encoder + 24L causal decoder with
cross-attention. Decode shapes run the DECODER serve_step (enc-dec archs do
have a decode step; only encoder-only models skip decode cells).

Serving caches: per-request the cross-attention K/V is computed ONCE from the
encoder output and is *static* thereafter — in WA-separation terms it behaves
like weights (reusable, non-growing) and lives on the weight domain, while the
growing self-attention KV lives on the attention domain (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kv.cache import KVCache, init_kv_cache
from repro.models import common
from repro.models.attention import decode_attention, make_attn_params
from repro.models.sharding import ShardingCtx
from repro.models.transformer import make_ffn_params, ffn_apply, write_prefill


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def _mha(p, x, cfg, ctx, kv_x=None, causal=True, positions=None):
    """Full-seq attention (self or cross). x: (B,S,D); kv_x: (B,F,D)."""
    B, S, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = kv_x if kv_x is not None else x
    q = common.linear(p["wq"], x).reshape(B, S, hq, hd)
    k = common.linear(p["wk"], src).reshape(B, src.shape[1], hkv, hd)
    v = common.linear(p["wv"], src).reshape(B, src.shape[1], hkv, hd)
    q = ctx.ann(q, "batch", "seq", "act_heads", "head_dim")
    k = ctx.ann(k, "batch", "seq", "kv_heads", "head_dim")
    v = ctx.ann(v, "batch", "seq", "kv_heads", "head_dim")
    from repro.models.attention import flash_attention_padded, q_chunk_for
    o = flash_attention_padded(q, k, v, causal and kv_x is None, 0,
                               q_chunk_for(S), q_chunk_for(src.shape[1]))
    o = common.linear(p["wo"], o.reshape(B, S, hq * hd))
    return o, (k, v)


def make_enc_block(key, cfg) -> Dict[str, Any]:
    ks = jax.random.split(key, 2)
    dt = common.dtype_of(cfg)
    return {"ln1": common.make_norm(cfg.norm, cfg.d_model, dt),
            "attn": make_attn_params(ks[0], cfg),
            "ln2": common.make_norm(cfg.norm, cfg.d_model, dt),
            "ffn": make_ffn_params(ks[1], cfg)}


def make_dec_block(key, cfg) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    dt = common.dtype_of(cfg)
    return {"ln1": common.make_norm(cfg.norm, cfg.d_model, dt),
            "attn": make_attn_params(ks[0], cfg),
            "ln_x": common.make_norm(cfg.norm, cfg.d_model, dt),
            "xattn": make_attn_params(ks[1], cfg),
            "ln2": common.make_norm(cfg.norm, cfg.d_model, dt),
            "ffn": make_ffn_params(ks[2], cfg)}


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    dt = common.dtype_of(cfg)
    enc_l = cfg.encoder.n_layers
    return {
        "embed": common.make_embedding(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "pos_embed": common.dense_init(ks[1], (32768 + 256, cfg.d_model), dt,
                                       fan_in=1),
        "enc_blocks": common.stacked_init(
            ks[2], enc_l, lambda k: make_enc_block(k, cfg)),
        "enc_ln_f": common.make_norm(cfg.norm, cfg.d_model, dt),
        "dec_blocks": common.stacked_init(
            ks[3], cfg.n_layers, lambda k: make_dec_block(k, cfg)),
        "ln_f": common.make_norm(cfg.norm, cfg.d_model, dt),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(params, frames: jax.Array, cfg, ctx: ShardingCtx,
           train: bool) -> jax.Array:
    """frames: (B,F,D) stub embeddings → (B,F,D)."""
    B, F, D = frames.shape
    x = frames.astype(common.dtype_of(cfg))
    x = x + common.sinusoidal_pos(F, D)[None].astype(x.dtype)
    x = ctx.ann(x, "batch", "seq", "embed")

    def blk(lp, h):
        y = common.apply_norm(cfg.norm, lp["ln1"], h, cfg.norm_eps)
        o, _ = _mha(lp["attn"], y, cfg, ctx, causal=False)
        h = ctx.ann(h + o, "batch", "seq", "embed_shard")
        y = common.apply_norm(cfg.norm, lp["ln2"], h, cfg.norm_eps)
        y = ctx.ann(y, "batch", "seq", "embed")
        return ctx.ann(h + ffn_apply(lp["ffn"], y, cfg, ctx),
                       "batch", "seq", "embed_shard")

    if train:
        blk = jax.checkpoint(blk, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(lambda h, lp: (blk(lp, h), None), x,
                        params["enc_blocks"], unroll=common.scan_unroll())
    return common.apply_norm(cfg.norm, params["enc_ln_f"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder (full-seq: train / prefill)
# ---------------------------------------------------------------------------

def decode_full(params, tokens, enc_out, cfg, ctx, train: bool,
                collect_kv: bool = False):
    B, S = tokens.shape
    x = common.embed(params["embed"], tokens, ctx)
    x = x + params["pos_embed"][:S][None].astype(x.dtype)

    def blk(lp, h):
        y = common.apply_norm(cfg.norm, lp["ln1"], h, cfg.norm_eps)
        o, self_kv = _mha(lp["attn"], y, cfg, ctx, causal=True)
        h = ctx.ann(h + o, "batch", "seq", "embed_shard")
        y = common.apply_norm(cfg.norm, lp["ln_x"], h, cfg.norm_eps)
        o, cross_kv = _mha(lp["xattn"], y, cfg, ctx, kv_x=enc_out)
        h = ctx.ann(h + o, "batch", "seq", "embed_shard")
        y = common.apply_norm(cfg.norm, lp["ln2"], h, cfg.norm_eps)
        y = ctx.ann(y, "batch", "seq", "embed")
        h = ctx.ann(h + ffn_apply(lp["ffn"], y, cfg, ctx),
                    "batch", "seq", "embed_shard")
        return h, (self_kv, cross_kv)

    blk_t = blk
    if train:
        blk_t = jax.checkpoint(blk,
                               policy=jax.checkpoint_policies.nothing_saveable)

    def body(h, lp):
        h, kvs = blk_t(lp, h)
        return h, kvs if collect_kv else None

    x, kvs = jax.lax.scan(body, x, params["dec_blocks"], unroll=common.scan_unroll())
    x = common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
    return (x, kvs) if collect_kv else (x, None)


def loss_fn(params, batch, cfg, ctx) -> jax.Array:
    enc_out = encode(params, batch["frames"], cfg, ctx, train=True)
    x, _ = decode_full(params, batch["tokens"], enc_out, cfg, ctx, train=True)
    return common.chunked_ce_loss(params["embed"]["table"], x, batch["labels"],
                                  ctx, chunk=common.ce_chunk(x.shape[1]))


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with (self KV, static cross KV)
# ---------------------------------------------------------------------------

def make_caches(cfg: ModelConfig, batch: int, max_len: int):
    self_kv = init_kv_cache(cfg.n_layers, batch, cfg.n_kv_heads, max_len,
                            cfg.head_dim, dtype=common.dtype_of(cfg),
                            quantized=(cfg.kv_dtype == "int8"))
    cross = {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads,
                        cfg.encoder.n_frames, cfg.head_dim),
                       common.dtype_of(cfg)),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads,
                        cfg.encoder.n_frames, cfg.head_dim),
                       common.dtype_of(cfg)),
    }
    return {"self": self_kv, "cross": cross}


def prefill(params, tokens, frames, cfg, ctx):
    B, S = tokens.shape
    caches = make_caches(cfg, B, S + 128)
    enc_out = encode(params, frames, cfg, ctx, train=False)
    x, kvs = decode_full(params, tokens, enc_out, cfg, ctx, train=False,
                         collect_kv=True)
    (sk, sv), (ck, cv) = kvs                    # (L,B,S,kv,hd) / (L,B,F,kv,hd)
    self_kv = write_prefill(caches["self"], jnp.swapaxes(sk, 2, 3),
                            jnp.swapaxes(sv, 2, 3), S)
    caches = {"self": self_kv,
              "cross": {"k": jnp.swapaxes(ck, 2, 3).astype(ck.dtype),
                        "v": jnp.swapaxes(cv, 2, 3).astype(cv.dtype)}}
    logits = common.unembed_logits(params["embed"]["table"], x[:, -1:], ctx)
    return caches, logits


def decode_step(params, caches, tokens, cfg, ctx):
    from repro.kv.cache import layer_append, layer_read, slot_valid_mask
    from repro.models.attention import qkv_project
    self_kv: KVCache = caches["self"]
    cross = caches["cross"]
    B = tokens.shape[0]
    pos = self_kv.length
    quant = self_kv.is_quantized
    x = common.embed(params["embed"], tokens[:, None], ctx)
    x = x + jax.lax.dynamic_index_in_dim(
        params["pos_embed"], pos, 0, keepdims=True)[None].astype(x.dtype)
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    def body(h, xs):
        if quant:
            lp, ck, cv, k_l, v_l, ks_l, vs_l = xs
        else:
            lp, ck, cv, k_l, v_l = xs
            ks_l = vs_l = None
        # self-attn residual over this layer's slices
        y = common.apply_norm(cfg.norm, lp["ln1"], h, cfg.norm_eps)
        q, k, v = qkv_project(lp["attn"], y, cfg, ctx,
                              jnp.full((B, 1), pos, jnp.int32))
        k_l, v_l, ks_l, vs_l = layer_append(k_l, v_l, ks_l, vs_l,
                                            k[:, 0], v[:, 0], pos, 0)
        kc, vc = layer_read(k_l, v_l, ks_l, vs_l, dtype=h.dtype)
        mask = slot_valid_mask(k_l.shape[2], 0, pos)
        o = decode_attention(q[:, 0], kc, vc, mask, ctx)
        h = h + common.linear(lp["attn"]["wo"], o.reshape(B, 1, -1))
        # cross-attention against static KV
        y = common.apply_norm(cfg.norm, lp["ln_x"], h, cfg.norm_eps)
        qx = common.linear(lp["xattn"]["wq"], y).reshape(B, 1, hq, hd)
        ones = jnp.ones((ck.shape[2],), bool)
        ox = decode_attention(qx[:, 0], ck, cv, ones, ctx)
        h = h + common.linear(lp["xattn"]["wo"], ox.reshape(B, 1, -1))
        # ffn
        y = common.apply_norm(cfg.norm, lp["ln2"], h, cfg.norm_eps)
        h = h + ffn_apply(lp["ffn"], y, cfg, ctx)
        ys = (k_l, v_l) + ((ks_l, vs_l) if quant else ())
        return h, ys

    xs = (params["dec_blocks"], cross["k"], cross["v"],
          self_kv.k, self_kv.v) + \
        ((self_kv.k_scale, self_kv.v_scale) if quant else ())
    x, ys = jax.lax.scan(body, x, xs, unroll=common.scan_unroll())
    if quant:
        k_new, v_new, ks_new, vs_new = ys
    else:
        (k_new, v_new), (ks_new, vs_new) = ys, (None, None)
    self_kv = KVCache(k_new, v_new, ks_new, vs_new, pos + 1)
    x = common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
    logits = common.unembed_logits(params["embed"]["table"], x, ctx)
    return {"self": self_kv, "cross": cross}, logits
