from repro.models.registry import ModelAPI, build_model, count_params  # noqa: F401
from repro.models.sharding import (  # noqa: F401
    ExecutionRules, NULL_CTX, ShardingCtx, operator_centric, seq_sharded_kv,
    sub_operator,
)
