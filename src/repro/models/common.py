"""Common building blocks: initializers, norms, RoPE, linear (bf16/int8),
embedding, and the memory-efficient chunked cross-entropy loss.

Pure-functional: params are nested dicts of arrays (pytrees); every array
carries a parallel "logical axes" annotation tree used by the sharding rules.
"""
from __future__ import annotations

import math
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


def scan_unroll() -> bool:
    """Cost-probe mode: when REPRO_UNROLL_SCANS=1, every lax.scan fully
    unrolls so compiled.cost_analysis() counts true trip-scaled FLOPs/bytes
    (XLA cost analysis counts while bodies ONCE — see launch/dryrun.py's
    probe-extrapolation protocol)."""
    return os.environ.get("REPRO_UNROLL_SCANS") == "1"

from repro.models.sharding import ShardingCtx
from repro.quant.int8 import QuantizedTensor, int8_matmul, quantize_int8

Params = Dict[str, Any]


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init — fan-in scaled normal (truncation unnecessary for benchmarking fidelity)
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def make_linear(key, d_in: int, d_out: int, dtype, *, bias: bool = False,
                int8: bool = False) -> Params:
    w = dense_init(key, (d_in, d_out), dtype)
    p: Params = {"w": quantize_int8(w, axis=0) if int8 else w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array, out_dtype=None) -> jax.Array:
    """x: (..., d_in) @ w: (d_in, d_out). Supports int8 QuantizedTensor w."""
    w = p["w"]
    if isinstance(w, QuantizedTensor):
        y = int8_matmul(x, w, out_dtype=out_dtype or x.dtype)
    else:
        y = jnp.einsum("...k,kn->...n", x, w,
                       preferred_element_type=jnp.float32).astype(out_dtype or x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def make_norm(kind: str, d: int, dtype) -> Params:
    p: Params = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(kind: str, p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        n = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (n * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    n = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (n * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., seq, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, d: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def gated_act(kind: str, up: jax.Array, gate: jax.Array) -> jax.Array:
    if kind == "swiglu":
        return jax.nn.silu(gate.astype(jnp.float32)).astype(up.dtype) * up
    if kind == "geglu":
        return jax.nn.gelu(gate.astype(jnp.float32)).astype(up.dtype) * up
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Embedding + memory-efficient CE loss
# ---------------------------------------------------------------------------

def make_embedding(key, vocab: int, d: int, dtype) -> Params:
    return {"table": dense_init(key, (vocab, d), dtype, fan_in=d)}


def embed(p: Params, tokens: jax.Array, ctx: ShardingCtx) -> jax.Array:
    tab = ctx.ann(p["table"], "vocab", "embed")
    return ctx.ann(jnp.take(tab, tokens, axis=0), "batch", "seq", "embed")


def unembed_logits(p_table: jax.Array, x: jax.Array, ctx: ShardingCtx) -> jax.Array:
    """Full logits — ONLY for decode (seq==1); training uses chunked_ce_loss."""
    logits = jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                        p_table.astype(jnp.float32))
    return ctx.ann(logits, "batch", "seq", "vocab")


def ce_chunk(S: int, target: int = 512) -> int:
    """Largest divisor of S that is ≤ target (vision-token offsets make S
    non-powers-of-two, e.g. 3840)."""
    for c in range(min(target, S), 0, -1):
        if S % c == 0:
            return c
    return S


def chunked_ce_loss(table: jax.Array, x: jax.Array, labels: jax.Array,
                    ctx: ShardingCtx, chunk: int = 512) -> jax.Array:
    """Cross-entropy WITHOUT materializing (B,S,V) logits.

    Scans the sequence in chunks; per chunk computes (B,c,V) logits against the
    (vocab-sharded) table, reduces to per-token loss, and discards. The paper's
    Table-1 "+1 serving socket" (embedding/argmax stage) maps onto this
    vocab-parallel head. Peak per-chip logit footprint: B·chunk·V/tp floats.
    """
    B, S, D = x.shape
    n = S // chunk
    assert n * chunk == S, (S, chunk)
    xs = x.reshape(B, n, chunk, D).swapaxes(0, 1)           # (n,B,c,D)
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)         # (n,B,c)

    def body(tot, xc_lc):
        xc, lc = xc_lc
        logits = jnp.einsum("bcd,vd->bcv", xc.astype(jnp.float32),
                            table.astype(jnp.float32))
        logits = ctx.ann(logits, "batch", "seq", "vocab")
        m = jnp.max(logits, axis=-1)
        lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls), unroll=scan_unroll())
    return total / (B * S)


# ---------------------------------------------------------------------------
# Key-splitting helper for building stacked (scan) layer params
# ---------------------------------------------------------------------------

def stacked_init(key, n: int, init_fn):
    """vmap an init over n layers → leaves with leading layer dim."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def fold(key, *ints) -> jax.Array:
    for i in ints:
        key = jax.random.fold_in(key, i)
    return key
