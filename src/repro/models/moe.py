"""Mixture-of-Experts FFN with expert parallelism.

The paper names MoE as the natural extension of its execution model (§7.2):
"routing-dependent communication ... topology-aware expert placement to keep
sparse activation from turning into cross-socket traffic". Here that becomes:
experts sharded over the ``model`` axis (EP); token→expert dispatch is a
sort-based, capacity-bounded scatter (static shapes — the static-runtime
requirement) whose resharding the compiler lowers to all-to-all on the ICI.

Routing IS sub-operator scheduling: each token's expert assignment is an
independent dependency edge; there is no operator-boundary barrier between
router, dispatch, expert GEMMs and combine.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.sharding import ShardingCtx


def make_moe_params(key, cfg: ModelConfig) -> Dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.expert_d_ff, m.num_experts
    dt = common.dtype_of(cfg)
    ks = jax.random.split(key, 4)

    def einit(k, shape, fan_in):
        return common.dense_init(k, shape, dt, fan_in=fan_in)

    return {
        "router": common.make_linear(ks[0], d, e, jnp.dtype(jnp.float32)),
        "w_gate": einit(ks[1], (e, d, f), d),
        "w_up": einit(ks[2], (e, d, f), d),
        "w_down": einit(ks[3], (e, f, d), f),
    }


def capacity(tokens: int, cfg: ModelConfig) -> int:
    """Per-expert slot count. capacity_factor <= 0 → no-drop (worst case:
    every assignment lands on one expert) — exact but FLOP-wasteful; used by
    correctness tests. Production uses GShard-style bounded capacity (static
    shapes = the paper's static-runtime requirement; overflow drops)."""
    m = cfg.moe
    if m.capacity_factor <= 0:
        return tokens * m.experts_per_token
    c = int(math.ceil(tokens * m.experts_per_token * m.capacity_factor
                      / m.num_experts))
    return max(8, -(-c // 8) * 8)                      # pad to 8 for layout


def moe_ffn(p: Dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
            train: bool) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) → (out (B,S,D), load-balance aux loss).

    LOCALITY-AWARE dispatch (paper §7.2: "topology-aware expert placement to
    keep sparse activation from turning into cross-socket traffic"): when a
    data axis exists, the token→slot scatter and slot→token combine run
    SHARD-LOCALLY per data row (shard_map manual over "data", per-row
    capacity C/rows) — a data-dependent scatter across a sharded dim would
    otherwise make GSPMD materialize the full (E·C, D) dispatch tensor with
    a cross-row all-reduce per layer (measured: ~10 PB/step at qwen3-235B
    train_4k; see EXPERIMENTS.md §Perf cell 2)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    K, E = m.experts_per_token, m.num_experts
    mesh = ctx.mesh
    mshape = dict(mesh.shape) if mesh is not None else {}
    batch_axes = ctx.rules.rules.get("batch") or ()
    dp_axes = tuple(a for a in ("pod", "data")
                    if a in mshape and a in batch_axes)
    data_rows = 1
    for a in dp_axes:
        data_rows *= mshape[a]
    # Gates (EXPERIMENTS §Perf cell 2):
    # - inference only: differentiating this shard_map at 512 simulated CPU
    #   devices trips an XLA-CPU check failure ("Invalid binary instruction
    #   opcode copy"); fwd+grad verified correct at 8 devices.
    # - per-row tokens ≥ 512: below that, the per-expert capacity floor
    #   (8-slot MXU alignment) pads ≥2× the expert GEMMs (measured at
    #   decode_32k: 3.0e13 → 8.6e13 flops) — tiny-batch decode keeps the
    #   GSPMD dispatch.
    t_local = T // max(data_rows, 1)
    if dp_axes and data_rows > 1 and B % data_rows == 0 and not train \
            and t_local >= 512:
        return _moe_ffn_sharded(p, x, cfg, ctx, train, dp_axes)
    C = capacity(T, cfg)
    xf = x.reshape(T, D)
    xf = ctx.ann(xf, "batch", "embed")

    # ---- router ------------------------------------------------------
    logits = common.linear(p["router"], xf.astype(jnp.float32))   # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                 # (T,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)              # renormalize

    # ---- load-balance loss (Switch-style) -----------------------------
    me = jnp.mean(probs, axis=0)                                  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=1), axis=0)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based capacity dispatch ---------------------------------
    flat_e = gate_idx.reshape(-1)                                 # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # rank within expert segment (sorted ⇒ segment-contiguous)
    seg_start = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))
    rank = jnp.arange(T * K, dtype=jnp.int32) - seg_start[se].astype(jnp.int32)
    keep = rank < C
    slot = jnp.where(keep, se.astype(jnp.int32) * C + rank, E * C)  # drop → OOB

    # gather tokens into expert buckets (E*C, D); OOB writes are dropped
    disp = jnp.zeros((E * C, D), x.dtype).at[slot].set(
        xf[st], mode="drop", unique_indices=True)
    disp = ctx.ann(disp.reshape(E, C, D), "experts", None, "embed")

    # ---- expert GEMMs (batched over the expert shard) ------------------
    gate = jnp.einsum("ecd,edf->ecf", disp, p["w_gate"].astype(disp.dtype))
    up = jnp.einsum("ecd,edf->ecf", disp, p["w_up"].astype(disp.dtype))
    h = common.gated_act(cfg.act if cfg.act != "gelu_mlp" else "swiglu", up, gate)
    h = ctx.ann(h, "experts", None, "mlp_shard")
    eo = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(h.dtype))
    eo = ctx.ann(eo, "experts", None, "embed").reshape(E * C, D)

    # ---- combine: weighted scatter-add back to token order -------------
    contrib = jnp.take(eo, jnp.minimum(slot, E * C - 1), axis=0)
    contrib = contrib * (sg * keep).astype(contrib.dtype)[:, None]
    out = jnp.zeros((T, D), x.dtype).at[st].add(contrib)
    out = ctx.ann(out, "batch", "embed")
    return out.reshape(B, S, D), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Shard-local dispatch: manual over the batch axes, auto over "model".
# Per data row: local top-k → local capacity buckets → expert GEMMs (experts
# still sharded over "model" by GSPMD) → local combine. No cross-row
# collective is needed for routing at all; experts see per-row slot batches.
# ---------------------------------------------------------------------------

def _moe_ffn_sharded(p: Dict, x: jax.Array, cfg: ModelConfig,
                     ctx: ShardingCtx, train: bool, dp_axes) -> Tuple:
    from repro.models.sharding import ExecutionRules
    mesh = ctx.mesh
    B, S, D = x.shape
    # inner constraints may only use non-manual (auto) axes
    inner_rules = ExecutionRules(ctx.rules.name + "+local", {
        k: (tuple(a for a in (v or ()) if a not in dp_axes) or None)
        for k, v in ctx.rules.rules.items()})
    inner_ctx = ShardingCtx(mesh, inner_rules)

    def local(xl, pl):
        # xl: (B/rows, S, D) — this row's tokens; expert weights arrive via
        # their auto-axis sharding (model EP; FSDP gathers per layer in train)
        out, aux = _moe_core(pl, xl, cfg, inner_ctx, train)
        return out, jax.lax.pmean(aux, dp_axes)

    from jax.sharding import PartitionSpec as P
    from repro.core.compat import shard_map
    x_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0], None, None)
    f = shard_map(local, mesh=mesh,
                  in_specs=(x_spec, P()),
                  out_specs=(x_spec, P()),
                  axis_names=frozenset(dp_axes), check_vma=False)
    return f(x, p)


def _moe_core(p: Dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
              train: bool) -> Tuple[jax.Array, jax.Array]:
    """The dispatch/compute/combine body on LOCAL tokens (original path)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    K, E = m.experts_per_token, m.num_experts
    C = capacity(T, cfg)
    xf = x.reshape(T, D)

    logits = common.linear(p["router"], xf.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=1), axis=0)
    aux = E * jnp.sum(me * ce)

    flat_e = gate_idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    seg_start = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))
    rank = jnp.arange(T * K, dtype=jnp.int32) - seg_start[se].astype(jnp.int32)
    keep = rank < C
    slot = jnp.where(keep, se.astype(jnp.int32) * C + rank, E * C)

    disp = jnp.zeros((E * C, D), x.dtype).at[slot].set(
        xf[st], mode="drop", unique_indices=True)
    disp = ctx.ann(disp.reshape(E, C, D), "experts", None, None)

    gate = jnp.einsum("ecd,edf->ecf", disp, p["w_gate"].astype(disp.dtype))
    up = jnp.einsum("ecd,edf->ecf", disp, p["w_up"].astype(disp.dtype))
    h = common.gated_act(cfg.act if cfg.act != "gelu_mlp" else "swiglu", up, gate)
    h = ctx.ann(h, "experts", None, "mlp_shard")
    eo = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(h.dtype))
    eo = ctx.ann(eo, "experts", None, None).reshape(E * C, D)

    contrib = jnp.take(eo, jnp.minimum(slot, E * C - 1), axis=0)
    contrib = contrib * (sg * keep).astype(contrib.dtype)[:, None]
    out = jnp.zeros((T, D), x.dtype).at[st].add(contrib)
    return out.reshape(B, S, D), aux.astype(jnp.float32)
