"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks interleaved with
local (sliding-window) attention, pattern (R, R, A) repeating.

Hybrid applicability (DESIGN.md §6): local-attn layers carry a *bounded*
window KV (ring buffer) — separable à la WA; RG-LRU layers carry O(1) state —
the paradox does not bind there. long_500k decode is runnable.

RG-LRU recurrence (per channel, gates block-diagonal over heads):
    r_t = σ(W_a ξ_t),  i_t = σ(W_x ξ_t)
    a_t = exp(−c · softplus(Λ) · r_t),  c = 8
    h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ ξ_t)

Full-seq path uses jax.lax.associative_scan (log-depth parallel scan).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRU, ModelConfig
from repro.kv.cache import KVCache, init_kv_cache
from repro.kv.state import (RecurrentState, causal_conv, conv_step,
    init_rglru_state)
from repro.models import common
from repro.models.sharding import ShardingCtx
from repro.models.transformer import (block_decode, block_full_seq,
                                      make_block_params, write_prefill)

C_RGLRU = 8.0


# ---------------------------------------------------------------------------
# RG-LRU temporal-mixing block
# ---------------------------------------------------------------------------

def lru_width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def make_rglru_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    d, lw = cfg.d_model, lru_width(cfg)
    nh = cfg.n_heads
    blk = lw // nh
    dt = common.dtype_of(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_a": common.make_linear(ks[0], d, lw, dt),       # gelu branch
        "in_b": common.make_linear(ks[1], d, lw, dt),       # recurrent branch
        "conv": common.dense_init(ks[2], (cfg.rglru.conv_width, lw), dt,
                                  fan_in=cfg.rglru.conv_width),
        "w_a": common.dense_init(ks[3], (nh, blk, blk), dt, fan_in=blk),
        "w_x": common.dense_init(ks[4], (nh, blk, blk), dt, fan_in=blk),
        "lam": jnp.log(jnp.expm1(  # softplus⁻¹ so a_t^c ∈ ~[0.9, 0.999]
            -jnp.log(jnp.linspace(0.9, 0.999, lw, dtype=jnp.float32)) / C_RGLRU)),
        "out": common.make_linear(ks[5], lw, d, dt),
    }


def _gates(p, xi: jax.Array, nh: int) -> Tuple[jax.Array, jax.Array]:
    """Block-diagonal gate projections. xi: (B,S,lw) → r, i (B,S,lw) f32."""
    B, S, lw = xi.shape
    blk = lw // nh
    xh = xi.reshape(B, S, nh, blk).astype(jnp.float32)
    r = jnp.einsum("bsnk,nkj->bsnj", xh, p["w_a"].astype(jnp.float32))
    i = jnp.einsum("bsnk,nkj->bsnj", xh, p["w_x"].astype(jnp.float32))
    return (jax.nn.sigmoid(r).reshape(B, S, lw),
            jax.nn.sigmoid(i).reshape(B, S, lw))


def _lru_coeffs(p, xi, nh):
    """Per-step (a_t, b_t) of h_t = a_t h + b_t. xi: (B,S,lw)."""
    r, i = _gates(p, xi, nh)
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"])[None, None, :] * r
    a = jnp.exp(log_a)
    gated = i * xi.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    return a, b


def rglru_full_seq(p: Dict, x: jax.Array, cfg: ModelConfig,
                   ctx: ShardingCtx) -> jax.Array:
    """x: (B,S,D) → (B,S,D)."""
    nh = cfg.n_heads
    ya = jax.nn.gelu(common.linear(p["in_a"], x).astype(jnp.float32))
    xb = common.linear(p["in_b"], x)
    xb = ctx.ann(xb, "batch", "seq", "lru")
    xb = causal_conv(xb, p["conv"])
    a, b = _lru_coeffs(p, xb, nh)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    A, Bc = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = Bc                                                  # h_t (zero init)
    y = (ya * h).astype(x.dtype)
    y = ctx.ann(y, "batch", "seq", "lru")
    return common.linear(p["out"], y)


def rglru_final_state(p, x, cfg, ctx):
    """State after a prefill pass → (h (B,lw) f32, conv tail (B,W-1,lw))."""
    nh = cfg.n_heads
    W = cfg.rglru.conv_width
    xb = common.linear(p["in_b"], x)
    conv_tail = xb[:, -(W - 1):, :].astype(jnp.float32)
    xb = causal_conv(xb, p["conv"])
    a, b = _lru_coeffs(p, xb, nh)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    A, Bc = jax.lax.associative_scan(combine, (a, b), axis=1)
    return Bc[:, -1, :], conv_tail


def rglru_decode(p: Dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
                 h: jax.Array, conv: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token step over one layer's state slices.
    x: (B,1,D); h: (B,lw) f32; conv: (B,W-1,lw) → (out, h', conv')."""
    nh = cfg.n_heads
    ya = jax.nn.gelu(common.linear(p["in_a"], x).astype(jnp.float32))[:, 0]
    xb = common.linear(p["in_b"], x)[:, 0]                  # (B,lw)
    xb_c, conv_new = conv_step(conv, xb, p["conv"])
    a, b = _lru_coeffs(p, xb_c[:, None, :], nh)
    h_new = a[:, 0] * h + b[:, 0]
    y = (ya * h_new).astype(x.dtype)[:, None, :]
    out = common.linear(p["out"], y)
    return out, h_new, conv_new.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Hybrid stack: scan over (R, R, A) superblocks + remainder R layers
# ---------------------------------------------------------------------------

def _layer_plan(cfg: ModelConfig):
    kinds = cfg.block_kinds()
    pat = cfg.rglru.block_pattern
    n_super = 0
    i = 0
    while i + len(pat) <= len(kinds) and tuple(kinds[i:i + len(pat)]) == pat:
        n_super += 1
        i += len(pat)
    tail = kinds[i:]
    assert all(k == RGLRU for k in tail), "tail must be recurrent-only"
    return n_super, len(tail)


def make_mix_block(key, cfg: ModelConfig) -> Dict[str, Any]:
    """One RG-LRU residual pair: temporal mix + GeGLU FFN.
    (Local-attention layers reuse transformer.make_block_params directly.)"""
    from repro.models.transformer import make_ffn_params
    ks = jax.random.split(key, 2)
    dt = common.dtype_of(cfg)
    return {"ln1": common.make_norm(cfg.norm, cfg.d_model, dt),
            "ln2": common.make_norm(cfg.norm, cfg.d_model, dt),
            "ffn": make_ffn_params(ks[1], cfg),
            "mix": make_rglru_params(ks[0], cfg)}


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    n_super, n_tail = _layer_plan(cfg)
    ks = jax.random.split(key, 5)
    dt = common.dtype_of(cfg)

    def super_blk(k):
        kk = jax.random.split(k, 3)
        return {"r1": make_mix_block(kk[0], cfg),
                "r2": make_mix_block(kk[1], cfg),
                "attn": make_block_params(kk[2], cfg)}

    params = {
        "embed": common.make_embedding(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "super": common.stacked_init(ks[1], n_super, super_blk),
        "ln_f": common.make_norm(cfg.norm, cfg.d_model, dt),
    }
    if n_tail:
        params["tail"] = common.stacked_init(
            ks[2], n_tail, lambda k: make_mix_block(k, cfg))
    return params


def _rglru_residual(p, h, cfg, ctx, full_seq: bool, state=None):
    """state (decode only): tuple (h_slice (B,lw), conv_slice (B,W-1,lw))."""
    y = common.apply_norm(cfg.norm, p["ln1"], h, cfg.norm_eps)
    y = ctx.ann(y, "batch", "seq", "embed")
    if full_seq:
        mix = rglru_full_seq(p["mix"], y, cfg, ctx)
    else:
        mix, h_new, conv_new = rglru_decode(p["mix"], y, cfg, ctx, *state)
        state = (h_new, conv_new)
    h = ctx.ann(h + mix, "batch", "seq", "embed_shard")
    y = common.apply_norm(cfg.norm, p["ln2"], h, cfg.norm_eps)
    y = ctx.ann(y, "batch", "seq", "embed")
    from repro.models.transformer import ffn_apply
    h = ctx.ann(h + ffn_apply(p["ffn"], y, cfg, ctx), "batch", "seq", "embed_shard")
    return h, state


def forward_hidden(params, tokens, cfg, ctx, train: bool):
    x = common.embed(params["embed"], tokens, ctx)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)        # gemma-style scale
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    win = cfg.rglru.window

    def super_fwd(lp, h):
        h, _ = _rglru_residual(lp["r1"], h, cfg, ctx, True)
        h, _ = _rglru_residual(lp["r2"], h, cfg, ctx, True)
        h, _ = block_full_seq(lp["attn"], h, cfg, ctx, positions,
                              causal=True, window=win, train=train)
        return h

    def tail_fwd(lp, h):
        h, _ = _rglru_residual(lp, h, cfg, ctx, True)
        return h

    if train:
        super_fwd = jax.checkpoint(super_fwd,
                                   policy=jax.checkpoint_policies.nothing_saveable)
        tail_fwd = jax.checkpoint(tail_fwd,
                                  policy=jax.checkpoint_policies.nothing_saveable)

    x, _ = jax.lax.scan(lambda h, lp: (super_fwd(lp, h), None), x, params["super"], unroll=common.scan_unroll())
    if "tail" in params:
        x, _ = jax.lax.scan(lambda h, lp: (tail_fwd(lp, h), None), x, params["tail"], unroll=common.scan_unroll())
    return common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)


def loss_fn(params, batch, cfg, ctx):
    x = forward_hidden(params, batch["tokens"], cfg, ctx, train=True)
    return common.chunked_ce_loss(params["embed"]["table"], x, batch["labels"],
                                  ctx, chunk=common.ce_chunk(x.shape[1]))


# --- serving: hybrid cache = (window KV for attn layers, recurrent state) ---

def make_caches(cfg: ModelConfig, batch: int, max_len: int):
    n_super, n_tail = _layer_plan(cfg)
    kv = init_kv_cache(n_super, batch, cfg.n_kv_heads,
                       min(cfg.rglru.window, max_len), cfg.head_dim,
                       dtype=common.dtype_of(cfg),
                       quantized=(cfg.kv_dtype == "int8"),
                       window=cfg.rglru.window)
    st = init_rglru_state(2 * n_super + n_tail, batch, lru_width(cfg),
                          cfg.rglru.conv_width)
    return {"kv": kv, "state": st}


def prefill(params, tokens, cfg, ctx):
    """Full-seq pass that also materializes decode caches."""
    n_super, n_tail = _layer_plan(cfg)
    B, S = tokens.shape
    caches = make_caches(cfg, B, S + 128)      # ring ≥ window needs decode slack
    x = common.embed(params["embed"], tokens, ctx)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    win = cfg.rglru.window

    def super_fwd(h, lp):
        hs, convs, kvs = [], [], None
        h, st = _rglru_state_residual(lp["r1"], h, cfg, ctx)
        hs.append(st)
        h, st = _rglru_state_residual(lp["r2"], h, cfg, ctx)
        hs.append(st)
        h, (q, k, v, _) = block_full_seq(lp["attn"], h, cfg, ctx,
                                         positions, causal=True, window=win,
                                         train=False)
        return h, (hs, (k, v))

    def _rglru_state_residual(p, h, cfg_, ctx_):
        y = common.apply_norm(cfg_.norm, p["ln1"], h, cfg_.norm_eps)
        hstate, conv_tail = rglru_final_state(p["mix"], y, cfg_, ctx_)
        mix = rglru_full_seq(p["mix"], y, cfg_, ctx_)
        h = h + mix
        y = common.apply_norm(cfg_.norm, p["ln2"], h, cfg_.norm_eps)
        from repro.models.transformer import ffn_apply
        h = h + ffn_apply(p["ffn"], y, cfg_, ctx_)
        return h, (hstate, conv_tail)

    x, (states, kvs) = jax.lax.scan(super_fwd, x, params["super"], unroll=common.scan_unroll())
    # states: list of 2 tuples of stacked (n_super,...) leaves
    h_list = [states[0][0], states[1][0]]                   # (n_super,B,lw)
    c_list = [states[0][1], states[1][1]]
    # interleave r1/r2 per superblock → layer order 2i, 2i+1
    hs = jnp.stack([h_list[0], h_list[1]], axis=1).reshape(
        2 * h_list[0].shape[0], *h_list[0].shape[1:])
    cs = jnp.stack([c_list[0], c_list[1]], axis=1).reshape(
        2 * c_list[0].shape[0], *c_list[0].shape[1:])
    if "tail" in params:
        def tail_fwd(h, lp):
            h, st = _rglru_state_residual(lp, h, cfg, ctx)
            return h, st
        x, (th, tc) = jax.lax.scan(tail_fwd, x, params["tail"], unroll=common.scan_unroll())
        hs = jnp.concatenate([hs, th], axis=0)
        cs = jnp.concatenate([cs, tc], axis=0)
    state = RecurrentState(h=hs, conv=cs)
    k_all, v_all = kvs                                      # (n_super,B,S,kv,hd)
    kv = write_prefill(caches["kv"], jnp.swapaxes(k_all, 2, 3),
                       jnp.swapaxes(v_all, 2, 3), S)
    x = common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
    logits = common.unembed_logits(params["embed"]["table"], x[:, -1:], ctx)
    return {"kv": kv, "state": state}, logits


def decode_step(params, caches, tokens, cfg, ctx):
    kv: KVCache = caches["kv"]
    state: RecurrentState = caches["state"]
    n_super, n_tail = _layer_plan(cfg)
    pos = kv.length
    x = common.embed(params["embed"], tokens[:, None], ctx)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    quant = kv.is_quantized

    # state slices: first 2·n_super entries pair up with superblocks
    def pairify(a):
        return a[:2 * n_super].reshape(n_super, 2, *a.shape[1:])

    def super_step(h, xs):
        if quant:
            lp, hs, cs, k_l, v_l, ks_l, vs_l = xs
        else:
            lp, hs, cs, k_l, v_l = xs
            ks_l = vs_l = None
        h, st1 = _rglru_residual(lp["r1"], h, cfg, ctx, False, (hs[0], cs[0]))
        h, st2 = _rglru_residual(lp["r2"], h, cfg, ctx, False, (hs[1], cs[1]))
        h, (k_l, v_l, ks_l, vs_l) = block_decode(
            lp["attn"], h, cfg, ctx, (k_l, v_l, ks_l, vs_l), pos,
            window=cfg.rglru.window)
        hs_new = jnp.stack([st1[0], st2[0]])
        cs_new = jnp.stack([st1[1], st2[1]])
        ys = (hs_new, cs_new, k_l, v_l) + ((ks_l, vs_l) if quant else ())
        return h, ys

    xs = (params["super"], pairify(state.h), pairify(state.conv), kv.k, kv.v) \
        + ((kv.k_scale, kv.v_scale) if quant else ())
    x, ys = jax.lax.scan(super_step, x, xs, unroll=common.scan_unroll())
    if quant:
        hs_new, cs_new, k_new, v_new, ks_new, vs_new = ys
    else:
        (hs_new, cs_new, k_new, v_new), (ks_new, vs_new) = ys, (None, None)
    h_all = hs_new.reshape(2 * n_super, *hs_new.shape[2:])
    c_all = cs_new.reshape(2 * n_super, *cs_new.shape[2:])

    if "tail" in params:
        def tail_step(h, xs):
            lp, hs, cs = xs
            h, st = _rglru_residual(lp, h, cfg, ctx, False, (hs, cs))
            return h, (st[0], st[1])
        x, (th, tc) = jax.lax.scan(
            tail_step, x,
            (params["tail"], state.h[2 * n_super:], state.conv[2 * n_super:]),
            unroll=common.scan_unroll())
        h_all = jnp.concatenate([h_all, th], axis=0)
        c_all = jnp.concatenate([c_all, tc], axis=0)

    kv = KVCache(k_new, v_new, ks_new, vs_new, pos + 1, window=kv.window)
    state = RecurrentState(h=h_all, conv=c_all)
    x = common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
    logits = common.unembed_logits(params["embed"]["table"], x, ctx)
    return {"kv": kv, "state": state}, logits
