"""Mamba-2 (SSD — state-space duality) blocks.

Attention-free: decode state is O(1) in context, so the paper's KV-pressure
paradox does not bind (DESIGN.md §6) and WA separation is inapplicable; the
sub-operator principle still applies (heads are independent → sharded over the
``model`` axis with no operator-boundary materialization).

Train/prefill uses the chunked SSD algorithm (intra-chunk quadratic +
inter-chunk state scan); decode is the exact single-step recurrence:

    H_t = a_t · H_{t-1} + dt_t · (x_t ⊗ B_t),   y_t = H_t C_t + D ⊙ x_t
    a_t = exp(−exp(A_log) · dt_t),  dt_t = softplus(dt_raw + dt_bias)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kv.state import (RecurrentState, causal_conv, conv_step,
    init_ssd_state)
from repro.models import common
from repro.models.sharding import ShardingCtx


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    return d_in, nh, s.head_dim, s.d_state, s.n_groups, s.conv_width


def make_ssd_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    d_in, nh, hd, N, G, W = dims(cfg)
    dt = common.dtype_of(cfg)
    ks = jax.random.split(key, 8)
    return {
        "z_proj": common.make_linear(ks[0], d, d_in, dt),
        "x_proj": common.make_linear(ks[1], d, d_in, dt),
        "bc_proj": common.make_linear(ks[2], d, 2 * G * N, dt),
        "dt_proj": common.make_linear(ks[3], d, nh, dt),
        "dt_bias": jnp.full((nh,), -3.0, jnp.float32),   # softplus ≈ 0.05
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "conv_x": common.dense_init(ks[4], (W, d_in), dt, fan_in=W),
        "conv_bc": common.dense_init(ks[5], (W, 2 * G * N), dt, fan_in=W),
        "norm": common.make_norm("rmsnorm", d_in, dt),
        "out_proj": common.make_linear(ks[6], d_in, d, dt),
    }


def _project(p, x, cfg, ctx: ShardingCtx):
    """Shared projections. x: (B,S,D) → z,xs (B,S,nh,hd), B,C (B,S,G,N),
    dt (B,S,nh) — pre-conv, pre-activation."""
    d_in, nh, hd, N, G, W = dims(cfg)
    B, S, _ = x.shape
    z = common.linear(p["z_proj"], x)
    xs = common.linear(p["x_proj"], x)
    bc = common.linear(p["bc_proj"], x)
    dt_raw = common.linear(p["dt_proj"], x).astype(jnp.float32)
    return z, xs, bc, dt_raw


def ssd_full_seq(p: Dict, x: jax.Array, cfg: ModelConfig,
                 ctx: ShardingCtx) -> jax.Array:
    """Chunked SSD over a full sequence. x: (B,S,D) → (B,S,D)."""
    d_in, nh, hd, N, G, W = dims(cfg)
    B, S0, _ = x.shape
    Q = min(cfg.ssm.chunk, S0)
    S = -(-S0 // Q) * Q                                    # pad to chunk multiple
    nc = S // Q

    z, xs, bc, dt_raw = _project(p, x, cfg, ctx)
    if S != S0:
        pad = ((0, 0), (0, S - S0), (0, 0))
        xs, bc = jnp.pad(xs, pad), jnp.pad(bc, pad)
        # padded steps: dt→0 ⇒ a=1, zero state contribution (exact no-op)
        dt_raw = jnp.pad(dt_raw, pad, constant_values=-1e4)
    xs = causal_conv(xs, p["conv_x"])
    xs = jax.nn.silu(xs.astype(jnp.float32))
    bc = jax.nn.silu(causal_conv(bc, p["conv_bc"]).astype(jnp.float32))
    Bm, Cm = jnp.split(bc, 2, axis=-1)                     # (B,S,G*N)
    Bm = Bm.reshape(B, nc, Q, G, N)
    Cm = Cm.reshape(B, nc, Q, G, N)
    xh = ctx.ann(xs.reshape(B, S, nh, hd), "batch", "seq", "ssm_heads", "head_dim")
    xh = xh.reshape(B, nc, Q, nh, hd)

    dt = jax.nn.softplus(dt_raw + p["dt_bias"])            # (B,S,nh) f32
    A = -jnp.exp(p["A_log"])                               # (nh,)
    loga = (dt * A).reshape(B, nc, Q, nh)                  # log decay per step
    L = jnp.cumsum(loga, axis=2)                           # (B,nc,Q,nh)

    # --- intra-chunk (quadratic within chunk) --------------------------
    # M[t,s] = C_t·B_s · exp(L_t − L_s) · dt_s   (s ≤ t)
    CB = jnp.einsum("bcqgn,bcsgn->bcgqs", Cm, Bm)          # (B,nc,G,Q,Q)
    # broadcast groups→heads (G==1 typical)
    CBh = jnp.repeat(CB, nh // G, axis=2)                  # (B,nc,nh,Q,Q)
    Lt = L.transpose(0, 1, 3, 2)                           # (B,nc,nh,Q)
    decay = jnp.exp(Lt[:, :, :, :, None] - Lt[:, :, :, None, :])  # (B,nc,nh,Q,Q)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(tri[None, None, None], CBh * decay, 0.0)
    M = M * dt.reshape(B, nc, Q, nh).transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", M, xh.astype(jnp.float32))

    # --- chunk boundary states -----------------------------------------
    # H_c = Σ_s exp(L_end − L_s) · dt_s · (x_s ⊗ B_s)
    dec_end = jnp.exp(L[:, :, -1:, :] - L)                 # (B,nc,Q,nh)
    w = (dec_end * dt.reshape(B, nc, Q, nh))               # (B,nc,Q,nh)
    Bh = jnp.repeat(Bm, nh // G, axis=3)                   # (B,nc,Q,nh,N)
    H_part = jnp.einsum("bcqh,bcqhp,bcqhn->bchpn",
                        w, xh.astype(jnp.float32), Bh)     # (B,nc,nh,hd,N)

    # --- inter-chunk scan ------------------------------------------------
    A_chunk = jnp.exp(L[:, :, -1, :])                      # (B,nc,nh)

    def chunk_body(H, inputs):
        a_c, h_part = inputs                               # (B,nh), (B,nh,hd,N)
        H_new = H * a_c[..., None, None] + h_part
        return H_new, H                                    # emit state BEFORE chunk

    H0 = jnp.zeros((B, nh, hd, N), jnp.float32)
    _, H_prev = jax.lax.scan(chunk_body, H0,
                             (A_chunk.swapaxes(0, 1), H_part.swapaxes(0, 1)), unroll=common.scan_unroll())
    H_prev = H_prev.swapaxes(0, 1)                         # (B,nc,nh,hd,N)

    # y_inter[t] = C_t · exp(L_t) · H_prev(chunk)
    Ch = jnp.repeat(Cm, nh // G, axis=3)                   # (B,nc,Q,nh,N)
    y_inter = jnp.einsum("bcqh,bcqhn,bchpn->bcqhp",
                         jnp.exp(L), Ch, H_prev)

    y = (y_intra + y_inter
         + p["D_skip"][None, None, None, :, None] * xh.astype(jnp.float32))
    y = y.reshape(B, S, d_in)[:, :S0]
    y = common.apply_norm("rmsnorm", p["norm"], y.astype(x.dtype), cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = ctx.ann(y, "batch", "seq", "mlp")
    return common.linear(p["out_proj"], y)


def ssd_final_state(p: Dict, x: jax.Array, cfg: ModelConfig,
                    ctx: ShardingCtx) -> Tuple[jax.Array, jax.Array]:
    """State after consuming x (for prefill → decode handoff).
    Returns (H (B,nh,hd,N), conv window (B,W-1,channels))."""
    d_in, nh, hd, N, G, W = dims(cfg)
    B, S, _ = x.shape
    z, xs, bc, dt_raw = _project(p, x, cfg, ctx)
    conv_tail = jnp.concatenate([xs, bc], axis=-1)[:, -(W - 1):, :].astype(jnp.float32)
    xs = jax.nn.silu(causal_conv(xs, p["conv_x"]).astype(jnp.float32))
    bc = jax.nn.silu(causal_conv(bc, p["conv_bc"]).astype(jnp.float32))
    Bm = bc[..., :G * N].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    loga = dt * A                                          # (B,S,nh)
    Lrev = jnp.cumsum(loga[:, ::-1], axis=1)[:, ::-1]      # Σ_{u≥s} loga_u
    dec = jnp.exp(Lrev - loga)                             # exp(Σ_{u>s})
    xh = xs.reshape(B, S, nh, hd)
    Bh = jnp.repeat(Bm, nh // G, axis=2)                   # (B,S,nh,N)
    H = jnp.einsum("bsh,bshp,bshn->bhpn", dec * dt, xh, Bh)
    return H, conv_tail


def ssd_decode(p: Dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
               H: jax.Array, conv: jax.Array
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token step over one layer's state slices.
    x: (B,1,D); H: (B,nh,hd,N); conv: (B,W-1,Ch) → (y, H', conv')."""
    d_in, nh, hd, N, G, W = dims(cfg)
    B = x.shape[0]
    z, xs, bc, dt_raw = _project(p, x, cfg, ctx)
    xbc_new = jnp.concatenate([xs[:, 0], bc[:, 0]], axis=-1)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=-1)
    y_conv, conv_new = conv_step(conv, xbc_new, conv_w)
    xs1 = jax.nn.silu(y_conv[:, :d_in].astype(jnp.float32))
    bc1 = jax.nn.silu(y_conv[:, d_in:].astype(jnp.float32))
    Bm = bc1[:, :G * N].reshape(B, G, N)
    Cm = bc1[:, G * N:].reshape(B, G, N)
    dt = jax.nn.softplus(dt_raw[:, 0] + p["dt_bias"])      # (B,nh)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)                 # (B,nh)
    xh = xs1.reshape(B, nh, hd)
    Bh = jnp.repeat(Bm, nh // G, axis=1)                   # (B,nh,N)
    Ch = jnp.repeat(Cm, nh // G, axis=1)
    H = (H * a[..., None, None]
         + jnp.einsum("bh,bhp,bhn->bhpn", dt, xh, Bh))
    y = jnp.einsum("bhpn,bhn->bhp", H, Ch) + p["D_skip"][None, :, None] * xh
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = common.apply_norm("rmsnorm", p["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = common.linear(p["out_proj"], y)
    return out, H, conv_new.astype(jnp.float32)


def ssd_chunk(p: Dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
              H0: jax.Array, conv0: jax.Array, valid_len: jax.Array
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One PROMPT chunk of the SSD recurrence with carried state (the
    chunked-prefill lane, DESIGN.md §7): the quadratic intra-chunk form of
    ``ssd_full_seq`` (nc == 1) plus the inter-chunk contribution of the
    incoming state ``H0`` and the rolling conv window ``conv0``.

    x: (1,C,D); H0: (1,nh,hd,N); conv0: (1,W-1,Ch); valid_len traced —
    chunk positions >= valid_len are last-chunk padding and are exact
    no-ops on the state (dt → 0 ⇒ decay 1, zero contribution — the same
    trick ssd_full_seq uses for its pad-to-chunk-multiple). Returns
    (y (1,C,D), H_end, conv_end) with conv_end holding the last W-1 REAL
    inputs (dynamic slice at valid_len, so a partial final chunk hands
    decode the right window)."""
    d_in, nh, hd, N, G, W = dims(cfg)
    B, C, _ = x.shape
    z, xs, bc, dt_raw = _project(p, x, cfg, ctx)
    valid = jnp.arange(C, dtype=jnp.int32) < valid_len          # (C,)
    dt_raw = jnp.where(valid[None, :, None], dt_raw, -1e4)
    # -- rolling causal conv across chunk boundaries --------------------
    # same accumulation dtype/order as causal_conv so chunk 0 (conv0 == 0)
    # is bit-identical to the monolithic zero-padded conv
    xbc = jnp.concatenate([xs, bc], axis=-1)                    # (1,C,Ch)
    full = jnp.concatenate([conv0.astype(xbc.dtype), xbc],
                           axis=1)                              # (1,W-1+C,Ch)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=-1)  # (W,Ch)
    y_conv = sum(full[:, w:w + C, :]
                 * conv_w[w][None, None, :].astype(xbc.dtype)
                 for w in range(W))                             # (1,C,Ch)
    conv_end = jax.lax.dynamic_slice(
        full.astype(jnp.float32), (0, valid_len, 0),
        (B, W - 1, full.shape[2]))
    xs1 = jax.nn.silu(y_conv[..., :d_in].astype(jnp.float32))
    bc1 = jax.nn.silu(y_conv[..., d_in:].astype(jnp.float32))
    Bm = bc1[..., :G * N].reshape(B, C, G, N)
    Cm = bc1[..., G * N:].reshape(B, C, G, N)
    xh = xs1.reshape(B, C, nh, hd)

    dt = jax.nn.softplus(dt_raw + p["dt_bias"])                 # (B,C,nh) f32
    A = -jnp.exp(p["A_log"])                                    # (nh,)
    loga = dt * A
    L = jnp.cumsum(loga, axis=1)                                # (B,C,nh)

    # intra-chunk: M[t,s] = C_t·B_s · exp(L_t − L_s) · dt_s  (s ≤ t)
    CB = jnp.einsum("bqgn,bsgn->bgqs", Cm, Bm)                  # (B,G,C,C)
    CBh = jnp.repeat(CB, nh // G, axis=1)                       # (B,nh,C,C)
    Lt = L.transpose(0, 2, 1)                                   # (B,nh,C)
    decay = jnp.exp(Lt[:, :, :, None] - Lt[:, :, None, :])
    tri = jnp.tril(jnp.ones((C, C), bool))
    M = jnp.where(tri[None, None], CBh * decay, 0.0)
    M = M * dt.transpose(0, 2, 1)[:, :, None, :]
    y_intra = jnp.einsum("bhqs,bshp->bqhp", M, xh)

    # inter-chunk: carried state decays into every position
    Ch_r = jnp.repeat(Cm, nh // G, axis=2)                      # (B,C,nh,N)
    y_inter = jnp.einsum("bqh,bqhn,bhpn->bqhp",
                         jnp.exp(L), Ch_r, H0.astype(jnp.float32))

    # end-of-chunk state: H_end = H0·exp(ΣL) + Σ_s exp(Σ_{u>s}) dt_s x_s⊗B_s
    dec_end = jnp.exp(L[:, -1:, :] - L)                         # (B,C,nh)
    Bh = jnp.repeat(Bm, nh // G, axis=2)                        # (B,C,nh,N)
    H_end = H0.astype(jnp.float32) \
        * jnp.exp(L[:, -1, :])[..., None, None] \
        + jnp.einsum("bsh,bshp,bshn->bhpn", dec_end * dt, xh, Bh)

    y = y_intra + y_inter + p["D_skip"][None, None, :, None] * xh
    y = y.reshape(B, C, d_in).astype(x.dtype)
    y = common.apply_norm("rmsnorm", p["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = ctx.ann(y, "batch", "seq", "mlp")
    return common.linear(p["out_proj"], y), H_end, conv_end


# ---------------------------------------------------------------------------
# Whole-model (mamba2 stacks SSD blocks + final norm; no separate FFN)
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    dt = common.dtype_of(cfg)

    def blk(k):
        kk = jax.random.split(k, 2)
        return {"ln": common.make_norm(cfg.norm, cfg.d_model, dt),
                "ssd": make_ssd_params(kk[0], cfg)}

    return {
        "embed": common.make_embedding(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "blocks": common.stacked_init(ks[1], cfg.n_layers, blk),
        "ln_f": common.make_norm(cfg.norm, cfg.d_model, dt),
    }


def forward_hidden(params, tokens, cfg, ctx, train: bool):
    x = common.embed(params["embed"], tokens, ctx)

    def blk(lp, h):
        y = common.apply_norm(cfg.norm, lp["ln"], h, cfg.norm_eps)
        y = ctx.ann(y, "batch", "seq", "embed")
        return ctx.ann(h + ssd_full_seq(lp["ssd"], y, cfg, ctx),
                       "batch", "seq", "embed_shard")

    if train:
        blk = jax.checkpoint(blk, policy=jax.checkpoint_policies.nothing_saveable)

    def body(h, lp):
        return blk(lp, h), None

    x, _ = jax.lax.scan(body, x, params["blocks"], unroll=common.scan_unroll())
    return common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)


def loss_fn(params, batch, cfg, ctx) -> jax.Array:
    x = forward_hidden(params, batch["tokens"], cfg, ctx, train=True)
    return common.chunked_ce_loss(params["embed"]["table"], x, batch["labels"],
                                  ctx, chunk=common.ce_chunk(x.shape[1]))


def prefill(params, tokens, cfg, ctx):
    """Returns (state, last logits)."""
    d_in, nh, hd, N, G, W = dims(cfg)
    B, S = tokens.shape
    x = common.embed(params["embed"], tokens, ctx)
    hs, convs, h = [], [], x

    def body(carry, lp):
        h = carry
        y = common.apply_norm(cfg.norm, lp["ln"], h, cfg.norm_eps)
        H, conv = ssd_final_state(lp["ssd"], y, cfg, ctx)
        h = h + ssd_full_seq(lp["ssd"], y, cfg, ctx)
        return h, (H, conv)

    h, (Hs, cs) = jax.lax.scan(body, x, params["blocks"], unroll=common.scan_unroll())
    state = RecurrentState(h=Hs, conv=cs)
    hfin = common.apply_norm(cfg.norm, params["ln_f"], h, cfg.norm_eps)
    logits = common.unembed_logits(params["embed"]["table"], hfin[:, -1:], ctx)
    return state, logits


def decode_step(params, state: RecurrentState, tokens, cfg, ctx):
    x = common.embed(params["embed"], tokens[:, None], ctx)

    def body(h, xs):
        lp, H, conv = xs
        y = common.apply_norm(cfg.norm, lp["ln"], h, cfg.norm_eps)
        y = ctx.ann(y, "batch", "seq", "embed")
        o, H, conv = ssd_decode(lp["ssd"], y, cfg, ctx, H, conv)
        return h + o, (H, conv)

    x, (Hs, convs) = jax.lax.scan(
        body, x, (params["blocks"], state.h, state.conv),
        unroll=common.scan_unroll())
    state = RecurrentState(h=Hs, conv=convs)
    x = common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
    logits = common.unembed_logits(params["embed"]["table"], x, ctx)
    return state, logits


def decode_step_slotted(params, state: RecurrentState, tokens,
                        positions, active, cfg: ModelConfig,
                        ctx: ShardingCtx, kv_bucket: int = 0
                        ) -> Tuple[RecurrentState, jax.Array]:
    """Continuous-batching decode step (DESIGN.md §7). Attention-free: the
    recurrence is position-independent, so the per-slot cursors only gate
    WHICH rows commit their state update (``mask_slots`` selects retired
    rows back to their old state — every step rewrites the whole tree).
    ``kv_bucket`` is accepted for API symmetry with the KV families and
    ignored: O(1) state has no length axis to walk."""
    from repro.kv.state import mask_slots
    del positions, kv_bucket
    new_state, logits = decode_step(params, state, tokens, cfg, ctx)
    return mask_slots(active, new_state, state), logits


def prefill_chunk(params, state: RecurrentState, tokens, slot, start,
                  valid_len, cfg: ModelConfig, ctx: ShardingCtx
                  ) -> Tuple[RecurrentState, jax.Array]:
    """Chunked prefill for the recurrent family (DESIGN.md §7): one fixed
    (1,C) program advances slot ``slot``'s per-layer (H, conv window) by one
    prompt chunk via ``ssd_chunk``. ``start == 0`` zeroes the slot's carried
    state first (a freed slot may hold the previous occupant's state — KV
    caches mask staleness with cursors, recurrences must overwrite it).
    Returns (state', logits (1,1,V)) — logits at the last valid position,
    meaningful on the prompt's final chunk."""
    x = common.embed(params["embed"], tokens, ctx)
    fresh = (start > 0).astype(jnp.float32)        # 0.0 on the first chunk

    def body(h, xs):
        lp, H_all, conv_all = xs
        H0 = jax.lax.dynamic_slice(
            H_all, (slot,) + (0,) * (H_all.ndim - 1),
            (1,) + H_all.shape[1:]) * fresh
        conv0 = jax.lax.dynamic_slice(
            conv_all, (slot,) + (0,) * (conv_all.ndim - 1),
            (1,) + conv_all.shape[1:]) * fresh
        y = common.apply_norm(cfg.norm, lp["ln"], h, cfg.norm_eps)
        y = ctx.ann(y, "batch", "seq", "embed")
        o, H1, conv1 = ssd_chunk(lp["ssd"], y, cfg, ctx, H0, conv0,
                                 valid_len)
        H_all = jax.lax.dynamic_update_slice(
            H_all, H1.astype(H_all.dtype), (slot,) + (0,) * (H1.ndim - 1))
        conv_all = jax.lax.dynamic_update_slice(
            conv_all, conv1.astype(conv_all.dtype),
            (slot,) + (0,) * (conv1.ndim - 1))
        return h + o, (H_all, conv_all)

    x, (Hs, convs) = jax.lax.scan(
        body, x, (params["blocks"], state.h, state.conv),
        unroll=common.scan_unroll())
    state = RecurrentState(h=Hs, conv=convs)
    x = common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
    last = jax.lax.dynamic_slice_in_dim(x, valid_len - 1, 1, axis=1)
    logits = common.unembed_logits(params["embed"]["table"], last, ctx)
    return state, logits


def make_state(cfg: ModelConfig, batch: int) -> RecurrentState:
    d_in, nh, hd, N, G, W = dims(cfg)
    return init_ssd_state(cfg.n_layers, batch, nh, hd, N, W,
                          conv_channels=d_in + 2 * G * N)
