"""Decoder-only transformer (dense + MoE + VLM backbone).

One code path serves train_step (full-seq + chunked CE), prefill (full-seq,
cache write) and decode (single-token, cache read/append). Layers execute via
``lax.scan`` over stacked params (HLO size O(1) in depth — required to compile
94-layer configs on the CPU dry-run host) with ``jax.checkpoint`` remat.

The paper's execution-model choice enters ONLY through the ShardingCtx rules
(operator-centric vs sub-operator; see models/sharding.py) — the math is
identical, the collective schedule is not.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kv.cache import KVCache, init_kv_cache
from repro.models import common
from repro.models.attention import (decode_attention, flash_attention,
                                    make_attn_params, qkv_project)
from repro.models.sharding import ShardingCtx
from repro.quant.int8 import quantize_kv


# ---------------------------------------------------------------------------
# FFN (dense gated / plain MLP); MoE plugs in via models.moe
# ---------------------------------------------------------------------------

def make_ffn_params(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = common.dtype_of(cfg)
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu_mlp":
        return {"w_in": common.make_linear(ks[0], d, f, dt, bias=True,
                                           int8=cfg.weight_int8),
                "w_out": common.make_linear(ks[1], f, d, dt, bias=True,
                                            int8=cfg.weight_int8)}
    return {"w_gate": common.make_linear(ks[0], d, f, dt, int8=cfg.weight_int8),
            "w_up": common.make_linear(ks[1], d, f, dt, int8=cfg.weight_int8),
            "w_down": common.make_linear(ks[2], f, d, dt, int8=cfg.weight_int8)}


def ffn_apply(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx) -> jax.Array:
    """Gated FFN. Per the paper (§4.2/Fig 6b): weights are streamed ONCE —
    both GEMVs read the same gathered activation and partial down-proj results
    merge in a single bounded-fan-in reduction (the trailing annotation)."""
    if cfg.act == "gelu_mlp":
        h = common.linear(p["w_in"], x)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        h = ctx.ann(h, "batch", "seq", "mlp")
        return common.linear(p["w_out"], h)
    up = common.linear(p["w_up"], x)
    gate = common.linear(p["w_gate"], x)
    h = ctx.ann(common.gated_act(cfg.act, up, gate), "batch", "seq", "mlp")
    return common.linear(p["w_down"], h)


# ---------------------------------------------------------------------------
# Transformer block
# ---------------------------------------------------------------------------

def make_block_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    dt = common.dtype_of(cfg)
    p = {
        "ln1": common.make_norm(cfg.norm, cfg.d_model, dt),
        "attn": make_attn_params(ks[0], cfg),
        "ln2": common.make_norm(cfg.norm, cfg.d_model, dt),
    }
    if cfg.moe is not None:
        from repro.models.moe import make_moe_params
        p["moe"] = make_moe_params(ks[1], cfg)
    else:
        p["ffn"] = make_ffn_params(ks[1], cfg)
    return p


def _mix_ffn(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
             train: bool) -> Tuple[jax.Array, jax.Array]:
    """FFN half of the block; returns (out, aux_loss)."""
    if cfg.moe is not None:
        from repro.models.moe import moe_ffn
        return moe_ffn(p["moe"], x, cfg, ctx, train=train)
    return ffn_apply(p["ffn"], x, cfg, ctx), jnp.zeros((), jnp.float32)


def block_full_seq(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
                   positions: jax.Array, causal: bool = True,
                   window: int = 0, train: bool = True,
                   q_chunk: int = 0,
                   kv_quant_roundtrip: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence block (train/prefill path). x: (B,S,D).

    ``kv_quant_roundtrip`` (int8-KV prefill only): attend the
    quantize→dequantize image of K/V — the exact values the cache will store
    — so prefill logits are a function of what decode will actually attend.
    Without it a chunked prefill (which reads its prefix back from the int8
    cache) could not be token-exact against the monolithic program. The
    ORIGINAL fp K/V still flow to the caller: ``write_prefill`` quantizes
    them identically (same per-position scales), keeping stored bytes
    byte-for-byte what they always were."""
    from repro.models.attention import q_chunk_for
    from repro.quant.int8 import dequantize_kv
    qc = q_chunk or q_chunk_for(x.shape[1])
    h = common.apply_norm(cfg.norm, p["ln1"], x, cfg.norm_eps)
    h = ctx.ann(h, "batch", "seq", "embed")
    q, k, v = qkv_project(p["attn"], h, cfg, ctx, positions)
    k_att, v_att = k, v
    if kv_quant_roundtrip:
        k_att = dequantize_kv(*quantize_kv(k), dtype=k.dtype)
        v_att = dequantize_kv(*quantize_kv(v), dtype=v.dtype)
    o = flash_attention(q, k_att, v_att, causal, window,
                        min(qc, x.shape[1]), min(qc, x.shape[1]))
    o = ctx.ann(o, "batch", "seq", "act_heads", "head_dim")
    o = common.linear(p["attn"]["wo"], o.reshape(x.shape[0], x.shape[1], -1))
    x = ctx.ann(x + o, "batch", "seq", "embed_shard")
    h = common.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
    h = ctx.ann(h, "batch", "seq", "embed")
    f, aux = _mix_ffn(p, h, cfg, ctx, train)
    x = ctx.ann(x + f, "batch", "seq", "embed_shard")
    return x, (q, k, v, aux)


def block_decode(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
                 kv_slices: Tuple, pos: jax.Array,
                 window: int = 0) -> Tuple[jax.Array, Tuple]:
    """Single-token block over ONE layer's cache slices.
    x: (B,1,D); kv_slices = (k_l, v_l, k_scale_l, v_scale_l) with k_l
    (B,n_kv,S,hd). Returns (x', updated slices)."""
    from repro.kv.cache import layer_append, layer_read, slot_valid_mask
    B = x.shape[0]
    k_l, v_l, ks_l, vs_l = kv_slices
    positions = jnp.full((B, 1), pos, jnp.int32)
    h = common.apply_norm(cfg.norm, p["ln1"], x, cfg.norm_eps)
    h = ctx.ann(h, "batch", "seq", "embed")
    q, k, v = qkv_project(p["attn"], h, cfg, ctx, positions)
    k_l, v_l, ks_l, vs_l = layer_append(k_l, v_l, ks_l, vs_l,
                                        k[:, 0], v[:, 0], pos, window)
    kc, vc = layer_read(k_l, v_l, ks_l, vs_l, dtype=x.dtype)
    kc = ctx.ann(kc, "batch", "kv_heads", "kv_seq", "head_dim")
    vc = ctx.ann(vc, "batch", "kv_heads", "kv_seq", "head_dim")
    mask = slot_valid_mask(k_l.shape[2], window, pos)
    o = decode_attention(q[:, 0], kc, vc, mask, ctx)
    o = common.linear(p["attn"]["wo"], o.reshape(B, 1, -1))
    x = ctx.ann(x + o, "batch", "seq", "embed_shard")
    h = common.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
    h = ctx.ann(h, "batch", "seq", "embed")
    f, _ = _mix_ffn(p, h, cfg, ctx, train=False)
    x = ctx.ann(x + f, "batch", "seq", "embed_shard")
    return x, (k_l, v_l, ks_l, vs_l)


def block_decode_slotted(p: dict, x: jax.Array, cfg: ModelConfig,
                         ctx: ShardingCtx, kv_slices: Tuple,
                         positions: jax.Array, active: jax.Array,
                         window: int = 0, kv_bucket: int = 0,
                         kv_shards: int = 1) -> Tuple[jax.Array, Tuple]:
    """``block_decode`` with PER-ROW cursors (continuous batching): row b
    appends at its own ``positions[b]`` and attends over its own prefix.
    Inactive rows write nothing (their KV slice stays byte-identical); their
    activations still flow — static shapes — but the engine masks the
    resulting logits.

    ``kv_bucket`` > 0 (non-windowed caches only) reads and attends only the
    first ``kv_bucket`` cache positions — the length-aware decode path. The
    caller must guarantee max(positions) < kv_bucket; the serving engine
    picks the bucket per macro-step from the live cursors.

    ``kv_shards`` > 1 (static, non-windowed only): split-KV flash decode —
    the bucketed read returns shard-major KV (``layer_read_shards``) and
    ``decode_attention_split`` combines the per-shard partial softmax
    statistics with the LSE merge. Token-exact vs the sequential walk; the
    engine guarantees every bucket divides by ``kv_shards``.

    Deliberately a twin of ``block_decode`` rather than its replacement: the
    vmapped per-row writes and (B,S) masks cost measurably more than the
    shared-cursor path, which stays on the uniform fast form (drain serving,
    pipeline decode). Keep the bodies in sync — the equality
    decode_step == decode_step_slotted under a uniform cursor is enforced by
    tests/test_serving_scheduler.py."""
    from repro.kv.cache import (batch_valid_mask, layer_append_slotted,
                                layer_append_tiered, layer_read_bucket,
                                layer_read_shards, layer_read_tiered,
                                layer_read_tiered_shards)
    from repro.models.attention import decode_attention_split
    B = x.shape[0]
    tiered = len(kv_slices) == 6
    if tiered:
        k_l, v_l, ks_l, vs_l, hk_l, hv_l = kv_slices
    else:
        k_l, v_l, ks_l, vs_l = kv_slices
        hk_l = hv_l = None
    if window:
        kv_bucket = 0                       # ring buffers have no prefix order
        kv_shards = 1                       # ... and no contiguous shard cut
    h = common.apply_norm(cfg.norm, p["ln1"], x, cfg.norm_eps)
    h = ctx.ann(h, "batch", "seq", "embed")
    q, k, v = qkv_project(p["attn"], h, cfg, ctx, positions[:, None])
    if tiered:
        k_l, v_l, ks_l, vs_l, hk_l, hv_l = layer_append_tiered(
            k_l, v_l, ks_l, vs_l, hk_l, hv_l, k[:, 0], v[:, 0], positions,
            cfg.kv_cold_dtype, active)
        counts = positions + 1              # append→attend: row b has p+1 toks
        if kv_shards > 1:
            kc, vc = layer_read_tiered_shards(
                k_l, v_l, ks_l, vs_l, hk_l, hv_l, counts, kv_bucket,
                kv_shards, cfg.hot_window, cfg.kv_cold_block,
                cfg.kv_cold_dtype, dtype=x.dtype)
        else:
            kc, vc = layer_read_tiered(
                k_l, v_l, ks_l, vs_l, hk_l, hv_l, counts, kv_bucket,
                cfg.hot_window, cfg.kv_cold_block, cfg.kv_cold_dtype,
                dtype=x.dtype)
    else:
        k_l, v_l, ks_l, vs_l = layer_append_slotted(
            k_l, v_l, ks_l, vs_l, k[:, 0], v[:, 0], positions, window, active)
        if kv_shards > 1:
            kc, vc = layer_read_shards(k_l, v_l, ks_l, vs_l, kv_bucket,
                                       kv_shards, dtype=x.dtype)
        else:
            kc, vc = layer_read_bucket(k_l, v_l, ks_l, vs_l, kv_bucket,
                                       dtype=x.dtype)
    if kv_shards > 1:
        kc = ctx.ann(kc, "batch", "kv_heads", "kv_shard", "kv_seq",
                     "head_dim")
        vc = ctx.ann(vc, "batch", "kv_heads", "kv_shard", "kv_seq",
                     "head_dim")
        mask = batch_valid_mask(kc.shape[2] * kc.shape[3], window, positions)
        o = decode_attention_split(q[:, 0], kc, vc, mask, ctx)
    else:
        kc = ctx.ann(kc, "batch", "kv_heads", "kv_seq", "head_dim")
        vc = ctx.ann(vc, "batch", "kv_heads", "kv_seq", "head_dim")
        mask = batch_valid_mask(kc.shape[2], window, positions)    # (B,Sb)
        o = decode_attention(q[:, 0], kc, vc, mask, ctx)
    o = common.linear(p["attn"]["wo"], o.reshape(B, 1, -1))
    x = ctx.ann(x + o, "batch", "seq", "embed_shard")
    h = common.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
    h = ctx.ann(h, "batch", "seq", "embed")
    f, _ = _mix_ffn(p, h, cfg, ctx, train=False)
    x = ctx.ann(x + f, "batch", "seq", "embed_shard")
    if tiered:
        return x, (k_l, v_l, ks_l, vs_l, hk_l, hv_l)
    return x, (k_l, v_l, ks_l, vs_l)


def block_prefill_chunk(p: dict, x: jax.Array, cfg: ModelConfig,
                        ctx: ShardingCtx, kv_slices: Tuple,
                        slot: jax.Array, start: jax.Array,
                        valid_len: jax.Array) -> Tuple[jax.Array, Tuple]:
    """Chunk-prefill block over ONE layer's cache slices (DESIGN.md §7
    chunked-prefill lane). x: (1,C,D) — slot ``slot``'s prompt chunk with
    absolute positions [start, start+C). Writes the chunk's K/V at its
    per-slot offset (``layer_write_chunk``; positions >= valid_len are
    last-chunk padding and never touch the cache), reads the slot's full
    prefix back from the STORED buffers (int8 caches dequantize — the same
    values every later decode step will attend) and runs causal chunk
    attention against it. slot/start/valid_len are traced: one compiled
    program serves every chunk of every prompt. Non-windowed caches only
    (ring order has no stable per-position offset to write at)."""
    from repro.kv.cache import (chunk_hot_image, cold_boundary,
                                layer_read_slot, layer_read_slot_cold,
                                layer_write_chunk, layer_write_chunk_tiered)
    from repro.models.attention import chunk_attention, chunk_attention_tiered
    _, C, _ = x.shape
    tiered = len(kv_slices) == 6
    if tiered:
        k_l, v_l, ks_l, vs_l, hk_l, hv_l = kv_slices
    else:
        k_l, v_l, ks_l, vs_l = kv_slices
    positions = start + jnp.arange(C, dtype=jnp.int32)[None]          # (1,C)
    h = common.apply_norm(cfg.norm, p["ln1"], x, cfg.norm_eps)
    h = ctx.ann(h, "batch", "seq", "embed")
    q, k, v = qkv_project(p["attn"], h, cfg, ctx, positions)
    S = k_l.shape[2]
    k_ch = jnp.swapaxes(k[0], 0, 1)                              # (n_kv,C,hd)
    v_ch = jnp.swapaxes(v[0], 0, 1)
    # causal over absolute positions: query i attends cache slots <= start+i
    # (padding queries i >= valid_len attend zeros/stale slots — their
    # outputs are discarded; valid queries only ever reach real positions)
    mask = jnp.arange(S, dtype=jnp.int32)[None, :] \
        <= positions[0][:, None]                                      # (C,S)
    if tiered:
        # exact hot image from the PRE-write ring + the incoming chunk (the
        # write below may overwrite exactly the ring slots early queries'
        # hot tails live in), then stage the chunk into both tiers
        kh, vh = chunk_hot_image(hk_l, hv_l, k_ch, v_ch, slot, start,
                                 valid_len, S, dtype=x.dtype)
        k_l, v_l, ks_l, vs_l, hk_l, hv_l = layer_write_chunk_tiered(
            k_l, v_l, ks_l, vs_l, hk_l, hv_l, k_ch, v_ch, slot, start,
            valid_len, cfg.kv_cold_dtype)
        kc, vc = layer_read_slot_cold(k_l, v_l, ks_l, vs_l, slot,
                                      cfg.kv_cold_dtype, dtype=x.dtype)
        kh = ctx.ann(kh, "batch", "kv_heads", "kv_seq", "head_dim")
        vh = ctx.ann(vh, "batch", "kv_heads", "kv_seq", "head_dim")
        kc = ctx.ann(kc, "batch", "kv_heads", "kv_seq", "head_dim")
        vc = ctx.ann(vc, "batch", "kv_heads", "kv_seq", "head_dim")
        # per-QUERY demotion boundary: query i has count start+i+1 tokens
        hot_mask = (jnp.arange(S, dtype=jnp.int32)[None, :] >=
                    cold_boundary(positions[0] + 1, cfg.hot_window,
                                  cfg.kv_cold_block)[:, None])[None]  # (1,C,S)
        o = chunk_attention_tiered(q, kh, vh, kc, vc, hot_mask, mask, ctx)
    else:
        k_l, v_l, ks_l, vs_l = layer_write_chunk(
            k_l, v_l, ks_l, vs_l, k_ch, v_ch, slot, start, valid_len)
        kc, vc = layer_read_slot(k_l, v_l, ks_l, vs_l, slot, dtype=x.dtype)
        kc = ctx.ann(kc, "batch", "kv_heads", "kv_seq", "head_dim")
        vc = ctx.ann(vc, "batch", "kv_heads", "kv_seq", "head_dim")
        o = chunk_attention(q, kc, vc, mask, ctx)
    o = common.linear(p["attn"]["wo"], o.reshape(1, C, -1))
    x = ctx.ann(x + o, "batch", "seq", "embed_shard")
    h = common.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
    h = ctx.ann(h, "batch", "seq", "embed")
    f, _ = _mix_ffn(p, h, cfg, ctx, train=False)
    x = ctx.ann(x + f, "batch", "seq", "embed_shard")
    if tiered:
        return x, (k_l, v_l, ks_l, vs_l, hk_l, hv_l)
    return x, (k_l, v_l, ks_l, vs_l)


# ---------------------------------------------------------------------------
# Whole-model parameter init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    dt = common.dtype_of(cfg)
    params: Dict[str, Any] = {
        "embed": common.make_embedding(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "blocks": common.stacked_init(
            ks[1], cfg.n_layers, lambda k: make_block_params(k, cfg)),
        "ln_f": common.make_norm(cfg.norm, cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = common.make_embedding(ks[2], cfg.vocab_size,
                                                  cfg.d_model, dt)
    if cfg.pos == "learned":
        # sized for the largest decode cell (+slack for appended tokens)
        params["pos_embed"] = common.dense_init(
            ks[3], (32768 + 256, cfg.d_model), dt, fan_in=1)
    return params


def unembed_table(params, cfg: ModelConfig) -> jax.Array:
    return (params["embed"] if cfg.tie_embeddings else params["unembed"])["table"]


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def forward_hidden(params, tokens: jax.Array, cfg: ModelConfig,
                   ctx: ShardingCtx, train: bool,
                   vision_embeds: Optional[jax.Array] = None,
                   collect_kv: bool = False):
    """tokens: (B,S_text). Returns (hidden (B,S,D), aux_loss[, kv list])."""
    x = common.embed(params["embed"], tokens, ctx)
    if vision_embeds is not None:                     # VLM stub frontend
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
        x = ctx.ann(x, "batch", "seq", "embed")
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.pos == "learned":
        x = x + params["pos_embed"][:S][None].astype(x.dtype)
    elif cfg.pos == "sinusoidal":
        x = x + common.sinusoidal_pos(S, cfg.d_model)[None].astype(x.dtype)

    # int8-KV prefill: attention sees the quantized image of K/V (what the
    # cache stores) so prefill logits and chunked-prefill logits agree
    roundtrip = collect_kv and not train and cfg.kv_dtype == "int8"

    def _blk(lp, h):
        y, extras = block_full_seq(lp, h, cfg, ctx, positions, causal=True,
                                   train=train,
                                   kv_quant_roundtrip=roundtrip)
        q, k, v, a = extras
        return y, (k, v, None, a)

    if train:
        _blk_r = jax.checkpoint(_blk,
                                policy=jax.checkpoint_policies.nothing_saveable)
    else:
        _blk_r = _blk

    def scan_body(carry, lp):
        h, aux = carry
        y, (k_, v_, _, a) = _blk_r(lp, h)
        out = (k_, v_) if collect_kv else None
        return (y, aux + a), out

    (x, aux), kvs = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                                 params["blocks"], unroll=common.scan_unroll())
    x = common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
    x = ctx.ann(x, "batch", "seq", "embed")
    if collect_kv:
        return x, aux, kvs
    return x, aux


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            ctx: ShardingCtx) -> jax.Array:
    tokens, labels = batch["tokens"], batch["labels"]
    vis = batch.get("vision_embeds")
    x, aux = forward_hidden(params, tokens, cfg, ctx, train=True,
                            vision_embeds=vis)
    if vis is not None:
        x = x[:, vis.shape[1]:]                      # loss over text positions
    table = unembed_table(params, cfg)
    ce = common.chunked_ce_loss(table, x, labels, ctx,
                                chunk=common.ce_chunk(x.shape[1]))
    return ce + 0.01 * aux


def prefill(params, tokens: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
            cache: KVCache, vision_embeds: Optional[jax.Array] = None
            ) -> Tuple[KVCache, jax.Array]:
    """Encode context, fill the cache, return last-position logits."""
    x, _, kvs = forward_hidden(params, tokens, cfg, ctx, train=False,
                               vision_embeds=vision_embeds, collect_kv=True)
    k_all, v_all = kvs                                # (L,B,S,n_kv,hd)
    k_all = jnp.swapaxes(k_all, 2, 3)                 # (L,B,n_kv,S,hd)
    v_all = jnp.swapaxes(v_all, 2, 3)
    S = k_all.shape[3]
    cache = write_prefill(cache, k_all, v_all, S)
    table = unembed_table(params, cfg)
    logits = common.unembed_logits(table, x[:, -1:, :], ctx)
    return cache, logits


def write_prefill(cache: KVCache, k_all, v_all, S: int) -> KVCache:
    """Bulk-write a prefilled context into the cache (window-aware)."""
    if cache.is_tiered:
        raise ValueError(
            "monolithic write_prefill does not support tiered caches — the "
            "serving engine routes tiered admissions through the chunk "
            "program (full-width), which stages both tiers")
    size = cache.k.shape[3]
    if cache.window and S > size:
        k_all = k_all[:, :, :, S - size:, :]
        v_all = v_all[:, :, :, S - size:, :]
        # ring alignment: slot of position p is p % size; after S tokens the
        # oldest kept position is S-size ≡ (S-size) % size. Roll so that
        # slot order matches position % size.
        shift = (S - size) % size
        k_all = jnp.roll(k_all, shift, axis=3)
        v_all = jnp.roll(v_all, shift, axis=3)
    if cache.is_quantized:
        kq, ks = quantize_kv(k_all)
        vq, vs = quantize_kv(v_all)
        k = jax.lax.dynamic_update_slice(cache.k, kq, (0, 0, 0, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.v, vq, (0, 0, 0, 0, 0))
        k_s = jax.lax.dynamic_update_slice(cache.k_scale, ks, (0, 0, 0, 0, 0))
        v_s = jax.lax.dynamic_update_slice(cache.v_scale, vs, (0, 0, 0, 0, 0))
        return cache._replace(k=k, v=v, k_scale=k_s, v_scale=v_s,
                              length=jnp.asarray(S, jnp.int32))
    k = jax.lax.dynamic_update_slice(cache.k, k_all.astype(cache.k.dtype),
                                     (0, 0, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_all.astype(cache.v.dtype),
                                     (0, 0, 0, 0, 0))
    return cache._replace(k=k, v=v, length=jnp.asarray(S, jnp.int32))


def decode_step(params, cache: KVCache, tokens: jax.Array, cfg: ModelConfig,
                ctx: ShardingCtx) -> Tuple[KVCache, jax.Array]:
    """tokens: (B,) last emitted token ids → (cache', logits (B,1,V)).

    The layer scan consumes per-layer cache slices as xs and emits updated
    slices as ys — each layer touches only its own (B,n_kv,S,hd) slice."""
    x = common.embed(params["embed"], tokens[:, None], ctx)
    pos = cache.length
    if cfg.pos == "learned":
        x = x + jax.lax.dynamic_index_in_dim(
            params["pos_embed"], pos, 0, keepdims=True)[None].astype(x.dtype)
    quant = cache.is_quantized

    def body(h, xs):
        if quant:
            lp, k_l, v_l, ks_l, vs_l = xs
        else:
            lp, k_l, v_l = xs
            ks_l = vs_l = None
        h, (k_l, v_l, ks_l, vs_l) = block_decode(
            lp, h, cfg, ctx, (k_l, v_l, ks_l, vs_l), pos, window=cache.window)
        ys = (k_l, v_l, ks_l, vs_l) if quant else (k_l, v_l)
        return h, ys

    xs = (params["blocks"], cache.k, cache.v) + \
        ((cache.k_scale, cache.v_scale) if quant else ())
    x, ys = jax.lax.scan(body, x, xs, unroll=common.scan_unroll())
    if quant:
        k_new, v_new, ks_new, vs_new = ys
    else:
        (k_new, v_new), (ks_new, vs_new) = ys, (None, None)
    cache = cache._replace(k=k_new, v=v_new, k_scale=ks_new, v_scale=vs_new,
                           length=pos + 1)
    x = common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
    logits = common.unembed_logits(unembed_table(params, cfg), x, ctx)
    return cache, logits


def decode_step_slotted(params, cache: KVCache, tokens: jax.Array,
                        positions: jax.Array, active: jax.Array,
                        cfg: ModelConfig, ctx: ShardingCtx,
                        kv_bucket: int = 0,
                        kv_shards: int = 1) -> Tuple[KVCache, jax.Array]:
    """Continuous-batching decode step (DESIGN.md §7). tokens/positions/
    active: (B,). Mirrors ``decode_step`` but each row carries its OWN
    cursor: row b appends at positions[b] and attends 0..positions[b]; the
    shared ``cache.length`` is kept only as an upper bound. Equal to
    ``decode_step`` when all rows share one cursor and are active.
    ``kv_bucket``: static length-aware KV extent; ``kv_shards``: static
    split-KV shard count (see block_decode_slotted)."""
    x = common.embed(params["embed"], tokens[:, None], ctx)
    if cfg.pos == "learned":
        x = x + jnp.take(params["pos_embed"], positions,
                         axis=0)[:, None].astype(x.dtype)
    scales = cache.k_scale is not None
    tiered = cache.is_tiered

    def body(h, xs):
        lp, k_l, v_l = xs[0], xs[1], xs[2]
        rest = list(xs[3:])
        ks_l, vs_l = (rest.pop(0), rest.pop(0)) if scales else (None, None)
        if tiered:
            hk_l, hv_l = rest
            slices = (k_l, v_l, ks_l, vs_l, hk_l, hv_l)
        else:
            slices = (k_l, v_l, ks_l, vs_l)
        h, slices = block_decode_slotted(
            lp, h, cfg, ctx, slices, positions, active,
            window=cache.window, kv_bucket=kv_bucket, kv_shards=kv_shards)
        ys = tuple(s for s in slices if s is not None)
        return h, ys

    xs = (params["blocks"], cache.k, cache.v) + \
        ((cache.k_scale, cache.v_scale) if scales else ()) + \
        ((cache.hot_k, cache.hot_v) if tiered else ())
    x, ys = jax.lax.scan(body, x, xs, unroll=common.scan_unroll())
    ys = list(ys)
    k_new, v_new = ys.pop(0), ys.pop(0)
    ks_new, vs_new = (ys.pop(0), ys.pop(0)) if scales else (None, None)
    hk_new, hv_new = (ys.pop(0), ys.pop(0)) if tiered else (None, None)
    new_len = jnp.maximum(
        cache.length, jnp.max(jnp.where(active, positions, 0)) + 1)
    cache = cache._replace(k=k_new, v=v_new, k_scale=ks_new, v_scale=vs_new,
                           hot_k=hk_new, hot_v=hv_new, length=new_len)
    x = common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
    logits = common.unembed_logits(unembed_table(params, cfg), x, ctx)
    return cache, logits


def prefill_chunk(params, cache: KVCache, tokens: jax.Array, slot: jax.Array,
                  start: jax.Array, valid_len: jax.Array, cfg: ModelConfig,
                  ctx: ShardingCtx) -> Tuple[KVCache, jax.Array]:
    """Chunked prefill: ONE fixed-(1,C) program reused for every chunk of
    every prompt (DESIGN.md §7 chunked-prefill lane). tokens: (1,C) — the
    chunk of slot ``slot``'s prompt covering absolute positions
    [start, start+valid_len); chunk positions >= valid_len are last-chunk
    padding (masked out of both the KV write and the returned logits).
    Returns (cache', logits (1,1,V)) — logits at the chunk's LAST VALID
    position, meaningful only on a prompt's final chunk (the first decoded
    token). slot/start/valid_len are traced scalars: zero retracing across
    chunks, prompts and slots."""
    if cache.window:
        raise ValueError("chunked prefill requires a non-windowed cache "
                         "(ring order has no per-position write offset)")
    x = common.embed(params["embed"], tokens, ctx)
    C = tokens.shape[1]
    positions = start + jnp.arange(C, dtype=jnp.int32)
    if cfg.pos == "learned":
        x = x + jnp.take(params["pos_embed"], positions,
                         axis=0)[None].astype(x.dtype)
    elif cfg.pos == "sinusoidal":
        table = common.sinusoidal_pos(cache.k.shape[3], cfg.d_model)
        x = x + jnp.take(table, positions, axis=0)[None].astype(x.dtype)
    scales = cache.k_scale is not None
    tiered = cache.is_tiered

    def body(h, xs):
        lp, k_l, v_l = xs[0], xs[1], xs[2]
        rest = list(xs[3:])
        ks_l, vs_l = (rest.pop(0), rest.pop(0)) if scales else (None, None)
        if tiered:
            hk_l, hv_l = rest
            slices = (k_l, v_l, ks_l, vs_l, hk_l, hv_l)
        else:
            slices = (k_l, v_l, ks_l, vs_l)
        h, slices = block_prefill_chunk(
            lp, h, cfg, ctx, slices, slot, start, valid_len)
        ys = tuple(s for s in slices if s is not None)
        return h, ys

    # pin the cache stacks to their planned layout at program ENTRY: GSPMD
    # infers each program's cache placement independently, and on a
    # data-sharded mesh the chunk program compiled its cache input
    # batch-REPLICATED while the decode programs compiled it batch-sharded —
    # one full-cache reshard per admission boundary on the donated buffer
    # (caught by the repro.analysis residency pass; invisible at data=1)
    k_st = ctx.ann(cache.k, None, "batch", "kv_heads", "kv_seq", "head_dim")
    v_st = ctx.ann(cache.v, None, "batch", "kv_heads", "kv_seq", "head_dim")
    xs = (params["blocks"], k_st, v_st) + \
        ((ctx.ann(cache.k_scale, None, "batch", "kv_heads", "kv_seq", None),
          ctx.ann(cache.v_scale, None, "batch", "kv_heads", "kv_seq", None))
         if scales else ()) + \
        ((ctx.ann(cache.hot_k, None, "batch", "kv_heads", None, "head_dim"),
          ctx.ann(cache.hot_v, None, "batch", "kv_heads", None, "head_dim"))
         if tiered else ())
    x, ys = jax.lax.scan(body, x, xs, unroll=common.scan_unroll())
    ys = list(ys)
    k_new, v_new = ys.pop(0), ys.pop(0)
    ks_new, vs_new = (ys.pop(0), ys.pop(0)) if scales else (None, None)
    hk_new, hv_new = (ys.pop(0), ys.pop(0)) if tiered else (None, None)
    new_len = jnp.maximum(cache.length, start + valid_len)
    cache = cache._replace(k=k_new, v=v_new, k_scale=ks_new, v_scale=vs_new,
                           hot_k=hk_new, hot_v=hv_new, length=new_len)
    x = common.apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
    last = jax.lax.dynamic_slice_in_dim(x, valid_len - 1, 1, axis=1)
    logits = common.unembed_logits(unembed_table(params, cfg), last, ctx)
    return cache, logits


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               window: int = 0) -> KVCache:
    tiered = cfg.hot_window > 0
    return init_kv_cache(cfg.n_layers, batch, cfg.n_kv_heads, max_len,
                         cfg.head_dim, dtype=common.dtype_of(cfg),
                         quantized=(cfg.kv_dtype == "int8"), window=window,
                         hot_window=cfg.hot_window if tiered else 0,
                         cold_block=cfg.kv_cold_block if tiered else 0,
                         cold_dtype=cfg.kv_cold_dtype if tiered
                         else "bfloat16")
